"""Cross-request prefix cache tests.

Three layers, mirroring the subsystem:

* radix-tree mechanics — match/insert/LRU-evict over a raw pool, lease
  refcounts, partial-chunk tail matches, the capacity cap;
* engine partial prefill — cached-prefix + suffix prefill must reproduce
  the full prefill's logits (float tolerance) and greedy token streams
  (exactly) across block-boundary-aligned and misaligned split points;
* scheduler integration — cache-aware admission serves shared headers
  from the tree at unchanged outputs, eviction precedes preemption, and
  eviction-then-readmission recomputes and re-caches correctly.

The full split-point × block-size grid with eviction churn is ``slow``;
the fast subset keeps every split class alive in CI.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.serving.engine import ContinuousScheduler, DecodeEngine, Request
from repro.serving.kv_pool import KVPool, OutOfBlocks, blocks_for
from repro.serving.prefix_cache import PrefixCache
from repro.serving.sampler import SamplerConfig

NO_STOP = (9999,)
GREEDY = SamplerConfig(greedy=True)
ATOL = 1e-4


def paged_engine(params, cfg, tok, *, max_len=64, block_size=8,
                 n_blocks=64):
    return DecodeEngine(params, cfg, max_len=max_len, eos_id=tok.eos_id,
                        pad_id=tok.pad_id, paged=True,
                        block_size=block_size, n_blocks=n_blocks)


# ---------------------------------------------------------------------------
# Radix-tree mechanics (no model: a raw pool is enough)
# ---------------------------------------------------------------------------


def test_match_insert_longest_prefix(tiny_cfg):
    pool = KVPool(tiny_cfg, n_blocks=32, block_size=4)
    cache = PrefixCache(pool)
    toks = list(range(100, 112))                       # 3 full blocks
    blocks = pool.alloc(3)
    assert cache.insert(toks, blocks) == 3
    assert pool.refcount[blocks[0]] == 2               # row + tree

    # full match leases every matched block
    got, clen = cache.match(toks)
    assert clen == 12 and got == blocks
    assert pool.refcount[blocks[0]] == 3               # + the lease
    pool.release(got)

    # diverging suffix: longest shared prefix only
    got, clen = cache.match(toks[:8] + [7, 7, 7, 7])
    assert clen == 8 and got == blocks[:2]
    pool.release(got)

    # miss takes no lease and counts no hit
    hits = cache.hits
    got, clen = cache.match([1, 2, 3, 4])
    assert got == [] and clen == 0 and cache.hits == hits

    # partial trailing chunk: first r positions of a cached block
    got, clen = cache.match(toks[:10])
    assert clen == 10 and got == blocks
    pool.release(got)
    # ...but only when the partial tokens agree
    got, clen = cache.match(toks[:8] + [7, 7])
    assert clen == 8 and got == blocks[:2]
    pool.release(got)

    # idempotent re-insert pins nothing new
    assert cache.insert(toks, blocks) == 0
    assert cache.n_cached_blocks == 3


def test_insert_skips_partial_trailing_block(tiny_cfg):
    pool = KVPool(tiny_cfg, n_blocks=16, block_size=4)
    cache = PrefixCache(pool)
    blocks = pool.alloc(3)
    assert cache.insert(list(range(10)), blocks) == 2  # 10 tokens: 2 full
    assert cache.n_cached_blocks == 2
    assert pool.refcount[blocks[2]] == 1               # tail never pinned


def test_lru_eviction_frees_leaves_only(tiny_cfg):
    pool = KVPool(tiny_cfg, n_blocks=32, block_size=4)
    cache = PrefixCache(pool)
    shared = list(range(8))
    a = pool.alloc(3)     # shared prefix + branch-a leaf
    b = pool.alloc(3)     # b[0:2] unused (prefix nodes already exist)
    cache.insert(shared + [20, 21, 22, 23], a)
    cache.insert(shared + [30, 31, 32, 33], b)
    # the shared path is deduped: 2 shared nodes + 2 distinct leaves
    assert cache.n_cached_blocks == 4
    pool.release(a)
    pool.release(b)       # b[0]/b[1] free; tree pins a[0..2] + b[2]
    assert pool.blocks_in_use == cache.n_cached_blocks

    # touch branch b so branch a's leaf becomes LRU
    got, _ = cache.match(shared + [30, 31, 32, 33])
    pool.release(got)
    freed = cache.evict(1)
    assert freed == 1
    got, clen = cache.match(shared + [20, 21, 22, 23])
    assert clen == 8      # a's unique leaf gone, shared prefix alive
    pool.release(got)
    got, clen = cache.match(shared + [30, 31, 32, 33])
    assert clen == 12     # b untouched (recently used)
    pool.release(got)


def test_evict_skips_blocks_leased_to_live_rows(tiny_cfg):
    pool = KVPool(tiny_cfg, n_blocks=16, block_size=4)
    cache = PrefixCache(pool)
    blocks = pool.alloc(2)
    cache.insert(list(range(8)), blocks)
    pool.release(blocks)                       # rows done: tree-only pins
    leased, clen = cache.match(list(range(8)))  # a "live row" leases them
    assert clen == 8
    assert cache.evict(2) == 0                 # nothing evictable: leased
    pool.release(leased)
    assert cache.evict(2) == 2                 # now both go, leaf first
    assert cache.n_cached_blocks == 0
    assert pool.blocks_in_use == 0


def test_pressure_hook_and_capacity(tiny_cfg):
    pool = KVPool(tiny_cfg, n_blocks=9, block_size=4)   # capacity 8
    cache = PrefixCache(pool, capacity_blocks=2)
    assert pool.pressure_hook == cache.evict  # registered at construction
    b = pool.alloc(4)
    # capacity cap: only 2 of 4 full blocks get pinned
    assert cache.insert(list(range(16)), b) == 2
    assert cache.n_cached_blocks == 2
    pool.release(b)
    assert pool.blocks_in_use == 2
    # pool pressure evicts through the hook: reserve() reclaims the 2
    # cached blocks instead of failing
    assert pool.reserve(8)
    assert cache.n_cached_blocks == 0
    assert pool.free_blocks == 8
    got = pool.alloc(8)
    pool.release(got)


def test_clear_and_cached_block_ids(tiny_cfg):
    pool = KVPool(tiny_cfg, n_blocks=16, block_size=4)
    cache = PrefixCache(pool)
    b = pool.alloc(3)
    cache.insert(list(range(12)), b)
    pool.release(b)
    assert cache.cached_block_ids() == set(b)
    assert cache.clear() == 3
    assert pool.blocks_in_use == 0 and cache.n_cached_blocks == 0


# ---------------------------------------------------------------------------
# Engine-level partial prefill parity
# ---------------------------------------------------------------------------


def _full_then_partial(eng, prompt, clen, n_steps, seed=0):
    """Full prefill+decode of ``prompt``, then a partial prefill reusing
    the full row's first blocks as the cached prefix.  Returns (reference
    logits/tokens, partial logits/tokens)."""
    plen = len(prompt)
    toks = jnp.asarray(prompt)[None]
    full = eng.prefill(toks, jnp.array([plen], jnp.int32))
    ref_logits = np.asarray(full.pending_logits)
    full, ref_out = eng.generate(full, n_steps, jax.random.key(seed), GREEDY,
                                 stop_ids=NO_STOP)
    table = np.asarray(jax.device_get(full.cache["table"]))
    nblk = blocks_for(clen, eng.pool.block_size)
    cached = table[0, :nblk]
    eng.pool.retain(cached)      # the lease PrefixCache.match would take
    suffix = prompt[clen:]
    st = eng.prefill(jnp.asarray(suffix)[None],
                     jnp.array([len(suffix)], jnp.int32),
                     cached_table=cached[None],
                     cached_lens=np.array([clen]))
    part_logits = np.asarray(st.pending_logits)
    st, part_out = eng.generate(st, n_steps, jax.random.key(seed), GREEDY,
                                stop_ids=NO_STOP)
    eng.release_rows(full, [0])
    eng.release_rows(st, [0])
    return (ref_logits, np.asarray(ref_out)), (part_logits,
                                               np.asarray(part_out))


def test_partial_prefill_parity_aligned_and_misaligned(trained_tiny,
                                                       tiny_cfg, tok):
    """The acceptance split classes on one block size: block-aligned,
    misaligned mid-block, and the all-but-last-token split."""
    eng = paged_engine(trained_tiny, tiny_cfg, tok, block_size=8)
    prompt = tok.encode("Q:33+44=?R:33+44=77.A:")
    for clen in (8, 16, 11, len(prompt) - 1):
        (rl, rt), (pl, pt) = _full_then_partial(eng, prompt, clen, 8)
        np.testing.assert_allclose(pl, rl, atol=ATOL)
        np.testing.assert_array_equal(pt, rt)
        assert eng.pool.blocks_in_use == 0


@pytest.mark.slow
def test_partial_prefill_parity_full_grid(trained_tiny, tiny_cfg, tok):
    """Every block size x split-point class, decode crossing block
    boundaries, against both the paged full prefill and the dense
    engine."""
    dense = DecodeEngine(trained_tiny, tiny_cfg, max_len=64,
                         eos_id=tok.eos_id, pad_id=tok.pad_id)
    prompt = tok.encode("Q:15+26=?R:15+26=41.A:")
    plen = len(prompt)
    for block_size in (4, 8, 16):
        eng = paged_engine(trained_tiny, tiny_cfg, tok,
                           block_size=block_size, n_blocks=128)
        sd = dense.prefill(jnp.asarray(prompt)[None],
                           jnp.array([plen], jnp.int32))
        dense_logits = np.asarray(sd.pending_logits)
        _, dense_out = dense.generate(sd, 2 * block_size + 3,
                                      jax.random.key(1), GREEDY,
                                      stop_ids=NO_STOP)
        splits = {block_size, 2 * block_size, block_size + 1,
                  block_size // 2, plen - 1}
        for clen in sorted(c for c in splits if 0 < c < plen):
            (rl, rt), (pl, pt) = _full_then_partial(
                eng, prompt, clen, 2 * block_size + 3, seed=1)
            np.testing.assert_allclose(pl, rl, atol=ATOL)
            np.testing.assert_array_equal(pt, rt)
            np.testing.assert_allclose(pl, dense_logits, atol=ATOL)
            np.testing.assert_array_equal(pt, np.asarray(dense_out))
            assert eng.pool.blocks_in_use == 0


def test_partial_prefill_validates_inputs(trained_tiny, tiny_cfg, tok):
    dense = DecodeEngine(trained_tiny, tiny_cfg, max_len=64,
                         eos_id=tok.eos_id, pad_id=tok.pad_id)
    with pytest.raises(ValueError):
        dense.prefill(jnp.ones((1, 4), jnp.int32),
                      cached_table=np.zeros((1, 1), np.int32),
                      cached_lens=np.array([8]))
    eng = paged_engine(trained_tiny, tiny_cfg, tok, block_size=8)
    st = eng.prefill(jnp.asarray(tok.encode("Q:1+2=?A:"))[None])
    table = np.asarray(jax.device_get(st.cache["table"]))
    with pytest.raises(ValueError):  # zero-token suffix
        eng.prefill(jnp.ones((1, 4), jnp.int32),
                    lengths=jnp.array([0], jnp.int32),
                    cached_table=table[:, :1], cached_lens=np.array([8]))
    with pytest.raises(ValueError):  # overruns usable length
        eng.prefill(jnp.ones((1, 60), jnp.int32),
                    cached_table=table[:, :1], cached_lens=np.array([8]))
    eng.release_rows(st, [0])
    assert eng.pool.blocks_in_use == 0


def test_partial_prefill_out_of_blocks_is_atomic(trained_tiny, tiny_cfg,
                                                 tok):
    """A failed partial prefill must leave the pool untouched (the
    caller's lease included) — the scheduler retries or waits."""
    eng = paged_engine(trained_tiny, tiny_cfg, tok, block_size=8,
                       n_blocks=4)  # capacity 3
    prompt = tok.encode("Q:33+44=?A:")  # 12 tokens -> 2 blocks
    st = eng.prefill(jnp.asarray(prompt)[None])
    table = np.asarray(jax.device_get(st.cache["table"]))
    cached = table[0, :1]
    eng.pool.retain(cached)
    rc = eng.pool.refcount.copy()
    with pytest.raises(OutOfBlocks):
        # suffix needs 2 fresh blocks + nothing free (1 block left, lease
        # on block 0 held): must fail before any retain/cow/alloc
        eng.prefill(jnp.asarray(prompt[8:] + prompt)[None],
                    cached_table=cached[None], cached_lens=np.array([8]))
    np.testing.assert_array_equal(eng.pool.refcount, rc)
    eng.pool.release(cached)
    eng.release_rows(st, [0])
    assert eng.pool.blocks_in_use == 0


# ---------------------------------------------------------------------------
# Scheduler integration
# ---------------------------------------------------------------------------

HEADER = "Q:1+2=?A:3.Q:4+5=?A:9.Q:7+2=?A:9."


def _sched(engine, cache, prompt_len=56, n_slots=3):
    return ContinuousScheduler(engine, n_slots=n_slots,
                               prompt_len=prompt_len, stop_ids=NO_STOP,
                               prefix_cache=cache)


def _submit_all(sched, tok, questions, max_new=5, header=HEADER):
    for i, q in enumerate(questions):
        sched.submit(Request(req_id=i,
                             prompt=jnp.asarray(tok.encode(header + q)),
                             max_new_tokens=max_new))


QUESTIONS = ["Q:1+2=?A:", "Q:3+4=?A:", "Q:5+6=?A:", "Q:7+8=?A:",
             "Q:2+9=?A:"]


def _run_workload(trained_tiny, tiny_cfg, tok, *, cache_on, n_blocks=97,
                  capacity=None):
    eng = paged_engine(trained_tiny, tiny_cfg, tok, max_len=96,
                       block_size=8, n_blocks=n_blocks)
    cache = (PrefixCache(eng.pool, capacity_blocks=capacity)
             if cache_on else None)
    sched = _sched(eng, cache)
    _submit_all(sched, tok, QUESTIONS)
    res = sched.run(jax.random.key(0), GREEDY)
    return res, sched, eng, cache


def test_scheduler_cache_hits_save_prefill_at_identical_outputs(
        trained_tiny, tiny_cfg, tok):
    res0, s0, e0, _ = _run_workload(trained_tiny, tiny_cfg, tok,
                                    cache_on=False)
    res1, s1, e1, cache = _run_workload(trained_tiny, tiny_cfg, tok,
                                        cache_on=True)
    assert res0 == res1  # greedy streams are bit-identical
    m0, m1 = s0.metrics.summary(), s1.metrics.summary()
    # every request after the first hits the shared header
    assert m1["prefix_cache_lookups"] == len(QUESTIONS)
    assert m1["prefix_cache_hits"] == len(QUESTIONS) - 1
    assert m1["prefix_cache_hit_rate"] == pytest.approx(0.8)
    assert m1["prefill_tokens_saved"] > 0
    assert (m1["prefill_tokens"] + m1["prefill_tokens_saved"]
            == m0["prefill_tokens"])
    # the shared-header workload clears the acceptance bar
    assert m1["prefill_tokens"] <= 0.5 * m0["prefill_tokens"]
    assert cache.stats()["hit_rate"] == pytest.approx(0.8)


def test_tts_group_partial_prefill_fork_parity(trained_tiny, tiny_cfg, tok):
    """A Best-of-N group admitted over a cached header: one partial
    prefill, fork, streams match the uncached group's streams."""

    def run(cache_on):
        eng = paged_engine(trained_tiny, tiny_cfg, tok, max_len=96,
                           block_size=8, n_blocks=97)
        cache = PrefixCache(eng.pool) if cache_on else None
        sched = _sched(eng, cache, n_slots=4)
        sched.submit(Request(req_id=0,
                             prompt=jnp.asarray(tok.encode(
                                 HEADER + "Q:6+3=?A:")),
                             max_new_tokens=4))
        sched.submit(Request(req_id=1,
                             prompt=jnp.asarray(tok.encode(
                                 HEADER + "Q:5+4=?A:")),
                             max_new_tokens=6, n_samples=3))
        res = sched.run(jax.random.key(0), GREEDY)
        return res, sched, eng

    res0, _, _ = run(False)
    res1, s1, e1 = run(True)
    assert res0 == res1
    assert len(res1[1]) == 3
    assert s1.metrics.cache_hits >= 1  # the group hit req 0's header


def test_eviction_then_readmission_recomputes_and_matches(trained_tiny,
                                                          tiny_cfg, tok):
    """Acceptance: evict a cached prefix, readmit the same prompt (miss,
    full recompute, re-insert), then admit once more (hit) — all three
    streams identical."""
    eng = paged_engine(trained_tiny, tiny_cfg, tok, max_len=96,
                       block_size=8, n_blocks=97)
    cache = PrefixCache(eng.pool)
    prompt = HEADER + "Q:5+6=?A:"
    streams = []
    for trial in range(3):
        sched = _sched(eng, cache)
        sched.submit(Request(req_id=trial,
                             prompt=jnp.asarray(tok.encode(prompt)),
                             max_new_tokens=5))
        streams.append(sched.run(jax.random.key(0), GREEDY)[trial])
        if trial == 0:
            assert cache.n_cached_blocks > 0
            evicted = cache.evict(cache.n_cached_blocks)
            assert evicted > 0 and cache.n_cached_blocks == 0
            assert eng.pool.blocks_in_use == 0
    assert streams[0] == streams[1] == streams[2]
    # trial 1 missed (cache was empty), trial 2 hit the re-inserted prefix
    assert cache.hits >= 1 and cache.evictions >= 1
    assert eng.pool.blocks_in_use == cache.n_cached_blocks


def test_pool_pressure_evicts_cache_before_preempting(trained_tiny,
                                                      tiny_cfg, tok):
    """A pool sized so the cached header + live rows cannot coexist: the
    pressure hook must reclaim cached blocks (evictions > 0) and the
    drain still completes with reference outputs."""
    res_ref, _, _, _ = _run_workload(trained_tiny, tiny_cfg, tok,
                                     cache_on=False, n_blocks=97)
    eng = paged_engine(trained_tiny, tiny_cfg, tok, max_len=96,
                       block_size=8, n_blocks=9)  # deliberately starved
    cache = PrefixCache(eng.pool)
    sched = _sched(eng, cache, n_slots=2)
    _submit_all(sched, tok, QUESTIONS)
    res = sched.run(jax.random.key(0), GREEDY)
    assert res == res_ref
    assert cache.evictions > 0
    assert eng.pool.blocks_in_use == cache.n_cached_blocks


@pytest.mark.slow
def test_scheduler_parity_grid_with_eviction_churn(trained_tiny, tiny_cfg,
                                                   tok):
    """Shared-header workloads across block sizes and starved/roomy pools:
    outputs must match the uncached reference everywhere, including runs
    that interleave eviction and preemption."""
    res_ref, _, _, _ = _run_workload(trained_tiny, tiny_cfg, tok,
                                     cache_on=False)
    for block_size in (4, 8, 16):
        wc = blocks_for(96, block_size)  # worst-case one-request footprint
        for n_blocks in (wc + wc // 2 + 1, 6 * (96 // block_size) + 1):
            eng = DecodeEngine(trained_tiny, tiny_cfg, max_len=96,
                               eos_id=tok.eos_id, pad_id=tok.pad_id,
                               paged=True, block_size=block_size,
                               n_blocks=n_blocks)
            cache = PrefixCache(eng.pool)
            sched = _sched(eng, cache, n_slots=2)
            _submit_all(sched, tok, QUESTIONS)
            res = sched.run(jax.random.key(0), GREEDY)
            assert res == res_ref, (block_size, n_blocks)
            assert eng.pool.blocks_in_use == cache.n_cached_blocks
            rc = eng.pool.refcount
            assert all(rc[b] == 1 for b in cache.cached_block_ids())


def test_prefix_cache_requires_paged_engine(trained_tiny, tiny_cfg, tok):
    dense = DecodeEngine(trained_tiny, tiny_cfg, max_len=64,
                         eos_id=tok.eos_id, pad_id=tok.pad_id)
    paged = paged_engine(trained_tiny, tiny_cfg, tok)
    other = paged_engine(trained_tiny, tiny_cfg, tok)
    cache = PrefixCache(other.pool)
    with pytest.raises(ValueError):
        ContinuousScheduler(dense, prefix_cache=cache)
    with pytest.raises(ValueError):  # bound to a different engine's pool
        ContinuousScheduler(paged, prefix_cache=cache)


def test_controller_serving_row_reports_cache_stats(trained_tiny, tiny_cfg,
                                                    tok):
    from repro.core import reward as R
    from repro.core.controller import serve_best_of_n
    from repro.data import tasks as T

    eng = paged_engine(trained_tiny, tiny_cfg, tok, max_len=96,
                       block_size=8, n_blocks=97)
    cache = PrefixCache(eng.pool)
    tasks = T.shared_prefix_dataset(41, 3, n_shots=2, reasoning=False,
                                    max_terms=2)
    row = serve_best_of_n(eng, tok, tasks, n=2, max_tokens=8,
                          rng=jax.random.key(0), scorer=R.OracleVerifier(),
                          n_slots=4, prefix_cache=cache)
    pc = row["serving"]["prefix_cache"]
    assert pc["lookups"] == 3 and pc["hits"] == 2
    assert row["serving"]["prefill_tokens_saved"] > 0
    assert 0.0 <= row["accuracy"] <= 1.0
