"""Per-architecture smoke tests (deliverable f): every assigned arch's
REDUCED config instantiates and runs one forward + one train step on CPU,
asserting output shapes and no NaNs.  Full configs are only exercised via
the dry-run (ShapeDtypeStruct, no allocation)."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs.base import SHAPES_BY_NAME
from repro.configs.registry import list_archs, get_config
from repro.models import api
from repro.train.loop import lm_loss
from repro.train.optimizer import AdamWConfig, adamw_update, init_opt_state

ARCHS = list_archs()


def _inputs(cfg, B=2, S=16):
    tokens = jax.random.randint(jax.random.key(1), (B, S), 0, cfg.vocab_size)
    extra = {}
    if cfg.frontend == "patch_stub":
        extra["embeddings"] = jnp.ones((B, 4, cfg.d_model), jnp.float32)
    if cfg.family == "encdec":
        extra["embeddings"] = jnp.ones((B, cfg.encoder_seq_len, cfg.d_model),
                                       jnp.float32)
    return tokens, extra


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_forward(arch):
    cfg = get_config(arch, smoke=True)
    model = api.get_model(cfg)
    params = model.init_params(jax.random.key(0), cfg)
    tokens, extra = _inputs(cfg)
    logits, _, aux = model.forward(params, tokens, cfg, **extra)
    assert logits.shape == (2, 16, cfg.vocab_size)
    assert not jnp.isnan(logits).any()
    assert not jnp.isnan(aux)


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_train_step(arch):
    cfg = get_config(arch, smoke=True)
    model = api.get_model(cfg)
    params = model.init_params(jax.random.key(0), cfg)
    tokens, extra = _inputs(cfg)
    targets = jnp.roll(tokens, -1, axis=1)
    mask = jnp.ones(tokens.shape, jnp.float32)
    batch = (tokens, targets, mask) + ((extra["embeddings"],)
                                       if extra else ())

    def loss_fn(p):
        return lm_loss(p, batch, cfg, None)

    (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(params)
    assert jnp.isfinite(loss)
    new_params, _, om = adamw_update(params, grads, init_opt_state(params),
                                     AdamWConfig())
    # at least one param changed, none went NaN
    changed = jax.tree.map(lambda a, b: bool(jnp.any(a != b)),
                           params, new_params)
    assert any(jax.tree.leaves(changed))
    assert all(bool(jnp.all(jnp.isfinite(l))) for l in
               jax.tree.leaves(new_params))


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_decode(arch):
    cfg = get_config(arch, smoke=True)
    model = api.get_model(cfg)
    params = model.init_params(jax.random.key(0), cfg)
    tokens, extra = _inputs(cfg, B=2, S=8)
    logits, cache = model.prefill(params, tokens, cfg, max_len=16, **extra)
    assert logits.shape == (2, cfg.vocab_size)
    lg, cache = model.decode_step(params, tokens[:, :1], cache,
                                  jnp.full((2,), 9, jnp.int32), cfg)
    assert lg.shape == (2, cfg.vocab_size)
    assert not jnp.isnan(lg).any()


def test_long_500k_applicability():
    """The sub-quadratic gate matches DESIGN.md §Arch-applicability."""
    runs = {a for a in ARCHS if get_config(a).supports_shape(
        SHAPES_BY_NAME["long_500k"])}
    assert runs == {"gemma3-1b", "mamba2-130m", "mixtral-8x7b",
                    "zamba2-1.2b"}


def test_param_counts_sane():
    expect = {  # rough published sizes (±35% — configs are from the brief)
        "gemma3-1b": 1.0e9, "stablelm-3b": 2.8e9, "qwen2.5-14b": 14e9,
        "command-r-35b": 35e9, "internvl2-1b": 0.8e9, "mamba2-130m": 130e6,
        "olmoe-1b-7b": 6.9e9, "mixtral-8x7b": 46e9, "zamba2-1.2b": 1.2e9,
        "whisper-base": 72e6,
    }
    for arch, n in expect.items():
        got = get_config(arch).param_count()
        assert 0.5 * n < got < 1.6 * n, (arch, got, n)
