"""Property tests for the quantization substrate invariants.

Originally hypothesis-based; rewritten as seeded-random property loops so
the suite collects and runs without optional dependencies (hypothesis is
not in the container).  Each test draws a spread of shapes/values from a
fixed seed and checks the same invariants over every draw.
"""
import itertools

import jax.numpy as jnp
import numpy as np
import pytest

from repro.quant import tile_quant as TQ
from repro.quant.codebooks import CODEBOOKS, codebook_absmax

_SHAPES = [(32, 32), (64, 64), (128, 32), (32, 128), (64, 128)]


def _draw_weights(seed: int, n: int = 8):
    """n random (K, N) float32 arrays in [-4, 4] over a spread of shapes."""
    rng = np.random.default_rng(seed)
    for i in range(n):
        K, N = _SHAPES[i % len(_SHAPES)]
        yield rng.uniform(-4, 4, size=(K, N)).astype(np.float32)


def test_pack_unpack_roundtrip():
    rng = np.random.default_rng(0)
    for _ in range(8):
        rows = int(rng.integers(1, 17))
        cols = int(rng.integers(1, 33)) * 2
        codes = rng.integers(0, 16, size=(rows, cols)).astype(np.uint8)
        packed = TQ.pack_int4(jnp.asarray(codes))
        assert packed.shape == (rows, cols // 2)
        out = np.asarray(TQ.unpack_int4(packed))
        np.testing.assert_array_equal(out, codes)


@pytest.mark.parametrize("scheme", ["tile", "common"])
def test_q4_error_bounded_by_half_grid_step(scheme):
    """Round-to-nearest on the Q4_0 grid: |w - deq| <= scale/2 per element
    (grid spacing is 1.0 in normalized units = `scale` after rescaling)."""
    for w in _draw_weights(1):
        qw = TQ.quantize(jnp.asarray(w), scheme=scheme, codebook="q4_0")
        deq = np.asarray(TQ.dequantize(qw))
        s = np.asarray(qw["scales"], np.float32)
        if scheme == "common":
            sc = np.repeat(s, 32, axis=0)
        else:
            sc = np.repeat(np.repeat(s, 2, axis=0), 16, axis=1)
        err = np.abs(w - deq)
        # the Q4_0 grid is asymmetric ([-8, 7]): +absmax rounds down a full
        # grid step; everything else rounds within half a step; fp16 scale
        # storage adds up to |w|·2^-10 relative rounding
        bound = np.maximum(sc, 1e-8) * 1.0 + np.abs(w) * 2 ** -10 + 1e-4
        assert (err <= bound).all(), float((err - bound).max())


@pytest.mark.parametrize("cb,scheme",
                         list(itertools.product(sorted(CODEBOOKS),
                                                ["tile", "common"])))
def test_dequantized_range_never_exceeds_group_absmax(cb, scheme):
    """|dequant| <= group absmax (up to fp16 scale rounding)."""
    for w in _draw_weights(2, n=4):
        qw = TQ.quantize(jnp.asarray(w), scheme=scheme, codebook=cb)
        deq = np.asarray(TQ.dequantize(qw))
        assert np.abs(deq).max() <= np.abs(w).max() * 1.01 + 1e-4


def test_constant_group_is_exact():
    """A weight constant within each (2,16) tile group quantizes exactly
    when negative (the asymmetric [-8,7] grid hits -absmax exactly; +absmax
    is one step off — same as llama.cpp Q4_0)."""
    for w in _draw_weights(3):
        K, N = w.shape
        wc = -np.abs(np.repeat(np.repeat(w[::2, ::16], 2, axis=0), 16,
                               axis=1)[:K, :N])
        qw = TQ.quantize(jnp.asarray(wc), scheme="tile", codebook="q4_0")
        deq = np.asarray(TQ.dequantize(qw))
        np.testing.assert_allclose(deq, wc, atol=2e-3, rtol=2e-3)


def test_q8_roundtrip_tight():
    for w in _draw_weights(4):
        qw = TQ.quantize_q8(jnp.asarray(w))
        deq = np.asarray(TQ.dequantize_q8(qw))
        s = np.repeat(np.asarray(qw["scales"], np.float32), 32, axis=0)
        assert (np.abs(w - deq) <= np.maximum(s, 1e-8) * 0.5 + 1e-4).all()


@pytest.mark.parametrize("scheme", ["tile", "common"])
def test_sign_symmetry(scheme):
    """quantize(-w) dequantizes to -dequantize(w) for a sign-symmetric
    codebook (FP4 E2M1 is ±symmetric; NF4/Q4_0 are deliberately not)."""
    for w in _draw_weights(5):
        q1 = np.asarray(TQ.dequantize(TQ.quantize(jnp.asarray(w),
                                                  scheme=scheme,
                                                  codebook="fp4")))
        q2 = np.asarray(TQ.dequantize(TQ.quantize(jnp.asarray(-w),
                                                  scheme=scheme,
                                                  codebook="fp4")))
        np.testing.assert_allclose(q1, -q2, atol=2e-2)
