"""Hypothesis property tests for the quantization substrate invariants."""
import hypothesis
import hypothesis.extra.numpy as hnp
import hypothesis.strategies as st
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings

from repro.quant import tile_quant as TQ
from repro.quant.codebooks import CODEBOOKS, codebook_absmax

SETTINGS = dict(max_examples=25, deadline=None)

w_arrays = hnp.arrays(
    np.float32, st.tuples(st.sampled_from([2, 4, 8]).map(lambda x: x * 16),
                          st.sampled_from([32, 64, 128])),
    elements=st.floats(-4, 4, width=32))


@given(codes=hnp.arrays(np.uint8, st.tuples(st.integers(1, 16),
                                            st.integers(1, 32).map(lambda x: x * 2)),
                        elements=st.integers(0, 15)))
@settings(**SETTINGS)
def test_pack_unpack_roundtrip(codes):
    packed = TQ.pack_int4(jnp.asarray(codes))
    assert packed.shape == (codes.shape[0], codes.shape[1] // 2)
    out = np.asarray(TQ.unpack_int4(packed))
    np.testing.assert_array_equal(out, codes)


@given(w=w_arrays, scheme=st.sampled_from(["tile", "common"]))
@settings(**SETTINGS)
def test_q4_error_bounded_by_half_grid_step(w, scheme):
    """Round-to-nearest on the Q4_0 grid: |w - deq| <= scale/2 per element
    (grid spacing is 1.0 in normalized units = `scale` after rescaling)."""
    qw = TQ.quantize(jnp.asarray(w), scheme=scheme, codebook="q4_0")
    deq = np.asarray(TQ.dequantize(qw))
    s = np.asarray(qw["scales"], np.float32)
    if scheme == "common":
        sc = np.repeat(s, 32, axis=0)
    else:
        sc = np.repeat(np.repeat(s, 2, axis=0), 16, axis=1)
    err = np.abs(w - deq)
    # the Q4_0 grid is asymmetric ([-8, 7]): +absmax rounds down a full grid
    # step; everything else rounds within half a step; fp16 scale storage
    # adds up to |w|·2^-10 relative rounding
    bound = np.maximum(sc, 1e-8) * 1.0 + np.abs(w) * 2 ** -10 + 1e-4
    assert (err <= bound).all(), float((err - bound).max())


@given(w=w_arrays,
       cb=st.sampled_from(sorted(CODEBOOKS)),
       scheme=st.sampled_from(["tile", "common"]))
@settings(**SETTINGS)
def test_dequantized_range_never_exceeds_group_absmax(w, cb, scheme):
    """|dequant| <= group absmax (up to fp16 scale rounding)."""
    qw = TQ.quantize(jnp.asarray(w), scheme=scheme, codebook=cb)
    deq = np.asarray(TQ.dequantize(qw))
    assert np.abs(deq).max() <= np.abs(w).max() * 1.01 + 1e-4


@given(w=w_arrays)
@settings(**SETTINGS)
def test_constant_group_is_exact(w):
    """A weight constant within each (2,16) tile group quantizes exactly
    when negative (the asymmetric [-8,7] grid hits -absmax exactly; +absmax
    is one step off — same as llama.cpp Q4_0)."""
    K, N = w.shape
    wc = -np.abs(np.repeat(np.repeat(w[::2, ::16], 2, axis=0), 16,
                           axis=1)[:K, :N])
    qw = TQ.quantize(jnp.asarray(wc), scheme="tile", codebook="q4_0")
    deq = np.asarray(TQ.dequantize(qw))
    np.testing.assert_allclose(deq, wc, atol=2e-3, rtol=2e-3)


@given(w=w_arrays)
@settings(**SETTINGS)
def test_q8_roundtrip_tight(w):
    qw = TQ.quantize_q8(jnp.asarray(w))
    deq = np.asarray(TQ.dequantize_q8(qw))
    s = np.repeat(np.asarray(qw["scales"], np.float32), 32, axis=0)
    assert (np.abs(w - deq) <= np.maximum(s, 1e-8) * 0.5 + 1e-4).all()


@given(w=w_arrays, scheme=st.sampled_from(["tile", "common"]))
@settings(**SETTINGS)
def test_sign_symmetry(w, scheme):
    """quantize(-w) dequantizes to -dequantize(w) for a sign-symmetric
    codebook (FP4 E2M1 is ±symmetric; NF4/Q4_0 are deliberately not)."""
    q1 = np.asarray(TQ.dequantize(TQ.quantize(jnp.asarray(w), scheme=scheme,
                                              codebook="fp4")))
    q2 = np.asarray(TQ.dequantize(TQ.quantize(jnp.asarray(-w), scheme=scheme,
                                              codebook="fp4")))
    np.testing.assert_allclose(q1, -q2, atol=2e-2)
