"""Tree-search-as-a-scheduler-workload tests: scheduler-served beam search
vs the direct ``core.beam_search`` path (greedy bit-parity on fp and
quantized paged pools), mixed beam + chat + Best-of-N queues, preemption
of starved trees, batched PRM scoring, and the direct-path block-release
fix (normal return and exception paths)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import reward as R
from repro.core.beam_search import beam_search
from repro.core.controller import serve_beam_search
from repro.data import tasks as T
from repro.serving.engine import (BeamSpec, ContinuousScheduler,
                                  DecodeEngine, Request, SpecConfig)
from repro.serving.sampler import SamplerConfig

NO_STOP = (9999,)
GREEDY = SamplerConfig(greedy=True)

# small enough to finish fast, large enough for >1 scoring boundary
WIDTH, EXPAND, STEP_TOKENS, MAX_STEPS = 2, 2, 6, 2
PROMPT_LEN = 16


def _paged_engine(trained_tiny, tiny_cfg, tok, n_blocks, kv_quant="none"):
    return DecodeEngine(trained_tiny, tiny_cfg, max_len=64,
                        eos_id=tok.eos_id, pad_id=tok.pad_id, paged=True,
                        block_size=8, n_blocks=n_blocks, kv_quant=kv_quant)


@pytest.fixture(scope="module")
def paged_engine(trained_tiny, tiny_cfg, tok):
    return _paged_engine(trained_tiny, tiny_cfg, tok, n_blocks=48)


@pytest.fixture(scope="module")
def paged_engine_q8(trained_tiny, tiny_cfg, tok):
    return _paged_engine(trained_tiny, tiny_cfg, tok, n_blocks=48,
                         kv_quant="q8")


def _beam_tasks(n):
    return T.gen_dataset(17, n, reasoning=True, max_terms=2)


def _direct(engine, tok, task, prm, rng):
    return beam_search(engine, tok, task, width=WIDTH, expand=EXPAND,
                       max_steps=MAX_STEPS, step_tokens=STEP_TOKENS,
                       rng=rng, prm=prm, sc=GREEDY, prompt_len=PROMPT_LEN)


def _served(engine, tok, tasks, prm, rng):
    return serve_beam_search(engine, tok, tasks, width=WIDTH, expand=EXPAND,
                             step_tokens=STEP_TOKENS, max_steps=MAX_STEPS,
                             rng=rng, prm=prm, n_slots=8,
                             prompt_len=PROMPT_LEN, sc=GREEDY)


def _assert_parity(engine, tok, tasks, prm):
    """Greedy direct-vs-scheduler bit-parity + zero-leak on both paths."""
    assert engine.pool.blocks_in_use == 0
    direct = [_direct(engine, tok, t, prm, jax.random.key(0))
              for t in tasks]
    assert engine.pool.blocks_in_use == 0  # direct path releases its tree
    row = _served(engine, tok, tasks, prm, jax.random.key(0))
    assert engine.pool.blocks_in_use == 0  # scheduler drains clean
    for d, s in zip(direct, row["results"]):
        assert s.completions == d.completions
        assert s.chosen == d.chosen
        assert s.answer == d.answer
    return row


def test_scheduler_beam_matches_direct_paged_fp(paged_engine, tok):
    """Greedy beam search through the scheduler is bit-identical to the
    direct path (same candidates, same PRM scores, same winner), and the
    PRM runs exactly one forward per scoring boundary / final selection."""
    cfg = R.reward_config(tok.vocab_size)
    prm = R.LearnedScorer(R.init_reward_params(jax.random.key(1), cfg),
                          cfg, tok)
    tasks = _beam_tasks(2)
    base = prm.n_forwards
    row = _assert_parity(paged_engine, tok, tasks, prm)
    s = row["serving"]
    assert s["completed_requests"] == 2
    assert s["completed_samples"] == 2 * WIDTH
    # every boundary scored all live candidates in ONE batched call; the
    # direct run above issued its own forwards, so count scheduler-side
    # batches against the metrics, not against `base`
    assert s["beam_boundaries"] >= 2            # >= 1 per task
    assert s["beam_expansions"] == s["beam_prunes"]
    assert s["prm_batches"] >= s["beam_boundaries"]
    assert s["prm_candidates"] >= s["prm_batches"] * WIDTH
    assert s["prm_candidates_per_batch"] > 1.0  # really batched
    assert prm.n_forwards > base                # forwards were counted


def test_scheduler_beam_matches_direct_paged_q8(paged_engine_q8, tok):
    """Same parity property on the tile-quantized Q8 block pool: fork /
    reorder / release move quantized blocks identically."""
    _assert_parity(paged_engine_q8, tok, _beam_tasks(1), R.LogProbScorer())


def _mean_logprob_spec(tok, step_tokens=STEP_TOKENS, max_steps=MAX_STEPS,
                       delim="."):
    """Tokenizer-free BeamSpec for driving the scheduler directly."""
    def score(token_lists, lp, ng):
        return np.asarray(lp) / np.maximum(np.asarray(ng), 1)
    stop = int(tok.encode(delim, bos=False)[0])
    return BeamSpec(width=WIDTH, expand=EXPAND, step_tokens=step_tokens,
                    max_steps=max_steps, step_stop_id=stop, score=score)


def _reference_tokens(engine, tok, text, max_new, prompt_len=PROMPT_LEN):
    """Per-request greedy DecodeEngine run with the scheduler's padding."""
    ids = tok.encode(text)
    padded = jnp.full((prompt_len,), engine.pad_id, jnp.int32)
    padded = padded.at[: len(ids)].set(jnp.asarray(ids))
    st = engine.prefill(padded[None], jnp.array([len(ids)], jnp.int32))
    st, out = engine.generate(st, max_new, jax.random.key(0), GREEDY,
                              stop_ids=NO_STOP)
    if engine.paged:
        engine.release_rows(st, [0])
    return out[0].tolist()


def test_mixed_queue_beam_chat_bon(paged_engine, tok):
    """A beam tree, plain chat requests and a Best-of-N fan-out coexist in
    one slot pool: the per-row stop mask only affects the tree's lanes
    (chat rows match the per-request reference exactly), and a full drain
    leaves zero blocks in use."""
    engine = paged_engine
    assert engine.pool.blocks_in_use == 0
    sched = ContinuousScheduler(engine, n_slots=8, prompt_len=PROMPT_LEN,
                                stop_ids=NO_STOP)
    task = _beam_tasks(1)[0]
    sched.submit(Request(req_id=0, prompt=jnp.asarray(tok.encode(task.prompt)),
                         search=_mean_logprob_spec(tok)))
    chat = {1: "Q:7+5=?A:", 2: "Q:19+23=?A:"}
    for rid, text in chat.items():
        sched.submit(Request(req_id=rid,
                             prompt=jnp.asarray(tok.encode(text)),
                             max_new_tokens=10))
    sched.submit(Request(req_id=3, prompt=jnp.asarray(tok.encode("Q:2+2=?A:")),
                         max_new_tokens=8, n_samples=2))
    res = sched.run(jax.random.key(0), GREEDY)

    assert set(res) == {0, 1, 2, 3}
    # chat rows decoded alongside the tree are untouched by its row_stops
    # mask: bit-identical to a solo greedy run
    for rid, text in chat.items():
        assert res[rid] == _reference_tokens(engine, tok, text, 10)
    assert len(res[3]) == 2                       # BoN fan-out intact
    assert len(res[0]) == WIDTH                   # tree emits width samples
    assert all(s.finish_reason == "beam" for s in sched.completed[0])
    assert 0 in sched.beam_results
    assert sched.beam_results[0]["beam_steps"] >= 1
    s = sched.metrics.summary()
    assert s["beam_boundaries"] >= 1 and s["prm_batches"] >= 1
    assert engine.pool.blocks_in_use == 0


def test_beam_lane_frozen_during_spec_verify_resumes_clean(paged_engine,
                                                           tok):
    """Freeze/resume × row_stops × speculation: beam lanes never draft
    (they ride every verify round at exactly one committed token so the
    boundary bookkeeping stays step-accurate), and a lane frozen at its
    step budget while speculative verify rounds are still in flight for
    the chat rows must resume from its committed state with no draft
    residue — asserted the strong way, by bit-parity of the whole mixed
    workload against the spec-disabled run.

    The scripted schedule: delimiter ``z`` is never sampled, so every
    beam lane exhausts its full step budget and takes the freeze path at
    each boundary while the chat rows keep speculating."""
    engine = paged_engine
    assert engine.pool.blocks_in_use == 0
    task = _beam_tasks(1)[0]
    chat = {1: "Q:7+5=?A:", 2: "Q:19+23=?A:"}

    def run(spec):
        sched = ContinuousScheduler(engine, n_slots=8,
                                    prompt_len=PROMPT_LEN,
                                    stop_ids=NO_STOP, spec=spec)
        sched.submit(Request(req_id=0,
                             prompt=jnp.asarray(tok.encode(task.prompt)),
                             search=_mean_logprob_spec(tok, delim="z")))
        for rid, text in chat.items():
            sched.submit(Request(req_id=rid,
                                 prompt=jnp.asarray(tok.encode(text)),
                                 max_new_tokens=10))
        res = sched.run(jax.random.key(0), GREEDY)
        assert engine.pool.blocks_in_use == 0
        return res, sched.metrics.summary(), sched.beam_results[0]

    base, _, beam_base = run(None)
    got, s, beam_spec = run(SpecConfig(k=4, self_draft=True))
    assert base == got            # incl. the frozen-then-resumed lanes
    assert beam_spec["beam_steps"] == beam_base["beam_steps"]
    assert s["spec_rounds"] > 0   # chat rows really speculated...
    assert s["beam_boundaries"] >= 1  # ...across a freeze boundary
    for rid, text in chat.items():
        assert got[rid] == _reference_tokens(engine, tok, text, 10)


def test_beam_preempted_under_block_pressure(trained_tiny, tiny_cfg, tok):
    """On a starved pool the youngest request is preempted when the tree's
    copy-on-write growth exhausts blocks — everything still completes and
    the pool drains to zero."""
    engine = _paged_engine(trained_tiny, tiny_cfg, tok, n_blocks=10)
    sched = ContinuousScheduler(engine, n_slots=6, prompt_len=PROMPT_LEN,
                                stop_ids=NO_STOP)
    task = _beam_tasks(1)[0]
    # a delimiter greedy decoding never samples: every lane exhausts its
    # full step budget (freeze path), so the tree stays live long enough
    # for the chats' cache growth to exhaust the pool
    sched.submit(Request(req_id=0, prompt=jnp.asarray(tok.encode(task.prompt)),
                         search=_mean_logprob_spec(tok, delim="z")))
    sched.submit(Request(req_id=1, prompt=jnp.asarray(tok.encode("Q:5+6=?A:")),
                         max_new_tokens=12))
    sched.submit(Request(req_id=2, prompt=jnp.asarray(tok.encode("Q:8+9=?A:")),
                         max_new_tokens=12))
    res = sched.run(jax.random.key(0), GREEDY)
    assert set(res) == {0, 1, 2}
    assert len(res[0]) == WIDTH
    assert sched.metrics.summary()["preemptions"] >= 1
    assert engine.pool.blocks_in_use == 0


def test_prm_step_batch_matches_sequential(tok):
    """``score_step_batch`` scores every candidate's last step in ONE
    forward and matches per-candidate ``score_steps`` exactly — the
    scheduler's batched boundary call is a pure batching of the direct
    path's sequential loop."""
    cfg = R.reward_config(tok.vocab_size)
    sc = R.LearnedScorer(R.init_reward_params(jax.random.key(2), cfg),
                         cfg, tok)
    task = T.gen_dataset(23, 1, reasoning=True)[0]
    comps = ["3+4=7.", "3+4=8.", "3+4=7.7+5=12.", "no delimiter yet"]
    seq = np.asarray([np.asarray(sc.score_steps(task, c))[-1]
                      for c in comps])
    base = sc.n_forwards
    batch = np.asarray(sc.score_step_batch(task, comps))
    assert sc.n_forwards == base + 1         # one forward for all four
    np.testing.assert_allclose(batch, seq, rtol=1e-5, atol=1e-6)


def test_direct_beam_search_releases_blocks(paged_engine, tok):
    """The direct path frees every pool block it held on normal return
    (the leak serve.py used to warn about)."""
    engine = paged_engine
    assert engine.pool.blocks_in_use == 0
    r = _direct(engine, tok, _beam_tasks(1)[0], R.LogProbScorer(),
                jax.random.key(0))
    assert len(r.completions) == WIDTH
    assert engine.pool.blocks_in_use == 0


def test_direct_beam_search_releases_blocks_on_error(paged_engine, tok):
    """...and on the exception path: a PRM that blows up mid-search must
    not strand the tree's blocks in the pool."""

    class Boom:
        def score_texts(self, task, texts):
            raise RuntimeError("prm fell over")

    engine = paged_engine
    assert engine.pool.blocks_in_use == 0
    with pytest.raises(RuntimeError, match="prm fell over"):
        _direct(engine, tok, _beam_tasks(1)[0], Boom(), jax.random.key(0))
    assert engine.pool.blocks_in_use == 0


def test_beam_submit_validation(paged_engine, tok):
    """Malformed tree requests are rejected at submit time."""
    sched = ContinuousScheduler(paged_engine, n_slots=4,
                                prompt_len=PROMPT_LEN)
    prompt = jnp.asarray(tok.encode("Q:1+2=?A:"))
    spec = _mean_logprob_spec(tok)
    with pytest.raises(ValueError, match="mutually exclusive"):
        sched.submit(Request(req_id=0, prompt=prompt, n_samples=2,
                             search=spec))
    with pytest.raises(ValueError, match="score is required"):
        sched.submit(Request(req_id=1, prompt=prompt,
                             search=BeamSpec(width=2, expand=2,
                                             step_stop_id=46)))
    with pytest.raises(ValueError, match="step_stop_id"):
        bad = BeamSpec(width=2, expand=2, score=spec.score)
        sched.submit(Request(req_id=2, prompt=prompt, search=bad))
    with pytest.raises(ValueError, match="exceeds n_slots"):
        wide = BeamSpec(width=4, expand=2, step_stop_id=46,
                        score=spec.score)
        sched.submit(Request(req_id=3, prompt=prompt, search=wide))


def test_beam_with_prefix_cache(trained_tiny, tiny_cfg, tok):
    """Finished trees insert their prompt into the prefix cache; a repeat
    submission of the same task re-uses the cached prefix blocks and the
    pool holds exactly the cache's pins after drain."""
    from repro.serving.prefix_cache import PrefixCache

    engine = _paged_engine(trained_tiny, tiny_cfg, tok, n_blocks=48)
    cache = PrefixCache(engine.pool)
    tasks = _beam_tasks(1)
    row1 = serve_beam_search(engine, tok, tasks, width=WIDTH, expand=EXPAND,
                             step_tokens=STEP_TOKENS, max_steps=MAX_STEPS,
                             rng=jax.random.key(0), prm=R.LogProbScorer(),
                             n_slots=8, prompt_len=PROMPT_LEN, sc=GREEDY,
                             prefix_cache=cache)
    pinned = cache.stats()["cached_blocks"]
    assert pinned >= 1                       # prompt prefix was inserted
    assert engine.pool.blocks_in_use == pinned
    row2 = serve_beam_search(engine, tok, tasks, width=WIDTH, expand=EXPAND,
                             step_tokens=STEP_TOKENS, max_steps=MAX_STEPS,
                             rng=jax.random.key(0), prm=R.LogProbScorer(),
                             n_slots=8, prompt_len=PROMPT_LEN, sc=GREEDY,
                             prefix_cache=cache)
    assert cache.stats()["hits"] >= 1        # cached admission path taken
    assert engine.pool.blocks_in_use == cache.stats()["cached_blocks"]
    # greedy: the cached-prefix run reproduces the uncached run exactly
    assert (row2["results"][0].completions == row1["results"][0].completions)
    assert row2["results"][0].chosen == row1["results"][0].chosen
