"""Paged KV block-pool unit tests: alloc/free round-trips, fork refcounts,
copy-on-write triggering exactly on first divergent write, free-list
exhaustion, and leak-free accounting through engine and scheduler runs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.serving.engine import ContinuousScheduler, DecodeEngine, Request
from repro.serving.kv_pool import (KVPool, OutOfBlocks, SCRATCH_BLOCK,
                                   blocks_for, dense_kv_bytes)
from repro.serving.sampler import SamplerConfig

NO_STOP = (9999,)
GREEDY = SamplerConfig(greedy=True)


def paged_engine(params, cfg, tok, *, max_len=64, block_size=8,
                 n_blocks=64):
    """Fresh engine per test: the pool is mutable shared state."""
    return DecodeEngine(params, cfg, max_len=max_len, eos_id=tok.eos_id,
                        pad_id=tok.pad_id, paged=True,
                        block_size=block_size, n_blocks=n_blocks)


def prefill_text(engine, tok, texts, prompt_len=16):
    ids, lens = tok.encode_batch(texts, prompt_len)
    return engine.prefill(jnp.asarray(ids), jnp.asarray(lens))


# ---------------------------------------------------------------------------
# Raw pool mechanics
# ---------------------------------------------------------------------------


def test_alloc_free_roundtrip(tiny_cfg):
    pool = KVPool(tiny_cfg, n_blocks=9, block_size=4)
    assert pool.capacity == 8 and pool.blocks_in_use == 0
    a = pool.alloc(3)
    b = pool.alloc(5)
    assert len(set(a + b)) == 8 and SCRATCH_BLOCK not in a + b
    assert pool.blocks_in_use == 8 and pool.free_blocks == 0
    pool.release(a)
    assert pool.blocks_in_use == 5 and pool.free_blocks == 3
    c = pool.alloc(3)
    assert set(c) == set(a)  # freed ids are reusable
    pool.release(b + c)
    assert pool.blocks_in_use == 0 and pool.free_blocks == 8
    assert (pool.refcount == 0).all()
    assert pool.peak_in_use == 8


def test_retain_release_refcounts(tiny_cfg):
    pool = KVPool(tiny_cfg, n_blocks=5, block_size=4)
    (b,) = pool.alloc(1)
    pool.retain([b], times=3)          # a 4-way fork's shared block
    assert pool.refcount[b] == 4
    for _ in range(3):
        pool.release([b])
        assert pool.blocks_in_use == 1  # still owned
    pool.release([b])
    assert pool.blocks_in_use == 0
    with pytest.raises(ValueError):
        pool.release([b])              # double free
    with pytest.raises(ValueError):
        pool.retain([b])               # retain of unallocated block


def test_free_list_exhaustion_raises(tiny_cfg):
    pool = KVPool(tiny_cfg, n_blocks=4, block_size=4)
    pool.alloc(2)
    with pytest.raises(OutOfBlocks) as e:
        pool.alloc(2)
    assert e.value.needed == 2 and e.value.free == 1
    assert pool.blocks_in_use == 2  # failed alloc took nothing


def test_cow_copies_contents_and_moves_ownership(tiny_cfg):
    pool = KVPool(tiny_cfg, n_blocks=6, block_size=4)
    (b,) = pool.alloc(1)
    pool.k = pool.k.at[:, b].set(7.0)
    pool.retain([b])                    # shared 2 ways
    (nb,) = pool.cow([b])
    assert nb != b
    assert pool.refcount[b] == 1 and pool.refcount[nb] == 1
    np.testing.assert_allclose(np.asarray(pool.k[:, nb]),
                               np.asarray(pool.k[:, b]))
    assert pool.cow_copies == 1
    # exhaustion raises before mutating anything
    pool.alloc(pool.free_blocks)
    rc_before = pool.refcount.copy()
    with pytest.raises(OutOfBlocks):
        pool.cow([b, nb])
    np.testing.assert_array_equal(pool.refcount, rc_before)


def test_blocks_for_and_bytes_accounting(tiny_cfg):
    assert blocks_for(1, 8) == 1 and blocks_for(8, 8) == 1
    assert blocks_for(9, 8) == 2 and blocks_for(17, 8) == 3
    pool = KVPool(tiny_cfg, n_blocks=9, block_size=8)
    # 8 blocks of 8 tokens == one dense row of 64: identical KV bytes
    assert 8 * pool.block_bytes() == dense_kv_bytes(tiny_cfg, 1, 64)


# ---------------------------------------------------------------------------
# Engine-level accounting (fork / CoW / release)
# ---------------------------------------------------------------------------


def test_fork_bumps_refcounts_allocates_zero_blocks(trained_tiny, tiny_cfg,
                                                    tok):
    eng = paged_engine(trained_tiny, tiny_cfg, tok)
    st = prefill_text(eng, tok, ["Q:3+4=?A:"])
    used = eng.pool.blocks_in_use
    table, n_blocks = jax.device_get((st.cache["table"],
                                      st.cache["n_blocks"]))
    st4 = eng.fork(st, 4)
    # the acceptance-criterion assertion: fork allocates no KV blocks
    assert eng.pool.blocks_in_use == used
    for b in table[0, :n_blocks[0]]:
        assert eng.pool.refcount[b] == 4
    # every forked row's table points at the same prompt blocks
    t4 = np.asarray(jax.device_get(st4.cache["table"]))
    for r in range(4):
        np.testing.assert_array_equal(t4[r], table[0])


def test_cow_triggers_exactly_on_first_divergent_write(trained_tiny,
                                                       tiny_cfg, tok):
    eng = paged_engine(trained_tiny, tiny_cfg, tok, block_size=8)
    st = prefill_text(eng, tok, ["Q:3+4=?A:"])  # 10 tokens -> 2 blocks
    plen = int(st.cache_len[0])
    assert plen % 8 != 0, "test needs a shared partial tail block"
    st = eng.fork(st, 2)
    used = eng.pool.blocks_in_use
    # first divergent write: exactly one CoW (one row copies the shared
    # tail, the last owner writes in place), no other allocation
    st, _ = eng.step(st, jax.random.key(0), GREEDY, stop_ids=NO_STOP)
    assert eng.pool.cow_copies == 1
    assert eng.pool.blocks_in_use == used + 1
    # subsequent writes inside the now-private blocks: no further CoW
    in_block = 8 - (plen + 1) % 8
    for i in range(in_block):
        st, _ = eng.step(st, jax.random.key(1 + i), GREEDY,
                         stop_ids=NO_STOP)
    assert eng.pool.cow_copies == 1
    used = eng.pool.blocks_in_use
    # crossing the block boundary allocates fresh blocks, not CoWs
    st, _ = eng.step(st, jax.random.key(99), GREEDY, stop_ids=NO_STOP)
    assert eng.pool.cow_copies == 1
    assert eng.pool.blocks_in_use == used + 2  # one new block per row


def test_block_aligned_fork_allocates_instead_of_cow(trained_tiny, tiny_cfg,
                                                     tok):
    eng = paged_engine(trained_tiny, tiny_cfg, tok, max_len=60,
                       block_size=5)
    st = prefill_text(eng, tok, ["Q:3+4=?A:"])  # 10 tokens: exactly 2 blocks
    assert int(st.cache_len[0]) % 5 == 0
    st = eng.fork(st, 3)
    used = eng.pool.blocks_in_use
    st, _ = eng.step(st, jax.random.key(0), GREEDY, stop_ids=NO_STOP)
    # nothing shared is written: every row opens a fresh block
    assert eng.pool.cow_copies == 0
    assert eng.pool.blocks_in_use == used + 3


def test_release_rows_returns_pool_to_baseline(trained_tiny, tiny_cfg, tok):
    eng = paged_engine(trained_tiny, tiny_cfg, tok)
    st = prefill_text(eng, tok, ["Q:1+2=?A:", "Q:3+4=?A:"])
    st = eng.fork(st, 2)
    st, _ = eng.generate(st, 9, jax.random.key(0), GREEDY, stop_ids=NO_STOP)
    assert eng.pool.blocks_in_use > 0
    st = eng.release_rows(st, [0, 1, 2, 3])
    assert eng.pool.blocks_in_use == 0
    assert (eng.pool.refcount == 0).all()
    # released tables point at scratch only
    assert (np.asarray(jax.device_get(st.cache["table"])) == 0).all()


def test_reorder_releases_dropped_and_retains_duplicated(trained_tiny,
                                                         tiny_cfg, tok):
    eng = paged_engine(trained_tiny, tiny_cfg, tok)
    st = prefill_text(eng, tok, ["Q:1+2=?A:", "Q:3+4=?A:"])
    used = eng.pool.blocks_in_use
    # drop row 1, keep two references to row 0 (beam survivor commit)
    st2 = eng.reorder(st, jnp.array([0, 0]))
    assert eng.pool.blocks_in_use == used // 2
    st2 = eng.release_rows(st2, [0, 1])
    assert eng.pool.blocks_in_use == 0


def test_out_of_blocks_prepare_is_atomic(trained_tiny, tiny_cfg, tok):
    # pool: scratch + 2 blocks -> prompt fits exactly, first decode
    # step needs a third block and must fail without touching the pool
    eng = paged_engine(trained_tiny, tiny_cfg, tok, block_size=8,
                       n_blocks=3)
    st = prefill_text(eng, tok, ["Q:33+44=?A:"])  # 13 tokens -> 2 blocks
    assert eng.pool.free_blocks == 0
    rc = eng.pool.refcount.copy()
    with pytest.raises(OutOfBlocks):
        eng.generate(st, 8, jax.random.key(0), GREEDY, stop_ids=NO_STOP)
    np.testing.assert_array_equal(eng.pool.refcount, rc)
    assert eng.pool.free_blocks == 0


def test_prefill_raises_when_pool_cannot_hold_prompt(trained_tiny, tiny_cfg,
                                                     tok):
    eng = paged_engine(trained_tiny, tiny_cfg, tok, block_size=8,
                       n_blocks=2)  # capacity 1 block
    with pytest.raises(OutOfBlocks):
        prefill_text(eng, tok, ["Q:33+44=?A:"])  # needs 2 blocks
    assert eng.pool.blocks_in_use == 0


# ---------------------------------------------------------------------------
# Scheduler-level accounting
# ---------------------------------------------------------------------------


def test_scheduler_drain_with_prefix_cache_pins_cache_blocks_only(
        trained_tiny, tiny_cfg, tok):
    """Leak check with the cross-request prefix cache attached: after a
    full drain the only live pool references are the radix tree's pins —
    ``refcount == 1`` exactly on the cached block set, zero elsewhere —
    and clearing the cache returns the pool to empty."""
    from repro.serving.prefix_cache import PrefixCache

    eng = paged_engine(trained_tiny, tiny_cfg, tok, max_len=96,
                       block_size=8, n_blocks=97)
    cache = PrefixCache(eng.pool)
    sched = ContinuousScheduler(eng, n_slots=3, prompt_len=48,
                                stop_ids=NO_STOP, prefix_cache=cache)
    header = "Q:1+2=?A:3.Q:4+5=?A:9."
    for i, m in enumerate([7, 3, 9, 5]):
        sched.submit(Request(
            req_id=i, prompt=jnp.asarray(tok.encode(f"{header}Q:{i}+2=?A:")),
            max_new_tokens=m))
    sched.submit(Request(req_id=9,
                         prompt=jnp.asarray(tok.encode(f"{header}Q:5+4=?A:")),
                         max_new_tokens=6, n_samples=3))
    res = sched.run(jax.random.key(0), GREEDY)
    assert set(res) == {0, 1, 2, 3, 9}
    assert sched.metrics.cache_hits > 0
    # pool refcounts == cache-pinned blocks only
    cached = cache.cached_block_ids()
    assert eng.pool.blocks_in_use == len(cached) == cache.n_cached_blocks
    assert all(eng.pool.refcount[b] == 1 for b in cached)
    assert int(eng.pool.refcount.sum()) == len(cached)
    cache.clear()
    assert eng.pool.blocks_in_use == 0
    assert (eng.pool.refcount == 0).all()


def test_scheduler_run_leaves_no_leaked_blocks(trained_tiny, tiny_cfg, tok):
    eng = paged_engine(trained_tiny, tiny_cfg, tok, max_len=64,
                       block_size=8, n_blocks=33)
    sched = ContinuousScheduler(eng, n_slots=3, prompt_len=16,
                                stop_ids=NO_STOP)
    for i, m in enumerate([7, 3, 9, 5]):
        sched.submit(Request(req_id=i,
                             prompt=jnp.asarray(tok.encode(f"Q:{i}+2=?A:")),
                             max_new_tokens=m))
    sched.submit(Request(req_id=9,
                         prompt=jnp.asarray(tok.encode("Q:5+4=?A:")),
                         max_new_tokens=6, n_samples=3))
    res = sched.run(jax.random.key(0), GREEDY)
    assert set(res) == {0, 1, 2, 3, 9}
    # pool accounting returns to baseline after a full drain
    assert eng.pool.blocks_in_use == 0
    assert (eng.pool.refcount == 0).all()
    assert eng.pool.peak_in_use > 0
