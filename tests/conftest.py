import os
import sys

# NOTE: deliberately NOT setting xla_force_host_platform_device_count here —
# smoke tests and benches must see 1 device (dry-run subprocesses set it
# themselves).

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import pytest

from repro.configs.base import ModelConfig
from repro.data.tokenizer import ByteTokenizer


@pytest.fixture(scope="session")
def tok():
    return ByteTokenizer()


@pytest.fixture(scope="session")
def tiny_cfg(tok):
    return ModelConfig(name="tiny", n_layers=2, d_model=64, n_heads=4,
                       n_kv_heads=2, d_ff=192, vocab_size=tok.vocab_size,
                       dtype="float32", param_dtype="float32", remat="none")


@pytest.fixture(scope="session")
def trained_tiny(tok, tiny_cfg):
    """A tiny model trained ~80 steps on the math tasks — enough signal for
    the TTS algorithms to show structure without being perfect."""
    from repro.data.dataset import MathDataLoader
    from repro.models import api
    from repro.train.loop import train_loop
    from repro.train.optimizer import AdamWConfig

    m = api.get_model(tiny_cfg)
    p = m.init_params(jax.random.key(0), tiny_cfg)
    loader = MathDataLoader(tok, batch_size=32, seq_len=64, seed=7,
                            max_terms=2, reasoning=False)
    oc = AdamWConfig(lr=2e-3, warmup_steps=10, total_steps=80)
    p, _ = train_loop(p, tiny_cfg, oc, iter(loader), n_steps=80, log_every=0,
                      log_fn=lambda *_: None)
    loader.close()
    return p
