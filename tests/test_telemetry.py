"""Telemetry tests: deterministic latency derivation under an injected
clock, scheduler event-stream integration (admit/preempt/readmit/release
ordering, beam boundary/freeze/resume), null-tracer parity (zero overhead
when disabled), Chrome-trace export validity, and the summary() latency
keys' robustness on empty drains."""
import itertools
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.serving.engine import (BeamSpec, ContinuousScheduler, DecodeEngine,
                                  Request)
from repro.serving.sampler import SamplerConfig
from repro.serving.telemetry import (Tracer, main, percentile,
                                     validate_chrome_trace)

NO_STOP = (9999,)
GREEDY = SamplerConfig(greedy=True)

# every latency-bearing key summary() must always carry (0.0-safe)
LATENCY_KEYS = ("latency_requests", "ttft_p50", "ttft_p90", "ttft_p99",
                "itl_p50", "itl_p99", "queue_wait_p50", "queue_wait_p99",
                "preempt_delay_s", "step_time_p50", "step_time_p99")


@pytest.fixture(scope="module")
def engine(trained_tiny, tiny_cfg, tok):
    return DecodeEngine(trained_tiny, tiny_cfg, max_len=128,
                        eos_id=tok.eos_id, pad_id=tok.pad_id)


def _req(tok, rid, text, max_new, n_samples=1):
    return Request(req_id=rid, prompt=jnp.asarray(tok.encode(text)),
                   max_new_tokens=max_new, n_samples=n_samples)


def _counting_clock(tick_s=1e-3):
    c = itertools.count()
    return lambda: next(c) * tick_s


class ManualClock:
    """Set ``.t`` before each tracer call to script exact timestamps."""

    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t


# ---------------------------------------------------------------------------
# Tracer unit tests (no scheduler)
# ---------------------------------------------------------------------------


def test_percentile_empty_is_zero():
    assert percentile([], 99) == 0.0
    assert percentile([1.0, 2.0, 3.0], 50) == 2.0


def test_hand_computed_latency_on_scripted_schedule():
    """Three scripted requests; every derived interval is hand-checked."""
    c = ManualClock()
    tr = Tracer(clock=c)  # epoch at t=0

    def at(t, kind, rid, **args):
        c.t = t
        tr.event(kind, rid, **args)

    # req 0: clean life, three tokens
    at(1.0, "enqueue", 0)
    at(2.0, "admit", 0, rows=[0])
    at(3.0, "first_token", 0)
    at(3.0, "token", 0)
    at(5.0, "token", 0)
    at(8.0, "token", 0)
    at(9.0, "release", 0, rows=[0])
    # req 1: preempted mid-flight, token gap spans the requeue wait
    at(1.5, "enqueue", 1)
    at(2.0, "admit", 1, rows=[1])
    at(3.0, "first_token", 1)
    at(3.0, "token", 1)
    at(6.0, "preempt", 1, rows=[1])
    at(7.0, "readmit", 1, rows=[1])
    at(9.0, "token", 1)
    at(10.0, "release", 1, rows=[1])
    # req 2: enqueued, never admitted
    at(4.0, "enqueue", 2)

    r0 = tr.request_latency(0)
    assert r0.queue_wait == 1.0 and r0.ttft == 2.0
    assert r0.gaps == (2.0, 3.0) and r0.itl_mean == 2.5
    assert r0.preempt_delay == 0.0 and r0.e2e == 8.0

    r1 = tr.request_latency(1)
    assert r1.queue_wait == 0.5 and r1.ttft == 1.5
    assert r1.gaps == (6.0,)        # 3.0 -> 9.0 includes the requeue wait
    assert r1.preempt_delay == 1.0  # preempt@6 -> readmit@7
    assert r1.e2e == 8.5

    r2 = tr.request_latency(2)
    assert r2.queue_wait == r2.ttft == r2.e2e == 0.0 and r2.gaps == ()

    with pytest.raises(ValueError, match="no events"):
        tr.request_latency(99)

    trace = tr.to_chrome_trace()
    assert validate_chrome_trace(trace) == []
    # the two admitted requests appear as slot-occupancy slices
    slices = [e for e in trace["traceEvents"]
              if e["ph"] == "X" and e["name"].startswith("req")]
    assert {e["name"] for e in slices} == {"req0", "req1"}


def test_validator_negative_cases():
    assert validate_chrome_trace([]) != []          # not an object
    assert validate_chrome_trace({}) != []          # no traceEvents
    assert "empty" in validate_chrome_trace({"traceEvents": []})[0]

    def one(ev):
        return validate_chrome_trace({"traceEvents": [ev]})

    base = {"name": "x", "ph": "i", "s": "t", "ts": 1.0, "pid": 1, "tid": 0}
    assert one({k: v for k, v in base.items() if k != "pid"})  # missing key
    assert "unknown phase" in one({**base, "ph": "Z"})[0]
    assert "bad ts" in one({**base, "ts": -1.0})[0]
    assert "without non-negative dur" in one({**base, "ph": "X"})[0]
    assert "counter without" in one(
        {**base, "ph": "C", "args": {"note": "nan"}})[0]
    # non-monotone timeline
    bad = validate_chrome_trace({"traceEvents": [
        {**base, "ts": 5.0}, {**base, "ts": 1.0}]})
    assert any("not monotone" in b for b in bad)
    # partially-overlapping spans on one track are unbalanced
    bad = validate_chrome_trace({"traceEvents": [
        {"name": "a", "ph": "X", "ts": 0.0, "dur": 10.0, "pid": 1, "tid": 0},
        {"name": "b", "ph": "X", "ts": 5.0, "dur": 10.0, "pid": 1, "tid": 0},
    ]})
    assert any("partially overlaps" in b for b in bad)
    # nested and disjoint spans are fine
    ok = validate_chrome_trace({"traceEvents": [
        {"name": "a", "ph": "X", "ts": 0.0, "dur": 10.0, "pid": 1, "tid": 0},
        {"name": "b", "ph": "X", "ts": 2.0, "dur": 3.0, "pid": 1, "tid": 0},
        {"name": "c", "ph": "X", "ts": 6.0, "dur": 4.0, "pid": 1, "tid": 0},
        {"name": "d", "ph": "X", "ts": 20.0, "dur": 1.0, "pid": 1, "tid": 0},
    ]})
    assert ok == []


def test_write_and_cli_validate(tmp_path, capsys):
    c = ManualClock()
    tr = Tracer(clock=c)
    c.t = 1.0
    tr.event("enqueue", 0)
    c.t = 2.0
    tr.event("admit", 0, rows=[0])
    c.t = 3.0
    tr.event("release", 0, rows=[0])
    tr.gauge("occupancy", 1)
    path = str(tmp_path / "trace.json")
    tr.write_chrome_trace(path)
    assert validate_chrome_trace(json.load(open(path))) == []
    assert main([path]) == 0
    assert "OK" in capsys.readouterr().out

    bad_path = str(tmp_path / "bad.json")
    json.dump({"traceEvents": [{"name": "x", "ph": "X", "ts": -4.0,
                                "pid": 1, "tid": 0}]}, open(bad_path, "w"))
    assert main([bad_path]) == 1
    assert main([str(tmp_path / "missing.json")]) == 1
    assert main([]) == 2


# ---------------------------------------------------------------------------
# Scheduler integration
# ---------------------------------------------------------------------------

_REQS = [("Q:2+7=?A:", 7), ("Q:1+1=?A:", 2), ("Q:9+9=?A:", 5),
         ("Q:4+5=?A:", 3)]


def _run(engine, tok, tracer):
    sched = ContinuousScheduler(engine, n_slots=2, prompt_len=16,
                                stop_ids=NO_STOP, tracer=tracer)
    for i, (text, max_new) in enumerate(_REQS):
        sched.submit(_req(tok, i, text, max_new))
    res = sched.run(jax.random.key(0), GREEDY)
    return res, sched


def test_null_tracer_parity_and_golden_summary_keys(engine, tok):
    """tracer=None (the default) must change nothing: bit-identical
    outputs vs a traced run, and summary() still carries every latency
    key (0.0 where only the tracer could fill it in)."""
    res_off, sched_off = _run(engine, tok, None)
    res_on, sched_on = _run(engine, tok, Tracer())
    assert res_off == res_on
    s = sched_off.metrics.summary()
    for k in LATENCY_KEYS:
        assert k in s, f"summary() lost key {k}"
    assert s["latency_requests"] == 0
    assert s["ttft_p50"] == s["itl_p99"] == s["queue_wait_p99"] == 0.0
    # step_time_* comes from StepRecord.wall_s — no tracer needed
    assert s["step_time_p99"] >= s["step_time_p50"] > 0.0
    assert sched_on.metrics.summary()["latency_requests"] == len(_REQS)


def test_summary_safe_on_empty_drain(engine):
    """admitted == 0: every dividing key must come back 0.0, not raise."""
    sched = ContinuousScheduler(engine, n_slots=2, prompt_len=16,
                                stop_ids=NO_STOP)
    assert sched.run(jax.random.key(0), GREEDY) == {}
    s = sched.metrics.summary()
    for k in LATENCY_KEYS:
        assert s[k] == 0, f"{k} != 0 on an empty drain"


def test_traced_run_deterministic_under_injected_clock(engine, tok):
    """Two identical runs under identical fake clocks produce identical
    event streams, spans and latency records — exact equality, no
    wall-clock in sight."""
    runs = []
    for _ in range(2):
        tr = Tracer(clock=_counting_clock())
        _run(engine, tok, tr)
        runs.append(tr)
    a, b = runs
    key = lambda e: (e.kind, e.t, e.req_id, e.step, sorted(e.args.items()))
    assert [key(e) for e in a.events] == [key(e) for e in b.events]
    assert ([(s.name, s.t0, s.t1, s.step) for s in a.spans]
            == [(s.name, s.t0, s.t1, s.step) for s in b.spans])
    assert ([a.request_latency(i) for i in range(len(_REQS))]
            == [b.request_latency(i) for i in range(len(_REQS))])
    assert a.to_chrome_trace() == b.to_chrome_trace()


def test_lifecycle_event_ordering(engine, tok):
    tr = Tracer(clock=_counting_clock())
    _, sched = _run(engine, tok, tr)
    for rid in range(len(_REQS)):
        evs = tr.request_events(rid)
        kinds = [e.kind for e in evs]
        assert kinds[0] == "enqueue" and kinds[-1] == "release"
        assert kinds.index("admit") < kinds.index("first_token")
        assert kinds.index("first_token") <= kinds.index("token")
        ts = [e.t for e in evs]
        assert ts == sorted(ts), f"req {rid}: event times not monotone"
        lat = tr.request_latency(rid)
        assert lat.e2e >= lat.ttft >= lat.queue_wait >= 0
        # max_new tokens -> max_new - 1 inter-token gaps
        assert len(lat.gaps) == _REQS[rid][1] - 1
    # every step span contains its admit/decode spans (the final drain
    # step can record an admit span and bail before its step span when
    # nothing was live — that admit is legitimately top-level)
    steps = {s.step: s for s in tr.spans if s.name == "step"}
    for sp in tr.spans:
        if sp.name in ("admit", "decode") and sp.step in steps:
            outer = steps[sp.step]
            assert outer.t0 <= sp.t0 and sp.t1 <= outer.t1
    assert any(sp.step in steps for sp in tr.spans
               if sp.name in ("admit", "decode"))
    assert validate_chrome_trace(tr.to_chrome_trace()) == []


def test_preemption_events_and_delay(trained_tiny, tiny_cfg, tok):
    """A starved paged pool: preempt/readmit land in the event stream in
    order, first_token re-arms for the rerun, and the derived
    preempt_delay is positive."""
    eng = DecodeEngine(trained_tiny, tiny_cfg, max_len=64,
                       eos_id=tok.eos_id, pad_id=tok.pad_id, paged=True,
                       block_size=8, n_blocks=8)
    tr = Tracer(clock=_counting_clock())
    sched = ContinuousScheduler(eng, n_slots=3, prompt_len=16,
                                stop_ids=NO_STOP, tracer=tr)
    reqs = [("Q:2+7=?A:", 12), ("Q:1+1=?A:", 6), ("Q:9+9=?A:", 10),
            ("Q:4+5=?A:", 8)]
    for i, (text, max_new) in enumerate(reqs):
        sched.submit(_req(tok, i, text, max_new))
    res = sched.run(jax.random.key(0), GREEDY)
    assert set(res) == set(range(len(reqs)))
    assert sched.metrics.preemptions > 0
    preempted = [rid for rid in range(len(reqs))
                 if any(e.kind == "preempt" for e in tr.request_events(rid))]
    assert preempted, "pool starvation produced no preempt events"
    for rid in preempted:
        kinds = [e.kind for e in tr.request_events(rid)]
        i_pre = kinds.index("preempt")
        assert "readmit" in kinds[i_pre:], "no readmit after preempt"
        # the rerun decodes its first token afresh
        assert kinds.count("first_token") == 1 + kinds[:i_pre].count(
            "first_token")
        assert kinds[-1] == "release"
        lat = tr.request_latency(rid)
        assert lat.preempt_delay > 0
    s = sched.metrics.summary()
    assert s["preempt_delay_s"] > 0
    assert s["latency_requests"] == len(reqs)
    # free_blocks gauge tracked the pool on every step
    free = [g for g in tr.gauges if g.name == "free_blocks"]
    assert len(free) == sched.metrics.summary()["steps"]
    assert validate_chrome_trace(tr.to_chrome_trace()) == []


def test_beam_request_trace(trained_tiny, tiny_cfg, tok):
    """A beam (tree) request's trace carries freeze / beam_boundary /
    resume events and closes with a reason='beam' release."""
    eng = DecodeEngine(trained_tiny, tiny_cfg, max_len=64,
                       eos_id=tok.eos_id, pad_id=tok.pad_id, paged=True,
                       block_size=8, n_blocks=33)
    tr = Tracer(clock=_counting_clock())
    sched = ContinuousScheduler(eng, n_slots=4, prompt_len=16,
                                stop_ids=NO_STOP, tracer=tr)
    # delimiter '4' on this prompt: some lanes emit it mid-step (they
    # freeze and wait), others run to the step budget — both paths to a
    # boundary appear in the trace
    stop = int(tok.encode("4", bos=False)[0])
    spec = BeamSpec(width=2, expand=2, step_tokens=4, max_steps=2,
                    step_stop_id=stop,
                    score=lambda tl, lp, ng: np.asarray(lp)
                    / np.maximum(np.asarray(ng), 1))
    sched.submit(Request(req_id=0,
                         prompt=jnp.asarray(tok.encode("Q:12+34=?A:")),
                         search=spec))
    res = sched.run(jax.random.key(0), GREEDY)
    assert 0 in res
    kinds = [e.kind for e in tr.request_events(0)]
    for kind in ("freeze", "beam_boundary", "resume"):
        assert kind in kinds, f"beam trace missing {kind}"
    assert kinds.count("beam_boundary") == spec.max_steps
    rel = [e for e in tr.request_events(0) if e.kind == "release"]
    assert len(rel) == 1 and rel[0].args["reason"] == "beam"
    # boundaries happen between freezes and resumes, in time order
    t = {k: next(e.t for e in tr.request_events(0) if e.kind == k)
         for k in ("freeze", "beam_boundary", "resume")}
    assert t["freeze"] <= t["beam_boundary"] <= t["resume"]
    assert tr.request_latency(0).e2e > 0
    assert any(s.name == "prm" for s in tr.spans)
    assert validate_chrome_trace(tr.to_chrome_trace()) == []


def test_step_once_wall_time_is_per_step(engine, tok):
    """Satellite: wall_s is measured inside step_once (covers submit-
    while-stepping drains), every record carries its own share, and the
    total is their sum."""
    _, sched = _run(engine, tok, None)
    recs = sched.metrics.records
    assert recs and all(r.wall_s > 0 for r in recs)
    assert sched.metrics.wall_s == pytest.approx(
        sum(r.wall_s for r in recs))
