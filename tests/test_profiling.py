"""KernelProfiler tests: deterministic sampling/attribution under an
injected clock, trace-time roster caching and replay, null-profiler
parity (profiler=None must be bit-identical to pre-profiler behavior,
mirroring tests/test_telemetry.py's null-tracer contract), the greedy-q8
canary's zero-drift guarantee, and report schema validation + CLI."""
import itertools
import json

import jax
import jax.numpy as jnp
import pytest

from repro.kernels import ops
from repro.serving.engine import ContinuousScheduler, DecodeEngine, Request
from repro.serving.profiling import (NULL_PROFILE_METRICS, SCHEMA,
                                     KernelProfiler, _interval, main,
                                     validate_profile_report)
from repro.serving.sampler import SamplerConfig

NO_STOP = (9999,)
GREEDY = SamplerConfig(greedy=True)


def _counting_clock(tick_s=1e-3):
    c = itertools.count()
    return lambda: next(c) * tick_s


def _paged_engine(params, cfg, tok, kv_quant="q8"):
    return DecodeEngine(params, cfg, max_len=64, eos_id=tok.eos_id,
                        pad_id=tok.pad_id, paged=True, block_size=8,
                        n_blocks=33, kv_quant=kv_quant)


_REQS = [("Q:2+7=?A:", 6), ("Q:1+1=?A:", 3), ("Q:9+9=?A:", 5)]


def _run(engine, tok, profiler):
    sched = ContinuousScheduler(engine, n_slots=2, prompt_len=16,
                                stop_ids=NO_STOP, profiler=profiler)
    for i, (text, max_new) in enumerate(_REQS):
        sched.submit(Request(req_id=i,
                             prompt=jnp.asarray(tok.encode(text)),
                             max_new_tokens=max_new))
    try:
        res = sched.run(jax.random.key(0), GREEDY)
    finally:
        if profiler is not None:
            profiler.uninstall()
    return res, sched


# ---------------------------------------------------------------------------
# Unit tests (no scheduler)
# ---------------------------------------------------------------------------


def test_interval_schedule():
    assert _interval(0.0) == 0 and _interval(-1.0) == 0
    assert _interval(1.0) == 1
    assert _interval(0.25) == 4
    assert _interval(1.0 / 3.0) == 3
    assert _interval(5.0) == 1  # rates clamp to "every step"


def test_roster_replay_and_wall_attribution():
    """The op hook fires at trace time only; later phase_end calls with
    an empty trace buffer must replay the cached roster, and sampled
    phase walls (injected clock) attribute across ops by bound share."""
    prof = KernelProfiler(sample_rate=1.0, clock=_counting_clock())
    prof.begin_step()
    t0 = prof.phase_begin("decode")
    prof.record_op("flash_attention", 1e6, 1e3)   # "traces" on call 1
    prof.phase_end("decode", t0, outputs=jnp.zeros(()))
    prof.end_step(1.0)
    for _ in range(2):  # cached-executable calls: no hook, roster replays
        prof.begin_step()
        t0 = prof.phase_begin("decode")
        prof.phase_end("decode", t0, outputs=jnp.zeros(()))
        prof.end_step(1.0)
    rep = prof.report()
    op = rep["kernels"]["flash_attention"]
    assert op["calls"] == 3
    assert op["flops"] == pytest.approx(3e6)
    assert rep["phases"]["decode"]["calls"] == 3
    assert rep["phases"]["decode"]["sampled"] == 3
    # the single op gets the whole sampled wall
    assert op["wall_s"] == pytest.approx(rep["phases"]["decode"]["wall_s"])
    assert op["efficiency"] > 0
    assert rep["breakdown"] == {"softmax": pytest.approx(1.0)}
    assert validate_profile_report(rep) == []


def test_sampling_interval_respected():
    """sample_rate=0.5 -> every 2nd step blocks and records a wall; the
    analytic totals still cover every step."""
    prof = KernelProfiler(sample_rate=0.5, clock=_counting_clock())
    for step in range(4):
        prof.begin_step()
        t0 = prof.phase_begin("decode")
        if step == 0:
            prof.record_op("flash_attention", 1e6, 1e3)
        prof.phase_end("decode", t0, outputs=jnp.zeros(()))
        prof.end_step(1.0)
    rep = prof.report()
    assert rep["steps"] == 4 and rep["sampled_steps"] == 2
    assert rep["phases"]["decode"]["sampled"] == 2
    assert rep["kernels"]["flash_attention"]["calls"] == 4
    s = prof.summary_metrics()
    assert s["profiled_steps"] == 2


def test_ops_outside_phase_land_untimed():
    prof = KernelProfiler(clock=_counting_clock())
    prof.record_op("tile_quantize", 1e6, 1e3)
    rep = prof.report()
    assert rep["kernels"]["tile_quantize"]["calls"] == 1
    assert rep["phases"]["untimed"]["bound_s"] > 0
    assert validate_profile_report(rep) == []


def test_canary_thresholds_warn():
    prof = KernelProfiler(canary_rate=1.0, clock=_counting_clock(),
                          logit_err_warn=0.05, flip_rate_warn=0.01,
                          kv_err_warn=0.25)
    prof.record_canary(max_logit_err=0.2, flips=3, rows=4,
                       kv_err_per_layer=[0.1, 0.5])
    assert any("logit error" in w for w in prof.warnings)
    assert any("flip rate" in w for w in prof.warnings)
    assert any("round-trip" in w for w in prof.warnings)
    s = prof.summary_metrics()
    assert s["canary_max_logit_err"] == pytest.approx(0.2)
    assert s["canary_argmax_flip_rate"] == pytest.approx(0.75)
    assert s["canary_kv_roundtrip_err"] == pytest.approx(0.5)
    rep = prof.report()
    assert rep["canary"]["warnings"] == prof.warnings
    assert validate_profile_report(rep) == []


def test_install_uninstall_restores_previous_hook():
    seen = []
    prev = ops.set_op_hook(lambda *a: seen.append(a))
    try:
        prof = KernelProfiler()
        prof.install()
        ops.record_op("flash_attention", 1.0, 1.0)
        assert prof._ops and not seen  # profiler intercepts
        prof.uninstall()
        ops.record_op("flash_attention", 1.0, 1.0)
        assert len(seen) == 1  # previous hook restored
    finally:
        ops.set_op_hook(prev)


# ---------------------------------------------------------------------------
# Report schema validation + CLI
# ---------------------------------------------------------------------------


def test_validator_negative_cases():
    assert validate_profile_report([]) != []
    assert any("schema" in b for b in validate_profile_report({}))
    rep = KernelProfiler(clock=_counting_clock()).report()
    assert validate_profile_report(rep) == []
    assert rep["schema"] == SCHEMA
    bad = dict(rep)
    del bad["canary"]
    assert any("missing top-level" in b for b in validate_profile_report(bad))
    bad = json.loads(json.dumps(rep))
    bad["kernels"]["x"] = {"calls": 1}
    assert any("kernel x" in b for b in validate_profile_report(bad))
    bad = json.loads(json.dumps(rep))
    bad["breakdown"] = {"softmax": 0.9, "dequant": 0.9}
    assert any("sum" in b for b in validate_profile_report(bad))
    bad = json.loads(json.dumps(rep))
    bad["summary"]["kernel_time_share"] = "high"
    assert any("kernel_time_share" in b for b in validate_profile_report(bad))
    bad = json.loads(json.dumps(rep))
    bad["canary"]["kv_roundtrip_err_per_layer"] = ["broken"]
    assert any("kv_roundtrip" in b for b in validate_profile_report(bad))


def test_write_report_and_cli(tmp_path, capsys):
    prof = KernelProfiler(clock=_counting_clock())
    prof.begin_step()
    t0 = prof.phase_begin("decode")
    prof.record_op("flash_attention", 1e6, 1e3)
    prof.phase_end("decode", t0, outputs=jnp.zeros(()))
    prof.end_step(1.0)
    path = str(tmp_path / "profile.json")
    prof.write_report(path)
    assert validate_profile_report(json.load(open(path))) == []
    assert main([path]) == 0
    assert "OK" in capsys.readouterr().out
    bad_path = str(tmp_path / "bad.json")
    json.dump({"schema": "nope"}, open(bad_path, "w"))
    assert main([bad_path]) == 1
    assert main([str(tmp_path / "missing.json")]) == 1
    assert main([]) == 2


def test_write_report_refuses_invalid(tmp_path, monkeypatch):
    prof = KernelProfiler(clock=_counting_clock())
    monkeypatch.setattr(prof, "report",
                        lambda: {"schema": "wrong"})
    with pytest.raises(ValueError, match="refusing"):
        prof.write_report(str(tmp_path / "never.json"))
    assert not (tmp_path / "never.json").exists()


# ---------------------------------------------------------------------------
# Scheduler integration
# ---------------------------------------------------------------------------


def test_null_profiler_bit_parity(trained_tiny, tiny_cfg, tok):
    """profiler=None (the default) must change nothing: bit-identical
    outputs vs a profiled-with-canary run, and summary() carries exactly
    the NULL_PROFILE_METRICS zeros (the stable-key-set contract) — the
    same shape of guarantee test_telemetry.py pins for tracer=None."""
    res_off, sched_off = _run(
        _paged_engine(trained_tiny, tiny_cfg, tok), tok, None)
    prof = KernelProfiler(sample_rate=1.0, canary_rate=0.5)
    res_on, sched_on = _run(
        _paged_engine(trained_tiny, tiny_cfg, tok), tok, prof)
    assert res_off == res_on, \
        "profiling changed scheduler outputs (parity violation)"
    s_off = sched_off.metrics.summary()
    for k, v in NULL_PROFILE_METRICS.items():
        assert s_off[k] == v, f"null summary key {k} != {v}"
    s_on = sched_on.metrics.summary()
    assert s_on["profiled_steps"] > 0
    assert s_on["canary_samples"] > 0
    assert set(NULL_PROFILE_METRICS) <= set(s_on)


def test_profiled_run_deterministic_under_injected_clock(trained_tiny,
                                                         tiny_cfg, tok):
    """Two profiled runs on fresh engines under identical fake clocks
    produce byte-identical reports — every wall, efficiency and canary
    gauge derives from the injected clock and the deterministic
    every-Nth-step schedules, never the host wall clock."""
    reps = []
    for _ in range(2):
        prof = KernelProfiler(sample_rate=0.5, canary_rate=0.5,
                              clock=_counting_clock())
        _run(_paged_engine(trained_tiny, tiny_cfg, tok), tok, prof)
        reps.append(prof.report())
    assert json.dumps(reps[0], sort_keys=True) == \
        json.dumps(reps[1], sort_keys=True)
    assert validate_profile_report(reps[0]) == []
    assert reps[0]["sampled_steps"] > 0
    assert reps[0]["kernels"], "no kernels attributed"


def test_canary_zero_drift_under_greedy_q8(trained_tiny, tiny_cfg, tok):
    """Under the default XLA paged-attention impl the canary's exact
    path IS the production path, so greedy q8 decode must show zero
    argmax flips and zero logit error; the KV round-trip gauge covers
    every layer."""
    prof = KernelProfiler(sample_rate=1.0, canary_rate=1.0)
    _, sched = _run(_paged_engine(trained_tiny, tiny_cfg, tok), tok, prof)
    rep = prof.report()
    assert rep["canary"]["samples"] > 0 and rep["canary"]["rows"] > 0
    assert rep["canary"]["flips"] == 0
    assert rep["canary"]["max_logit_err"] == 0.0
    assert rep["canary"]["warnings"] == []
    errs = rep["canary"]["kv_roundtrip_err_per_layer"]
    assert len(errs) == tiny_cfg.n_layers
    assert all(e >= 0.0 for e in errs)
    s = sched.metrics.summary()
    assert s["canary_argmax_flip_rate"] == 0.0
    assert s["canary_max_logit_err"] == 0.0
    # attribution ran alongside: the decode phase carries an op roster
    assert rep["phases"]["decode"]["bound_s"] > 0
    assert any(op["calls"] > 0 for op in rep["kernels"].values())


def test_profiler_attributes_decode_kernels(trained_tiny, tiny_cfg, tok):
    """A fully-sampled paged run attributes the paged-attention dispatch
    with nonzero analytic cost, measured wall and efficiency, and the
    scheduler summary's kernel_time_share lands in (0, 1]."""
    prof = KernelProfiler(sample_rate=1.0, canary_rate=0.0)
    _, sched = _run(_paged_engine(trained_tiny, tiny_cfg, tok), tok, prof)
    rep = prof.report()
    assert "paged_attention_xla" in rep["kernels"]
    op = rep["kernels"]["paged_attention_xla"]
    assert op["calls"] > 0 and op["flops"] > 0 and op["hbm_bytes"] > 0
    assert op["wall_s"] > 0 and op["efficiency"] > 0
    assert op["category"] == "softmax"
    s = sched.metrics.summary()
    assert 0.0 < s["kernel_time_share"] <= 1.0
    assert s["roofline_efficiency_p50"] > 0
    assert abs(sum(rep["breakdown"].values()) - 1.0) < 1e-6
