"""Continuous-batching scheduler tests: continuous admission, slot-reuse
correctness against per-request generate, fork-shared TTS admission,
step-level metrics, and paged-KV block budgeting (out-of-blocks
preemption)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import reward as R
from repro.core.controller import TTSSpec, serve_best_of_n, sweep
from repro.data import tasks as T
from repro.serving.engine import ContinuousScheduler, DecodeEngine, Request
from repro.serving.sampler import SamplerConfig

# a token id no sampler can produce (vocab 320): requests run to their
# max_new_tokens budget, making slot-lifecycle timing deterministic
NO_STOP = (9999,)
GREEDY = SamplerConfig(greedy=True)


@pytest.fixture(scope="module")
def engine(trained_tiny, tiny_cfg, tok):
    return DecodeEngine(trained_tiny, tiny_cfg, max_len=128,
                        eos_id=tok.eos_id, pad_id=tok.pad_id)


def _req(tok, rid, text, max_new, n_samples=1):
    return Request(req_id=rid, prompt=jnp.asarray(tok.encode(text)),
                   max_new_tokens=max_new, n_samples=n_samples)


def _reference_tokens(engine, tok, text, max_new, prompt_len=16):
    """Per-request greedy DecodeEngine run with the scheduler's padding."""
    ids = tok.encode(text)
    padded = jnp.full((prompt_len,), engine.pad_id, jnp.int32)
    padded = padded.at[: len(ids)].set(jnp.asarray(ids))
    st = engine.prefill(padded[None], jnp.array([len(ids)], jnp.int32))
    _, out = engine.generate(st, max_new, jax.random.key(0), GREEDY,
                             stop_ids=NO_STOP)
    return out[0].tolist()


def test_late_request_admitted_before_long_request_finishes(engine, tok):
    """True continuous admission: a request submitted *after* decoding has
    started lands in a freed slot and finishes while an earlier long
    request is still decoding."""
    sched = ContinuousScheduler(engine, n_slots=2, prompt_len=16,
                                stop_ids=NO_STOP)
    sched.submit(_req(tok, 0, "Q:7+5=?A:", max_new=20))   # long
    sched.submit(_req(tok, 1, "Q:1+1=?A:", max_new=2))    # short
    rng = jax.random.key(0)
    for _ in range(3):  # short request finishes at step 2
        rng, k = jax.random.split(rng)
        assert sched.step_once(k, GREEDY)
    assert 1 in sched.completed and 0 not in sched.completed
    sched.submit(_req(tok, 2, "Q:2+2=?A:", max_new=3))    # late arrival
    sched.run(rng, GREEDY)

    late = sched.completed[2][0]
    long_ = sched.completed[0][0]
    # the late request started decoding — and finished — while the long
    # request was still occupying its slot
    assert late.first_decode_step < long_.finished_step
    assert late.finished_step < long_.finished_step
    # it really decoded alongside the long request (occupancy 2 that step)
    rec = sched.metrics.records[late.first_decode_step]
    assert rec.occupancy == 2


def test_queued_request_fills_freed_slot_mid_drain(engine, tok):
    """With 2 slots and 3 requests, the 3rd (queued at submit time) starts
    decoding in the short request's freed slot before the long one ends."""
    sched = ContinuousScheduler(engine, n_slots=2, prompt_len=16,
                                stop_ids=NO_STOP)
    sched.submit(_req(tok, 0, "Q:8+4=?A:", max_new=16))
    sched.submit(_req(tok, 1, "Q:1+2=?A:", max_new=2))
    sched.submit(_req(tok, 2, "Q:3+3=?A:", max_new=3))
    res = sched.run(jax.random.key(0), GREEDY)
    assert set(res) == {0, 1, 2}
    assert (sched.completed[2][0].first_decode_step
            < sched.completed[0][0].finished_step)


def test_slot_reuse_matches_per_request_generate(engine, tok):
    """Token streams through churned slots equal standalone greedy
    DecodeEngine.generate runs — slot reuse never leaks state."""
    sched = ContinuousScheduler(engine, n_slots=2, prompt_len=16,
                                stop_ids=NO_STOP)
    reqs = [("Q:2+7=?A:", 7), ("Q:1+1=?A:", 2), ("Q:9+9=?A:", 5),
            ("Q:4+5=?A:", 3), ("Q:8+2=?A:", 6)]
    for i, (text, max_new) in enumerate(reqs):
        sched.submit(_req(tok, i, text, max_new))
    res = sched.run(jax.random.key(0), GREEDY)
    assert set(res) == set(range(len(reqs)))
    for i, (text, max_new) in enumerate(reqs):
        ref = _reference_tokens(engine, tok, text, max_new)
        assert res[i] == ref, f"req {i}: {res[i]} != {ref}"


def test_tts_group_prefills_once_and_forks(engine, tok):
    """N samples of one prompt = exactly one prefill; greedy fork produces
    identical streams matching the plain request's stream."""
    sched = ContinuousScheduler(engine, n_slots=4, prompt_len=16,
                                stop_ids=NO_STOP)
    sched.submit(_req(tok, 0, "Q:5+4=?A:", max_new=5, n_samples=4))
    res = sched.run(jax.random.key(0), GREEDY)
    assert sched.n_prefills == 1
    assert len(res[0]) == 4
    ref = _reference_tokens(engine, tok, "Q:5+4=?A:", 5)
    for stream in res[0]:
        assert stream == ref


def test_tts_group_waits_for_enough_slots(engine, tok):
    """A Best-of-4 group behind a single in 2 free slots waits (FIFO) but
    eventually runs; groups larger than n_slots are rejected at submit."""
    sched = ContinuousScheduler(engine, n_slots=4, prompt_len=16,
                                stop_ids=NO_STOP)
    sched.submit(_req(tok, 0, "Q:1+5=?A:", max_new=6))
    sched.submit(_req(tok, 1, "Q:2+5=?A:", max_new=6))
    sched.submit(_req(tok, 2, "Q:3+5=?A:", max_new=4, n_samples=4))
    res = sched.run(jax.random.key(0), GREEDY)
    assert set(res) == {0, 1, 2} and len(res[2]) == 4
    with pytest.raises(ValueError):
        sched.submit(_req(tok, 9, "Q:0+0=?A:", max_new=2, n_samples=5))


def test_submit_rejects_over_budget_and_run_reports_truncation(engine, tok):
    """A request whose prompt + max_new_tokens would spill into the KV
    scratch slot is rejected at submit; a drain that hits max_steps raises
    instead of silently returning partial results."""
    sched = ContinuousScheduler(engine, n_slots=2, prompt_len=16,
                                stop_ids=NO_STOP)
    with pytest.raises(ValueError):  # engine.max_len == 128
        sched.submit(_req(tok, 0, "Q:1+1=?A:", max_new=128))
    with pytest.raises(ValueError):  # zero-token requests are rejected
        sched.submit(_req(tok, 7, "Q:1+1=?A:", max_new=0))
    sched.submit(_req(tok, 1, "Q:1+1=?A:", max_new=10))
    with pytest.raises(ValueError):  # req_id reuse would corrupt results
        sched.submit(_req(tok, 1, "Q:2+2=?A:", max_new=4))
    with pytest.raises(RuntimeError):
        sched.run(jax.random.key(0), GREEDY, max_steps=3)
    # the drain is resumable: finishing it yields the full stream
    res = sched.run(jax.random.key(1), GREEDY)
    assert len(res[1]) == 10


def test_same_step_plain_admissions_share_one_prefill(engine, tok):
    """Plain requests admitted in the same step are batched into a single
    prefill; trickle-in admissions prefill separately."""
    sched = ContinuousScheduler(engine, n_slots=4, prompt_len=16,
                                stop_ids=NO_STOP)
    for i in range(4):
        sched.submit(_req(tok, i, f"Q:{i}+2=?A:", max_new=2 + i))
    sched.submit(_req(tok, 9, "Q:9+9=?A:", max_new=2))
    res = sched.run(jax.random.key(0), GREEDY)
    # step 0 admits reqs 0-3 as one batch; req 9 lands alone in a freed slot
    assert sched.n_prefills == 2
    assert set(res) == {0, 1, 2, 3, 9}
    for i in range(4):
        assert res[i] == _reference_tokens(engine, tok, f"Q:{i}+2=?A:", 2 + i)


def test_eos_releases_slot_and_is_excluded(engine, tok):
    """Default stop (EOS): a trained row that emits EOS releases its slot
    with finish_reason 'stop' and the stop token is excluded."""
    sched = ContinuousScheduler(engine, n_slots=2, prompt_len=16)
    sched.submit(_req(tok, 0, "Q:3+4=?A:", max_new=30))
    res = sched.run(jax.random.key(0), GREEDY)
    sample = sched.completed[0][0]
    assert tok.eos_id not in res[0]
    if sample.finish_reason == "stop":
        assert len(res[0]) < 30


def test_metrics_track_occupancy_and_throughput(engine, tok):
    sched = ContinuousScheduler(engine, n_slots=2, prompt_len=16,
                                stop_ids=NO_STOP)
    for i in range(3):
        sched.submit(_req(tok, i, f"Q:{i}+1=?A:", max_new=3))
    sched.run(jax.random.key(0), GREEDY)
    s = sched.metrics.summary()
    assert s["completed_requests"] == 3
    assert s["decode_tokens"] == sum(r.occupancy for r in
                                     sched.metrics.records)
    assert 0.0 < s["avg_slot_occupancy"] <= 1.0
    assert s["requests_per_s"] > 0
    assert s["prefill_tokens"] > 0
    # per-step decode never exceeds the slot count
    assert all(r.occupancy <= 2 for r in sched.metrics.records)


def test_scheduler_drains_interleaved_queue(engine, tok):
    """Seed regression: a queue larger than n_slots fully drains."""
    sched = ContinuousScheduler(engine, n_slots=2, prompt_len=16)
    for i in range(3):
        sched.submit(_req(tok, i, f"Q:{i}+1=?A:", max_new=4))
    res = sched.run(jax.random.key(0))
    assert set(res) == {0, 1, 2}


def test_controller_continuous_best_of_n(engine, tok):
    """Best-of-N sweeps run through the scheduler and report serving
    metrics alongside accuracy."""
    tasks = T.gen_dataset(41, 4, reasoning=False, max_terms=2)
    row = serve_best_of_n(engine, tok, tasks, n=4, max_tokens=10,
                          rng=jax.random.key(0), scorer=R.OracleVerifier(),
                          n_slots=8)
    assert 0.0 <= row["accuracy"] <= 1.0
    assert row["decode_tokens"] > 0
    assert row["serving"]["completed_requests"] == 4
    assert row["serving"]["avg_slot_occupancy"] > 0

    rows = sweep(engine, tok, tasks,
                 [TTSSpec(method="best_of_n", budget=2, max_tokens=8)],
                 jax.random.key(1), R.OracleVerifier(), continuous=True)
    assert "serving" in rows[0]
    assert 0.0 <= rows[0]["accuracy"] <= 1.0


def test_logprob_scorer_through_scheduler(engine, tok):
    """The LogProbScorer path scores from per-slot decode statistics."""
    tasks = T.gen_dataset(43, 2, reasoning=False, max_terms=2)
    row = serve_best_of_n(engine, tok, tasks, n=2, max_tokens=8,
                          rng=jax.random.key(0), scorer=R.LogProbScorer(),
                          n_slots=4)
    assert 0.0 <= row["accuracy"] <= 1.0


# ---------------------------------------------------------------------------
# Paged KV: block-budget admission and out-of-blocks preemption
# ---------------------------------------------------------------------------


def _paged_engine(trained_tiny, tiny_cfg, tok, n_blocks):
    return DecodeEngine(trained_tiny, tiny_cfg, max_len=64,
                        eos_id=tok.eos_id, pad_id=tok.pad_id, paged=True,
                        block_size=8, n_blocks=n_blocks)


_PAGED_REQS = [("Q:2+7=?A:", 12), ("Q:1+1=?A:", 6), ("Q:9+9=?A:", 10),
               ("Q:4+5=?A:", 8)]


def test_tiny_pool_preempts_but_completes_everything(trained_tiny, tiny_cfg,
                                                     tok, engine):
    """A deliberately starved pool forces out-of-blocks preemption; every
    request still completes with the same greedy tokens as the dense
    reference, and the preemption count is reported in the metrics."""
    eng = _paged_engine(trained_tiny, tiny_cfg, tok, n_blocks=8)
    sched = ContinuousScheduler(eng, n_slots=3, prompt_len=16,
                                stop_ids=NO_STOP)
    for i, (text, max_new) in enumerate(_PAGED_REQS):
        sched.submit(_req(tok, i, text, max_new))
    res = sched.run(jax.random.key(0), GREEDY)
    assert set(res) == set(range(len(_PAGED_REQS)))
    assert sched.metrics.preemptions > 0
    assert sched.metrics.summary()["preemptions"] == \
        sched.metrics.preemptions
    # preempted requests rerun from scratch: outputs stay deterministic
    for i, (text, max_new) in enumerate(_PAGED_REQS):
        ref = _reference_tokens(engine, tok, text, max_new)
        assert res[i] == ref, f"req {i}: {res[i]} != {ref}"
    # nothing leaked despite the preemption churn
    assert eng.pool.blocks_in_use == 0
    assert sched.metrics.completed_requests == len(_PAGED_REQS)


def test_roomy_pool_matches_dense_without_preemption(trained_tiny, tiny_cfg,
                                                     tok, engine):
    eng = _paged_engine(trained_tiny, tiny_cfg, tok, n_blocks=64)
    sched = ContinuousScheduler(eng, n_slots=3, prompt_len=16,
                                stop_ids=NO_STOP)
    for i, (text, max_new) in enumerate(_PAGED_REQS):
        sched.submit(_req(tok, i, text, max_new))
    res = sched.run(jax.random.key(0), GREEDY)
    assert sched.metrics.preemptions == 0
    for i, (text, max_new) in enumerate(_PAGED_REQS):
        assert res[i] == _reference_tokens(engine, tok, text, max_new)
    assert eng.pool.blocks_in_use == 0


def test_paged_tts_group_preempted_mid_flight_reruns_all_samples(
        trained_tiny, tiny_cfg, tok, engine):
    """A Best-of-2 group admitted behind a long request gets preempted when
    the pool runs dry; after its rerun both samples match the standalone
    greedy stream (one fresh prefill, fork, CoW again)."""
    eng = _paged_engine(trained_tiny, tiny_cfg, tok, n_blocks=9)
    sched = ContinuousScheduler(eng, n_slots=3, prompt_len=16,
                                stop_ids=NO_STOP)
    sched.submit(_req(tok, 0, "Q:2+7=?A:", max_new=14))
    sched.submit(_req(tok, 1, "Q:5+4=?A:", max_new=8, n_samples=2))
    res = sched.run(jax.random.key(0), GREEDY)
    assert set(res) == {0, 1} and len(res[1]) == 2
    ref = _reference_tokens(engine, tok, "Q:5+4=?A:", 8)
    for stream in res[1]:
        assert stream == ref
    assert res[0] == _reference_tokens(engine, tok, "Q:2+7=?A:", 14)
    assert eng.pool.blocks_in_use == 0


def test_submit_rejects_request_that_could_never_fit(trained_tiny, tiny_cfg,
                                                     tok):
    """Worst-case block footprint beyond pool capacity fails fast at
    submit instead of livelocking the preemption loop."""
    eng = _paged_engine(trained_tiny, tiny_cfg, tok, n_blocks=4)
    sched = ContinuousScheduler(eng, n_slots=3, prompt_len=16,
                                stop_ids=NO_STOP)
    with pytest.raises(ValueError):  # 10 + 30 tokens -> 5 blocks > 3
        sched.submit(_req(tok, 0, "Q:2+7=?A:", max_new=30))
    sched.submit(_req(tok, 1, "Q:2+7=?A:", max_new=10))  # 3 blocks: fits


def test_paged_serving_row_reports_kv_stats(trained_tiny, tiny_cfg, tok):
    """serve_best_of_n on a paged engine reports pool accounting and a
    positive HBM saving vs the dense reservation at equal slot count."""
    eng = _paged_engine(trained_tiny, tiny_cfg, tok, n_blocks=33)
    tasks = T.gen_dataset(41, 3, reasoning=False, max_terms=2)
    row = serve_best_of_n(eng, tok, tasks, n=2, max_tokens=8,
                          rng=jax.random.key(0), scorer=R.OracleVerifier(),
                          n_slots=4)
    kv = row["serving"]["kv"]
    assert kv["blocks_in_use"] == 0
    assert 0 < kv["peak_blocks_in_use"] <= 32
    assert kv["peak_bytes_in_use"] < kv["dense_bytes"]
    assert kv["hbm_saved_bytes"] == (kv["dense_bytes"]
                                     - kv["peak_bytes_in_use"])
