"""Snapshot record/check machinery (benchmarks.common) — pure logic."""
import json

import pytest

from benchmarks import common


ROWS = [
    ("tbl5.lut", 0.0, "max_err=5.2e-04 relRMS=3.4e-04"),
    ("fig15.fused", 5000.0, "speedup=0.14 (interpret-mode python timing)"),
    ("serving.kv_quant", 1.5e6,
     "mode=q8 kv_byte_reduction=73% accuracy=0.600 fp_accuracy=0.700"),
]


def test_parse_metrics_extracts_numbers_only():
    m = common.parse_metrics(ROWS[2][2])
    assert m["kv_byte_reduction"] == 73.0
    assert m["accuracy"] == 0.6
    assert "mode" not in m  # q8 is not numeric
    assert common.parse_metrics("free text (no metrics)") == {}


def test_snapshot_roundtrip(tmp_path, monkeypatch):
    monkeypatch.chdir(tmp_path)
    path = common.write_snapshot("t", ROWS)
    snap = json.load(open(path))
    assert snap["area"] == "t" and len(snap["rows"]) == 3
    assert common.check_snapshot("t", ROWS, snap) == []


def test_check_flags_missing_row():
    snap = common.snapshot("t", ROWS)
    bad = common.check_snapshot("t", ROWS[:-1], snap)
    assert len(bad) == 1 and "missing" in bad[0]


def test_check_flags_error_regression():
    snap = common.snapshot("t", ROWS)
    worse = [("tbl5.lut", 0.0, "max_err=5.2e-03 relRMS=3.4e-04")] + ROWS[1:]
    bad = common.check_snapshot("t", worse, snap)
    assert len(bad) == 1 and "max_err" in bad[0]
    # growth inside the ratio envelope is fine
    ok = [("tbl5.lut", 0.0, "max_err=9.9e-04 relRMS=3.4e-04")] + ROWS[1:]
    assert common.check_snapshot("t", ok, snap) == []


def test_check_flags_reduction_and_accuracy_drops():
    snap = common.snapshot("t", ROWS)
    worse = ROWS[:-1] + [("serving.kv_quant", 1.5e6,
                          "mode=q8 kv_byte_reduction=30% accuracy=0.100 "
                          "fp_accuracy=0.700")]
    bad = common.check_snapshot("t", worse, snap)
    assert any("kv_byte_reduction" in b for b in bad)
    assert any("accuracy" in b for b in bad)


def test_check_time_envelope(monkeypatch):
    snap = common.snapshot("t", ROWS)
    # 10x the snapshot (with the 500us floor) trips; anything below rides
    slow = ROWS[:1] + [("fig15.fused", 5.1e4, ROWS[1][2])] + ROWS[2:]
    bad = common.check_snapshot("t", slow, snap)
    assert len(bad) == 1 and "envelope" in bad[0]
    noisy = ROWS[:1] + [("fig15.fused", 4.9e4, ROWS[1][2])] + ROWS[2:]
    assert common.check_snapshot("t", noisy, snap) == []
    # machine-dependent override
    monkeypatch.setenv("REPRO_BENCH_TIME_FACTOR", "100")
    assert common.check_snapshot("t", slow, snap) == []


LAT_ROW = [("serving.latency", 2.0e6,
            "ttft_p50_ms=12.00 ttft_p99_ms=80.00 itl_p50_ms=1.50 "
            "itl_p99_ms=9.00 queue_wait_p99_ms=30.00 "
            "step_time_p50_ms=2.00 step_time_p99_ms=11.00 preemptions=0")]


def test_check_latency_envelope(monkeypatch):
    snap = common.snapshot("t", LAT_ROW)
    # the floor dominates small snapshots: p50 of 12ms is checked against
    # 25x max(12, 50) = 1250ms, so CI jitter never trips it...
    noisy = [("serving.latency", 2.0e6,
              LAT_ROW[0][2].replace("ttft_p50_ms=12.00",
                                    "ttft_p50_ms=1200.00"))]
    assert common.check_snapshot("t", noisy, snap) == []
    # ...but a stalled scheduler does
    stalled = [("serving.latency", 2.0e6,
                LAT_ROW[0][2].replace("ttft_p50_ms=12.00",
                                      "ttft_p50_ms=1300.00"))]
    bad = common.check_snapshot("t", stalled, snap)
    assert len(bad) == 1 and "ttft_p50_ms" in bad[0] and "envelope" in bad[0]
    # snapshots above the floor scale with the snapshot value
    worse = [("serving.latency", 2.0e6,
              LAT_ROW[0][2].replace("ttft_p99_ms=80.00",
                                    "ttft_p99_ms=2100.00"))]
    bad = common.check_snapshot("t", worse, snap)
    assert len(bad) == 1 and "ttft_p99_ms" in bad[0]
    # machine-dependent overrides mirror the time envelope's
    monkeypatch.setenv("REPRO_BENCH_LAT_FACTOR", "50")
    assert common.check_snapshot("t", worse, snap) == []
    monkeypatch.delenv("REPRO_BENCH_LAT_FACTOR")
    monkeypatch.setenv("REPRO_BENCH_LAT_FLOOR_MS", "100")
    assert common.check_snapshot("t", stalled, snap) == []


def test_committed_snapshots_are_well_formed():
    """The repo must carry the recorded perf trajectory for both areas."""
    import os

    root = os.path.join(os.path.dirname(__file__), "..")
    for area in ("kernels", "serving"):
        path = os.path.join(root, common.snapshot_path(area))
        assert os.path.exists(path), f"{path} missing"
        snap = json.load(open(path))
        assert snap["version"] == 1 and snap["area"] == area
        assert snap["rows"], f"{path} has no rows"


def test_run_snapshot_area_registry():
    from benchmarks import run as bench_run

    areas = bench_run.snapshot_areas()
    assert set(areas) == {"kernels", "serving"}
    assert all(callable(v) for v in areas.values())
