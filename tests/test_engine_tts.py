"""Serving engine + test-time-scaling behaviour tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import reward as R
from repro.core.best_of_n import best_of_n
from repro.core.beam_search import beam_search
from repro.core.self_consistency import self_consistency
from repro.data import tasks as T
from repro.models import api
from repro.serving.engine import (ContinuousScheduler, DecodeEngine,
                                  GenState, Request)
from repro.serving.sampler import SamplerConfig, sample


@pytest.fixture(scope="module")
def engine(trained_tiny, tiny_cfg, tok):
    return DecodeEngine(trained_tiny, tiny_cfg, max_len=128,
                        eos_id=tok.eos_id, pad_id=tok.pad_id)


def test_greedy_decode_matches_teacher_forcing(trained_tiny, tiny_cfg, tok):
    m = api.get_model(tiny_cfg)
    eng = DecodeEngine(trained_tiny, tiny_cfg, max_len=64, eos_id=999)
    toks = jax.random.randint(jax.random.key(1), (2, 10), 3, 200)
    st = eng.prefill(toks)
    st, out = eng.generate(st, 6, jax.random.key(2), SamplerConfig(greedy=True))
    seq = jnp.concatenate([toks, out], axis=1)
    logits, _, _ = m.forward(trained_tiny, seq[:, :-1], tiny_cfg)
    pred = jnp.argmax(logits, -1)[:, 9:15]
    np.testing.assert_array_equal(np.asarray(pred), np.asarray(out))


def test_fork_shares_prefix(engine, tok):
    ids, lens = tok.encode_batch(["Q:3+4=?A:"], 32)
    st = engine.prefill(jnp.asarray(ids), jnp.asarray(lens))
    st = engine.fork(st, 4)
    assert st.pending_logits.shape[0] == 4
    _, out = engine.generate(st, 5, jax.random.key(0),
                             SamplerConfig(greedy=True))
    assert (np.asarray(out) == np.asarray(out)[0]).all()


def test_reorder_gathers_rows(engine, tok):
    ids, lens = tok.encode_batch(["Q:1+1=?A:", "Q:2+2=?A:"], 32)
    st = engine.prefill(jnp.asarray(ids), jnp.asarray(lens))
    st2 = engine.reorder(st, jnp.array([1, 0]))
    np.testing.assert_array_equal(np.asarray(st2.cache_len),
                                  np.asarray(st.cache_len)[[1, 0]])
    np.testing.assert_allclose(np.asarray(st2.pending_logits),
                               np.asarray(st.pending_logits)[[1, 0]])


def test_stop_ids_and_resume(engine, tok):
    dot = tok.encode(".", bos=False)[0]
    ids, lens = tok.encode_batch(["Q:2+3=?A:"], 32)
    st = engine.prefill(jnp.asarray(ids), jnp.asarray(lens))
    st, out = engine.generate(st, 20, jax.random.key(0),
                              SamplerConfig(greedy=True),
                              stop_ids=(engine.eos_id, dot))
    toks = [t for t in out[0].tolist() if t != engine.pad_id]
    # generation stopped at the first '.' or EOS
    assert len(toks) < 20 or toks[-1] in (engine.eos_id, dot) or True
    assert bool(st.done.all())
    st = engine.resume(st)
    assert not bool(st.done.any())
    st, out2 = engine.generate(st, 4, jax.random.key(1),
                               SamplerConfig(greedy=True))
    assert out2.shape == (1, 4)


def test_done_rows_freeze(engine, tok):
    """After EOS, tokens are pad and cache_len/n_gen stop advancing."""
    ids, lens = tok.encode_batch(["Q:9-1=?A:"], 32)
    st = engine.prefill(jnp.asarray(ids), jnp.asarray(lens))
    st, _ = engine.generate(st, 30, jax.random.key(0),
                            SamplerConfig(greedy=True))
    if bool(st.done[0]):
        before = int(st.cache_len[0])
        st2, out = engine.generate(st, 5, jax.random.key(1),
                                   SamplerConfig(greedy=True))
        assert int(st2.cache_len[0]) == before
        assert (np.asarray(out) == engine.pad_id).all()


def test_scheduler_drains_queue(engine, tok):
    sched = ContinuousScheduler(engine, n_slots=2, prompt_len=16)
    for i in range(3):
        sched.submit(Request(req_id=i,
                             prompt=jnp.asarray(tok.encode(f"Q:{i}+1=?A:")),
                             max_new_tokens=4))
    res = sched.run(jax.random.key(0))
    assert set(res) == {0, 1, 2}


def test_reorder_after_fork_row_mapping(engine, tok):
    """fork maps row i to rows [i*n, (i+1)*n); reorder must gather those
    replicated rows correctly (beam-search survivor commit after fan-out)."""
    ids, lens = tok.encode_batch(["Q:1+1=?A:", "Q:2+2=?A:"], 32)
    st = engine.prefill(jnp.asarray(ids), jnp.asarray(lens))
    forked = engine.fork(st, 2)  # rows: [p0, p0, p1, p1]
    # pick one copy of prompt 1 and one of prompt 0, swapped order
    picked = engine.reorder(forked, jnp.array([3, 0]))
    np.testing.assert_allclose(np.asarray(picked.pending_logits[0]),
                               np.asarray(st.pending_logits[1]))
    np.testing.assert_allclose(np.asarray(picked.pending_logits[1]),
                               np.asarray(st.pending_logits[0]))
    np.testing.assert_array_equal(np.asarray(picked.cache_len),
                                  np.asarray(st.cache_len)[[1, 0]])
    # the gathered rows keep decoding like the originals (greedy)
    _, out_ref = engine.generate(st, 4, jax.random.key(0),
                                 SamplerConfig(greedy=True))
    _, out_picked = engine.generate(picked, 4, jax.random.key(0),
                                    SamplerConfig(greedy=True))
    np.testing.assert_array_equal(np.asarray(out_picked),
                                  np.asarray(out_ref)[[1, 0]])


def test_resume_continues_from_post_stop_pending_logits(engine, tok):
    """After a stop, pending_logits freeze at the logits that followed the
    stop token; resume() must continue sampling from exactly those, even
    when extra (masked) generate steps ran after the stop."""
    dot = tok.encode(".", bos=False)[0]
    ids, lens = tok.encode_batch(["Q:2+3=?A:"], 32)
    st = engine.prefill(jnp.asarray(ids), jnp.asarray(lens))
    st, _ = engine.generate(st, 20, jax.random.key(0),
                            SamplerConfig(greedy=True),
                            stop_ids=(engine.eos_id, dot))
    assert bool(st.done.all())
    frozen = np.asarray(st.pending_logits[0])
    # run more steps while done: pending must not move
    st2, _ = engine.generate(st, 5, jax.random.key(1),
                             SamplerConfig(greedy=True),
                             stop_ids=(engine.eos_id, dot))
    np.testing.assert_array_equal(np.asarray(st2.pending_logits[0]), frozen)
    # resume: the first token continues from the frozen logits
    st3, out = engine.generate(engine.resume(st2), 1, jax.random.key(2),
                               SamplerConfig(greedy=True))
    assert int(out[0, 0]) == int(np.argmax(frozen))


def test_multi_stop_ids_mask_generation(engine, tok):
    """With several stop_ids, each row stops at its first occurrence of
    *any* of them, pads afterwards, and sets done."""
    ids, lens = tok.encode_batch(["Q:2+3=?A:", "Q:8+1=?A:"], 32)
    st = engine.prefill(jnp.asarray(ids), jnp.asarray(lens))
    _, free = engine.generate(st, 12, jax.random.key(0),
                              SamplerConfig(greedy=True), stop_ids=(9999,))
    free = np.asarray(free)
    # choose stop ids appearing mid-stream in each row (fall back to a
    # never-sampled id when a row has no repeated token)
    stops = tuple({int(free[0, min(2, free.shape[1] - 1)]),
                   int(free[1, min(3, free.shape[1] - 1)])})
    st2, out = engine.generate(st, 12, jax.random.key(0),
                               SamplerConfig(greedy=True), stop_ids=stops)
    out = np.asarray(out)
    for b in range(2):
        hits = [i for i, t in enumerate(free[b].tolist()) if t in stops]
        assert hits, "test setup: chosen stop id must occur in the stream"
        first = hits[0]
        # prefix matches the unrestricted run, stop token kept at the stop
        # position, everything after is pad
        np.testing.assert_array_equal(out[b, :first], free[b, :first])
        assert out[b, first] in stops
        assert (out[b, first + 1:] == engine.pad_id).all()
    assert bool(np.asarray(st2.done).all())


def test_merge_rows_scatters_into_live_state(engine, tok):
    """merge_rows grafts a prefilled request onto arbitrary rows of a live
    state without disturbing the other rows."""
    base_ids, base_lens = tok.encode_batch(["Q:1+2=?A:", "Q:3+4=?A:",
                                            "Q:5+6=?A:"], 32)
    base = engine.prefill(jnp.asarray(base_ids), jnp.asarray(base_lens))
    new_ids, new_lens = tok.encode_batch(["Q:7+8=?A:"], 32)
    new = engine.prefill(jnp.asarray(new_ids), jnp.asarray(new_lens))
    merged = engine.merge_rows(base, new, jnp.array([1]))
    np.testing.assert_allclose(np.asarray(merged.pending_logits[1]),
                               np.asarray(new.pending_logits[0]))
    for row in (0, 2):
        np.testing.assert_allclose(np.asarray(merged.pending_logits[row]),
                                   np.asarray(base.pending_logits[row]))
    # merged row decodes exactly like the standalone prefill (greedy)
    _, out_merged = engine.generate(merged, 4, jax.random.key(0),
                                    SamplerConfig(greedy=True))
    _, out_new = engine.generate(new, 4, jax.random.key(0),
                                 SamplerConfig(greedy=True))
    np.testing.assert_array_equal(np.asarray(out_merged)[1],
                                  np.asarray(out_new)[0])


def test_empty_state_rows_stay_inert(engine):
    """empty_state rows are done: stepping them emits pads and never
    advances lengths (free slots are harmless idle lanes)."""
    st = engine.empty_state(3)
    st2, toks = engine.step(st, jax.random.key(0), SamplerConfig(greedy=True))
    assert (np.asarray(toks) == engine.pad_id).all()
    np.testing.assert_array_equal(np.asarray(st2.cache_len),
                                  np.zeros(3, np.int32))
    assert bool(np.asarray(st2.done).all())


def test_sampler_top_k_top_p():
    logits = jnp.log(jnp.array([[0.5, 0.3, 0.15, 0.05]]))
    for _ in range(3):
        t = sample(logits, jax.random.key(_), SamplerConfig(top_k=1))
        assert int(t[0]) == 0
    t = sample(logits, jax.random.key(9), SamplerConfig(top_p=0.5))
    assert int(t[0]) == 0  # nucleus of 0.5 keeps only the argmax here


def test_sampler_top_p_ties_respect_target_mass():
    """Tied logits at the nucleus boundary: the mask cuts by sorted rank,
    not by value, so a four-way tie at p=0.5 keeps exactly two tokens
    (a value cutoff would keep all four and double the target mass)."""
    logits = jnp.log(jnp.array([[0.25, 0.25, 0.25, 0.25]]))
    hits = {int(sample(logits, jax.random.key(i),
                       SamplerConfig(top_p=0.5))[0]) for i in range(40)}
    assert hits == {0, 1}  # stable sort: lowest ids fill the nucleus
    # ties *below* the boundary still sample freely
    logits = jnp.log(jnp.array([[0.1, 0.3, 0.1, 0.3, 0.2]]))
    hits = {int(sample(logits, jax.random.key(i),
                       SamplerConfig(top_p=0.6))[0]) for i in range(40)}
    assert hits == {1, 3}


# ---------------------------------------------------------------------------
# TTS algorithms
# ---------------------------------------------------------------------------


def test_best_of_n_structure(engine, tok):
    task = T.gen_dataset(5, 1, reasoning=False)[0]
    r = best_of_n(engine, tok, task, n=4, max_tokens=12, rng=jax.random.key(0),
                  scorer=R.OracleVerifier())
    assert len(r.completions) == 4
    assert r.scores.shape == (4,)
    assert r.decode_tokens > 0
    if r.correct:
        assert T.verify(task, r.completions[r.chosen])


def test_best_of_n_monotone_coverage(engine, tok):
    """Oracle-scored Best-of-N accuracy is monotone in N when computed on
    the same sample set (coverage property, paper Fig. 5)."""
    tasks = T.gen_dataset(11, 8, reasoning=False, max_terms=2)
    rng = jax.random.key(3)
    acc = {1: 0, 4: 0}
    for task in tasks:
        rng, k = jax.random.split(rng)
        r = best_of_n(engine, tok, task, n=4, max_tokens=12, rng=k,
                      scorer=R.OracleVerifier())
        hits = [T.verify(task, c) for c in r.completions]
        acc[1] += int(hits[0])
        acc[4] += int(any(hits))
    assert acc[4] >= acc[1]


def test_self_consistency_majority(engine, tok):
    task = T.gen_dataset(7, 1, reasoning=False)[0]
    r = self_consistency(engine, tok, task, n=5, max_tokens=12,
                         rng=jax.random.key(0))
    assert len(r.completions) == 5


def test_beam_search_runs(engine, tok):
    task = T.gen_dataset(9, 1, reasoning=True, max_terms=2)[0]
    r = beam_search(engine, tok, task, width=2, expand=2, max_steps=3,
                    step_tokens=10, rng=jax.random.key(0),
                    prm=R.LogProbScorer())
    assert len(r.completions) == 2
    assert r.decode_tokens > 0


def test_learned_scorer_api(tok):
    cfg = R.reward_config(tok.vocab_size)
    params = R.init_reward_params(jax.random.key(0), cfg)
    task = T.gen_dataset(13, 1)[0]
    sc = R.LearnedScorer(params, cfg, tok)
    scores = sc.score_texts(task, ["11.", "7."])
    assert scores.shape == (2,)
    assert ((scores >= 0) & (scores <= 1)).all()
    steps = sc.score_steps(task, "3+4=7.7+5=12.A:12.")
    assert steps.shape[0] == 3
