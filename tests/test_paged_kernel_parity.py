"""Scheduler-level greedy parity across paged-attention backends.

The fused Pallas table-walk kernels (exact and LUT-softmax) are drop-in
replacements for the XLA gather fallback inside the *decode* hot loop; a
full continuous-batching workload on the quantized pool must produce
argmax-identical greedy token streams whichever backend serves it.

The speculative cross-feature grid rides the same harness: draft-then-
verify greedy must be bit-identical to the direct decode path for every
{fp, q8, q4} pool × {xla, kernel, kernel_lut} attention impl combination
(the verify forward takes the prefill/kernel path, the baseline the
decode path — the grid pins both ends), including under OutOfBlocks
preemption mid-verify, where the draft snapshot's blocks must be
released atomically (leak-checked by every run)."""
import jax
import jax.numpy as jnp
import pytest

from repro.data.tasks import gen_dataset
from repro.models import layers
from repro.serving.engine import (ContinuousScheduler, DecodeEngine,
                                  Request, SpecConfig)
from repro.serving.sampler import SamplerConfig

SELF_DRAFT = SpecConfig(k=4, self_draft=True)


def _run_workload(params, cfg, tok, impl, kv_quant="q8", spec=None,
                  n_blocks=1 + 2 * 4):
    prev = layers.set_paged_attention_impl(impl)
    try:
        eng = DecodeEngine(params, cfg, max_len=32, eos_id=tok.eos_id,
                           pad_id=tok.pad_id, paged=True, block_size=8,
                           n_blocks=n_blocks, kv_quant=kv_quant)
        sched = ContinuousScheduler(eng, n_slots=2, prompt_len=24,
                                    stop_ids=(tok.eos_id,), spec=spec)
        for i, task in enumerate(gen_dataset(5, 4, reasoning=False,
                                             max_terms=2)):
            sched.submit(Request(req_id=i,
                                 prompt=jnp.asarray(tok.encode(task.prompt)),
                                 max_new_tokens=6))
        res = sched.run(jax.random.key(0), SamplerConfig(greedy=True))
        assert eng.pool.blocks_in_use == 0
        return res, sched.metrics.summary()
    finally:
        layers.set_paged_attention_impl(prev)


@pytest.mark.parametrize("impl", ["kernel", "kernel_lut"])
def test_scheduler_greedy_parity_quant_pool(trained_tiny, tiny_cfg, tok,
                                            impl):
    base, _ = _run_workload(trained_tiny, tiny_cfg, tok, "xla")
    got, _ = _run_workload(trained_tiny, tiny_cfg, tok, impl)
    assert base == got, (impl, base, got)


def test_scheduler_greedy_parity_fp_pool(trained_tiny, tiny_cfg, tok):
    base, _ = _run_workload(trained_tiny, tiny_cfg, tok, "xla",
                            kv_quant="none")
    got, _ = _run_workload(trained_tiny, tiny_cfg, tok, "kernel_lut",
                           kv_quant="none")
    assert base == got


@pytest.mark.parametrize("impl", ["xla", "kernel", "kernel_lut"])
@pytest.mark.parametrize("kv_quant", ["none", "q8", "q4"])
def test_speculative_greedy_parity_grid(trained_tiny, tiny_cfg, tok, impl,
                                        kv_quant):
    """Draft-then-verify greedy ≡ direct greedy for every pool × backend
    combination, with the acceptance counters live."""
    base, _ = _run_workload(trained_tiny, tiny_cfg, tok, impl,
                            kv_quant=kv_quant)
    got, s = _run_workload(trained_tiny, tiny_cfg, tok, impl,
                           kv_quant=kv_quant, spec=SELF_DRAFT)
    assert base == got, (impl, kv_quant, base, got)
    assert s["spec_rounds"] > 0
    assert s["spec_acceptance_rate"] > 0


@pytest.mark.parametrize("kv_quant", ["none", "q8"])
def test_speculative_parity_under_out_of_blocks(trained_tiny, tiny_cfg, tok,
                                                kv_quant):
    """A pool too small for both slots' speculative growth: verify plans
    and draft snapshots hit OutOfBlocks mid-round.  The round must abort
    atomically (draft blocks released before the retry — the harness
    leak-checks after drain) and the preempt/retry path must land on the
    same greedy tokens as the direct run."""
    base, _ = _run_workload(trained_tiny, tiny_cfg, tok, "xla",
                            kv_quant=kv_quant, n_blocks=1 + 6)
    got, s = _run_workload(trained_tiny, tiny_cfg, tok, "xla",
                           kv_quant=kv_quant, spec=SELF_DRAFT,
                           n_blocks=1 + 6)
    assert base == got
    assert s["spec_rounds"] > 0


def test_set_paged_attention_impl_validates():
    with pytest.raises(ValueError, match="unknown paged-attention impl"):
        layers.set_paged_attention_impl("npu")
    prev = layers.set_paged_attention_impl("kernel")
    assert layers.set_paged_attention_impl(prev) == "kernel"
