"""Scheduler-level greedy parity across paged-attention backends.

The fused Pallas table-walk kernels (exact and LUT-softmax) are drop-in
replacements for the XLA gather fallback inside the *decode* hot loop; a
full continuous-batching workload on the quantized pool must produce
argmax-identical greedy token streams whichever backend serves it.
"""
import jax
import jax.numpy as jnp
import pytest

from repro.data.tasks import gen_dataset
from repro.models import layers
from repro.serving.engine import ContinuousScheduler, DecodeEngine, Request
from repro.serving.sampler import SamplerConfig


def _run_workload(params, cfg, tok, impl, kv_quant="q8"):
    prev = layers.set_paged_attention_impl(impl)
    try:
        eng = DecodeEngine(params, cfg, max_len=32, eos_id=tok.eos_id,
                           pad_id=tok.pad_id, paged=True, block_size=8,
                           n_blocks=1 + 2 * 4, kv_quant=kv_quant)
        sched = ContinuousScheduler(eng, n_slots=2, prompt_len=24,
                                    stop_ids=(tok.eos_id,))
        for i, task in enumerate(gen_dataset(5, 4, reasoning=False,
                                             max_terms=2)):
            sched.submit(Request(req_id=i,
                                 prompt=jnp.asarray(tok.encode(task.prompt)),
                                 max_new_tokens=6))
        res = sched.run(jax.random.key(0), SamplerConfig(greedy=True))
        assert eng.pool.blocks_in_use == 0
        return res
    finally:
        layers.set_paged_attention_impl(prev)


@pytest.mark.parametrize("impl", ["kernel", "kernel_lut"])
def test_scheduler_greedy_parity_quant_pool(trained_tiny, tiny_cfg, tok,
                                            impl):
    base = _run_workload(trained_tiny, tiny_cfg, tok, "xla")
    got = _run_workload(trained_tiny, tiny_cfg, tok, impl)
    assert base == got, (impl, base, got)


def test_scheduler_greedy_parity_fp_pool(trained_tiny, tiny_cfg, tok):
    base = _run_workload(trained_tiny, tiny_cfg, tok, "xla",
                         kv_quant="none")
    got = _run_workload(trained_tiny, tiny_cfg, tok, "kernel_lut",
                        kv_quant="none")
    assert base == got


def test_set_paged_attention_impl_validates():
    with pytest.raises(ValueError, match="unknown paged-attention impl"):
        layers.set_paged_attention_impl("npu")
    prev = layers.set_paged_attention_impl("kernel")
    assert layers.set_paged_attention_impl(prev) == "kernel"
