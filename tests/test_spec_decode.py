"""Speculative decoding correctness: draft-then-verify on the paged engine.

The feature's acceptance rule IS its test: greedy speculative decoding
must be bit-identical to the plain greedy path — k drafted tokens are
verified by one batched target forward, the longest agreeing prefix
commits, and a rejected suffix is only ever a block free (PR-2 CoW
semantics), so no numeric state survives a rejection.  The suite locks
that down on fp and q8 pools, for self-drafting and a registry draft
model, under mixed chat + Best-of-N traffic, under OutOfBlocks
preemption mid-round, and for the ``Request.no_spec`` opt-out, plus the
acceptance metrics / tracer / profiler threading.
"""
import jax
import jax.numpy as jnp
import pytest

from repro.serving.engine import (ContinuousScheduler, DecodeEngine,
                                  Request, SpecConfig)
from repro.serving.sampler import SamplerConfig
from repro.serving.telemetry import Tracer

NO_STOP = (9999,)
GREEDY = SamplerConfig(greedy=True)
REQS = [("Q:2+7=?A:", 12), ("Q:1+1=?A:", 6), ("Q:9+9=?A:", 10),
        ("Q:4+5=?A:", 8)]
SELF_DRAFT = SpecConfig(k=4, self_draft=True)


def _engine(params, cfg, tok, n_blocks=48, kv_quant="none"):
    return DecodeEngine(params, cfg, max_len=64, eos_id=tok.eos_id,
                        pad_id=tok.pad_id, paged=True, block_size=8,
                        n_blocks=n_blocks, kv_quant=kv_quant)


def _run(params, cfg, tok, spec, n_blocks=48, kv_quant="none", bon=False,
         no_spec=False, tracer=None, profiler=None, stop_ids=NO_STOP):
    eng = _engine(params, cfg, tok, n_blocks=n_blocks, kv_quant=kv_quant)
    sched = ContinuousScheduler(eng, n_slots=3, prompt_len=16,
                                stop_ids=stop_ids, spec=spec,
                                tracer=tracer, profiler=profiler)
    for i, (text, max_new) in enumerate(REQS):
        sched.submit(Request(req_id=i, prompt=jnp.asarray(tok.encode(text)),
                             max_new_tokens=max_new, no_spec=no_spec))
    if bon:
        sched.submit(Request(req_id=len(REQS),
                             prompt=jnp.asarray(tok.encode(REQS[0][0])),
                             max_new_tokens=8, n_samples=2))
    res = sched.run(jax.random.key(0), GREEDY)
    assert eng.pool.blocks_in_use == 0, "speculative run leaked blocks"
    return res, sched.metrics.summary()


@pytest.mark.parametrize("kv_quant", ["none", "q8"])
def test_self_draft_greedy_parity(trained_tiny, tiny_cfg, tok, kv_quant):
    """Speculative greedy ≡ plain greedy, bitwise, on fp and q8 pools —
    with the acceptance counters live (self-drafting the target model
    greedily must accept every draft)."""
    base, _ = _run(trained_tiny, tiny_cfg, tok, None, kv_quant=kv_quant)
    spec, s = _run(trained_tiny, tiny_cfg, tok, SELF_DRAFT,
                   kv_quant=kv_quant)
    assert base == spec, f"{kv_quant}: speculative diverged from plain"
    assert s["spec_rounds"] > 0 and s["draft_tokens"] > 0
    assert s["spec_acceptance_rate"] > 0
    assert s["accepted_tokens_per_step"] > 1
    # stop-token traffic too: the committed-stop path must match
    bs, _ = _run(trained_tiny, tiny_cfg, tok, None, kv_quant=kv_quant,
                 stop_ids=(tok.eos_id,))
    ss, _ = _run(trained_tiny, tiny_cfg, tok, SELF_DRAFT,
                 kv_quant=kv_quant, stop_ids=(tok.eos_id,))
    assert bs == ss


def test_draft_model_greedy_parity(trained_tiny, tiny_cfg, tok):
    """A registry draft model proposes; whatever it proposes, the target's
    verify keeps outputs bit-identical to the plain path (the draft only
    moves the accept rate, never the tokens)."""
    spec = SpecConfig(k=3, draft_model="qwen2.5-1.5b")
    base, _ = _run(trained_tiny, tiny_cfg, tok, None)
    got, s = _run(trained_tiny, tiny_cfg, tok, spec)
    assert base == got
    assert s["spec_rounds"] > 0 and s["draft_tokens"] > 0
    # an untrained random draft almost never agrees with the trained
    # target, but every round still commits its verified first token
    assert s["accepted_tokens_per_step"] >= 1


def test_spec_with_mixed_bon_traffic(trained_tiny, tiny_cfg, tok):
    """Chat + a Best-of-N fork group under speculation: the forked lanes
    ride the same verify rounds and everything stays bit-identical."""
    base, _ = _run(trained_tiny, tiny_cfg, tok, None, bon=True)
    spec, s = _run(trained_tiny, tiny_cfg, tok, SELF_DRAFT, bon=True)
    assert base == spec
    assert len(spec[len(REQS)]) == 2
    assert s["spec_acceptance_rate"] > 0


def test_spec_parity_under_preemption(trained_tiny, tiny_cfg, tok):
    """A starved pool preempts mid-workload; OutOfBlocks inside a
    speculative round (snapshot, draft growth or the W-token verify plan)
    must abort the round atomically — outputs match the plain starved run
    and nothing leaks."""
    base, sb = _run(trained_tiny, tiny_cfg, tok, None, n_blocks=8)
    spec, ss = _run(trained_tiny, tiny_cfg, tok, SELF_DRAFT, n_blocks=8)
    assert base == spec
    assert sb["preemptions"] > 0 and ss["preemptions"] > 0
    assert ss["spec_rounds"] > 0


def test_no_spec_opt_out(trained_tiny, tiny_cfg, tok):
    """``Request(no_spec=True)`` rides plain rounds: same outputs, zero
    draft tokens recorded."""
    base, _ = _run(trained_tiny, tiny_cfg, tok, None)
    got, s = _run(trained_tiny, tiny_cfg, tok, SELF_DRAFT, no_spec=True)
    assert base == got
    assert s["draft_tokens"] == 0 and s["spec_rounds"] == 0


def test_spec_non_greedy_sampling_falls_back(trained_tiny, tiny_cfg, tok):
    """Speculative rounds only fire under greedy sampling (greedy
    acceptance is exact there); a temperature run serves plain steps and
    must match the spec-disabled run token for token."""
    eng = _engine(trained_tiny, tiny_cfg, tok)

    def run(spec):
        e = _engine(trained_tiny, tiny_cfg, tok)
        sched = ContinuousScheduler(e, n_slots=3, prompt_len=16,
                                    stop_ids=NO_STOP, spec=spec)
        for i, (text, max_new) in enumerate(REQS[:2]):
            sched.submit(Request(req_id=i,
                                 prompt=jnp.asarray(tok.encode(text)),
                                 max_new_tokens=max_new))
        res = sched.run(jax.random.key(0), SamplerConfig(temperature=0.8))
        return res, sched.metrics.summary()

    base, _ = run(None)
    got, s = run(SELF_DRAFT)
    assert base == got
    assert s["spec_rounds"] == 0


def test_spec_config_validation(trained_tiny, tiny_cfg, tok):
    with pytest.raises(ValueError, match="must be >= 2"):
        SpecConfig(k=1, self_draft=True)
    with pytest.raises(ValueError, match="exactly one"):
        SpecConfig(k=4)
    with pytest.raises(ValueError, match="exactly one"):
        SpecConfig(k=4, draft_model="qwen2.5-1.5b", self_draft=True)
    # scheduler-side: speculation needs the paged engine
    dense = DecodeEngine(trained_tiny, tiny_cfg, max_len=64,
                         eos_id=tok.eos_id, pad_id=tok.pad_id)
    with pytest.raises(ValueError, match="paged"):
        ContinuousScheduler(dense, n_slots=2, spec=SELF_DRAFT)
    # engine-side: spec_verify is a paged-only primitive
    st = dense.prefill(jnp.ones((1, 8), jnp.int32))
    with pytest.raises(ValueError, match="paged"):
        dense.spec_verify(st, jnp.ones((1, 2), jnp.int32),
                          jnp.ones((1,), jnp.int32))


def test_spec_telemetry_and_profiler_threading(trained_tiny, tiny_cfg, tok):
    """Verify rounds land in the tracer (a ``spec_verify`` span per round,
    an accepted-token gauge track) and in the profiler's phase
    attribution."""
    from repro.serving.profiling import KernelProfiler

    tracer = Tracer()
    prof = KernelProfiler(sample_rate=1.0, canary_rate=0.0)
    try:
        _, s = _run(trained_tiny, tiny_cfg, tok, SELF_DRAFT, tracer=tracer,
                    profiler=prof)
    finally:
        prof.uninstall()
    spans = [sp for sp in tracer.spans if sp.name == "spec_verify"]
    assert len(spans) == s["spec_rounds"] > 0
    gauges = [g for g in tracer.gauges if g.name == "spec_accepted_tokens"]
    assert len(gauges) == s["spec_rounds"]
    assert sum(g.value for g in gauges) > 0
    phases = prof.report()["phases"]
    assert "spec_verify" in phases and phases["spec_verify"]["calls"] > 0


def test_spec_metrics_summary_keys(trained_tiny, tiny_cfg, tok):
    """The summary threads the three headline counters with sane values:
    acceptance rate in (0, 1], accepted/step in (1, k]."""
    _, s = _run(trained_tiny, tiny_cfg, tok, SELF_DRAFT)
    assert 0 < s["spec_acceptance_rate"] <= 1
    assert 1 < s["accepted_tokens_per_step"] <= SELF_DRAFT.k
    assert s["draft_tokens"] >= s["spec_rounds"]
    # spec-disabled runs report zeros, not missing keys
    _, s0 = _run(trained_tiny, tiny_cfg, tok, None)
    assert s0["spec_rounds"] == 0 and s0["spec_acceptance_rate"] == 0.0
    assert s0["accepted_tokens_per_step"] == 0.0
