"""Randomized pool-invariant stress tests for the paged KV block pool.

A seeded (hypothesis-free, per ``test_quant_properties`` precedent)
harness drives hundreds of random op sequences — the pool-level moves
behind the engine's ``fork`` (retain), ``cow``, ``reorder`` (retain +
release), ``release_rows`` (release) and a speculative reject
(``spec_snapshot`` retain, draft growth, suffix free) — against both
:class:`~repro.serving.kv_pool.KVPool` and
:class:`~repro.serving.kv_quant.QuantKVPool`, checking after EVERY op
that the pool's refcounts match an independent shadow model, that the
free list is exactly the zero-refcount id set (no duplicates, no
scratch), and that the accounting properties stay consistent.  Draining
every live row at the end must return the pool to zero blocks in use.

A second harness drives the same ops through the engine layer
(``fork`` / ``reorder`` / ``release_rows`` / ``spec_snapshot``) on real
block tables, asserting refcount == table-reference-count throughout.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ModelConfig
from repro.serving.kv_pool import SCRATCH_BLOCK, KVPool, OutOfBlocks
from repro.serving.kv_quant import QuantKVPool

CFG = ModelConfig(name="pool-stress", n_layers=2, d_model=64, n_heads=4,
                  n_kv_heads=2, d_ff=192, vocab_size=384,
                  dtype="float32", param_dtype="float32", remat="none")


def _make_pool(mode: str, n_blocks: int = 24, block_size: int = 8):
    if mode == "none":
        return KVPool(CFG, n_blocks, block_size)
    return QuantKVPool(CFG, n_blocks, block_size, mode=mode)


def _check_invariants(pool, shadow: dict):
    """Pool state must match the shadow refcount model exactly."""
    # refcount array == shadow (all unmentioned ids are zero)
    for b in range(pool.n_blocks):
        want = shadow.get(b, 0)
        assert pool.refcount[b] == want, \
            f"block {b}: refcount {pool.refcount[b]} != shadow {want}"
    # free list: exactly the zero-refcount non-scratch ids, no duplicates
    free = list(pool._free)
    assert len(free) == len(set(free)), f"duplicate ids in free list: {free}"
    assert SCRATCH_BLOCK not in free, "scratch block leaked into free list"
    want_free = {b for b in range(1, pool.n_blocks)
                 if shadow.get(b, 0) == 0}
    assert set(free) == want_free, \
        f"free list {sorted(free)} != zero-refcount set {sorted(want_free)}"
    # accounting properties derive from the same sets
    assert pool.free_blocks == len(want_free)
    assert pool.blocks_in_use == pool.capacity - len(want_free)
    assert pool.peak_in_use >= pool.blocks_in_use


def _random_op(rng, pool, rows: list, shadow: dict):
    """Apply one random pool op, mirroring it into the shadow model.

    ``rows`` holds live block-id lists (the stand-in for sequence block
    tables); ``shadow`` maps block id -> expected refcount.
    """
    op = rng.choice(["alloc", "fork", "cow", "release", "reorder", "spec"])
    if op == "alloc":
        # admission: a fresh sequence takes 1..3 private blocks
        n = int(rng.integers(1, 4))
        if pool.free_blocks < n:
            with pytest.raises(OutOfBlocks):
                pool.alloc(pool.free_blocks + 1)
            return
        got = pool.alloc(n)
        for b in got:
            assert shadow.get(b, 0) == 0, f"alloc returned live block {b}"
            shadow[b] = 1
        rows.append(got)
    elif op == "fork" and rows:
        # Best-of-N fan-out: k extra owners per block, zero copies
        src = rows[int(rng.integers(len(rows)))]
        k = int(rng.integers(1, 3))
        pool.retain(src, times=k)
        for b in src:
            shadow[b] += k
        rows.extend([list(src)] * k)
    elif op == "cow" and rows:
        # first divergent write: shared blocks get private copies
        r = int(rng.integers(len(rows)))
        row = rows[r]
        take = [b for b in row if rng.random() < 0.5] or row[:1]
        if pool.free_blocks < len(take):
            return
        new = pool.cow(take)
        for b in take:
            shadow[b] -= 1
            if shadow[b] == 0:
                del shadow[b]
        for b in new:
            assert shadow.get(b, 0) == 0
            shadow[b] = 1
        sub = dict(zip(take, new))
        rows[r] = [sub.get(b, b) for b in row]
    elif op == "release" and rows:
        # release_rows / a speculative draft lane rejected wholesale
        r = int(rng.integers(len(rows)))
        row = rows.pop(r)
        pool.release(row)
        for b in row:
            shadow[b] -= 1
            if shadow[b] == 0:
                del shadow[b]
    elif op == "reorder" and rows:
        # beam survivor commit: drop one lane, duplicate another
        drop = rows.pop(int(rng.integers(len(rows))))
        pool.release(drop)
        for b in drop:
            shadow[b] -= 1
            if shadow[b] == 0:
                del shadow[b]
        if rows:
            keep = rows[int(rng.integers(len(rows)))]
            pool.retain(keep, times=1)
            for b in keep:
                shadow[b] += 1
            rows.append(list(keep))
    elif op == "spec" and rows:
        # speculative round: snapshot a lane (refcount bump), draft grows
        # it by a private block, verify rejects -> suffix freed, snapshot
        # released; net zero whatever the acceptance
        src = rows[int(rng.integers(len(rows)))]
        pool.retain(src, times=1)            # spec_snapshot
        draft = list(src)
        if pool.free_blocks >= 1:
            got = pool.alloc(1)              # draft lane grows one block
            draft += got
            shadow[got[0]] = 1
        for b in src:
            shadow[b] += 1
        pool.release(draft)                  # reject: snapshot + suffix
        for b in draft:
            shadow[b] -= 1
            if shadow[b] == 0:
                del shadow[b]


def _drive(mode: str, seed: int, n_ops: int):
    pool = _make_pool(mode)
    rng = np.random.default_rng(seed)
    rows, shadow = [], {}
    for _ in range(n_ops):
        _random_op(rng, pool, rows, shadow)
        _check_invariants(pool, shadow)
    # drain: releasing every live row must return the pool to empty
    for row in rows:
        pool.release(row)
    assert pool.blocks_in_use == 0, \
        f"{pool.blocks_in_use} blocks leaked after drain"
    assert sorted(pool._free) == list(range(1, pool.n_blocks))


@pytest.mark.parametrize("mode", ["none", "q8", "q4"])
@pytest.mark.parametrize("seed", [0, 1, 2])
def test_pool_random_op_stress(mode, seed):
    """A few hundred random fork/cow/reorder/release/spec-reject ops keep
    refcounts, free list and accounting exactly consistent on the fp and
    both quantized pools, and the pool drains leak-free."""
    _drive(mode, seed=seed, n_ops=120)


@pytest.mark.slow
@pytest.mark.parametrize("mode", ["none", "q8"])
def test_pool_random_op_stress_long(mode):
    """Long-sequence variant: thousands of ops across many seeds."""
    for seed in range(8):
        _drive(mode, seed=100 + seed, n_ops=1000)


def test_pool_misuse_raises():
    """The guard rails the random harness relies on: double release and
    retain-of-free are errors, never silent corruption."""
    pool = _make_pool("none")
    got = pool.alloc(2)
    pool.release(got)
    with pytest.raises(ValueError, match="release of unallocated"):
        pool.release(got[:1])
    with pytest.raises(ValueError, match="retain of unallocated"):
        pool.retain(got[:1])
    with pytest.raises(OutOfBlocks):
        pool.alloc(pool.capacity + 1)
    assert pool.blocks_in_use == 0


def _table_refcounts(eng, states) -> dict:
    """Expected refcounts: one reference per (state row, table slot)."""
    want = {}
    for st in states:
        table, n_blocks = jax.device_get((st.cache["table"],
                                          st.cache["n_blocks"]))
        for r in range(table.shape[0]):
            for b in table[r, :n_blocks[r]]:
                if int(b) != SCRATCH_BLOCK:
                    want[int(b)] = want.get(int(b), 0) + 1
    return want


@pytest.mark.parametrize("kv_quant", ["none", "q8"])
def test_engine_row_ops_random_stress(trained_tiny, tiny_cfg, tok, kv_quant):
    """The same invariant through the engine layer: after any random mix
    of fork / reorder / release_rows / spec_snapshot+reject on real block
    tables, every block's refcount equals the number of live table
    references to it, and a full drain leaves the pool empty."""
    from repro.serving.engine import DecodeEngine

    eng = DecodeEngine(trained_tiny, tiny_cfg, max_len=32, eos_id=tok.eos_id,
                       pad_id=tok.pad_id, paged=True, block_size=8,
                       n_blocks=64, kv_quant=kv_quant)
    prompt = jnp.asarray(tok.encode("Q:2+7=?A:"))
    padded = jnp.full((2, 16), eng.pad_id, jnp.int32)
    padded = padded.at[:, :prompt.shape[0]].set(jnp.tile(prompt, (2, 1)))
    state = eng.prefill(padded, jnp.full((2,), prompt.shape[0], jnp.int32))
    rng = np.random.default_rng(7)
    for _ in range(60):
        batch = int(state.cache_len.shape[0])
        op = rng.choice(["fork", "reorder", "release", "spec"])
        if op == "fork" and batch <= 8:
            state = eng.fork(state, 2)
        elif op == "reorder":
            idx = jnp.asarray(rng.integers(0, batch, size=batch), jnp.int32)
            state = eng.reorder(state, idx)
        elif op == "release":
            r = int(rng.integers(batch))
            state = eng.release_rows(state, [r])
            # released rows are re-pointed at scratch; drop them from the
            # live set via reorder so the walk below stays simple
            keep = [i for i in range(batch) if i != r]
            if not keep:
                break
            state = eng.reorder(state, jnp.asarray(keep, jnp.int32))
        elif op == "spec":
            rows = [int(rng.integers(batch))]
            snap = eng.spec_snapshot(state, rows)
            snap = eng.release_rows(snap, rows)  # verify rejected the lane
        want = _table_refcounts(eng, [state])
        for b in range(eng.pool.n_blocks):
            assert eng.pool.refcount[b] == want.get(b, 0), \
                f"block {b}: refcount {eng.pool.refcount[b]} != " \
                f"{want.get(b, 0)} table refs"
    batch = int(state.cache_len.shape[0])
    eng.release_rows(state, list(range(batch)))
    assert eng.pool.blocks_in_use == 0
