"""Batched cache-aware admission: parity + metrics.

Two layers, mirroring the tentpole:

* engine level — one B>1 partial prefill with *ragged* per-row cached
  lengths (block-aligned and mid-block in the same batch) must reproduce
  per-row B=1 partial prefills bit-for-bit on greedy token streams (and
  logits to float tolerance), on the fp pool and the quantized Q8 pool;
* scheduler level — N same-header requests admitted in one step through
  the batched path must produce results identical to strict one-at-a-time
  admission (``max_admission_batch=1``), while ``SchedulerMetrics``
  records an admission batch size > 1 and fewer prefill calls than
  admitted requests.

Both layers end with pool leak checks (drains leave only cache pins).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.serving.engine import ContinuousScheduler, DecodeEngine, Request
from repro.serving.kv_pool import blocks_for
from repro.serving.prefix_cache import PrefixCache
from repro.serving.sampler import SamplerConfig

NO_STOP = (9999,)
GREEDY = SamplerConfig(greedy=True)
ATOL = 1e-4
BS = 8


def _engine(params, cfg, tok, *, kv_quant="none", max_len=64, n_blocks=128):
    return DecodeEngine(params, cfg, max_len=max_len, eos_id=tok.eos_id,
                        pad_id=tok.pad_id, paged=True, block_size=BS,
                        n_blocks=n_blocks, kv_quant=kv_quant)


# ---------------------------------------------------------------------------
# Engine level: batched ragged partial prefill == per-row partial prefills
# ---------------------------------------------------------------------------


def _partial(eng, src_table, prompt, clens, pad_to, n_steps, seed=0):
    """Partial-prefill ``len(clens)`` rows off one source row's cached
    blocks (leasing them like PrefixCache.match would), decode, release.
    Suffixes are right-padded to ``pad_to`` so B=1 references and the
    batched run share the suffix width (the scheduler pads to prompt_len
    the same way).  Returns (next-token logits, greedy tokens)."""
    B = len(clens)
    W = max(blocks_for(c, BS) for c in clens)
    ctab = np.zeros((B, W), np.int32)
    for i, c in enumerate(clens):
        nb = blocks_for(c, BS)
        ctab[i, :nb] = src_table[:nb]
        eng.pool.retain(src_table[:nb])
    toks = np.full((B, pad_to), eng.pad_id, np.int32)
    lens = []
    for i, c in enumerate(clens):
        suf = prompt[c:]
        toks[i, :len(suf)] = suf
        lens.append(len(suf))
    st = eng.prefill(jnp.asarray(toks), jnp.asarray(lens, jnp.int32),
                     cached_table=ctab,
                     cached_lens=np.asarray(clens, np.int64))
    logits = np.asarray(st.pending_logits)
    st, out = eng.generate(st, n_steps, jax.random.key(seed), GREEDY,
                           stop_ids=NO_STOP)
    eng.release_rows(st, list(range(B)))
    return logits, np.asarray(out)


@pytest.mark.parametrize("kv_quant", ["none", "q8"])
def test_batched_ragged_partial_prefill_matches_per_row(trained_tiny,
                                                        tiny_cfg, tok,
                                                        kv_quant):
    """Aligned (8, 16) and misaligned (11) cached lengths in ONE batched
    partial prefill reproduce the per-row B=1 runs."""
    eng = _engine(trained_tiny, tiny_cfg, tok, kv_quant=kv_quant)
    prompt = tok.encode("Q:33+44=?R:33+44=77.A:")
    clens = [8, 11, 16]
    pad_to = len(prompt) - min(clens)
    full = eng.prefill(jnp.asarray(prompt)[None],
                       jnp.array([len(prompt)], jnp.int32))
    src_table = np.asarray(jax.device_get(full.cache["table"]))[0]

    refs = [_partial(eng, src_table, prompt, [c], pad_to, 6) for c in clens]
    bl, bt = _partial(eng, src_table, prompt, clens, pad_to, 6)
    for i, (rl, rt) in enumerate(refs):
        np.testing.assert_allclose(bl[i], rl[0], atol=ATOL, err_msg=f"row {i}")
        np.testing.assert_array_equal(bt[i], rt[0], err_msg=f"row {i}")
    eng.release_rows(full, [0])
    assert eng.pool.blocks_in_use == 0


def test_batched_tail_cow_commits_once_per_batch(trained_tiny, tiny_cfg,
                                                 tok):
    """Every misaligned row's tail CoW commits in one pool.cow call:
    cow_copies grows by exactly the number of misaligned rows, and the
    shared source block keeps one reference per remaining owner."""
    eng = _engine(trained_tiny, tiny_cfg, tok)
    prompt = tok.encode("Q:15+26=?R:15+26=41.A:")
    full = eng.prefill(jnp.asarray(prompt)[None],
                       jnp.array([len(prompt)], jnp.int32))
    src_table = np.asarray(jax.device_get(full.cache["table"]))[0]
    clens = [9, 11, 13]       # all misaligned: three tail CoWs, one call
    before = eng.pool.cow_copies
    _partial(eng, src_table, prompt, clens, len(prompt) - min(clens), 2)
    assert eng.pool.cow_copies - before == len(clens)
    eng.release_rows(full, [0])
    assert eng.pool.blocks_in_use == 0


# ---------------------------------------------------------------------------
# Scheduler level: one-step batched admission == one-at-a-time admission
# ---------------------------------------------------------------------------

HEADER = "Q:1+2=?A:3.Q:4+5=?A:9.Q:7+2=?A:9."
WARM_Q = "Q:9+9=?A:"
QUESTIONS = ["Q:1+2=?A:", "Q:3+4=?A:", "Q:5+6=?A:", "Q:7+8=?A:"]


def _run_shared_header(params, cfg, tok, *, kv_quant, max_batch):
    eng = _engine(params, cfg, tok, kv_quant=kv_quant, max_len=96,
                  n_blocks=161)
    cache = PrefixCache(eng.pool)
    sched = ContinuousScheduler(eng, n_slots=6, prompt_len=56,
                                stop_ids=NO_STOP, prefix_cache=cache,
                                max_admission_batch=max_batch)
    # warm the header so the test batch admits as hits in one step
    sched.submit(Request(req_id=100,
                         prompt=jnp.asarray(tok.encode(HEADER + WARM_Q)),
                         max_new_tokens=3))
    sched.run(jax.random.key(7), GREEDY)
    # 4 distinct questions (one cached-width bucket) + an exact repeat of
    # the warm prompt (longer match incl. a mid-block tail: its own bucket)
    for i, q in enumerate(QUESTIONS):
        sched.submit(Request(req_id=i,
                             prompt=jnp.asarray(tok.encode(HEADER + q)),
                             max_new_tokens=4))
    sched.submit(Request(req_id=4,
                         prompt=jnp.asarray(tok.encode(HEADER + WARM_Q)),
                         max_new_tokens=4))
    res = sched.run(jax.random.key(0), GREEDY)
    assert eng.pool.blocks_in_use == cache.n_cached_blocks  # rows drained
    return {i: res[i] for i in range(5)}, sched


@pytest.mark.parametrize("kv_quant", ["none", "q8"])
def test_one_step_batched_admission_parity_and_metrics(trained_tiny,
                                                       tiny_cfg, tok,
                                                       kv_quant):
    res_seq, s_seq = _run_shared_header(trained_tiny, tiny_cfg, tok,
                                        kv_quant=kv_quant, max_batch=1)
    res_bat, s_bat = _run_shared_header(trained_tiny, tiny_cfg, tok,
                                        kv_quant=kv_quant, max_batch=None)
    # bit-identical greedy streams vs one-at-a-time admission
    assert res_bat == res_seq
    m_seq = s_seq.metrics.summary()
    m_bat = s_bat.metrics.summary()
    # sequential baseline: every admission call carried one request
    assert m_seq["admission_batch_max"] == 1
    assert m_seq["prefill_calls"] == m_seq["admitted_requests"] == 6
    # batched: the 4 same-width hits shared one partial prefill (the
    # repeat prompt buckets separately on its longer cached width)
    assert m_bat["admission_batch_max"] >= len(QUESTIONS)
    assert m_bat["prefill_calls"] < m_bat["admitted_requests"]
    assert m_bat["prefill_calls_per_request"] < 1.0
    # batching changed call shapes only — not what was cached or saved
    for key in ("prefix_cache_hits", "prefill_tokens_saved",
                "prefill_tokens"):
        assert m_bat[key] == m_seq[key], key


def test_same_step_cold_header_still_hits(trained_tiny, tiny_cfg, tok):
    """Deferral keeps the sequential path's same-step-hit property: a
    cold shared header admits one full prefill in round one, and the
    followers admit as hits in round two of the SAME step."""
    eng = _engine(trained_tiny, tiny_cfg, tok, max_len=96, n_blocks=161)
    cache = PrefixCache(eng.pool)
    sched = ContinuousScheduler(eng, n_slots=4, prompt_len=56,
                                stop_ids=NO_STOP, prefix_cache=cache)
    for i, q in enumerate(QUESTIONS[:3]):
        sched.submit(Request(req_id=i,
                             prompt=jnp.asarray(tok.encode(HEADER + q)),
                             max_new_tokens=3))
    assert sched.step_once(jax.random.key(0), GREEDY)
    m = sched.metrics.summary()
    assert m["admitted_requests"] == 3         # all admitted in step 0
    assert m["prefix_cache_hits"] == 2         # followers hit the insert
    assert m["prefill_calls"] == 2             # cold miss + one hit batch
    assert sched.metrics.admission_batch_sizes == [1, 2]
    sched.run(jax.random.key(1), GREEDY)
    assert eng.pool.blocks_in_use == cache.n_cached_blocks


def test_duplicate_prompts_defer_once_then_batch(trained_tiny, tiny_cfg,
                                                 tok):
    """Byte-identical prompts — the most cache-friendly workload — defer
    exactly once: the cold head prefills alone, then the followers batch
    into one partial-prefill call as hits (the deferral estimate mirrors
    match's plen-1 cap, so identical prompts are not serialized)."""
    eng = _engine(trained_tiny, tiny_cfg, tok, max_len=96, n_blocks=161)
    cache = PrefixCache(eng.pool)
    sched = ContinuousScheduler(eng, n_slots=4, prompt_len=56,
                                stop_ids=NO_STOP, prefix_cache=cache)
    prompt = jnp.asarray(tok.encode(HEADER + WARM_Q))
    for i in range(3):
        sched.submit(Request(req_id=i, prompt=prompt, max_new_tokens=3))
    assert sched.step_once(jax.random.key(0), GREEDY)
    assert sched.metrics.admission_batch_sizes == [1, 2]
    assert sched.metrics.summary()["prefix_cache_hits"] == 2
    res = sched.run(jax.random.key(1), GREEDY)
    assert res[0] == res[1] == res[2]
    assert eng.pool.blocks_in_use == cache.n_cached_blocks


def test_max_admission_batch_validation(trained_tiny, tiny_cfg, tok):
    eng = _engine(trained_tiny, tiny_cfg, tok)
    with pytest.raises(ValueError):
        ContinuousScheduler(eng, max_admission_batch=0)
