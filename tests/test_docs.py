"""Docs stay truthful: links resolve, commands reference real code, and
the quickstart ``--dry`` smokes actually execute.

This is the CI ``docs`` job (it also runs inside tier-1).  Three layers:

* every intra-repo markdown link in README.md / docs/*.md points at a
  file that exists;
* every ``python -m <module>`` / ``python <script>`` command in a fenced
  block names a real file, and every ``--flag`` it passes appears
  literally in that file's source (catches flag renames rotting the
  docs);
* the commands that carry ``--dry`` are executed end-to-end (small
  untrained models, seconds each) — the docs' own smoke test.
"""
import os
import re
import subprocess
import sys
from pathlib import Path

import pytest

ROOT = Path(__file__).resolve().parents[1]
DOC_FILES = [ROOT / "README.md", *sorted((ROOT / "docs").glob("*.md"))]


def _docs():
    assert DOC_FILES and all(p.exists() for p in DOC_FILES), DOC_FILES
    return [(p, p.read_text()) for p in DOC_FILES]


def _commands():
    """(doc, command) for every shell line in a fenced block that invokes
    python; backslash continuations are joined."""
    out = []
    for doc, text in _docs():
        for block in re.findall(r"```(?:bash|sh|shell)?\n(.*?)```", text,
                                re.S):
            joined = block.replace("\\\n", " ")
            for line in joined.splitlines():
                line = line.strip()
                if re.search(r"\bpython3?\b", line):
                    out.append((doc, line))
    return out


def _target_file(cmd: str) -> Path:
    """Source file a doc command executes (module or script path)."""
    m = re.search(r"python3?\s+-m\s+([\w.]+)", cmd)
    if m:
        name = m.group(1)
        mod = name.replace(".", "/")
        for cand in (ROOT / f"{mod}.py", ROOT / "src" / f"{mod}.py",
                     ROOT / mod / "__main__.py"):
            if cand.exists():
                return cand
        if name.split(".")[0] in ("repro", "benchmarks", "examples"):
            raise AssertionError(f"doc command references missing module "
                                 f"{name!r}: {cmd}")
        return None  # third-party entry point (pytest, pip, ...)
    m = re.search(r"python3?\s+([\w./-]+\.py)", cmd)
    if m:
        cand = ROOT / m.group(1)
        assert cand.exists(), f"doc command references missing script: {cmd}"
        return cand
    return None


def test_doc_surface_exists():
    names = {p.name for p in DOC_FILES}
    assert {"README.md", "serving.md", "benchmarks.md"} <= names


def test_intra_repo_links_resolve():
    broken = []
    for doc, text in _docs():
        for label, target in re.findall(r"\[([^\]]*)\]\(([^)]+)\)", text):
            target = target.split("#")[0].strip()
            if not target or target.startswith(("http://", "https://",
                                                "mailto:")):
                continue
            if not (doc.parent / target).resolve().exists():
                broken.append(f"{doc.name}: [{label}]({target})")
    assert not broken, f"broken intra-repo doc links: {broken}"


def test_doc_commands_reference_real_modules_and_flags():
    cmds = _commands()
    assert cmds, "no python commands found in the docs"
    stale = []
    for doc, cmd in cmds:
        target = _target_file(cmd)
        if target is None:
            continue
        src = target.read_text()
        for flag in re.findall(r"(--[\w-]+)", cmd):
            if flag not in src:
                stale.append(f"{doc.name}: {flag} not in {target.name}: "
                             f"{cmd}")
    assert not stale, f"doc commands pass flags their targets lack: {stale}"


def test_doc_flag_matrix_matches_serve():
    """Every flag named in the README's serve flag matrix exists in
    launch/serve.py (and the core serving flags are all documented)."""
    readme = (ROOT / "README.md").read_text()
    serve = (ROOT / "src/repro/launch/serve.py").read_text()
    documented = set(re.findall(r"`(--[\w-]+)", readme))
    real = set(re.findall(r"add_argument\(\s*\"(--[\w-]+)\"", serve))
    assert documented & real, "README documents no serve flags?"
    ghost = {f for f in documented if f not in real
             and f in ("--continuous", "--paged", "--prefix-cache",
                       "--kv-quant", "--quantize", "--fewshot", "--ckpt",
                       "--cache-capacity", "--block-size", "--kv-blocks",
                       "--slots")}
    assert not ghost, f"README flag matrix names flags serve.py lacks: {ghost}"
    undocumented = {"--continuous", "--paged", "--prefix-cache",
                    "--kv-quant"} - documented
    assert not undocumented, \
        f"core serving flags missing from the README: {undocumented}"


@pytest.mark.parametrize("cmd", sorted({c for _, c in _commands()
                                        if "--dry" in c}))
def test_quickstart_dry_commands_run(cmd):
    """Execute each documented --dry smoke exactly as the docs print it
    (module invocation; env vars from the line are honored)."""
    env = dict(os.environ)
    m = re.match(r"((?:[\w]+=[^\s]+\s+)*)(.*)", cmd)
    for assign in m.group(1).split():
        k, _, v = assign.partition("=")
        env[k] = v.replace("$PYTHONPATH", env.get("PYTHONPATH", ""))
    rest = m.group(2)
    assert rest.startswith("python"), cmd
    argv = [sys.executable] + rest.split()[1:]
    proc = subprocess.run(argv, cwd=ROOT, env=env, capture_output=True,
                          text=True, timeout=900)
    assert proc.returncode == 0, \
        (f"documented command failed: {cmd}\n--- stdout ---\n"
         f"{proc.stdout[-2000:]}\n--- stderr ---\n{proc.stderr[-2000:]}")
