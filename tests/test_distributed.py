"""Distributed-path tests: run in a subprocess with 8 forced host devices so
the main pytest process keeps seeing 1 device."""
import os
import subprocess
import sys
import textwrap

import pytest

SRC = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "src"))

pytestmark = pytest.mark.slow  # subprocess-per-test with 8 forced devices


def run_with_devices(code: str, n: int = 8, timeout: int = 420):
    env = dict(os.environ,
               XLA_FLAGS=f"--xla_force_host_platform_device_count={n}",
               PYTHONPATH=SRC)
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                       capture_output=True, text=True, env=env,
                       timeout=timeout)
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr}"
    return r.stdout


def test_compressed_psum_matches_psum():
    run_with_devices("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P
        from repro.distributed.compat import shard_map
        from repro.distributed.compression import compressed_psum_local
        mesh = jax.make_mesh((8,), ("pod",))
        x = jnp.arange(64, dtype=jnp.float32).reshape(8, 8) / 7.0
        def local(v):
            return compressed_psum_local(v, "pod")
        fn = shard_map(local, mesh=mesh, in_specs=(P(),), out_specs=P(),
                       check_vma=False)
        got = fn(x)
        want = x * 8  # psum of identical replicas
        rel = float(jnp.abs(got - want).max() / jnp.abs(want).max())
        assert rel < 2e-2, rel   # int8 quantization error bound
        print("compressed_psum ok", rel)
    """)


def test_compressed_psum_reduces_allreduce_bytes():
    run_with_devices("""
        import jax, jax.numpy as jnp, re
        from jax.sharding import PartitionSpec as P, NamedSharding
        from repro.distributed.compat import shard_map
        from repro.distributed.compression import compressed_psum_local
        mesh = jax.make_mesh((8,), ("pod",))
        x = jnp.zeros((1024, 64), jnp.float32)
        sh = NamedSharding(mesh, P())
        plain = jax.jit(
            shard_map(lambda v: jax.lax.psum(v, "pod"), mesh=mesh,
                      in_specs=(P(),), out_specs=P(), check_vma=False),
            in_shardings=(sh,)).lower(x).compile().as_text()
        comp = jax.jit(
            shard_map(lambda v: compressed_psum_local(v, "pod"),
                      mesh=mesh, in_specs=(P(),), out_specs=P(),
                      check_vma=False),
            in_shardings=(sh,)).lower(x).compile().as_text()
        def coll_bytes(txt):
            tot = 0
            for line in txt.splitlines():
                if re.search(r"= \\S+ (all-gather|all-reduce|reduce-scatter)", line) or (
                        " = " in line and re.search(r"(all-gather|all-reduce|reduce-scatter)\\(", line)):
                    m = re.search(r"= (\\w+)\\[([\\d,]*)\\]", line)
                    if m:
                        dt, dims = m.groups()
                        n = 1
                        for d in dims.split(","):
                            if d: n *= int(d)
                        tot += n * {"f32":4,"bf16":2,"s8":1,"u8":1}.get(dt, 4)
            return tot
        b_plain, b_comp = coll_bytes(plain), coll_bytes(comp)
        print("plain", b_plain, "compressed", b_comp)
        assert b_comp < b_plain, (b_plain, b_comp)
    """)


def test_distributed_sample_greedy_matches_argmax():
    run_with_devices("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.distributed.sharding import ParallelContext
        from repro.serving.sampler import SamplerConfig, distributed_sample
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        par = ParallelContext(mesh=mesh)
        logits = jax.random.normal(jax.random.key(0), (4, 64))
        tok = distributed_sample(logits, jax.random.key(1),
                                 SamplerConfig(greedy=True), par)
        np.testing.assert_array_equal(np.asarray(tok),
                                      np.asarray(jnp.argmax(logits, -1)))
        # stochastic: valid token ids
        tok2 = distributed_sample(logits, jax.random.key(2),
                                  SamplerConfig(temperature=1.0), par)
        assert ((np.asarray(tok2) >= 0) & (np.asarray(tok2) < 64)).all()
        print("distributed_sample ok")
    """)


def test_sharded_train_step_matches_single_device():
    run_with_devices("""
        import jax, jax.numpy as jnp
        from repro.configs.base import ModelConfig
        from repro.distributed.sharding import ParallelContext, param_shardings
        from repro.models import api
        from repro.train.loop import make_train_step
        from repro.train.optimizer import AdamWConfig, init_opt_state
        from repro.launch.mesh import make_host_mesh

        cfg = ModelConfig(name="t", n_layers=2, d_model=64, n_heads=4,
                          n_kv_heads=2, d_ff=128, vocab_size=320,
                          dtype="float32", param_dtype="float32", remat="none")
        m = api.get_model(cfg)
        p = m.init_params(jax.random.key(0), cfg)
        toks = jax.random.randint(jax.random.key(1), (8, 32), 0, 320)
        batch = (toks, jnp.roll(toks, -1, 1), jnp.ones((8, 32), jnp.float32))
        oc = AdamWConfig(lr=1e-3)
        p_ref, _, met_ref = jax.jit(make_train_step(cfg, oc, None))(
            p, init_opt_state(p), batch)

        mesh = make_host_mesh(4, 2)
        par = ParallelContext(mesh=mesh)
        sh = param_shardings(p, par)
        p_sh = jax.device_put(p, sh)
        step = jax.jit(make_train_step(cfg, oc, par))
        p_out, _, met = step(p_sh, init_opt_state(p_sh), batch)
        d = jax.tree.map(lambda a, b: float(jnp.abs(a - jax.device_get(b)).max()),
                         p_ref, p_out)
        mx = max(jax.tree.leaves(d))
        assert mx < 1e-4, mx
        assert abs(float(met["loss"]) - float(met_ref["loss"])) < 1e-4
        print("sharded train step ok", mx)
    """)


def test_seq_parallel_decode_matches_dense():
    run_with_devices("""
        import jax, jax.numpy as jnp, dataclasses
        from repro.configs.base import ModelConfig
        from repro.distributed.sharding import ParallelContext
        from repro.models import api
        from repro.launch.mesh import make_host_mesh

        cfg = ModelConfig(name="t", n_layers=2, d_model=64, n_heads=4,
                          n_kv_heads=2, d_ff=128, vocab_size=320,
                          dtype="float32", param_dtype="float32")
        m = api.get_model(cfg)
        p = m.init_params(jax.random.key(0), cfg)
        toks = jax.random.randint(jax.random.key(1), (2, 12), 3, 300)
        logits_full, _, _ = m.forward(p, toks, cfg)
        _, cache = m.prefill(p, toks[:, :11], cfg, max_len=16)
        lg_ref, _ = m.decode_step(p, toks[:, 11:12], cache,
                                  jnp.full((2,), 12, jnp.int32), cfg)

        mesh = make_host_mesh(8, 1)
        par = ParallelContext(mesh=mesh, kv_seq_axis="data", fsdp=False)
        lg_sp, _ = m.decode_step(p, toks[:, 11:12], cache,
                                 jnp.full((2,), 12, jnp.int32), cfg, par)
        err = float(jnp.abs(lg_sp - lg_ref).max())
        assert err < 1e-3, err
        print("seq-parallel decode ok", err)
    """)


def test_elastic_restore_across_meshes(tmp_path):
    run_with_devices(f"""
        import jax, jax.numpy as jnp
        from repro.checkpoint.checkpointer import Checkpointer
        from repro.distributed.elastic import elastic_restore
        from repro.distributed.sharding import ParallelContext
        from repro.launch.mesh import make_host_mesh
        from repro.configs.base import ModelConfig
        from repro.models import api

        cfg = ModelConfig(name="t", n_layers=2, d_model=64, n_heads=4,
                          n_kv_heads=2, d_ff=128, vocab_size=320,
                          dtype="float32")
        m = api.get_model(cfg)
        p = m.init_params(jax.random.key(0), cfg)
        ck = Checkpointer(r"{tmp_path}")
        ck.save(p, step=3)
        # restore onto a (2,4) mesh, then onto (8,1) — same values both times
        for shape in [(2, 4), (8, 1)]:
            par = ParallelContext(mesh=make_host_mesh(*shape))
            restored, s = elastic_restore(ck, jax.eval_shape(lambda: p), par)
            ok = jax.tree.map(lambda a, b: bool(jnp.array_equal(
                jax.device_get(a), jax.device_get(b))), p, restored)
            assert all(jax.tree.leaves(ok))
        print("elastic restore ok")
    """)


def test_distributed_greedy_tie_break_matches_argmax():
    """Tied logits across vocab shards: the shard-winner merge must pick
    the LOWEST global index (like unsharded ``jnp.argmax``), not whichever
    shard the pmax reduction visits last."""
    run_with_devices("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.distributed.sharding import ParallelContext
        from repro.launch.mesh import make_host_mesh
        from repro.serving.sampler import SamplerConfig, distributed_sample

        par = ParallelContext(mesh=make_host_mesh(1, 8))
        V = 64  # 8 tokens per shard
        # ties spanning shards: {7, 23, 55} -> 7, {0, 63} -> 0,
        # {40, 41} (same shard) -> 40
        rows = np.full((3, V), -5.0, np.float32)
        rows[0, [7, 23, 55]] = 2.0
        rows[1, [0, 63]] = 1.0
        rows[2, [40, 41]] = 3.0
        logits = jnp.asarray(rows)
        tok = distributed_sample(logits, jax.random.key(0),
                                 SamplerConfig(greedy=True), par)
        np.testing.assert_array_equal(np.asarray(tok), [7, 0, 40])
        np.testing.assert_array_equal(np.asarray(tok),
                                      np.asarray(jnp.argmax(logits, -1)))
        # gumbel path still returns valid ids under ties
        tok2 = distributed_sample(logits, jax.random.key(1),
                                  SamplerConfig(temperature=1.0), par)
        assert ((np.asarray(tok2) >= 0) & (np.asarray(tok2) < V)).all()
        print("tie-break ok")
    """)
