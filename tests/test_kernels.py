"""Per-Pallas-kernel shape/dtype sweeps vs the pure-jnp oracles (ref.py)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.kernels.lut_softmax_attention import build_exp_lut
from repro.kernels.tile_quantize import tile_quantize
from repro.quant import tile_quant as TQ

KEY = jax.random.key(0)


@pytest.mark.parametrize("scheme", ["tile", "common"])
@pytest.mark.parametrize("codebook", ["q4_0", "nf4", "fp4", "iq4_nl"])
@pytest.mark.parametrize("mkn", [(4, 64, 128), (8, 256, 512), (16, 128, 96),
                                 (128, 512, 256)])
def test_lut_dequant_gemm_vs_oracle(scheme, codebook, mkn):
    M, K, N = mkn
    w = jax.random.normal(jax.random.fold_in(KEY, hash((scheme, codebook, M)) %
                                             2**31), (K, N)) * 0.1
    x = jax.random.normal(KEY, (M, K), jnp.float32)
    qw = TQ.quantize(w, scheme=scheme, codebook=codebook)
    y_kernel = ops.lut_dequant_matmul(x, qw)
    y_ref = ref.dequant_matmul_ref(x, qw["codes"], qw["scales"], qw["codebook"])
    np.testing.assert_allclose(np.asarray(y_kernel), np.asarray(y_ref),
                               atol=1e-3, rtol=1e-3)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_lut_dequant_gemm_dtypes(dtype):
    w = jax.random.normal(KEY, (128, 256)) * 0.1
    x = jax.random.normal(jax.random.fold_in(KEY, 1), (8, 128)).astype(dtype)
    qw = TQ.quantize(w, scheme="tile")
    y = ops.lut_dequant_matmul(x, qw)
    assert y.dtype == dtype
    y_ref = ref.dequant_matmul_ref(x.astype(jnp.float32), qw["codes"],
                                   qw["scales"], qw["codebook"])
    np.testing.assert_allclose(np.asarray(y, np.float32), np.asarray(y_ref),
                               atol=2e-2, rtol=2e-2)


@pytest.mark.parametrize("shape", [(2, 128, 128, 4, 2, 64),
                                   (1, 256, 256, 8, 8, 32),
                                   (2, 128, 384, 4, 1, 64)])
@pytest.mark.parametrize("exp_mode", ["lut", "exact"])
def test_lut_attention_vs_oracle(shape, exp_mode):
    B, Sq, Skv, Hq, Hkv, D = shape
    causal = Sq == Skv
    q = jax.random.normal(jax.random.fold_in(KEY, 2), (B, Sq, Hq, D)) * 0.5
    k = jax.random.normal(jax.random.fold_in(KEY, 3), (B, Skv, Hkv, D)) * 0.5
    v = jax.random.normal(jax.random.fold_in(KEY, 4), (B, Skv, Hkv, D)) * 0.5
    o = ops.flash_attention(q, k, v, causal=causal, exp_mode=exp_mode)
    # oracle runs the same fp16 blocked recurrence
    G = Hq // Hkv
    qt = q.transpose(0, 2, 1, 3).reshape(B * Hq, Sq, D).astype(jnp.float16)
    kt = jnp.repeat(k.transpose(0, 2, 1, 3), G, 1).reshape(B * Hq, Skv, D).astype(jnp.float16)
    vt = jnp.repeat(v.transpose(0, 2, 1, 3), G, 1).reshape(B * Hq, Skv, D).astype(jnp.float16)
    o_ref = ref.lut_flash_attention_ref(qt, kt, vt, causal=causal,
                                        exp_mode=exp_mode)
    o_ref = o_ref.reshape(B, Hq, Sq, D).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(o, np.float32),
                               np.asarray(o_ref, np.float32), atol=2e-3)


def test_lut_attention_accuracy_vs_f32():
    """Paper Table 5: LUT-fp16 attention ≈ f32 attention."""
    B, S, H, D = 2, 256, 4, 64
    q = jax.random.normal(jax.random.fold_in(KEY, 5), (B, S, H, D)) * 0.5
    k = jax.random.normal(jax.random.fold_in(KEY, 6), (B, S, H, D)) * 0.5
    v = jax.random.normal(jax.random.fold_in(KEY, 7), (B, S, H, D)) * 0.5
    o = ops.flash_attention(q, k, v, causal=True, exp_mode="lut")
    qt = q.transpose(0, 2, 1, 3).reshape(B * H, S, D)
    kt = k.transpose(0, 2, 1, 3).reshape(B * H, S, D)
    vt = v.transpose(0, 2, 1, 3).reshape(B * H, S, D)
    o32 = ref.attention_f32_ref(qt, kt, vt, causal=True)
    o32 = o32.reshape(B, H, S, D).transpose(0, 2, 1, 3)
    err = float(jnp.abs(o.astype(jnp.float32) - o32).max())
    assert err < 2e-2, err


def test_exp_lut_table_exactness():
    """LUT[i] must equal exp of the fp16 decoded from (0x8000 | i)."""
    lut = build_exp_lut()
    idx = jnp.array([0, 1, 1000, 20000, 0x7BFF], jnp.uint32)
    bits = (idx | 0x8000).astype(jnp.uint16)
    x = jax.lax.bitcast_convert_type(bits, jnp.float16).astype(jnp.float32)
    want = jnp.exp(x).astype(jnp.float16)
    got = lut[0, idx]
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), rtol=1e-3)
    # inf/nan patterns hold 0
    assert float(lut[0, 0x7C00]) == 0.0


@pytest.mark.parametrize("shape", [(2, 14, 4, 2, 4, 6, 32),
                                   (1, 8, 8, 1, 1, 4, 64),
                                   (3, 16, 16, 4, 4, 3, 16)])
@pytest.mark.parametrize("window,softcap", [(0, 0.0), (6, 0.0), (0, 30.0)])
def test_paged_attention_kernel_vs_oracle(shape, window, softcap):
    """The block-table-walking Pallas kernel must match the materialized
    gather + masked-softmax oracle for ragged lengths, GQA grouping,
    sliding windows and softcapping alike."""
    B, nb, bs, Hkv, G, W, D = shape
    rng = np.random.default_rng(B * 100 + bs)
    q = jax.random.normal(jax.random.fold_in(KEY, 10), (B, Hkv, G, D)) * 0.5
    k_pool = jax.random.normal(jax.random.fold_in(KEY, 11),
                               (nb, bs, Hkv, D)) * 0.5
    v_pool = jax.random.normal(jax.random.fold_in(KEY, 12),
                               (nb, bs, Hkv, D)) * 0.5
    # ragged rows: each picks distinct non-scratch blocks, padding -> 0;
    # row 0 is pinned completely full (len == W*bs) so the last position
    # of a fully-occupied table is covered, not just interior lengths
    lens = rng.integers(1, W * bs + 1, size=B).astype(np.int32)
    lens[0] = W * bs
    table = np.zeros((B, W), np.int32)
    avail = list(range(1, nb))
    for b in range(B):
        n = -(-int(lens[b]) // bs)
        table[b, :n] = [avail.pop(rng.integers(len(avail))) for _ in range(n)]
    table, lens = jnp.asarray(table), jnp.asarray(lens)
    o = ops.paged_flash_decode(q.reshape(B, 1, Hkv * G, D), k_pool, v_pool,
                               table, lens, window=window, softcap=softcap)
    o_ref = ref.paged_decode_attention_ref(q, k_pool, v_pool, table, lens,
                                           window=window, softcap=softcap)
    np.testing.assert_allclose(np.asarray(o.reshape(B, Hkv, G, D)),
                               np.asarray(o_ref), atol=2e-5)


def test_paged_attention_matches_xla_layers_path():
    """Kernel == the model's XLA gather fallback (identical semantics on
    the exact arrays the decode path produces)."""
    from repro.models.layers import paged_decode_attention

    B, nb, bs, Hkv, G, W, D = 2, 10, 4, 2, 3, 5, 16
    q = jax.random.normal(jax.random.fold_in(KEY, 20), (B, 1, Hkv * G, D))
    k_pool = jax.random.normal(jax.random.fold_in(KEY, 21), (nb, bs, Hkv, D))
    v_pool = jax.random.normal(jax.random.fold_in(KEY, 22), (nb, bs, Hkv, D))
    table = jnp.array([[1, 2, 3, 0, 0], [4, 5, 6, 7, 8]], jnp.int32)
    lens = jnp.array([9, 18], jnp.int32)
    o_kernel = ops.paged_flash_decode(q, k_pool, v_pool, table, lens)
    o_xla = paged_decode_attention(q, k_pool, v_pool, table=table,
                                   cache_len=lens)
    np.testing.assert_allclose(np.asarray(o_kernel), np.asarray(o_xla),
                               atol=2e-5)


@pytest.mark.parametrize("mode", ["q8", "q4"])
@pytest.mark.parametrize("shape", [(2, 14, 4, 2, 4, 6, 32),
                                   (1, 8, 8, 1, 1, 4, 64),
                                   (3, 16, 16, 4, 4, 3, 16)])
@pytest.mark.parametrize("window,softcap", [(0, 0.0), (6, 30.0)])
def test_quant_paged_attention_kernel_vs_oracle(mode, shape, window,
                                                softcap):
    """The fused-dequant table walk must match dequantize-the-whole-pool
    + the fp paged oracle — Q8 scale-multiply and packed-Q4 codebook
    lookups alike, incl. the gr=1 odd-head-count scale geometry."""
    from repro.serving import kv_quant as KQ

    B, nb, bs, Hkv, G, W, D = shape
    rng = np.random.default_rng(B * 100 + bs)
    q = jax.random.normal(jax.random.fold_in(KEY, 30), (B, Hkv, G, D)) * 0.5
    gr, gc = KQ.kv_tile_geometry(Hkv, D)
    pools = []
    for i in (31, 32):
        fp = jax.random.normal(jax.random.fold_in(KEY, i),
                               (nb, bs, Hkv, D)) * 0.5
        pools.append(KQ.quantize_kv(fp, mode=mode, gr=gr, gc=gc))
    k_pool, v_pool = pools
    lens = rng.integers(1, W * bs + 1, size=B).astype(np.int32)
    lens[0] = W * bs
    table = np.zeros((B, W), np.int32)
    avail = list(range(1, nb))
    for b in range(B):
        n = -(-int(lens[b]) // bs)
        table[b, :n] = [avail.pop(rng.integers(len(avail))) for _ in range(n)]
    table, lens = jnp.asarray(table), jnp.asarray(lens)
    o = ops.paged_flash_decode(q.reshape(B, 1, Hkv * G, D), k_pool, v_pool,
                               table, lens, window=window, softcap=softcap)
    o_ref = ref.quant_paged_decode_attention_ref(
        q, k_pool, v_pool, table, lens, window=window, softcap=softcap)
    np.testing.assert_allclose(np.asarray(o.reshape(B, Hkv, G, D)),
                               np.asarray(o_ref), atol=2e-5)


def test_quant_paged_attention_matches_xla_layers_path():
    """Fused-dequant kernel == the model's XLA gather-then-dequant
    fallback on the exact leaf dicts the quantized decode path carries."""
    from repro.models.layers import paged_decode_attention
    from repro.serving import kv_quant as KQ

    B, nb, bs, Hkv, G, W, D = 2, 10, 4, 2, 3, 5, 16
    q = jax.random.normal(jax.random.fold_in(KEY, 40), (B, 1, Hkv * G, D))
    k_pool = KQ.quantize_kv(
        jax.random.normal(jax.random.fold_in(KEY, 41), (nb, bs, Hkv, D)),
        mode="q8", gr=2, gc=16)
    v_pool = KQ.quantize_kv(
        jax.random.normal(jax.random.fold_in(KEY, 42), (nb, bs, Hkv, D)),
        mode="q8", gr=2, gc=16)
    table = jnp.array([[1, 2, 3, 0, 0], [4, 5, 6, 7, 8]], jnp.int32)
    lens = jnp.array([9, 18], jnp.int32)
    o_kernel = ops.paged_flash_decode(q, k_pool, v_pool, table, lens)
    o_xla = paged_decode_attention(q, k_pool, v_pool, table=table,
                                   cache_len=lens)
    np.testing.assert_allclose(np.asarray(o_kernel), np.asarray(o_xla),
                               atol=2e-5)


@pytest.mark.parametrize("kn", [(128, 256), (256, 512), (512, 1024)])
def test_tile_quantize_kernel_vs_oracle(kn):
    K, N = kn
    w = jax.random.normal(KEY, (K, N)) * 0.2
    ck, sk = tile_quantize(w)
    cr, sr = ref.tile_quantize_ref(w)
    assert (np.asarray(ck) == np.asarray(cr)).mean() > 0.999  # rounding ties
    np.testing.assert_allclose(np.asarray(sk, np.float32),
                               np.asarray(sr, np.float32), rtol=1e-3)


# ---------------------------------------------------------------------------
# LUT-fused paged decode (exp_mode='lut')
# ---------------------------------------------------------------------------


def _paged_case(shape, seed_base, pool_kind):
    """Build a ragged paged-decode case; pools fp or tile-quantized."""
    from repro.serving import kv_quant as KQ

    B, nb, bs, Hkv, G, W, D = shape
    rng = np.random.default_rng(B * 100 + bs)
    q = jax.random.normal(jax.random.fold_in(KEY, seed_base),
                          (B, Hkv, G, D)) * 0.5
    pools = []
    for i in (seed_base + 1, seed_base + 2):
        fp = jax.random.normal(jax.random.fold_in(KEY, i),
                               (nb, bs, Hkv, D)) * 0.5
        if pool_kind == "fp":
            pools.append(fp)
        else:
            gr, gc = KQ.kv_tile_geometry(Hkv, D)
            pools.append(KQ.quantize_kv(fp, mode=pool_kind, gr=gr, gc=gc))
    lens = rng.integers(1, W * bs + 1, size=B).astype(np.int32)
    lens[0] = W * bs
    table = np.zeros((B, W), np.int32)
    avail = list(range(1, nb))
    for b in range(B):
        n = -(-int(lens[b]) // bs)
        table[b, :n] = [avail.pop(rng.integers(len(avail))) for _ in range(n)]
    return q, pools[0], pools[1], jnp.asarray(table), jnp.asarray(lens)


@pytest.mark.parametrize("pool_kind", ["fp", "q8", "q4"])
@pytest.mark.parametrize("shape", [(2, 14, 4, 2, 4, 6, 32),
                                   (1, 8, 8, 1, 1, 4, 64)])
@pytest.mark.parametrize("window,softcap", [(0, 0.0), (6, 30.0)])
def test_lut_paged_attention_kernel_vs_oracle(pool_kind, shape, window,
                                              softcap):
    """exp_mode='lut': the fused fp16 LUT-softmax table walk must match
    the blocked fp16 LUT oracle over fp, Q8 and packed-Q4 pools."""
    B, nb, bs, Hkv, G, W, D = shape
    q, k_pool, v_pool, table, lens = _paged_case(shape, 50, pool_kind)
    o = ops.paged_flash_decode(q.reshape(B, 1, Hkv * G, D), k_pool, v_pool,
                               table, lens, window=window, softcap=softcap,
                               exp_mode="lut")
    fn = (ref.lut_paged_decode_attention_ref if pool_kind == "fp"
          else ref.quant_lut_paged_decode_attention_ref)
    o_ref = fn(q, k_pool, v_pool, table, lens, window=window,
               softcap=softcap)
    np.testing.assert_allclose(np.asarray(o.reshape(B, Hkv, G, D)),
                               np.asarray(o_ref), atol=2e-3)


@pytest.mark.parametrize("pool_kind", ["fp", "q8"])
def test_lut_paged_attention_accuracy_vs_f32(pool_kind):
    """Table-5 envelope on the paged decode path: the fused LUT-fp16
    recurrence stays within ~2e-2 of the exact-f32 oracle."""
    shape = (2, 14, 4, 2, 4, 6, 32)
    B, nb, bs, Hkv, G, W, D = shape
    q, k_pool, v_pool, table, lens = _paged_case(shape, 60, pool_kind)
    o = ops.paged_flash_decode(q.reshape(B, 1, Hkv * G, D), k_pool, v_pool,
                               table, lens, exp_mode="lut")
    fn = (ref.paged_decode_attention_ref if pool_kind == "fp"
          else ref.quant_paged_decode_attention_ref)
    o32 = fn(q, k_pool, v_pool, table, lens)
    err = float(jnp.abs(o.reshape(B, Hkv, G, D).astype(jnp.float32)
                        - o32).max())
    assert err < 2e-2, err


@pytest.mark.parametrize("pool_kind", ["fp", "q8", "q4"])
@pytest.mark.parametrize("exp_mode", ["exact", "lut"])
def test_paged_attention_zero_length_row(pool_kind, exp_mode):
    """A slot with lengths[b] == 0 (empty/just-freed row in a live batch)
    must contribute exactly 0 — before the all-masked guard, every block's
    p was exp(s - m) with m == s (all-masked), i.e. 1, so the kernel
    silently averaged garbage pool contents into the output."""
    shape = (3, 14, 4, 2, 2, 4, 32)
    B, nb, bs, Hkv, G, W, D = shape
    q, k_pool, v_pool, table, lens = _paged_case(shape, 70, pool_kind)
    lens = lens.at[1].set(0)
    o = ops.paged_flash_decode(q.reshape(B, 1, Hkv * G, D), k_pool, v_pool,
                               table, lens, exp_mode=exp_mode)
    o = o.reshape(B, Hkv, G, D)
    assert float(jnp.abs(o[1]).max()) == 0.0
    # live rows are untouched by the guard
    if exp_mode == "exact":
        fn = (ref.paged_decode_attention_ref if pool_kind == "fp"
              else ref.quant_paged_decode_attention_ref)
        atol = 2e-5
    else:
        fn = (ref.lut_paged_decode_attention_ref if pool_kind == "fp"
              else ref.quant_lut_paged_decode_attention_ref)
        atol = 2e-3
    o_ref = fn(q, k_pool, v_pool, table, lens)
    for b in (0, 2):
        np.testing.assert_allclose(np.asarray(o[b]), np.asarray(o_ref[b]),
                                   atol=atol)


# ---------------------------------------------------------------------------
# vlut16 gather dequant + plan wrapper
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("mode", ["q8", "q4"])
def test_lut_dequant_gather_bitwise(mode):
    """The kernel twin of dequantize_kv must be bit-identical on the
    (L, B, P, Hkv, D) prefix-gather views the engine produces."""
    from repro.serving import kv_quant as KQ

    x = jax.random.normal(jax.random.fold_in(KEY, 80), (3, 2, 8, 2, 32))
    qd = KQ.quantize_kv(x, mode=mode, gr=2, gc=16)
    a = KQ.dequantize_kv(qd)
    b = ops.lut_dequant_gather(qd)
    assert a.dtype == b.dtype and a.shape == b.shape
    assert bool(jnp.all(a == b))
    # identity on fp views
    assert ops.lut_dequant_gather(x) is x


def test_plan_lut_dequant_matmul_hoists_python_work(monkeypatch):
    """plan() must match the one-shot wrapper bitwise and resolve scheme
    inference once, not per call."""
    w = jax.random.normal(KEY, (128, 256)) * 0.1
    x = jax.random.normal(jax.random.fold_in(KEY, 81), (8, 128))
    qw = TQ.quantize(w, scheme="tile")
    y0 = ops.lut_dequant_matmul(x, qw)

    calls = []
    orig = TQ.infer_scheme
    monkeypatch.setattr(ops.TQ, "infer_scheme",
                        lambda *a, **k: calls.append(1) or orig(*a, **k))
    run = ops.plan_lut_dequant_matmul(qw, m=8)
    for _ in range(3):
        y1 = run(x)
    assert len(calls) == 1
    assert bool(jnp.all(y0 == y1))


# ---------------------------------------------------------------------------
# Block-size contracts: ValueErrors instead of silent truncation/asserts
# ---------------------------------------------------------------------------


def test_pick_block_raises_on_impossible_constraint():
    """_pick_block(n, ...) used to silently return n when n itself
    violated multiple_of, truncating downstream BlockSpec shapes (e.g. the
    tile-scheme scales block bn // (group_size // 2))."""
    with pytest.raises(ValueError, match="multiple of 16"):
        ops._pick_block(24, 256, multiple_of=16)
    # legacy behavior everywhere a valid block exists
    assert ops._pick_block(256, 128) == 128
    assert ops._pick_block(48, 32, 16) == 16
    assert ops._pick_block(7, 4) == 7  # prime: falls back to n


def test_lut_attention_rejects_indivisible_blocks():
    q = jnp.zeros((1, 12, 64), jnp.float16)
    lut = build_exp_lut()
    from repro.kernels.lut_softmax_attention import lut_softmax_attention

    with pytest.raises(ValueError, match=r"Sq=12 with bq=8"):
        lut_softmax_attention(q, q, q, lut, bq=8, bkv=4)


def test_lut_dequant_gemm_rejects_bad_shapes():
    from repro.kernels.lut_dequant_gemm import lut_dequant_gemm

    w = jax.random.normal(KEY, (96, 64)) * 0.1
    qw = TQ.quantize(w, scheme="tile")
    x = jnp.zeros((4, 100), jnp.float32)
    with pytest.raises(ValueError, match="96 rows but x has K=100"):
        lut_dequant_gemm(x, qw["codes"], qw["scales"], qw["codebook"])
    x = jnp.zeros((4, 96), jnp.float32)
    with pytest.raises(ValueError, match="must divide the GEMM shape"):
        lut_dequant_gemm(x, qw["codes"], qw["scales"], qw["codebook"],
                         bk=36)


# ---------------------------------------------------------------------------
# Autotuner
# ---------------------------------------------------------------------------


def test_autotune_defaults_match_legacy_choices(tmp_path, monkeypatch):
    """With no measured cache, the analytic roofline reproduces the old
    fixed-target picks — autotuning must not churn kernel behavior.
    (Point the cache at an empty path: a benchmark run in this checkout
    may have recorded measured winners in runs/autotune.json, and those
    legitimately override the analytic choice this test pins.)"""
    from repro.kernels import autotune as AT

    monkeypatch.setenv("REPRO_AUTOTUNE_CACHE", str(tmp_path / "empty.json"))
    AT.reset()
    assert AT.gemm_blocks(16, 1024, 1024, scheme="tile") == (16, 256, 128)
    assert AT.gemm_blocks(8, 256, 512, scheme="common") == (8, 256, 128)
    assert AT.attn_blocks(8, 256, 256, 64) == (128, 128)
    assert AT.quantize_blocks(512, 1024) == (128, 256)
    assert AT.dequant_rows(48, 2, 32, "q8") == 48
    AT.reset()  # drop memo entries computed under the empty cache


def test_autotune_cache_roundtrip(tmp_path, monkeypatch):
    """A measured entry recorded by the benchmark overrides the analytic
    choice; REPRO_AUTOTUNE=0 restores the legacy path."""
    from repro.kernels import autotune as AT

    cache = tmp_path / "autotune.json"
    monkeypatch.setenv("REPRO_AUTOTUNE_CACHE", str(cache))
    AT.reset()
    key = AT.gemm_key(16, 1024, 1024, "tile", 32)
    AT.record(key, (16, 64, 32), 12.5)
    assert AT.gemm_blocks(16, 1024, 1024, scheme="tile") == (16, 64, 32)
    # survives a fresh load
    AT.reset()
    assert AT.gemm_blocks(16, 1024, 1024, scheme="tile") == (16, 64, 32)
    # kill switch: measured entry ignored, legacy picks
    monkeypatch.setenv("REPRO_AUTOTUNE", "0")
    AT.reset()
    assert AT.gemm_blocks(16, 1024, 1024, scheme="tile") == (16, 256, 128)
    monkeypatch.delenv("REPRO_AUTOTUNE")
    AT.reset()
