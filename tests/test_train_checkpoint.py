"""Training substrate + checkpoint/fault-tolerance tests."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.checkpointer import Checkpointer
from repro.configs.base import ModelConfig
from repro.data.dataset import MathDataLoader, pack_documents
from repro.data.tokenizer import ByteTokenizer
from repro.distributed.compression import ef_quantize, make_ef_state
from repro.distributed.fault_tolerance import (PreemptionHandler,
                                               StragglerMonitor,
                                               resume_or_init)
from repro.models import api
from repro.train.loop import make_train_step, train_loop
from repro.train.optimizer import (AdamWConfig, adamw_update,
                                   clip_by_global_norm, init_opt_state, lr_at)


def test_loss_decreases(tok, tiny_cfg):
    m = api.get_model(tiny_cfg)
    p = m.init_params(jax.random.key(0), tiny_cfg)
    loader = MathDataLoader(tok, batch_size=16, seq_len=64, seed=1)
    losses = []
    oc = AdamWConfig(lr=2e-3, warmup_steps=5, total_steps=60)
    step = jax.jit(make_train_step(tiny_cfg, oc, None))
    opt = init_opt_state(p)
    for i in range(40):
        batch = tuple(jnp.asarray(b) for b in next(loader))
        p, opt, metrics = step(p, opt, batch)
        losses.append(float(metrics["loss"]))
    loader.close()
    assert losses[-1] < losses[0] * 0.75, (losses[0], losses[-1])


def test_microbatch_close_to_full_batch(tok, tiny_cfg):
    m = api.get_model(tiny_cfg)
    p = m.init_params(jax.random.key(0), tiny_cfg)
    loader = MathDataLoader(tok, batch_size=16, seq_len=64, seed=2)
    batch = tuple(jnp.asarray(b) for b in next(loader))
    loader.close()
    oc = AdamWConfig(lr=1e-3)
    s1 = jax.jit(make_train_step(tiny_cfg, oc, None, microbatches=1))
    s4 = jax.jit(make_train_step(tiny_cfg, oc, None, microbatches=4))
    p1, _, _ = s1(p, init_opt_state(p), batch)
    p4, _, _ = s4(p, init_opt_state(p), batch)
    diffs = jax.tree.map(lambda a, b: float(jnp.abs(a - b).max()), p1, p4)
    assert max(jax.tree.leaves(diffs)) < 2e-3  # per-microbatch normalization


def test_grad_clip_and_lr_schedule():
    g = {"a": jnp.full((4,), 10.0)}
    clipped, norm = clip_by_global_norm(g, 1.0)
    assert float(jnp.linalg.norm(clipped["a"])) <= 1.0 + 1e-5
    oc = AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100)
    assert float(lr_at(5, oc)) == pytest.approx(0.5)
    assert float(lr_at(10, oc)) == pytest.approx(1.0)
    assert float(lr_at(100, oc)) == pytest.approx(0.0, abs=1e-6)


def test_ef_quantize_error_feedback():
    g = {"w": jnp.array([1.0, -0.3, 0.0001, 2.0])}
    ef = make_ef_state(g)
    comp, ef = ef_quantize(g, ef)
    # error feedback accumulates the residual
    resid = jax.tree.leaves(ef)[0]
    np.testing.assert_allclose(np.asarray(comp["w"] + resid),
                               np.asarray(g["w"]), atol=1e-6)


def test_checkpoint_roundtrip(tmp_path, tiny_cfg):
    m = api.get_model(tiny_cfg)
    p = m.init_params(jax.random.key(0), tiny_cfg)
    ck = Checkpointer(str(tmp_path), keep=2)
    state = {"params": p, "step": jnp.asarray(7, jnp.int32)}
    ck.save(state, step=7)
    abstract = jax.eval_shape(lambda: state)
    restored, step = ck.restore(abstract)
    assert step == 7
    ok = jax.tree.map(lambda a, b: bool(jnp.array_equal(a, b)), state, restored)
    assert all(jax.tree.leaves(ok))


def test_checkpoint_quantized_params_roundtrip(tmp_path, tiny_cfg):
    from repro.quant.qlinear import quantize_model_params

    m = api.get_model(tiny_cfg)
    p = quantize_model_params(m.init_params(jax.random.key(0), tiny_cfg))
    ck = Checkpointer(str(tmp_path))
    ck.save(p, step=1)
    restored, _ = ck.restore(jax.eval_shape(lambda: p))
    ok = jax.tree.map(lambda a, b: bool(jnp.array_equal(a, b)), p, restored)
    assert all(jax.tree.leaves(ok))


def test_checkpoint_gc_and_latest(tmp_path):
    ck = Checkpointer(str(tmp_path), keep=2)
    for s in (1, 2, 3):
        ck.save({"x": jnp.ones((2,))}, step=s)
    assert ck.latest_step() == 3
    steps = sorted(int(n.split("_")[1]) for n in os.listdir(tmp_path))
    assert steps == [2, 3]


def test_async_checkpoint(tmp_path):
    ck = Checkpointer(str(tmp_path))
    ck.save_async({"x": jnp.arange(8)}, step=5)
    ck.wait()
    restored, s = ck.restore(jax.eval_shape(lambda: {"x": jnp.arange(8)}))
    assert s == 5


def test_resume_or_init(tmp_path):
    ck = Checkpointer(str(tmp_path))
    abstract = jax.eval_shape(lambda: {"x": jnp.zeros((3,))})
    tree, step = resume_or_init(ck, abstract, lambda: {"x": jnp.ones((3,))},
                                log_fn=lambda *_: None)
    assert step == 0 and float(tree["x"][0]) == 1.0
    ck.save({"x": jnp.full((3,), 5.0)}, step=9)
    tree, step = resume_or_init(ck, abstract, lambda: {"x": jnp.ones((3,))},
                                log_fn=lambda *_: None)
    assert step == 9 and float(tree["x"][0]) == 5.0


def test_preemption_handler_runs_save():
    saved = []
    with PreemptionHandler(lambda: saved.append(1)) as ph:
        ph._handler(15, None)
    assert saved == [1] and ph.preempted


def test_straggler_monitor_flags_outliers():
    logs = []
    mon = StragglerMonitor(threshold=2.0, log_fn=logs.append)
    for _ in range(10):
        mon.record_step(0.1)
    mon.record_step(0.5)
    assert mon.slow_steps == 1 and logs


def test_pack_documents_shapes(tok):
    t, y, m = pack_documents([("Q:1+1=?A:", "2.")], tok, seq_len=16)
    assert t.shape == y.shape == m.shape
    assert t.shape[1] == 16
    # targets are 1-shifted tokens
    np.testing.assert_array_equal(t[0, 1:], y[0, :-1])


def test_loader_host_sharding_disjoint(tok):
    l0 = MathDataLoader(tok, batch_size=4, seq_len=32, seed=0, host_id=0,
                        n_hosts=2)
    l1 = MathDataLoader(tok, batch_size=4, seq_len=32, seed=0, host_id=1,
                        n_hosts=2)
    b0, b1 = next(l0)[0], next(l1)[0]
    l0.close(); l1.close()
    assert not np.array_equal(b0, b1)


def test_tokenizer_roundtrip(tok):
    s = "Q:12+34=?A:46."
    assert tok.decode(tok.encode(s)) == s


def test_task_verify_and_extract():
    t = [x for x in [__import__("repro.data.tasks", fromlist=["gen_task"])]][0]
    task = t.gen_dataset(0, 1)[0]
    assert t.verify(task, task.target)
    assert t.extract_answer("A:42.") == 42
    assert t.extract_answer("junk") is None
