"""Quantized KV block pool tests.

Three layers, mirroring the subsystem:

* quantizer mechanics — Q8/Q4 tile round-trip error bounds, the
  (2, g//2) scale geometry incl. odd-shape fallbacks, q4 pack/unpack;
* pool mechanics — dtype-aware byte accounting, CoW moving code+scale
  payloads intact (mirrors ``test_kv_pool``'s fp CoW test);
* engine/scheduler parity — the Q8 pool must be logit-close to the fp
  paged engine with **bit-identical greedy argmax** on a seeded grid
  across every write/read path: plain prefill + decode, fork/CoW
  divergence, and the prefix-cache partial-prefill hit path; plus the
  pool-drain leak checks from ``test_kv_pool`` rerun on quantized pools.

The full block-size × batch × prompt grid is ``slow``; the fast subset
keeps every path class alive in CI.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.serving import kv_quant as KQ
from repro.serving.engine import ContinuousScheduler, DecodeEngine, Request
from repro.serving.kv_pool import KVPool, blocks_for
from repro.serving.kv_quant import QuantKVPool
from repro.serving.prefix_cache import PrefixCache
from repro.serving.sampler import SamplerConfig

NO_STOP = (9999,)
GREEDY = SamplerConfig(greedy=True)
# measured on the trained tiny model: q8 max logit err ~0.012, q4 ~0.20
# at logit scale ~5.4 — bounds carry ~4x headroom without hiding breakage
ATOL = {"q8": 0.05, "q4": 0.8}


def quant_engine(params, cfg, tok, mode, *, max_len=64, block_size=8,
                 n_blocks=128):
    return DecodeEngine(params, cfg, max_len=max_len, eos_id=tok.eos_id,
                        pad_id=tok.pad_id, paged=True,
                        block_size=block_size, n_blocks=n_blocks,
                        kv_quant=mode)


# ---------------------------------------------------------------------------
# Quantizer mechanics (no model)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("mode,rel_bound", [("q8", 0.01), ("q4", 0.16)])
def test_roundtrip_error_bounds(mode, rel_bound):
    x = jax.random.normal(jax.random.key(0), (3, 5, 2, 16)) * 0.7
    q = KQ.quantize_kv(x, mode=mode, gr=2, gc=16)
    err = np.abs(np.asarray(KQ.dequantize_kv(q) - x)).max()
    assert err / float(np.abs(np.asarray(x)).max()) < rel_bound
    assert q["scales"].shape == (3, 5, 1, 1)
    assert q["scales"].dtype == jnp.float16
    if mode == "q8":
        assert q["codes"].shape == (3, 5, 2, 16)
        assert q["codes"].dtype == jnp.int8
    else:
        assert q["codes"].shape == (3, 5, 2, 8)  # packed two-per-byte
        assert q["codes"].dtype == jnp.uint8
    # geometry round-trips from the leaf shapes alone
    assert KQ.kv_geometry(q) == (mode, 2, 16, 16)


def test_q4_pack_unpack_exact():
    codes = jnp.arange(64, dtype=jnp.uint8).reshape(4, 16) % 16
    np.testing.assert_array_equal(
        np.asarray(KQ._unpack_q4(KQ._pack_q4(codes))), np.asarray(codes))


def test_tile_geometry_fallbacks():
    assert KQ.kv_tile_geometry(2, 16) == (2, 16)     # canonical (2, g//2)
    assert KQ.kv_tile_geometry(3, 64) == (1, 16)     # odd heads: gr=1
    assert KQ.kv_tile_geometry(4, 24) == (2, 8)      # 24 % 16: gc halves
    # fallback geometries still round-trip
    x = jax.random.normal(jax.random.key(1), (2, 3, 24))
    q = KQ.quantize_kv(x, mode="q8", gr=1, gc=8)
    assert q["scales"].shape == (2, 3, 3)
    err = np.abs(np.asarray(KQ.dequantize_kv(q) - x)).max()
    assert err / float(np.abs(np.asarray(x)).max()) < 0.01


def test_zero_slab_quantizes_to_zero():
    """Scratch-block contents (zeros) must dequantize to exact zeros —
    scale 0 guards the divide, codes land on the zero entry."""
    z = jnp.zeros((2, 4, 2, 16))
    for mode in ("q8", "q4"):
        q = KQ.quantize_kv(z, mode=mode, gr=2, gc=16)
        assert float(np.abs(np.asarray(KQ.dequantize_kv(q))).max()) == 0.0


# ---------------------------------------------------------------------------
# Pool mechanics
# ---------------------------------------------------------------------------


def test_block_bytes_dtype_aware(tiny_cfg):
    fp = KVPool(tiny_cfg, n_blocks=9, block_size=8)
    q8 = QuantKVPool(tiny_cfg, n_blocks=9, block_size=8, mode="q8")
    q4 = QuantKVPool(tiny_cfg, n_blocks=9, block_size=8, mode="q4")
    # f32 value = 4 bytes; q8 = 1 + 2/32 (f16 scale per 32-tile); q4 half
    # the codes.  Ratios hold exactly for the tiny cfg (Hkv=2, D=16)
    assert q8.block_bytes() * 4 < fp.block_bytes() * 1.1
    assert q4.block_bytes() * 7 < fp.block_bytes() * 1.1
    assert fp.stats()["kv_quant"] == "none"
    assert q8.stats()["kv_quant"] == "q8"
    assert q8.stats()["peak_bytes_in_use"] == 0
    q8.alloc(3)
    assert q8.stats()["peak_bytes_in_use"] == 3 * q8.block_bytes()


def test_cow_copies_code_and_scale_payloads(tiny_cfg):
    """Mirror of the fp CoW test on quantized storage: a block copy must
    move codes *and* scales verbatim and fix refcounts atomically."""
    pool = QuantKVPool(tiny_cfg, n_blocks=6, block_size=4, mode="q8")
    (b,) = pool.alloc(1)
    pool.k = {"codes": pool.k["codes"].at[:, b].set(7),
              "scales": pool.k["scales"].at[:, b].set(0.5)}
    pool.retain([b])
    (nb,) = pool.cow([b])
    assert nb != b
    assert pool.refcount[b] == 1 and pool.refcount[nb] == 1
    np.testing.assert_array_equal(np.asarray(pool.k["codes"][:, nb]),
                                  np.asarray(pool.k["codes"][:, b]))
    np.testing.assert_array_equal(np.asarray(pool.k["scales"][:, nb]),
                                  np.asarray(pool.k["scales"][:, b]))
    assert pool.cow_copies == 1


def test_quant_pool_validates_mode(tiny_cfg):
    with pytest.raises(ValueError):
        QuantKVPool(tiny_cfg, n_blocks=4, block_size=4, mode="q2")
    with pytest.raises(ValueError):
        DecodeEngine(None, tiny_cfg, kv_quant="q8")  # needs paged=True


# ---------------------------------------------------------------------------
# Engine parity vs the fp paged engine
# ---------------------------------------------------------------------------


def _draw_prompts(seed, batch, max_prompt=20, vocab=300):
    rng = np.random.default_rng(seed)
    lens = rng.integers(3, max_prompt + 1, size=batch)
    toks = np.zeros((batch, max_prompt), np.int32)
    for i, l in enumerate(lens):
        toks[i, :l] = rng.integers(3, vocab, size=l)
    return jnp.asarray(toks), jnp.asarray(lens.astype(np.int32))


def _assert_quant_parity(fp_eng, q_eng, mode, toks, lens, n_steps, seed,
                         exact_tokens=True):
    sf = fp_eng.prefill(toks, lens)
    sq = q_eng.prefill(toks, lens)
    # prefill logits come from the fp forward pass: identical by design
    np.testing.assert_array_equal(np.asarray(sf.pending_logits),
                                  np.asarray(sq.pending_logits))
    sf, of = fp_eng.generate(sf, n_steps, jax.random.key(seed), GREEDY,
                             stop_ids=NO_STOP)
    sq, oq = q_eng.generate(sq, n_steps, jax.random.key(seed), GREEDY,
                            stop_ids=NO_STOP)
    if exact_tokens:
        np.testing.assert_array_equal(np.asarray(of), np.asarray(oq))
    np.testing.assert_allclose(np.asarray(sf.pending_logits),
                               np.asarray(sq.pending_logits),
                               atol=ATOL[mode])
    return sf, sq


@pytest.mark.parametrize("mode", ["q8", "q4"])
def test_prefill_decode_parity_seeded(trained_tiny, tiny_cfg, tok, mode):
    """Fast seeded grid: bit-identical greedy argmax + bounded logits
    across decode runs crossing several block boundaries."""
    fp = DecodeEngine(trained_tiny, tiny_cfg, max_len=64, eos_id=tok.eos_id,
                      pad_id=tok.pad_id, paged=True, block_size=8,
                      n_blocks=128)
    qe = quant_engine(trained_tiny, tiny_cfg, tok, mode)
    for seed, batch in [(0, 1), (1, 3), (2, 2)]:
        toks, lens = _draw_prompts(seed, batch)
        sf, sq = _assert_quant_parity(fp, qe, mode, toks, lens,
                                      n_steps=12, seed=seed)
        fp.release_rows(sf, list(range(batch)))
        qe.release_rows(sq, list(range(batch)))
        assert qe.pool.blocks_in_use == 0


@pytest.mark.parametrize("mode", ["q8", "q4"])
def test_fork_cow_divergence_parity(trained_tiny, tiny_cfg, tok, mode):
    """Best-of-N path: fork shares quantized prompt blocks, CoW splits
    them on first divergent write; streams must match the fp paged fork
    token for token (and actually diverge, so CoW fired on code+scale
    payloads)."""
    fp = DecodeEngine(trained_tiny, tiny_cfg, max_len=64, eos_id=tok.eos_id,
                      pad_id=tok.pad_id, paged=True, block_size=8,
                      n_blocks=128)
    qe = quant_engine(trained_tiny, tiny_cfg, tok, mode)
    toks, lens = _draw_prompts(42, 1, max_prompt=14)
    sf = fp.fork(fp.prefill(toks, lens), 3)
    sq = qe.fork(qe.prefill(toks, lens), 3)
    assert qe.pool.cow_copies == 0
    sc = SamplerConfig(temperature=0.8)
    sf, of = fp.generate(sf, 12, jax.random.key(7), sc, stop_ids=NO_STOP)
    sq, oq = qe.generate(sq, 12, jax.random.key(7), sc, stop_ids=NO_STOP)
    np.testing.assert_array_equal(np.asarray(of), np.asarray(oq))
    np.testing.assert_allclose(np.asarray(sf.pending_logits),
                               np.asarray(sq.pending_logits),
                               atol=ATOL[mode])
    assert len({tuple(r) for r in np.asarray(oq).tolist()}) > 1
    assert qe.pool.cow_copies == fp.pool.cow_copies > 0
    qe.release_rows(sq, [0, 1, 2])
    assert qe.pool.blocks_in_use == 0


@pytest.mark.parametrize("mode", ["q8", "q4"])
def test_partial_prefill_prefix_hit_parity(trained_tiny, tiny_cfg, tok,
                                           mode):
    """Prefix-cache-hit path on a quantized pool: a partial prefill that
    gathers *quantized* cached blocks (bucketed to the cached width) must
    reproduce the same engine's full prefill — aligned, misaligned and
    all-but-last-token splits, incl. the tail-block CoW on code+scale
    payloads."""
    eng = quant_engine(trained_tiny, tiny_cfg, tok, mode)
    prompt = tok.encode("Q:33+44=?R:33+44=77.A:")
    plen = len(prompt)
    for clen in (8, 16, 11, plen - 1):
        full = eng.prefill(jnp.asarray(prompt)[None],
                           jnp.array([plen], jnp.int32))
        ref_logits = np.asarray(full.pending_logits)
        full, ref_out = eng.generate(full, 8, jax.random.key(0), GREEDY,
                                     stop_ids=NO_STOP)
        table = np.asarray(jax.device_get(full.cache["table"]))
        cached = table[0, :blocks_for(clen, eng.pool.block_size)]
        eng.pool.retain(cached)  # the lease PrefixCache.match would take
        suffix = prompt[clen:]
        st = eng.prefill(jnp.asarray(suffix)[None],
                         jnp.array([len(suffix)], jnp.int32),
                         cached_table=cached[None],
                         cached_lens=np.array([clen]))
        np.testing.assert_allclose(np.asarray(st.pending_logits),
                                   ref_logits, atol=ATOL[mode])
        st, out = eng.generate(st, 8, jax.random.key(0), GREEDY,
                               stop_ids=NO_STOP)
        np.testing.assert_array_equal(np.asarray(out), np.asarray(ref_out))
        eng.release_rows(full, [0])
        eng.release_rows(st, [0])
        assert eng.pool.blocks_in_use == 0


@pytest.mark.slow
@pytest.mark.parametrize("mode", ["q8", "q4"])
def test_quant_parity_full_grid(trained_tiny, tiny_cfg, tok, mode):
    """Full block-size × batch × prompt-length sweep (mirrors the paged
    parity slow grid), decode runs crossing >= 2 block boundaries.

    Random prompts are out-of-distribution for the trained tiny model,
    so occasionally the fp top-2 logits tie to within the quantization
    noise; a greedy flip there is a legitimate rounding outcome, not a
    broken dequant path.  The step-wise harness therefore demands
    bit-identical argmax *except* where the fp top-2 gap is itself below
    the mode's tolerance — at which point that row's trajectories have
    forked and it leaves the comparison.  (On in-distribution prompts —
    the fast seeded grid and the benchmark's math workload — argmax is
    bit-identical outright.)"""
    fp = DecodeEngine(trained_tiny, tiny_cfg, max_len=64, eos_id=tok.eos_id,
                      pad_id=tok.pad_id, paged=True, block_size=8,
                      n_blocks=256)
    seed = ties = 0
    for block_size in (4, 8, 16):
        qe = quant_engine(trained_tiny, tiny_cfg, tok, mode,
                          block_size=block_size, n_blocks=256)
        for batch in (1, 2, 4):
            for max_prompt in (5, 13, 24):
                seed += 1
                toks, lens = _draw_prompts(seed, batch,
                                           max_prompt=max_prompt)
                n_steps = min(2 * block_size + 3, 63 - max_prompt)
                sf = fp.prefill(toks, lens)
                sq = qe.prefill(toks, lens)
                live = np.ones(batch, bool)
                for t in range(n_steps):
                    lf = np.asarray(sf.pending_logits)
                    lq = np.asarray(sq.pending_logits)
                    np.testing.assert_allclose(lf[live], lq[live],
                                               atol=ATOL[mode])
                    key = jax.random.key(1000 * seed + t)
                    sf, tf = fp.step(sf, key, GREEDY, stop_ids=NO_STOP)
                    sq, tq = qe.step(sq, key, GREEDY, stop_ids=NO_STOP)
                    tf, tq = np.asarray(tf), np.asarray(tq)
                    for r in np.nonzero(live)[0]:
                        if tf[r] == tq[r]:
                            continue
                        gap = np.diff(np.sort(lf[r])[-2:])[0]
                        assert gap < ATOL[mode], (
                            f"greedy mismatch beyond tie range: seed "
                            f"{seed} step {t} row {r} fp top-2 gap {gap}")
                        live[r] = False
                        ties += 1
                fp.release_rows(sf, list(range(batch)))
                qe.release_rows(sq, list(range(batch)))
                assert qe.pool.blocks_in_use == 0
    # ties must stay the rare exception, not the comparison's escape hatch
    assert ties <= 3, f"{ties} near-tie divergences (expected O(1))"


# ---------------------------------------------------------------------------
# Scheduler-level accounting (drain / leak checks, mirrors test_kv_pool)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("mode", ["q8", "q4"])
def test_scheduler_drain_leaves_no_leaked_blocks(trained_tiny, tiny_cfg,
                                                 tok, mode):
    eng = quant_engine(trained_tiny, tiny_cfg, tok, mode, block_size=8,
                       n_blocks=33)
    sched = ContinuousScheduler(eng, n_slots=3, prompt_len=16,
                                stop_ids=NO_STOP)
    for i, m in enumerate([7, 3, 9, 5]):
        sched.submit(Request(req_id=i,
                             prompt=jnp.asarray(tok.encode(f"Q:{i}+2=?A:")),
                             max_new_tokens=m))
    sched.submit(Request(req_id=9,
                         prompt=jnp.asarray(tok.encode("Q:5+4=?A:")),
                         max_new_tokens=6, n_samples=3))
    res = sched.run(jax.random.key(0), GREEDY)
    assert set(res) == {0, 1, 2, 3, 9}
    assert eng.pool.blocks_in_use == 0
    assert (eng.pool.refcount == 0).all()
    assert eng.pool.peak_in_use > 0
    # scheduler reports the byte-denominated peak (dtype-aware)
    s = sched.metrics.summary()
    assert s["kv_quant"] == mode
    assert s["peak_kv_bytes"] == eng.pool.peak_in_use * eng.pool.block_bytes()


def test_scheduler_drain_with_prefix_cache_pins_only(trained_tiny, tiny_cfg,
                                                     tok):
    """Prefix-cache pinning over a quantized pool: after a full drain the
    radix tree's pins are the only live references, and the cached (still
    quantized) blocks serve later hits at unchanged greedy outputs."""
    eng = quant_engine(trained_tiny, tiny_cfg, tok, "q8", max_len=96,
                       n_blocks=97)
    cache = PrefixCache(eng.pool)
    sched = ContinuousScheduler(eng, n_slots=3, prompt_len=48,
                                stop_ids=NO_STOP, prefix_cache=cache)
    header = "Q:1+2=?A:3.Q:4+5=?A:9."
    for i, m in enumerate([7, 3, 9, 5]):
        sched.submit(Request(
            req_id=i, prompt=jnp.asarray(tok.encode(f"{header}Q:{i}+2=?A:")),
            max_new_tokens=m))
    res = sched.run(jax.random.key(0), GREEDY)
    assert sched.metrics.cache_hits > 0
    cached = cache.cached_block_ids()
    assert eng.pool.blocks_in_use == len(cached) == cache.n_cached_blocks
    assert all(eng.pool.refcount[b] == 1 for b in cached)
    # hits must serve the same outputs as an uncached quantized run
    eng2 = quant_engine(trained_tiny, tiny_cfg, tok, "q8", max_len=96,
                        n_blocks=97)
    sched2 = ContinuousScheduler(eng2, n_slots=3, prompt_len=48,
                                 stop_ids=NO_STOP)
    for i, m in enumerate([7, 3, 9, 5]):
        sched2.submit(Request(
            req_id=i, prompt=jnp.asarray(tok.encode(f"{header}Q:{i}+2=?A:")),
            max_new_tokens=m))
    assert res == sched2.run(jax.random.key(0), GREEDY)
    cache.clear()
    assert eng.pool.blocks_in_use == 0
    assert (eng.pool.refcount == 0).all()
