"""End-to-end system behaviour tests (replaces placeholder)."""
import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import pytest

SRC = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "src"))


def test_quantize_then_serve_tts(trained_tiny, tiny_cfg, tok):
    """The paper's full pipeline: quantize weights (tile Q4 + Q8 down),
    serve with batched Best-of-N, verify accuracy is preserved-ish."""
    from repro.core import reward as R
    from repro.core.best_of_n import evaluate_best_of_n
    from repro.data import tasks as T
    from repro.quant.qlinear import quantize_model_params
    from repro.serving.engine import DecodeEngine

    tasks = T.gen_dataset(21, 6, reasoning=False, max_terms=2)
    qp = quantize_model_params(trained_tiny)
    eng = DecodeEngine(qp, tiny_cfg, max_len=96, eos_id=tok.eos_id,
                      pad_id=tok.pad_id)
    res = evaluate_best_of_n(eng, tok, tasks, n=4, max_tokens=10,
                             rng=jax.random.key(0), scorer=R.OracleVerifier())
    assert 0.0 <= res["accuracy"] <= 1.0
    assert res["decode_tokens"] > 0


def test_dryrun_single_cell_subprocess():
    """The multi-pod dry-run entrypoint works end to end (one fast cell on
    the 512-device multi-pod mesh)."""
    env = dict(os.environ, PYTHONPATH=SRC)
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch", "mamba2-130m",
         "--shape", "decode_32k", "--multi-pod", "--out",
         "/tmp/dryrun_test"],
        capture_output=True, text=True, env=env, timeout=420)
    assert r.returncode == 0, r.stdout + r.stderr
    rec = json.load(open("/tmp/dryrun_test/mamba2-130m__decode_32k__2x16x16.json"))
    assert rec["n_devices"] == 512
    assert rec["per_device"]["flops"] > 0


def test_train_entrypoint_smoke():
    env = dict(os.environ, PYTHONPATH=SRC)
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.train", "--arch", "llama3.2-1b",
         "--smoke", "--steps", "6", "--batch", "4", "--seq", "64"],
        capture_output=True, text=True, env=env, timeout=420)
    assert r.returncode == 0, r.stdout + r.stderr
    assert "loss" in r.stdout


def test_serve_entrypoint_smoke():
    env = dict(os.environ, PYTHONPATH=SRC)
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.serve", "--arch", "qwen2.5-1.5b",
         "--smoke", "--budget", "2", "--tasks", "2", "--max-tokens", "8"],
        capture_output=True, text=True, env=env, timeout=420)
    assert r.returncode == 0, r.stdout + r.stderr
    assert "accuracy" in r.stdout
