"""Property-style parity suite locking paged == dense.

Seeded loops (same style as test_quant_properties) drive the dense and the
paged engine through identical prefill/decode/fork/reorder histories and
assert the logits and greedy token streams match: the block pool, block
tables, scatter writes, copy-on-write splits and table gathers must be
*invisible* to the model's numerics.  Masked positions differ physically
(dense zeros vs pool garbage) but are NEG_INF'd out before softmax, so the
paths agree to float tolerance.

The full batch × seq-len × block-size grid is marked ``slow``; a reduced
grid keeps fast CI honest.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.serving.engine import DecodeEngine
from repro.serving.sampler import SamplerConfig

NO_STOP = (9999,)
GREEDY = SamplerConfig(greedy=True)
ATOL = 1e-4


@pytest.fixture(scope="module")
def dense_engine(trained_tiny, tiny_cfg, tok):
    return DecodeEngine(trained_tiny, tiny_cfg, max_len=64,
                        eos_id=tok.eos_id, pad_id=tok.pad_id)


def make_paged(trained_tiny, tiny_cfg, tok, block_size, n_blocks=128):
    return DecodeEngine(trained_tiny, tiny_cfg, max_len=64,
                        eos_id=tok.eos_id, pad_id=tok.pad_id, paged=True,
                        block_size=block_size, n_blocks=n_blocks)


def _draw_prompts(seed, batch, max_prompt=20, vocab=300):
    """Right-padded random token prompts with ragged true lengths."""
    rng = np.random.default_rng(seed)
    lens = rng.integers(3, max_prompt + 1, size=batch)
    toks = np.zeros((batch, max_prompt), np.int32)
    for i, l in enumerate(lens):
        toks[i, :l] = rng.integers(3, vocab, size=l)
    return jnp.asarray(toks), jnp.asarray(lens.astype(np.int32))


def _assert_run_parity(dense, paged, toks, lens, n_steps, seed):
    sd = dense.prefill(toks, lens)
    sp = paged.prefill(toks, lens)
    np.testing.assert_allclose(np.asarray(sd.pending_logits),
                               np.asarray(sp.pending_logits), atol=ATOL)
    sd, out_d = dense.generate(sd, n_steps, jax.random.key(seed), GREEDY,
                               stop_ids=NO_STOP)
    sp, out_p = paged.generate(sp, n_steps, jax.random.key(seed), GREEDY,
                               stop_ids=NO_STOP)
    np.testing.assert_array_equal(np.asarray(out_d), np.asarray(out_p))
    np.testing.assert_allclose(np.asarray(sd.pending_logits),
                               np.asarray(sp.pending_logits), atol=ATOL)
    np.testing.assert_array_equal(np.asarray(sd.cache_len),
                                  np.asarray(sp.cache_len))
    return sp


def test_prefill_and_decode_parity_small_grid(dense_engine, trained_tiny,
                                              tiny_cfg, tok):
    """Fast subset: every block size, one ragged batch each."""
    for seed, (batch, block_size) in enumerate([(1, 8), (3, 16), (2, 4)]):
        paged = make_paged(trained_tiny, tiny_cfg, tok, block_size)
        toks, lens = _draw_prompts(seed, batch)
        sp = _assert_run_parity(dense_engine, paged, toks, lens,
                                n_steps=10, seed=seed)
        paged.release_rows(sp, list(range(batch)))
        assert paged.pool.blocks_in_use == 0


@pytest.mark.slow
def test_prefill_and_decode_parity_full_grid(dense_engine, trained_tiny,
                                             tiny_cfg, tok):
    """Full batch × seq-len × block-size sweep, incl. decode runs that
    cross several block boundaries."""
    seed = 0
    for block_size in (4, 8, 16, 32):
        paged = make_paged(trained_tiny, tiny_cfg, tok, block_size,
                           n_blocks=256)
        for batch in (1, 2, 5):
            for max_prompt in (5, 13, 24):
                seed += 1
                toks, lens = _draw_prompts(seed, batch,
                                           max_prompt=max_prompt)
                # cross >= 2 block boundaries where the length budget
                # (prompt + steps <= max_len - 1) allows it
                n_steps = min(2 * block_size + 3, 63 - max_prompt)
                sp = _assert_run_parity(dense_engine, paged, toks, lens,
                                        n_steps=n_steps, seed=seed)
                paged.release_rows(sp, list(range(batch)))
                assert paged.pool.blocks_in_use == 0


def test_fork_then_diverge_parity(dense_engine, trained_tiny, tiny_cfg,
                                  tok):
    """Best-of-N shape: one prefill, fork, stochastic divergence.  The
    paged fork shares prompt blocks (CoW on first write); streams must
    match the dense fork's replicated-rows streams token for token."""
    for seed, (n, block_size) in enumerate([(2, 8), (4, 8), (3, 16)]):
        paged = make_paged(trained_tiny, tiny_cfg, tok, block_size)
        toks, lens = _draw_prompts(100 + seed, 1, max_prompt=14)
        sd = dense_engine.fork(dense_engine.prefill(toks, lens), n)
        sp = paged.fork(paged.prefill(toks, lens), n)
        sc = SamplerConfig(temperature=0.8)
        sd, out_d = dense_engine.generate(sd, 12, jax.random.key(seed), sc,
                                          stop_ids=NO_STOP)
        sp, out_p = paged.generate(sp, 12, jax.random.key(seed), sc,
                                   stop_ids=NO_STOP)
        np.testing.assert_array_equal(np.asarray(out_d), np.asarray(out_p))
        np.testing.assert_allclose(np.asarray(sd.pending_logits),
                                   np.asarray(sp.pending_logits), atol=ATOL)
        # samples really diverged (otherwise CoW was never exercised)
        assert len({tuple(r) for r in np.asarray(out_p).tolist()}) > 1
        assert paged.pool.cow_copies > 0
        paged.release_rows(sp, list(range(n)))
        assert paged.pool.blocks_in_use == 0


def test_reorder_after_fork_parity(dense_engine, trained_tiny, tiny_cfg,
                                   tok):
    """The beam-search shape from test_engine_tts: fork maps row i to rows
    [i*n, (i+1)*n); a reorder picking swapped copies must keep decoding
    identically on both layouts."""
    paged = make_paged(trained_tiny, tiny_cfg, tok, block_size=8)
    ids, lens = tok.encode_batch(["Q:1+1=?A:", "Q:2+2=?A:"], 24)
    toks, lens = jnp.asarray(ids), jnp.asarray(lens)
    sd = dense_engine.fork(dense_engine.prefill(toks, lens), 2)
    sp = paged.fork(paged.prefill(toks, lens), 2)
    idx = jnp.array([3, 0])
    pd = dense_engine.reorder(sd, idx)
    pp = paged.reorder(sp, idx)
    np.testing.assert_allclose(np.asarray(pd.pending_logits),
                               np.asarray(pp.pending_logits), atol=ATOL)
    _, out_d = dense_engine.generate(pd, 8, jax.random.key(0), GREEDY,
                                     stop_ids=NO_STOP)
    sp2, out_p = paged.generate(pp, 8, jax.random.key(0), GREEDY,
                                stop_ids=NO_STOP)
    np.testing.assert_array_equal(np.asarray(out_d), np.asarray(out_p))
    paged.release_rows(sp2, [0, 1])
    assert paged.pool.blocks_in_use == 0


def test_merge_rows_parity_into_live_state(dense_engine, trained_tiny,
                                           tiny_cfg, tok):
    """Admission primitive: grafting a prefilled request into a live paged
    state behaves exactly like the dense scatter."""
    paged = make_paged(trained_tiny, tiny_cfg, tok, block_size=8)
    base_ids, base_lens = tok.encode_batch(["Q:1+2=?A:", "Q:3+4=?A:",
                                            "Q:5+6=?A:"], 24)
    new_ids, new_lens = tok.encode_batch(["Q:7+8=?A:"], 24)
    outs = {}
    for name, eng in (("dense", dense_engine), ("paged", paged)):
        base = eng.prefill(jnp.asarray(base_ids), jnp.asarray(base_lens))
        new = eng.prefill(jnp.asarray(new_ids), jnp.asarray(new_lens))
        # paged contract: a merged-over row must be released first (its
        # blocks go back to the pool); mirrored on dense for symmetry
        base = eng.release_rows(base, [1])
        merged = eng.merge_rows(base, new, jnp.array([1]))
        st, out = eng.generate(merged, 6, jax.random.key(0), GREEDY,
                               stop_ids=NO_STOP)
        outs[name] = (np.asarray(out), np.asarray(st.pending_logits))
        if eng.paged:
            eng.release_rows(st, [0, 1, 2])
            assert eng.pool.blocks_in_use == 0
    np.testing.assert_array_equal(outs["dense"][0], outs["paged"][0])
    np.testing.assert_allclose(outs["dense"][1], outs["paged"][1],
                               atol=ATOL)


def test_stop_ids_and_done_freezing_parity(dense_engine, trained_tiny,
                                           tiny_cfg, tok):
    """Stop masking, scratch-slot routing and pending-logit freezing all
    behave identically on the paged path (done rows write into the scratch
    block instead of the dense scratch slot)."""
    paged = make_paged(trained_tiny, tiny_cfg, tok, block_size=8)
    ids, lens = tok.encode_batch(["Q:2+3=?A:", "Q:8+1=?A:"], 24)
    toks, lens = jnp.asarray(ids), jnp.asarray(lens)
    dot = tok.encode(".", bos=False)[0]
    stops = (dense_engine.eos_id, dot)
    sd, out_d = dense_engine.generate(dense_engine.prefill(toks, lens), 16,
                                      jax.random.key(0), GREEDY,
                                      stop_ids=stops)
    sp, out_p = paged.generate(paged.prefill(toks, lens), 16,
                               jax.random.key(0), GREEDY, stop_ids=stops)
    np.testing.assert_array_equal(np.asarray(out_d), np.asarray(out_p))
    np.testing.assert_array_equal(np.asarray(sd.done), np.asarray(sp.done))
    np.testing.assert_allclose(np.asarray(sd.pending_logits),
                               np.asarray(sp.pending_logits), atol=ATOL)
    np.testing.assert_array_equal(np.asarray(sd.cache_len),
                                  np.asarray(sp.cache_len))
