"""Numerical-correctness tests for the model substrates."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ModelConfig, SSMConfig
from repro.models import api, layers as L
from repro.models import mamba2 as M


def _naive_attn(q, k, v, window=0):
    B, S, Hq, D = q.shape
    Hkv = k.shape[2]
    G = Hq // Hkv
    qg = q.reshape(B, S, Hkv, G, D)
    s = jnp.einsum("bqhgd,bkhd->bhgqk", qg, k) / np.sqrt(D)
    i = jnp.arange(S)[:, None]
    j = jnp.arange(S)[None]
    m = j <= i
    if window:
        m &= (i - j) < window
    s = jnp.where(m[None, None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgqk,bkhd->bhgqd", p, v)
    return jnp.moveaxis(o, 3, 1).reshape(B, S, Hq, D)


@pytest.mark.parametrize("window", [0, 7])
@pytest.mark.parametrize("S", [32, 24])  # 24 exercises chunk padding
def test_chunked_attention_matches_naive(window, S):
    B, Hq, Hkv, D = 2, 4, 2, 16
    key = jax.random.key(1)
    q = jax.random.normal(jax.random.fold_in(key, 0), (B, S, Hq, D))
    k = jax.random.normal(jax.random.fold_in(key, 1), (B, S, Hkv, D))
    v = jax.random.normal(jax.random.fold_in(key, 2), (B, S, Hkv, D))
    pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    o1 = L.chunked_attention(q, k, v, q_positions=pos, kv_positions=pos,
                             causal=True, window=window, q_chunk=8, kv_chunk=8)
    o2 = _naive_attn(q, k, v, window)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), atol=1e-5)


def test_decode_attention_partial_merge_identity():
    """Splitting KV into shards and merging partials == full attention."""
    B, S, Hq, Hkv, D = 2, 32, 4, 2, 16
    key = jax.random.key(2)
    q = jax.random.normal(jax.random.fold_in(key, 0), (B, 1, Hq, D))
    k = jax.random.normal(jax.random.fold_in(key, 1), (B, S, Hkv, D))
    v = jax.random.normal(jax.random.fold_in(key, 2), (B, S, Hkv, D))
    cl = jnp.array([S, S - 5], jnp.int32)
    o_full = L.decode_attention(q, k, v, cache_len=cl)
    # two "shards"
    parts = []
    for sh in range(2):
        sl = slice(sh * 16, (sh + 1) * 16)
        kv_pos = jnp.arange(S)[sl][None]
        valid = kv_pos < cl[:, None]
        parts.append(L.decode_attention_partial(q, k[:, sl], v[:, sl],
                                                valid=valid))
    m_star = jnp.maximum(parts[0][1], parts[1][1])
    l_star = sum(p[2] * jnp.exp(p[1] - m_star) for p in parts)
    o_star = sum(p[0] * jnp.exp(p[1] - m_star)[:, None, :, None]
                 for p in parts) / jnp.maximum(l_star[:, None, :, None], 1e-30)
    np.testing.assert_allclose(np.asarray(o_star), np.asarray(o_full),
                               atol=1e-5)


def test_ssd_chunked_matches_recurrence():
    cfg = ModelConfig(name="m", family="mamba2", n_layers=1, d_model=32,
                      vocab_size=50,
                      ssm=SSMConfig(d_state=8, head_dim=8, chunk_size=4))
    s = cfg.ssm
    H, P, N = s.n_heads(cfg.d_model), s.head_dim, s.d_state
    Bb, Sq = 2, 16
    key = jax.random.key(3)
    x = jax.random.normal(jax.random.fold_in(key, 0), (Bb, Sq, H, P))
    dt = jax.nn.softplus(jax.random.normal(jax.random.fold_in(key, 1),
                                           (Bb, Sq, H)))
    A = -jnp.exp(jax.random.normal(jax.random.fold_in(key, 2), (H,)) * 0.3)
    Bm = jax.random.normal(jax.random.fold_in(key, 3), (Bb, Sq, 1, N))
    Cm = jax.random.normal(jax.random.fold_in(key, 4), (Bb, Sq, 1, N))
    y_c, h_c = M._ssd_chunked(x, dt, A, Bm, Cm, cfg)
    h = jnp.zeros((Bb, H, P, N))
    ys = []
    for t in range(Sq):
        decay = jnp.exp(dt[:, t] * A[None])
        dx = x[:, t] * dt[:, t][..., None]
        h = (h * decay[:, :, None, None] +
             dx[..., None] * jnp.broadcast_to(Bm[:, t], (Bb, H, N))[:, :, None, :])
        ys.append(jnp.einsum("bhpn,bhn->bhp", h,
                             jnp.broadcast_to(Cm[:, t], (Bb, H, N))))
    y_n = jnp.stack(ys, 1)
    np.testing.assert_allclose(np.asarray(y_c), np.asarray(y_n), atol=1e-3)
    np.testing.assert_allclose(np.asarray(h_c), np.asarray(h), atol=1e-3)


@pytest.mark.parametrize("name,kw", [
    ("dense", dict(n_layers=3, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
                   vocab_size=97)),
    ("lg", dict(n_layers=6, d_model=64, n_heads=4, n_kv_heads=1, d_ff=128,
                vocab_size=97, attn_pattern="local_global:5", window_size=8)),
    ("mamba", dict(family="mamba2", n_layers=3, d_model=64, vocab_size=97,
                   ssm=SSMConfig(d_state=16, head_dim=16, chunk_size=4))),
    ("hybrid", dict(family="hybrid", n_layers=5, d_model=64, n_heads=4,
                    n_kv_heads=4, d_ff=128, vocab_size=97,
                    hybrid_attn_every=2,
                    ssm=SSMConfig(d_state=16, head_dim=16, chunk_size=4))),
])
def test_decode_consistency(name, kw):
    """prefill(t) + decode steps must reproduce teacher-forced logits."""
    cfg = ModelConfig(name=name, dtype="float32", **kw)
    m = api.get_model(cfg)
    p = m.init_params(jax.random.key(0), cfg)
    toks = jax.random.randint(jax.random.key(8), (2, 12), 0, cfg.vocab_size)
    logits_full, _, _ = m.forward(p, toks, cfg)
    last, cache = m.prefill(p, toks[:, :11], cfg, max_len=16)
    assert float(jnp.abs(last - logits_full[:, 10]).max()) < 2e-2
    lg, cache = m.decode_step(p, toks[:, 11:12], cache,
                              jnp.full((2,), 12, jnp.int32), cfg)
    assert float(jnp.abs(lg - logits_full[:, 11]).max()) < 2e-2


def test_ring_cache_decode_matches_teacher_forcing():
    cfg = ModelConfig(name="swa", n_layers=2, d_model=64, n_heads=4,
                      n_kv_heads=2, d_ff=128, vocab_size=97, window_size=8,
                      dtype="float32")
    cfg_ring = cfg.with_(ring_cache=True)
    m = api.get_model(cfg)
    p = m.init_params(jax.random.key(0), cfg)
    toks = jax.random.randint(jax.random.key(1), (2, 30), 2, 90)
    logits_full, _, _ = m.forward(p, toks, cfg)
    _, cache = m.prefill(p, toks[:, :8], cfg_ring, max_len=8)
    for t in range(8, 30):
        cl = jnp.full((2,), t + 1, jnp.int32)
        lg, cache = m.decode_step(p, toks[:, t:t + 1], cache, cl, cfg_ring)
        assert float(jnp.abs(lg - logits_full[:, t]).max()) < 1e-4, t


def test_quantized_model_close_to_fp(tiny_cfg):
    from repro.quant.qlinear import quantize_model_params

    m = api.get_model(tiny_cfg)
    p = m.init_params(jax.random.key(0), tiny_cfg)
    qp = quantize_model_params(p)
    toks = jnp.ones((2, 16), jnp.int32)
    l1, _, _ = m.forward(p, toks, tiny_cfg)
    l2, _, _ = m.forward(qp, toks, tiny_cfg)
    # logits close in distribution: top-1 agreement mostly preserved
    agree = float(jnp.mean(jnp.argmax(l1, -1) == jnp.argmax(l2, -1)))
    assert agree > 0.9, agree
