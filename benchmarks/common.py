"""Shared benchmark helpers: timing, CSV rows, a pre-trained tiny model."""
from __future__ import annotations

import time
from functools import lru_cache

import jax
import jax.numpy as jnp

ROWS = []


def emit(name: str, us_per_call: float, derived: str = ""):
    ROWS.append((name, us_per_call, derived))
    print(f"{name},{us_per_call:.1f},{derived}")


def time_fn(fn, *args, iters: int = 5, warmup: int = 2) -> float:
    """Median wall time in µs (blocks on jax results)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append((time.perf_counter() - t0) * 1e6)
    ts.sort()
    return ts[len(ts) // 2]


@lru_cache(maxsize=1)
def trained_tiny():
    """Tiny math model trained ~100 steps (shared across benchmarks)."""
    from repro.configs.base import ModelConfig
    from repro.data.dataset import MathDataLoader
    from repro.data.tokenizer import ByteTokenizer
    from repro.models import api
    from repro.train.loop import train_loop
    from repro.train.optimizer import AdamWConfig

    tok = ByteTokenizer()
    cfg = ModelConfig(name="bench-tiny", n_layers=3, d_model=96, n_heads=6,
                      n_kv_heads=2, d_ff=256, vocab_size=tok.vocab_size,
                      dtype="float32", param_dtype="float32", remat="none")
    m = api.get_model(cfg)
    p = m.init_params(jax.random.key(0), cfg)
    loader = MathDataLoader(tok, batch_size=32, seq_len=64, seed=11,
                            max_terms=2, reasoning=False)
    oc = AdamWConfig(lr=2e-3, warmup_steps=20, total_steps=240)
    p, _ = train_loop(p, cfg, oc, iter(loader), n_steps=240, log_every=0,
                      log_fn=lambda *_: None)
    loader.close()
    return tok, cfg, p


def eval_ppl(params, cfg, tok, n_tasks: int = 64, seed: int = 99) -> float:
    """Masked-CE perplexity on held-out math tasks."""
    from repro.data.dataset import pack_documents
    from repro.data.tasks import gen_dataset
    from repro.train.loop import lm_loss

    tasks = gen_dataset(seed, n_tasks, reasoning=False, max_terms=2)
    t, y, m = pack_documents([(tk.prompt, tk.target) for tk in tasks], tok, 64)
    loss, _ = lm_loss(params, (jnp.asarray(t), jnp.asarray(y),
                               jnp.asarray(m)), cfg, None)
    return float(jnp.exp(loss))
