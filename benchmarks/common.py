"""Shared benchmark helpers: timing, CSV rows, a pre-trained tiny model,
and the BENCH_<area>.json snapshot machinery (record / envelope check)."""
from __future__ import annotations

import json
import os
import re
import time
from functools import lru_cache

import jax
import jax.numpy as jnp

ROWS = []


def emit(name: str, us_per_call: float, derived: str = ""):
    ROWS.append((name, us_per_call, derived))
    print(f"{name},{us_per_call:.1f},{derived}")


def time_fn(fn, *args, iters: int = 5, warmup: int = 2) -> float:
    """Median wall time in µs (blocks on jax results)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append((time.perf_counter() - t0) * 1e6)
    ts.sort()
    return ts[len(ts) // 2]


@lru_cache(maxsize=1)
def trained_tiny():
    """Tiny math model trained ~100 steps (shared across benchmarks)."""
    from repro.configs.base import ModelConfig
    from repro.data.dataset import MathDataLoader
    from repro.data.tokenizer import ByteTokenizer
    from repro.models import api
    from repro.train.loop import train_loop
    from repro.train.optimizer import AdamWConfig

    tok = ByteTokenizer()
    cfg = ModelConfig(name="bench-tiny", n_layers=3, d_model=96, n_heads=6,
                      n_kv_heads=2, d_ff=256, vocab_size=tok.vocab_size,
                      dtype="float32", param_dtype="float32", remat="none")
    m = api.get_model(cfg)
    p = m.init_params(jax.random.key(0), cfg)
    loader = MathDataLoader(tok, batch_size=32, seq_len=64, seed=11,
                            max_terms=2, reasoning=False)
    oc = AdamWConfig(lr=2e-3, warmup_steps=20, total_steps=240)
    p, _ = train_loop(p, cfg, oc, iter(loader), n_steps=240, log_every=0,
                      log_fn=lambda *_: None)
    loader.close()
    return tok, cfg, p


# ---------------------------------------------------------------------------
# Benchmark snapshots (BENCH_<area>.json): record + envelope check
# ---------------------------------------------------------------------------
#
# A snapshot freezes one benchmark area's rows.  ``check_snapshot`` compares
# a fresh run against the committed snapshot under an *envelope* policy:
#
# * error metrics (name contains "err", or relRMS) must not grow by more
#   than ERR_RATIO (accuracy must not silently rot);
# * "reduction" percentages (KV bytes, prefill tokens) must not drop more
#   than REDUCTION_SLACK_POINTS below the snapshot;
# * accuracy/hit-rate metrics must not drop more than ACC_SLACK;
# * wall times only fail on order-of-magnitude blowups — TIME_FACTOR× the
#   snapshot with a TIME_FLOOR_US floor (CI machines are noisy; the
#   trajectory is the signal, the gate only catches catastrophes).  Both
#   knobs are env-overridable (REPRO_BENCH_TIME_FACTOR / _TIME_FLOOR_US).
# * latency percentiles (``ttft_*``/``itl_*``/``queue_wait*``/
#   ``step_time*``, reported in ms) get the same catastrophe-only shape
#   with their own, even more generous knobs: LAT_FACTOR× the snapshot
#   with a LAT_FLOOR_MS floor (tail percentiles jitter far more than
#   medians on shared CI machines; the gate exists to catch a scheduler
#   regression that stalls requests, not a slow runner).  Env-overridable
#   via REPRO_BENCH_LAT_FACTOR / _LAT_FLOOR_MS.
# * a row present in the snapshot but missing from the run is a failure.
#
# Everything else rides along informationally — the snapshot file itself
# is the recorded perf trajectory.

ERR_RATIO = 4.0
REDUCTION_SLACK_POINTS = 5.0
ACC_SLACK = 0.26
_ACC_KEYS = ("accuracy", "fp_accuracy", "hit_rate")
_LAT_PREFIXES = ("ttft_", "itl_", "queue_wait", "step_time")


def _time_envelope() -> tuple[float, float]:
    return (float(os.environ.get("REPRO_BENCH_TIME_FACTOR", "10")),
            float(os.environ.get("REPRO_BENCH_TIME_FLOOR_US", "500")))


def _latency_envelope() -> tuple[float, float]:
    return (float(os.environ.get("REPRO_BENCH_LAT_FACTOR", "25")),
            float(os.environ.get("REPRO_BENCH_LAT_FLOOR_MS", "50")))


def parse_metrics(derived: str) -> dict:
    """Pull ``key=value`` numeric metrics out of a row's derived string
    (values like ``3.1e-07``, ``42%``, ``0.95`` all parse; prose such as
    ``(interpret-mode python timing)`` is ignored)."""
    out = {}
    for key, val in re.findall(r"(\w+)=([-+0-9.eE]+)%?", derived):
        try:
            out[key] = float(val)
        except ValueError:
            pass
    return out


def snapshot_path(area: str) -> str:
    return f"BENCH_{area}.json"


def snapshot(area: str, rows) -> dict:
    return {"version": 1, "area": area,
            "rows": [{"name": n, "us": round(us, 1), "derived": d,
                      "metrics": parse_metrics(d)} for n, us, d in rows]}


def write_snapshot(area: str, rows) -> str:
    path = snapshot_path(area)
    with open(path, "w") as f:
        json.dump(snapshot(area, rows), f, indent=2, sort_keys=True)
        f.write("\n")
    return path


def check_snapshot(area: str, rows, old: dict) -> list[str]:
    """Envelope-check fresh ``rows`` against a previously recorded
    snapshot dict; returns violation strings (empty = pass)."""
    new = {r["name"]: r for r in snapshot(area, rows)["rows"]}
    tf, tfloor = _time_envelope()
    bad = []
    for prev in old.get("rows", ()):
        name = prev["name"]
        cur = new.get(name)
        if cur is None:
            bad.append(f"{area}:{name}: row missing from this run")
            continue
        us_old, us_new = prev.get("us", 0.0), cur.get("us", 0.0)
        if us_old > 0 and us_new > tf * max(us_old, tfloor):
            bad.append(f"{area}:{name}: time {us_new:.1f}us > {tf:.0f}x "
                       f"envelope over {us_old:.1f}us")
        mo, mn = prev.get("metrics", {}), cur.get("metrics", {})
        for k, vo in mo.items():
            if k not in mn:
                continue
            vn = mn[k]
            if "err" in k or k == "relRMS":
                if vn > ERR_RATIO * vo + 1e-7:
                    bad.append(f"{area}:{name}: {k} {vn:.3g} > "
                               f"{ERR_RATIO:.0f}x snapshot {vo:.3g}")
            elif k.endswith("reduction"):
                if vn < vo - REDUCTION_SLACK_POINTS:
                    bad.append(f"{area}:{name}: {k} {vn:.1f} dropped > "
                               f"{REDUCTION_SLACK_POINTS:.0f} points below "
                               f"snapshot {vo:.1f}")
            elif k in _ACC_KEYS:
                if vn < vo - ACC_SLACK:
                    bad.append(f"{area}:{name}: {k} {vn:.3f} dropped > "
                               f"{ACC_SLACK} below snapshot {vo:.3f}")
            elif k.startswith(_LAT_PREFIXES):
                lf, lfloor = _latency_envelope()
                if vn > lf * max(vo, lfloor):
                    bad.append(f"{area}:{name}: {k} {vn:.1f}ms > "
                               f"{lf:.0f}x envelope over "
                               f"{max(vo, lfloor):.1f}ms")
    return bad


def eval_ppl(params, cfg, tok, n_tasks: int = 64, seed: int = 99) -> float:
    """Masked-CE perplexity on held-out math tasks."""
    from repro.data.dataset import pack_documents
    from repro.data.tasks import gen_dataset
    from repro.train.loop import lm_loss

    tasks = gen_dataset(seed, n_tasks, reasoning=False, max_terms=2)
    t, y, m = pack_documents([(tk.prompt, tk.target) for tk in tasks], tok, 64)
    loss, _ = lm_loss(params, (jnp.asarray(t), jnp.asarray(y),
                               jnp.asarray(m)), cfg, None)
    return float(jnp.exp(loss))
