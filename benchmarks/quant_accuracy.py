"""Tables 1 & 4: quantization-granularity and tile-vs-common accuracy.

Table 1 analogue — coarse per-channel (one scale per output column over the
whole K dim) vs fine-grained per-group quantization: held-out math PPL of a
trained tiny model + weight RMSE. Reproduces the claim that coarse
quantization destroys task performance while g=32 grouping preserves it.

Table 4 analogue — the paper's tile (2×16) groups vs conventional (32×1)
column groups: equivalent accuracy (the statistical-equivalence claim).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import emit, eval_ppl, time_fn, trained_tiny
from repro.quant import tile_quant as TQ
from repro.quant.qlinear import quantize_model_params


def _quantize_per_channel(w):
    """Coarse baseline: one scale per output column (the QNN-style scheme)."""
    absmax = jnp.max(jnp.abs(w), axis=0, keepdims=True)  # (1, N)
    sc = jnp.maximum(absmax / 8.0, 1e-8)
    codes = jnp.clip(jnp.round(w / sc), -8, 7)
    return codes * sc


def _apply(params, fn):
    def one(path, leaf):
        name = "/".join(str(getattr(k, "key", k)) for k in path)
        if leaf.ndim == 3 and name.endswith("/w"):
            return jax.vmap(fn)(leaf)
        if leaf.ndim == 2 and name.endswith("/w"):
            return fn(leaf)
        return leaf

    return jax.tree_util.tree_map_with_path(one, params)


def run():
    tok, cfg, params = trained_tiny()
    ppl_fp = eval_ppl(params, cfg, tok)

    # Table 1: per-channel vs per-group
    pc = _apply(params, _quantize_per_channel)
    ppl_pc = eval_ppl(pc, cfg, tok)
    grp = quantize_model_params(params, scheme="common")
    ppl_grp = eval_ppl(grp, cfg, tok)
    emit("tbl1.fp_ppl", 0, f"ppl={ppl_fp:.3f}")
    emit("tbl1.per_channel_ppl", 0, f"ppl={ppl_pc:.3f}")
    emit("tbl1.per_group_ppl", 0, f"ppl={ppl_grp:.3f}")

    # Table 4: tile vs common group (model + weight space)
    tile = quantize_model_params(params, scheme="tile")
    ppl_tile = eval_ppl(tile, cfg, tok)
    emit("tbl4.tile_group_ppl", 0, f"ppl={ppl_tile:.3f}")
    emit("tbl4.common_group_ppl", 0, f"ppl={ppl_grp:.3f}")

    w = jax.random.normal(jax.random.key(5), (512, 512)) * 0.05
    for scheme in ("tile", "common"):
        qw = TQ.quantize(w, scheme=scheme)
        rel = float(jnp.sqrt(jnp.mean((w - TQ.dequantize(qw)) ** 2)) /
                    jnp.sqrt(jnp.mean(w ** 2)))
        emit(f"tbl4.weight_relRMS.{scheme}", 0, f"rel={rel:.4f}")


if __name__ == "__main__":
    run()
