"""Benchmark harness: one section per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows (benchmarks/common.emit).

  python -m benchmarks.run            # everything
  python -m benchmarks.run --only fig14,fig15
"""
from __future__ import annotations

import argparse
import sys
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="",
                    help="comma list: quant,kernels,serving,roofline")
    args = ap.parse_args()
    only = set(args.only.split(",")) if args.only else None

    print("name,us_per_call,derived")
    sections = []
    if only is None or {"quant", "tbl1", "tbl4"} & only:
        from benchmarks import quant_accuracy
        sections.append(("quant_accuracy", quant_accuracy.run))
    if only is None or {"kernels", "fig14", "fig15", "tbl2", "tbl5"} & only:
        from benchmarks import kernel_ablation
        sections.append(("kernel_ablation", kernel_ablation.run))
    if only is None or {"serving", "fig8", "fig10", "fig11", "fig17"} & only:
        from benchmarks import serving_scaling
        sections.append(("serving_scaling", serving_scaling.run))
    if only is None or "roofline" in only:
        from benchmarks import roofline
        sections.append(("roofline", roofline.run))

    failed = []
    for name, fn in sections:
        try:
            fn()
        except Exception:  # noqa
            failed.append(name)
            traceback.print_exc()
    if failed:
        print(f"FAILED sections: {failed}", file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
