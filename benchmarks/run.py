"""Benchmark harness: one section per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows (benchmarks/common.emit).

  python -m benchmarks.run            # everything
  python -m benchmarks.run --only fig14,fig15

Snapshot mode (the recorded perf trajectory):

  python -m benchmarks.run --record --areas kernels,serving
  python -m benchmarks.run --check  --areas kernels,serving

``--record`` runs each area and (re)writes its ``BENCH_<area>.json``
snapshot; ``--check`` asserts the fresh rows against the committed
snapshot's envelope (see ``benchmarks.common.check_snapshot``) and exits
non-zero on violations.  Combined ``--check --record`` (what CI runs)
checks first, then refreshes the snapshot only for areas that passed, so
a regressed run cannot overwrite the evidence against it.
"""
from __future__ import annotations

import argparse
import sys
import traceback

from benchmarks import common


def snapshot_areas() -> dict:
    """Area name -> callable emitting that area's snapshot rows.

    ``kernels`` is the full kernel-ablation sweep (pure kernel work,
    stable shapes); ``serving`` is the dry serving sweep — small enough
    for CI, still exercising the paged / prefix-cache / kv-quant engines
    end to end with their built-in assertions.
    """
    from benchmarks import kernel_ablation, serving_scaling

    return {"kernels": kernel_ablation.run,
            "serving": serving_scaling.dry_rows}


def run_snapshots(areas, record: bool, check: bool) -> int:
    import json
    import os

    table = snapshot_areas()
    unknown = [a for a in areas if a not in table]
    if unknown:
        print(f"unknown areas {unknown}; have {sorted(table)}",
              file=sys.stderr)
        return 2
    failures = []
    for area in areas:
        mark = len(common.ROWS)
        table[area]()
        rows = common.ROWS[mark:]
        path = common.snapshot_path(area)
        ok = True
        if check:
            if os.path.exists(path):
                old = json.load(open(path))
                bad = common.check_snapshot(area, rows, old)
                for msg in bad:
                    print(f"ENVELOPE VIOLATION: {msg}", file=sys.stderr)
                ok = not bad
                failures.extend(bad)
            else:
                print(f"{path} not found; treating this run as the "
                      f"baseline", file=sys.stderr)
        if record and ok:
            print(f"recorded {common.write_snapshot(area, rows)}")
    return 1 if failures else 0


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="",
                    help="comma list: quant,kernels,serving,roofline")
    ap.add_argument("--record", action="store_true",
                    help="write BENCH_<area>.json snapshots for --areas")
    ap.add_argument("--check", action="store_true",
                    help="assert fresh rows against the committed "
                         "BENCH_<area>.json envelopes for --areas")
    ap.add_argument("--areas", default="kernels,serving",
                    help="comma list of snapshot areas (default "
                         "kernels,serving)")
    args = ap.parse_args()

    if args.record or args.check:
        print("name,us_per_call,derived")
        areas = [a for a in args.areas.split(",") if a]
        sys.exit(run_snapshots(areas, record=args.record, check=args.check))

    only = set(args.only.split(",")) if args.only else None

    print("name,us_per_call,derived")
    sections = []
    if only is None or {"quant", "tbl1", "tbl4"} & only:
        from benchmarks import quant_accuracy
        sections.append(("quant_accuracy", quant_accuracy.run))
    if only is None or {"kernels", "fig14", "fig15", "tbl2", "tbl5"} & only:
        from benchmarks import kernel_ablation
        sections.append(("kernel_ablation", kernel_ablation.run))
    if only is None or {"serving", "fig8", "fig10", "fig11", "fig17"} & only:
        from benchmarks import serving_scaling
        sections.append(("serving_scaling", serving_scaling.run))
    if only is None or "roofline" in only:
        from benchmarks import roofline
        sections.append(("roofline", roofline.run))

    failed = []
    for name, fn in sections:
        try:
            fn()
        except Exception:  # noqa
            failed.append(name)
            traceback.print_exc()
    if failed:
        print(f"FAILED sections: {failed}", file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
