"""Roofline analysis (deliverable g).

    compute    = FLOPs            / (chips × peak_FLOP/s)
    memory     = HBM bytes        / (chips × HBM_bw)
    collective = collective bytes / (chips × link_bw)

Hardware constants (TPU v5e): 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link.

Two sources are combined per cell:

* **measured** — the dry-run's compiled artifact (runs/dryrun/*.json):
  per-device cost_analysis FLOPs/bytes + collective bytes parsed from the
  HLO.  CAVEAT (documented in EXPERIMENTS.md): XLA cost analysis counts
  each while-loop *body once*, so scanned-layer models under-report by the
  trip count; and XLA:CPU materializes f32 shadows of bf16 weights.  The
  measured numbers are therefore per-layer-iteration evidence, not totals.

* **analytic** — closed-form totals from the architecture math below
  (linear-layer FLOPs, windowed attention, SSD, MoE capacity, FSDP/TP/
  flash-decode collective schedules as actually lowered).  The bottleneck
  verdict and §Perf iterations use the analytic terms; the measured HLO
  validates the per-iteration constants.
"""
from __future__ import annotations

import glob
import json
import os

# single-sourced with the kernel block-size autotuner's roofline model
from repro.kernels.autotune import HBM_BW, LINK_BW, PEAK_FLOPS

MESHES = {"16x16": dict(pod=1, data=16, model=16, chips=256),
          "2x16x16": dict(pod=2, data=16, model=16, chips=512)}


# ---------------------------------------------------------------------------
# Analytic model
# ---------------------------------------------------------------------------


def _attn_kv_len(cfg, S, layer_window):
    return min(S, layer_window) if layer_window else S


def analytic_terms(arch: str, shape_name: str, mesh: str = "16x16") -> dict:
    from repro.configs.base import SHAPES_BY_NAME
    from repro.configs.registry import get_config
    from repro.models.transformer import layer_windows

    cfg = get_config(arch)
    shape = SHAPES_BY_NAME[shape_name]
    m = MESHES[mesh]
    chips, n_data, n_model = m["chips"], m["data"], m["model"]
    B, S = shape.global_batch, shape.seq_len
    kind = shape.kind
    d, L, V = cfg.d_model, cfg.n_layers, cfg.vocab_size
    hd = cfg.resolved_head_dim()
    Hq, Hkv = cfg.n_heads, cfg.n_kv_heads

    n_total = cfg.param_count()
    n_active = cfg.param_count(active_only=True)
    emb = V * d * (1 if cfg.tie_embeddings else 2)
    n_body = (n_active or n_total) - emb          # per-token matmul params

    tokens = B * (S if kind != "decode" else 1)
    logit_tokens = B * S if kind == "train" else B

    # ---- FLOPs ---------------------------------------------------------------
    f = 2.0 * n_body * tokens + 2.0 * d * V * logit_tokens
    # attention scores+PV (2 matmuls, causal ≈ half for prefill/train)
    if cfg.family in ("transformer", "encdec"):
        try:
            wins = [int(w) for w in layer_windows(cfg)]
        except Exception:
            wins = [cfg.window_size] * L
        for w in wins:
            if kind == "decode":
                kv = _attn_kv_len(cfg, S, w)
                f += 2 * 2 * B * kv * Hq * hd
            else:
                kv = _attn_kv_len(cfg, S, w)
                f += 2 * 2 * B * S * kv * Hq * hd * (0.5 if not w else 1.0)
        if cfg.family == "encdec":
            Te = cfg.encoder_seq_len
            f += cfg.n_encoder_layers * 2 * 2 * B * Te * Te * Hq * hd
            f += L * 2 * 2 * B * (S if kind != "decode" else 1) * Te * Hq * hd
    if cfg.family in ("mamba2", "hybrid"):
        s = cfg.ssm
        H, P, N, Q = s.n_heads(d), s.head_dim, s.d_state, s.chunk_size
        if kind == "decode":
            f += L * 2 * B * H * P * N * 2           # state update + C·h
        else:
            # SSD: intra-chunk ~2·B·S·Q·(G·N + H·P); inter ~2·B·S·H·P·N/Q·Q
            f += L * (2 * B * S * Q * (s.ngroups * N + H * P)
                      + 2 * B * S * H * P * N)
        if cfg.family == "hybrid" and cfg.hybrid_attn_every:
            napp = L // cfg.hybrid_attn_every
            kv = S if kind != "decode" else S
            if kind == "decode":
                f += napp * 2 * 2 * B * S * Hq * hd
            else:
                f += napp * 2 * 2 * B * S * S * Hq * hd * 0.5
    if cfg.moe:
        # router (cheap) + capacity overhead ≈ ×cf on expert matmuls
        f *= 1.0  # capacity factor applied to expert share below
        expert_share = (3 * cfg.d_model * cfg.moe.expert_d_ff *
                        cfg.moe.top_k * L) * 2.0 * tokens
        f += expert_share * (cfg.moe.capacity_factor - 1.0)
    if kind == "train":
        f *= 3.0          # fwd + 2×bwd
        f *= 4.0 / 3.0    # full remat recomputes fwd once more

    # ---- HBM bytes (per chip, then totalled) ----------------------------------
    pb = 2.0  # bf16 weight bytes (serve); train master f32 handled below
    if kind == "train":
        # params f32 + grads + adam m,v (r+w each) + bf16 compute copy
        param_traffic = n_total * (4 + 4 + 4 * 4 + 2)
        # activations: remat saves one residual per layer (r+w+r)
        act = 3.0 * B * S * d * 2 * L
        hbm = param_traffic + act
    elif kind == "prefill":
        hbm = n_total * pb + 2 * B * S * Hkv * hd * 2 * L * 2  # + KV write
        hbm += 4.0 * B * S * d * 2 * L
    else:  # decode: weights once + KV cache read per step (+tiny writes)
        hbm = n_total * pb
        if cfg.family in ("transformer", "encdec"):
            wins = ([cfg.window_size] * L if cfg.window_size else [0] * L)
            try:
                wins = [int(w) for w in layer_windows(cfg)]
            except Exception:
                pass
            for w in wins:
                hbm += 2 * B * _attn_kv_len(cfg, S, w) * Hkv * hd * 2
        if cfg.family in ("mamba2", "hybrid"):
            s = cfg.ssm
            hbm += L * B * s.n_heads(d) * s.head_dim * s.d_state * 4 * 2
            if cfg.family == "hybrid" and cfg.hybrid_attn_every:
                hbm += (L // cfg.hybrid_attn_every) * 2 * B * S * Hkv * hd * 2
        if cfg.moe:
            # only active experts' weights are *needed*; dense layout reads
            # all resident experts once per step — count resident weights
            pass

    # ---- collective bytes (per chip) ------------------------------------------
    # training: FSDP all-gather params fwd+bwd (2×) + reduce-scatter grads
    #           (1×), each ≈ param bytes landing per chip; plus TP psums of
    #           activations (2 per layer, bf16, (B,S,d)/data-shard).
    if kind == "train":
        coll = 3.0 * (n_total * 2) / n_model      # AG×2 + RS over data, bf16
        coll += 2 * L * (B // (n_data * m["pod"])) * S * d * 2  # TP psums
        if m["pod"] > 1:
            coll += n_total * 4 / chips           # cross-pod grad reduce
    elif kind == "prefill":
        coll = 2 * L * (B // min(B, n_data * m["pod"]) if B else 1)
        coll = 2 * L * max(B // (n_data * m["pod"]), 1) * S * d * 2
    else:
        # decode: TP psum of (B,1,d) ×2/layer + flash-decode softmax merge
        coll = 2 * L * B * d * 2
        coll += L * B * Hq * hd * 4               # (o, m, l) psum merge
    total = {
        "flops": f,
        "hbm_bytes": hbm,
        "coll_bytes_per_chip": coll,
    }
    t_comp = f / (chips * PEAK_FLOPS)
    t_mem = hbm / (chips * HBM_BW)
    t_coll = coll / LINK_BW
    mult = 6 if kind == "train" else 2
    model_flops = mult * (n_active or n_total) * tokens
    terms = {"compute": t_comp, "memory": t_mem, "collective": t_coll}
    dom = max(terms, key=terms.get)
    bound = max(terms.values())
    frac = (model_flops / PEAK_FLOPS / chips) / bound if bound else 0.0
    return {
        "cell": f"{arch}:{shape_name}", "kind": kind, "mesh": mesh,
        "compute_s": t_comp, "memory_s": t_mem, "collective_s": t_coll,
        "dominant": dom, "model_flops": model_flops,
        "useful_ratio": model_flops / f if f else 0.0,
        "roofline_fraction": frac,
        **total,
    }


def suggest(kind: str, dom: str) -> str:
    if dom == "compute":
        return "compute-bound: raise MXU util (bigger per-chip microbatch, fusion)"
    if dom == "memory":
        if kind == "decode":
            return ("weight-bandwidth-bound: int4 tile-quant weights "
                    "(paper §5.1); batch amortizes HBM")
        return "bandwidth-bound: fuse elementwise, trim remat traffic"
    return ("collective-bound: overlap, int8-compressed reductions, "
            "resharding diet")


# ---------------------------------------------------------------------------
# Reporting
# ---------------------------------------------------------------------------


def measured(run_dir: str = "runs/dryrun", mesh: str = "16x16"):
    out = {}
    for path in sorted(glob.glob(os.path.join(run_dir, f"*__{mesh}.json"))):
        r = json.load(open(path))
        pd = r["per_device"]
        coll = sum(v["bytes"] for v in r.get("collectives", {}).values())
        out[f"{r['arch']}:{r['shape']}"] = {
            "hlo_flops_dev": pd["flops"],
            "hlo_bytes_dev": pd["bytes_accessed"],
            "coll_bytes_dev": coll,
            "args_mib": pd["argument_bytes"] / 2**20,
            "temp_mib": pd["temp_bytes"] / 2**20,
        }
    return out


def full_table(mesh: str = "16x16"):
    from repro.configs.registry import cells

    meas = measured(mesh=mesh)
    rows = []
    for arch, shape, runnable, reason in cells():
        if not runnable:
            rows.append({"cell": f"{arch}:{shape.name}", "skipped": reason})
            continue
        r = analytic_terms(arch, shape.name, mesh)
        r["suggestion"] = suggest(r["kind"], r["dominant"])
        r.update(meas.get(r["cell"], {}))
        rows.append(r)
    return rows


def to_markdown(rows) -> str:
    out = ["| cell | compute (s) | memory (s) | collective (s) | dominant | "
           "useful ratio | roofline frac | next lever |",
           "|---|---|---|---|---|---|---|---|"]
    for r in rows:
        if "skipped" in r:
            out.append(f"| {r['cell']} | — | — | — | SKIP | — | — | "
                       "full attention (DESIGN.md §5) |")
            continue
        out.append(
            f"| {r['cell']} | {r['compute_s']:.2e} | {r['memory_s']:.2e} | "
            f"{r['collective_s']:.2e} | **{r['dominant']}** | "
            f"{r['useful_ratio']:.2f} | {r['roofline_fraction']:.2f} | "
            f"{r['suggestion'].split(':')[0]} |")
    return "\n".join(out)


def run():
    from benchmarks.common import emit

    rows = full_table()
    for r in rows:
        if "skipped" in r:
            emit(f"roofline.{r['cell']}", 0, "SKIP (full attention)")
            continue
        emit(f"roofline.{r['cell']}", 0,
             f"dom={r['dominant']} comp={r['compute_s']:.2e}s "
             f"mem={r['memory_s']:.2e}s coll={r['collective_s']:.2e}s "
             f"useful={r['useful_ratio']:.2f} "
             f"frac={r['roofline_fraction']:.2f}")
    os.makedirs("runs", exist_ok=True)
    with open("runs/roofline.md", "w") as f:
        f.write(to_markdown(rows) + "\n")


if __name__ == "__main__":
    run()
