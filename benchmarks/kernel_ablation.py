"""Figures 14 & 15 + Tables 2 & 5: kernel-level ablations.

Fig. 14 — softmax exp implementations: LUT vs fp16 polynomial vs exact f32,
accuracy vs f64 + CPU wall time of the interpret-mode kernel (relative
ordering; absolute speed is TPU territory).

Fig. 15 — dequant-GEMM layouts: (a) conventional column-group layout with
the runtime scatter the paper describes (emulated with a gather), (b) tile
layout (unit-stride), (c) + coalesced packing (the Pallas kernel path),
(d) the no-dequantization upper bound (fp16 weights straight to matmul).

Table 2 — the matrix-vs-vector unit gap, analytic for TPU v5e (MXU 197
TFLOP/s bf16 vs VPU ~4 TFLOP/s) + measured CPU proxy.

Table 5 — LUT-fp16 attention vs f32 attention output error, plus the
fused LUT-softmax quantized paged-decode kernel vs its exact-f32 mode
(time + error against the f32 oracle).

The ``autotune.*`` rows time the dequant-GEMM block-size candidate set at
the Fig. 15 shape and record the measured winner in the autotune cache
(``repro.kernels.autotune``), which subsequent wrapper calls pick up.
"""
from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from benchmarks.common import emit, time_fn
from repro.kernels import ops, ref
from repro.kernels.lut_softmax_attention import build_exp_lut
from repro.quant import tile_quant as TQ

KEY = jax.random.key(0)


def fig14_softmax():
    lut = build_exp_lut()
    x = -jnp.abs(jax.random.normal(KEY, (64, 16384))).astype(jnp.float16)
    exact64 = np.exp(np.asarray(x, np.float64))

    from repro.kernels.lut_softmax_attention import _lut_exp, _poly_exp

    lut_fn = jax.jit(lambda v: _lut_exp(lut, v))
    poly_fn = jax.jit(_poly_exp)
    exact_fn = jax.jit(lambda v: jnp.exp(v.astype(jnp.float32)))
    for name, fn in [("lut", lut_fn), ("poly_f16", poly_fn),
                     ("exact_f32", exact_fn)]:
        t = time_fn(fn, x)
        err = float(np.abs(np.asarray(fn(x), np.float64) - exact64).max())
        emit(f"fig14.exp.{name}", t, f"max_err_vs_f64={err:.2e}")

    # full attention softmax path latency at (reduced) paper shapes (q x kv);
    # interpret mode executes the kernel body in python — relative ordering
    # only, absolute numbers are TPU territory.
    for (q, kv) in [(1, 1024), (16, 2048)]:
        qv = jax.random.normal(KEY, (2, max(q, 8), 4, 64)) * 0.5
        kvv = jax.random.normal(KEY, (2, kv, 4, 64)) * 0.5
        B, Sq, H, D = qv.shape
        kt = kvv.transpose(0, 2, 1, 3).reshape(B * H, kv, D)
        o32 = ref.attention_f32_ref(
            qv.transpose(0, 2, 1, 3).reshape(B * H, Sq, D), kt, kt,
            causal=False).reshape(B, H, Sq, D).transpose(0, 2, 1, 3)
        for mode in ("lut", "exact"):
            t = time_fn(lambda a, b, c: ops.flash_attention(
                a, b, c, causal=False, exp_mode=mode), qv, kvv, kvv,
                iters=2, warmup=1)
            o = ops.flash_attention(qv, kvv, kvv, causal=False,
                                    exp_mode=mode).astype(jnp.float32)
            err = float(jnp.abs(o - o32).max())
            emit(f"fig14.attn_q{q}_kv{kv}.{mode}", t,
                 f"max_err_vs_f32={err:.2e}")


def fig15_dequant_gemm():
    M, K, N = 16, 1024, 1024
    w = jax.random.normal(KEY, (K, N)) * 0.05
    x = jax.random.normal(jax.random.fold_in(KEY, 1), (M, K))
    qw_common = TQ.quantize(w, scheme="common")
    qw_tile = TQ.quantize(w, scheme="tile")

    # (a) baseline: conventional layout + runtime scatter (emulated: dequant
    # in group order then permute elements into matmul order with a gather)
    perm = jax.random.permutation(KEY, K * N).reshape(K, N)  # worst-case scatter

    def baseline(xv):
        wd = TQ.dequantize(qw_common, dtype=xv.dtype)
        wd = wd.reshape(-1)[perm.reshape(-1)].reshape(K, N)  # scatter cost
        return xv @ wd

    # (b) tile layout: unit-stride dequant then matmul
    def hmx_layout(xv):
        return xv @ TQ.dequantize(qw_tile, dtype=xv.dtype)

    # (c) ours: Pallas kernel, dequant fused in the MXU tile loop.  The
    # plan hoists the wrapper's scheme inference and block-size choice out
    # of the timed region, so this bar times the jitted kernel the same
    # way (a)/(b)/(d) time their jitted closures — previously the unjitted
    # wrapper re-ran that python work on every timed call, overstating the
    # fused bar's cost.
    fused = ops.plan_lut_dequant_matmul(qw_tile, m=M)

    # (d) upper bound: no dequantization
    w16 = w.astype(jnp.bfloat16)

    def no_dequant(xv):
        return xv @ w16.astype(xv.dtype)

    t_base = time_fn(jax.jit(baseline), x, iters=3)
    t_hmx = time_fn(jax.jit(hmx_layout), x, iters=3)
    t_fused = time_fn(fused, x, iters=3)
    t_ub = time_fn(jax.jit(no_dequant), x, iters=3)

    # accuracy of each bar against its own f32 unfused reference product
    # (the scatter baseline computes a deliberately permuted weight, so
    # its reference permutes the same way — the metric checks the *path*,
    # timing emulation included, not the permutation).  The interesting
    # bar is (c): the fused Pallas kernel must reproduce the unfused
    # f32 dequant-then-matmul; (d) shows the bf16 weight-cast error.
    ref_scatter = x @ TQ.dequantize(qw_common, dtype=jnp.float32) \
        .reshape(-1)[perm.reshape(-1)].reshape(K, N)
    ref_tile = x @ TQ.dequantize(qw_tile, dtype=jnp.float32)
    err_base = float(jnp.abs(jax.jit(baseline)(x) - ref_scatter).max())
    err_hmx = float(jnp.abs(jax.jit(hmx_layout)(x) - ref_tile).max())
    err_fused = float(jnp.abs(fused(x) - ref_tile).max())
    err_ub = float(jnp.abs(jax.jit(no_dequant)(x) - x @ w).max())

    emit("fig15.baseline_scatter", t_base,
         f"speedup=1.0 max_err_vs_f32={err_base:.2e} "
         "(conventional group layout + runtime permute)")
    emit("fig15.hmx_tile_layout", t_hmx,
         f"speedup={t_base / t_hmx:.2f} max_err_vs_f32={err_hmx:.2e} "
         "(tile layout: unit-stride dequant, no permute)")
    emit("fig15.ours_fused_kernel", t_fused,
         f"speedup={t_base / t_fused:.2f} max_err_vs_f32={err_fused:.2e} "
         "(interpret-mode python timing; "
         "on TPU the fused kernel also removes the HBM round-trip of the "
         "dequantized weights)")
    emit("fig15.no_dequant_bound", t_ub,
         f"speedup={t_base / t_ub:.2f} max_err_vs_f32={err_ub:.2e}")
    # the perf-relevant byte counts (HBM traffic per call, analytic)
    int4_bytes = K * N // 2 + (K // 2) * (N // 16) * 2
    bf16_bytes = K * N * 2
    emit("fig15.bytes_int4_weights", 0, f"{int4_bytes}")
    emit("fig15.bytes_bf16_weights", 0,
         f"{bf16_bytes} ({bf16_bytes / int4_bytes:.2f}x more HBM traffic)")


def autotune_gemm():
    """Measure the dequant-GEMM block-size candidates at the Fig. 15 shape
    and record the winner in the autotune cache (``runs/autotune.json``) —
    subsequent ``lut_dequant_matmul`` calls at this shape pick it up.
    Interpret-mode timings only order candidates by python-loop trip
    count, but the record/lookup plumbing is identical on TPU."""
    from repro.kernels import autotune as AT
    from repro.kernels import lut_dequant_gemm as G

    M, K, N = 16, 1024, 1024
    g = 32
    w = jax.random.normal(KEY, (K, N)) * 0.05
    x = jax.random.normal(jax.random.fold_in(KEY, 1), (M, K))
    qw = TQ.quantize(w, scheme="tile")
    bm = AT.pick_block(M, 128)
    best, best_us = None, float("inf")
    for bn in AT.block_candidates(N, 256, g // 2, max_candidates=2):
        for bk in AT.block_candidates(K, 128, 2, max_candidates=2):
            fn = lambda xv: G.lut_dequant_gemm(
                xv, qw["codes"], qw["scales"], qw["codebook"], scheme="tile",
                group_size=g, bm=bm, bn=bn, bk=bk, interpret=ops.INTERPRET)
            t = time_fn(fn, x, iters=2, warmup=1)
            emit(f"autotune.gemm_bm{bm}_bn{bn}_bk{bk}", t, "")
            if t < best_us:
                best, best_us = (bm, bn, bk), t
    AT.record(AT.gemm_key(M, K, N, "tile", g), best, best_us)
    emit("autotune.gemm_best", best_us,
         f"blocks={best} recorded_in={AT.cache_path()}")


def paged_lut_attention():
    """Fused LUT-softmax quantized paged decode vs the exact-f32 mode:
    wall time of both paths plus the LUT path's error against the f32
    oracle (the Table-5 envelope applied to the paged decode kernel)."""
    import numpy as np

    from repro.serving import kv_quant as KQ

    B, W, bs, Hkv, G, D = 2, 4, 4, 2, 2, 32
    nb = 1 + B * W
    rng = np.random.default_rng(5)
    kp = jnp.asarray(rng.normal(size=(nb, bs, Hkv, D)), jnp.float32) * 0.5
    vp = jnp.asarray(rng.normal(size=(nb, bs, Hkv, D)), jnp.float32) * 0.5
    q = jnp.asarray(rng.normal(size=(B, 1, Hkv * G, D)), jnp.float32) * 0.5
    avail = list(range(1, nb))
    table = np.zeros((B, W), np.int32)
    for b in range(B):
        for j in range(W):
            table[b, j] = avail.pop(rng.integers(len(avail)))
    table = jnp.asarray(table)
    lens = jnp.asarray([W * bs, 2 * bs + 3], jnp.int32)
    kq = KQ.quantize_kv(kp, mode="q8", gr=2, gc=16)
    vq = KQ.quantize_kv(vp, mode="q8", gr=2, gc=16)

    qg = q.reshape(B, Hkv, G, D)
    o32 = ref.quant_paged_decode_attention_ref(qg, kq, vq, table, lens)
    for mode in ("exact", "lut"):
        fn = lambda a: ops.paged_flash_decode(a, kq, vq, table, lens,
                                              exp_mode=mode)
        t = time_fn(fn, q, iters=3, warmup=1)
        o = fn(q).reshape(B, Hkv, G, D).astype(jnp.float32)
        err = float(jnp.abs(o - o32).max())
        emit(f"tbl5.quant_paged_decode.{mode}", t,
             f"max_err_vs_f32={err:.2e}")


def tbl2_unit_gap():
    # analytic v5e: MXU 197 TFLOP/s bf16; VPU ≈ 8 lanes*128*2ops*0.94GHz/core…
    emit("tbl2.v5e_mxu_tflops", 0, "197")
    emit("tbl2.v5e_vpu_tflops_est", 0, "~4 (≈50x gap; Hexagon's was ~365x)")
    # CPU proxy: matmul vs elementwise throughput on this host
    a = jax.random.normal(KEY, (1024, 1024))
    mm = jax.jit(lambda v: v @ v)
    ew = jax.jit(lambda v: jax.nn.silu(v) * v + 1.0)
    t_mm = time_fn(mm, a, iters=3)
    t_ew = time_fn(ew, a, iters=3)
    gf_mm = 2 * 1024 ** 3 / (t_mm * 1e-6) / 1e9
    gf_ew = 3 * 1024 ** 2 / (t_ew * 1e-6) / 1e9
    emit("tbl2.cpu_matmul_gflops", t_mm, f"{gf_mm:.1f}")
    emit("tbl2.cpu_elementwise_gflops", t_ew, f"{gf_ew:.1f}")


def tbl5_attention_accuracy():
    B, S, H, D = 2, 256, 4, 64
    q = jax.random.normal(jax.random.fold_in(KEY, 2), (B, S, H, D)) * 0.5
    k = jax.random.normal(jax.random.fold_in(KEY, 3), (B, S, H, D)) * 0.5
    v = jax.random.normal(jax.random.fold_in(KEY, 4), (B, S, H, D)) * 0.5
    o_lut = ops.flash_attention(q, k, v, causal=True, exp_mode="lut")
    qt = q.transpose(0, 2, 1, 3).reshape(B * H, S, D)
    o32 = ref.attention_f32_ref(qt, k.transpose(0, 2, 1, 3).reshape(B * H, S, D),
                                v.transpose(0, 2, 1, 3).reshape(B * H, S, D),
                                causal=True)
    o32 = o32.reshape(B, H, S, D).transpose(0, 2, 1, 3)
    err = float(jnp.abs(o_lut.astype(jnp.float32) - o32).max())
    rel = float(jnp.sqrt(jnp.mean((o_lut.astype(jnp.float32) - o32) ** 2)) /
                jnp.sqrt(jnp.mean(o32 ** 2)))
    emit("tbl5.lut16_vs_f32_attention", 0, f"max_err={err:.2e} relRMS={rel:.2e}")


def run():
    fig14_softmax()
    fig15_dequant_gemm()
    autotune_gemm()
    tbl2_unit_gap()
    tbl5_attention_accuracy()
    paged_lut_attention()


if __name__ == "__main__":
    run()
