"""Figures 14 & 15 + Tables 2 & 5: kernel-level ablations.

Fig. 14 — softmax exp implementations: LUT vs fp16 polynomial vs exact f32,
accuracy vs f64 + CPU wall time of the interpret-mode kernel (relative
ordering; absolute speed is TPU territory).

Fig. 15 — dequant-GEMM layouts: (a) conventional column-group layout with
the runtime scatter the paper describes (emulated with a gather), (b) tile
layout (unit-stride), (c) + coalesced packing (the Pallas kernel path),
(d) the no-dequantization upper bound (fp16 weights straight to matmul).

Table 2 — the matrix-vs-vector unit gap, analytic for TPU v5e (MXU 197
TFLOP/s bf16 vs VPU ~4 TFLOP/s) + measured CPU proxy.

Table 5 — LUT-fp16 attention vs f32 attention output error.
"""
from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from benchmarks.common import emit, time_fn
from repro.kernels import ops, ref
from repro.kernels.lut_softmax_attention import build_exp_lut
from repro.quant import tile_quant as TQ

KEY = jax.random.key(0)


def fig14_softmax():
    lut = build_exp_lut()
    x = -jnp.abs(jax.random.normal(KEY, (64, 16384))).astype(jnp.float16)
    exact64 = np.exp(np.asarray(x, np.float64))

    from repro.kernels.lut_softmax_attention import _lut_exp, _poly_exp

    lut_fn = jax.jit(lambda v: _lut_exp(lut, v))
    poly_fn = jax.jit(_poly_exp)
    exact_fn = jax.jit(lambda v: jnp.exp(v.astype(jnp.float32)))
    for name, fn in [("lut", lut_fn), ("poly_f16", poly_fn),
                     ("exact_f32", exact_fn)]:
        t = time_fn(fn, x)
        err = float(np.abs(np.asarray(fn(x), np.float64) - exact64).max())
        emit(f"fig14.exp.{name}", t, f"max_err_vs_f64={err:.2e}")

    # full attention softmax path latency at (reduced) paper shapes (q x kv);
    # interpret mode executes the kernel body in python — relative ordering
    # only, absolute numbers are TPU territory.
    for (q, kv) in [(1, 1024), (16, 2048)]:
        qv = jax.random.normal(KEY, (2, max(q, 8), 4, 64)) * 0.5
        kvv = jax.random.normal(KEY, (2, kv, 4, 64)) * 0.5
        for mode in ("lut", "exact"):
            t = time_fn(lambda a, b, c: ops.flash_attention(
                a, b, c, causal=False, exp_mode=mode), qv, kvv, kvv,
                iters=2, warmup=1)
            emit(f"fig14.attn_q{q}_kv{kv}.{mode}", t, "")


def fig15_dequant_gemm():
    M, K, N = 16, 1024, 1024
    w = jax.random.normal(KEY, (K, N)) * 0.05
    x = jax.random.normal(jax.random.fold_in(KEY, 1), (M, K))
    qw_common = TQ.quantize(w, scheme="common")
    qw_tile = TQ.quantize(w, scheme="tile")

    # (a) baseline: conventional layout + runtime scatter (emulated: dequant
    # in group order then permute elements into matmul order with a gather)
    perm = jax.random.permutation(KEY, K * N).reshape(K, N)  # worst-case scatter

    def baseline(xv):
        wd = TQ.dequantize(qw_common, dtype=xv.dtype)
        wd = wd.reshape(-1)[perm.reshape(-1)].reshape(K, N)  # scatter cost
        return xv @ wd

    # (b) tile layout: unit-stride dequant then matmul
    def hmx_layout(xv):
        return xv @ TQ.dequantize(qw_tile, dtype=xv.dtype)

    # (c) ours: Pallas kernel, dequant fused in the MXU tile loop
    def fused(xv):
        return ops.lut_dequant_matmul(xv, qw_tile)

    # (d) upper bound: no dequantization
    w16 = w.astype(jnp.bfloat16)

    def no_dequant(xv):
        return xv @ w16.astype(xv.dtype)

    t_base = time_fn(jax.jit(baseline), x, iters=3)
    t_hmx = time_fn(jax.jit(hmx_layout), x, iters=3)
    t_fused = time_fn(fused, x, iters=3)
    t_ub = time_fn(jax.jit(no_dequant), x, iters=3)

    emit("fig15.baseline_scatter", t_base,
         "speedup=1.0 (conventional group layout + runtime permute)")
    emit("fig15.hmx_tile_layout", t_hmx,
         f"speedup={t_base / t_hmx:.2f} (tile layout: unit-stride dequant, "
         "no permute)")
    emit("fig15.ours_fused_kernel", t_fused,
         f"speedup={t_base / t_fused:.2f} (interpret-mode python timing; "
         "on TPU the fused kernel also removes the HBM round-trip of the "
         "dequantized weights)")
    emit("fig15.no_dequant_bound", t_ub, f"speedup={t_base / t_ub:.2f}")
    # the perf-relevant byte counts (HBM traffic per call, analytic)
    int4_bytes = K * N // 2 + (K // 2) * (N // 16) * 2
    bf16_bytes = K * N * 2
    emit("fig15.bytes_int4_weights", 0, f"{int4_bytes}")
    emit("fig15.bytes_bf16_weights", 0,
         f"{bf16_bytes} ({bf16_bytes / int4_bytes:.2f}x more HBM traffic)")


def tbl2_unit_gap():
    # analytic v5e: MXU 197 TFLOP/s bf16; VPU ≈ 8 lanes*128*2ops*0.94GHz/core…
    emit("tbl2.v5e_mxu_tflops", 0, "197")
    emit("tbl2.v5e_vpu_tflops_est", 0, "~4 (≈50x gap; Hexagon's was ~365x)")
    # CPU proxy: matmul vs elementwise throughput on this host
    a = jax.random.normal(KEY, (1024, 1024))
    mm = jax.jit(lambda v: v @ v)
    ew = jax.jit(lambda v: jax.nn.silu(v) * v + 1.0)
    t_mm = time_fn(mm, a, iters=3)
    t_ew = time_fn(ew, a, iters=3)
    gf_mm = 2 * 1024 ** 3 / (t_mm * 1e-6) / 1e9
    gf_ew = 3 * 1024 ** 2 / (t_ew * 1e-6) / 1e9
    emit("tbl2.cpu_matmul_gflops", t_mm, f"{gf_mm:.1f}")
    emit("tbl2.cpu_elementwise_gflops", t_ew, f"{gf_ew:.1f}")


def tbl5_attention_accuracy():
    B, S, H, D = 2, 256, 4, 64
    q = jax.random.normal(jax.random.fold_in(KEY, 2), (B, S, H, D)) * 0.5
    k = jax.random.normal(jax.random.fold_in(KEY, 3), (B, S, H, D)) * 0.5
    v = jax.random.normal(jax.random.fold_in(KEY, 4), (B, S, H, D)) * 0.5
    o_lut = ops.flash_attention(q, k, v, causal=True, exp_mode="lut")
    qt = q.transpose(0, 2, 1, 3).reshape(B * H, S, D)
    o32 = ref.attention_f32_ref(qt, k.transpose(0, 2, 1, 3).reshape(B * H, S, D),
                                v.transpose(0, 2, 1, 3).reshape(B * H, S, D),
                                causal=True)
    o32 = o32.reshape(B, H, S, D).transpose(0, 2, 1, 3)
    err = float(jnp.abs(o_lut.astype(jnp.float32) - o32).max())
    rel = float(jnp.sqrt(jnp.mean((o_lut.astype(jnp.float32) - o32) ** 2)) /
                jnp.sqrt(jnp.mean(o32 ** 2)))
    emit("tbl5.lut16_vs_f32_attention", 0, f"max_err={err:.2e} relRMS={rel:.2e}")


def run():
    fig14_softmax()
    fig15_dequant_gemm()
    tbl2_unit_gap()
    tbl5_attention_accuracy()


if __name__ == "__main__":
    run()
