"""Figures 8, 11, 17 + the Fig. 5/10 headline result.

Fig. 8  — attention cost breakdown (matmul vs softmax/exp share) vs query
          length, from HLO FLOPs of the two sub-computations.
Fig. 11 — decode throughput vs batch size: the free-MXU claim.  On CPU we
          report measured step time; the sub-linear growth (time(b=16) ≪
          16×time(b=1)) is the paper's core observation.
Fig. 17 — decode throughput vs prompt length.
Fig. 5/10 — accuracy vs TTS budget (Best-of-N w/ oracle ORM, self-
          consistency) on held-out verifiable math with the trained tiny
          model; demonstrates accuracy scaling with parallel budget.
serving.paged — the paged-KV counterpart of serving.continuous: the same
          mixed workload through a block-pooled engine, reporting peak
          blocks/bytes in use vs the dense per-slot reservation.
serving.prefix_cache — a shared-few-shot-header workload through the
          paged engine with and without the cross-request prefix cache:
          the radix tree serves the common header from pinned pool
          blocks, so the cached run prefills >= 50% fewer prompt tokens
          at identical outputs; cache-aware admission is *batched*
          (runs of same-width hits share one partial prefill), so the
          row also asserts prefill_calls_per_request < 1.
serving.kv_quant — the paged workload with the KV pool stored as
          tile-quantized Q8 (or Q4) blocks vs fp, at equal slots: peak
          KV bytes must drop >= 40% while greedy accuracy on the math
          task stays within one task of the fp run (the §5.1 weight
          story compounded onto the paged KV saving).
serving.beam — step-level PRM beam search as a scheduler workload vs the
          direct per-task loop: asserts greedy bit-parity, a leak-free
          pool after both paths, and batched PRM scoring (one scorer
          forward per scoring boundary) before reporting tree metrics.
serving.latency — tail-latency percentiles (TTFT / inter-token / queue
          wait / step time) for a mixed chat + Best-of-N + beam workload
          on a deliberately tight paged pool, recorded by the request-
          lifecycle Tracer; the emitted *_ms metrics are enforced by the
          snapshot check's latency envelope and the in-memory Chrome
          trace must pass schema validation before the row emits.
serving.speculative — the paged mixed workload decoded draft-then-verify
          (self-drafting, k=4) against the plain greedy runs on fp AND q8
          pools: greedy outputs must be bit-identical on both, the pool
          leak-free after every run, and the acceptance counters live
          (`spec_acceptance_rate` > 0, `accepted_tokens_per_step` > 1).
          The emitted ``spec_accept_reduction`` percentage (accepted /
          drafted) rides the snapshot check's reduction envelope, so an
          acceptance regression > 5 points fails ``--check``.
serving.profile — the paged q8 greedy workload under the roofline-
          attributed KernelProfiler with the numerics-drift canary armed:
          per-kernel achieved-vs-peak efficiency and the kernel-time
          share of step wall, plus the canary's max logit error / argmax
          flip rate / KV round-trip error.  Asserts flip rate == 0 (the
          exact-path replica must agree bit-for-bit with greedy q8
          production) and that the report passes schema validation; the
          drift metrics are named ``*err*`` so the snapshot check's
          error envelope arms against numerics rot.

Standalone smoke (CI keeps the paged paths alive):

    PYTHONPATH=src python -m benchmarks.serving_scaling --paged --dry
    PYTHONPATH=src python -m benchmarks.serving_scaling --prefix-cache --dry
    PYTHONPATH=src python -m benchmarks.serving_scaling --kv-quant q8 --dry
    PYTHONPATH=src python -m benchmarks.serving_scaling --beam --dry
    PYTHONPATH=src python -m benchmarks.serving_scaling --latency --dry
    PYTHONPATH=src python -m benchmarks.serving_scaling --profile --dry
    PYTHONPATH=src python -m benchmarks.serving_scaling --speculative --dry
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp

from benchmarks.common import emit, time_fn, trained_tiny
from repro.core import reward as R
from repro.core.best_of_n import best_of_n
from repro.core.self_consistency import self_consistency
from repro.data import tasks as T
from repro.serving.engine import (BeamSpec, ContinuousScheduler, DecodeEngine,
                                  Request, SpecConfig)
from repro.serving.prefix_cache import PrefixCache
from repro.serving.sampler import SamplerConfig
from repro.serving.telemetry import Tracer, validate_chrome_trace


def fig8_attention_breakdown():
    import math

    B, H, D = 1, 8, 64
    kv = 4096
    for q in (1, 4, 16):
        flops_mm = 2 * B * H * q * kv * D * 2      # QK^T + PV
        flops_exp = B * H * q * kv                  # one exp per score
        # bytes: scores materialize q*kv f16 twice (S and P)
        emit(f"fig8.q{q}_kv{kv}", 0,
             f"matmul_flops={flops_mm:.2e} exp_ops={flops_exp:.2e} "
             f"exp_share_of_vector_work=1.0")


def fig11_decode_throughput():
    tok, cfg, params = trained_tiny()
    base = None
    for batch in (1, 2, 4, 8, 16):
        eng = DecodeEngine(params, cfg, max_len=64, eos_id=999)
        toks = jnp.ones((batch, 8), jnp.int32)
        st = eng.prefill(toks)
        sc = SamplerConfig(greedy=True)

        def step(s):
            s2, _ = eng._step_jit(eng.params, s, jax.random.key(0), sc=sc)
            return s2.pending_logits

        t = time_fn(step, st, iters=5)
        if base is None:
            base = t
        tput = batch / (t * 1e-6)
        emit(f"fig11.decode_b{batch}", t,
             f"tok_per_s={tput:.0f} rel_time_vs_b1={t / base:.2f}")


def fig17_prompt_length():
    tok, cfg, params = trained_tiny()
    for plen in (16, 32, 64, 128):
        eng = DecodeEngine(params, cfg, max_len=plen + 16, eos_id=999)
        toks = jnp.ones((4, plen), jnp.int32)
        st = eng.prefill(toks)
        sc = SamplerConfig(greedy=True)

        def step(s):
            s2, _ = eng._step_jit(eng.params, s, jax.random.key(0), sc=sc)
            return s2.pending_logits

        t = time_fn(step, st, iters=5)
        emit(f"fig17.decode_prompt{plen}", t, f"tok_per_s={4 / (t * 1e-6):.0f}")


def fig10_tts_scaling(n_tasks: int = 12):
    tok, cfg, params = trained_tiny()
    eng = DecodeEngine(params, cfg, max_len=96, eos_id=tok.eos_id,
                       pad_id=tok.pad_id)
    tasks = T.gen_dataset(31, n_tasks, reasoning=False, max_terms=2)
    scorer = R.OracleVerifier()
    for n in (1, 2, 4, 8, 16):
        rng = jax.random.key(n)
        correct = cost = 0
        for task in tasks:
            rng, k = jax.random.split(rng)
            r = best_of_n(eng, tok, task, n=n, max_tokens=10, rng=k,
                          scorer=scorer, sc=SamplerConfig(temperature=0.9))
            correct += int(r.correct)
            cost += r.decode_tokens
        emit(f"fig10.best_of_{n}", 0,
             f"accuracy={correct / n_tasks:.3f} decode_tokens={cost}")
    for n in (4, 16):
        rng = jax.random.key(100 + n)
        correct = 0
        for task in tasks:
            rng, k = jax.random.split(rng)
            r = self_consistency(eng, tok, task, n=n, max_tokens=10, rng=k,
                                 sc=SamplerConfig(temperature=0.9))
            correct += int(r.correct)
        emit(f"fig10.self_consistency_{n}", 0,
             f"accuracy={correct / n_tasks:.3f}")


def continuous_serving(n_requests: int = 10, n_slots: int = 4):
    """Continuous-batching serving metrics: mixed-length traffic plus one
    Best-of-4 TTS group through the slot scheduler.  Reports per-step slot
    occupancy (how full the decode batch stays under churn), requests/s and
    the prefill/decode token split — the serving-layer counterpart of the
    Fig. 11 free-MXU claim."""
    tok, cfg, params = trained_tiny()
    eng = DecodeEngine(params, cfg, max_len=96, eos_id=tok.eos_id,
                       pad_id=tok.pad_id)
    tasks = T.gen_dataset(77, n_requests, reasoning=False, max_terms=2)
    # warmup: compile every admission shape the timed run could hit
    # (prefill/merge at each batch size 1..n_slots, fork, decode step) —
    # release timing is data-dependent, so shapes are warmed explicitly
    # rather than through a throwaway drain
    wprompt = jnp.asarray(tok.encode(tasks[0].prompt))
    L = int(wprompt.shape[0])
    state = eng.empty_state(n_slots)
    for b in range(1, n_slots + 1):
        padded = jnp.full((b, 24), tok.pad_id, jnp.int32)
        padded = padded.at[:, :L].set(jnp.tile(wprompt, (b, 1)))
        st = eng.prefill(padded, jnp.full((b,), L, jnp.int32))
        if b == 1:
            eng.fork(st, 4)
        state = eng.merge_rows(state, st, jnp.arange(b, dtype=jnp.int32),
                               donate=True)
    state, _ = eng.step(state, jax.random.key(1), SamplerConfig(greedy=True),
                        stop_ids=(tok.eos_id,))
    sched = ContinuousScheduler(eng, n_slots=n_slots, prompt_len=24,
                                stop_ids=(tok.eos_id,))
    for i, task in enumerate(tasks):
        # alternate short/long budgets so slots churn at different times
        sched.submit(Request(req_id=i,
                             prompt=jnp.asarray(tok.encode(task.prompt)),
                             max_new_tokens=4 + 8 * (i % 3)))
    sched.submit(Request(req_id=n_requests,
                         prompt=jnp.asarray(tok.encode(tasks[0].prompt)),
                         max_new_tokens=8, n_samples=4))
    sched.run(jax.random.key(0), SamplerConfig(greedy=True))
    s = sched.metrics.summary()
    emit("serving.continuous", s["wall_s"] * 1e6,
         f"slots={s['n_slots']} occupancy={s['avg_slot_occupancy']:.2f} "
         f"requests_per_s={s['requests_per_s']:.1f} "
         f"decode_tok_per_s={s['decode_tok_per_s']:.0f} "
         f"prefill_tokens={s['prefill_tokens']} "
         f"decode_tokens={s['decode_tokens']} "
         f"prefills={sched.n_prefills} steps={s['steps']}")


def _untrained_tiny():
    """Init-only tiny model for --dry smoke runs (no training loop)."""
    from repro.configs.base import ModelConfig
    from repro.data.tokenizer import ByteTokenizer
    from repro.models import api

    tok = ByteTokenizer()
    cfg = ModelConfig(name="dry-tiny", n_layers=2, d_model=64, n_heads=4,
                      n_kv_heads=2, d_ff=192, vocab_size=tok.vocab_size,
                      dtype="float32", param_dtype="float32", remat="none")
    params = api.get_model(cfg).init_params(jax.random.key(0), cfg)
    return tok, cfg, params


def paged_serving(n_requests: int = 10, n_slots: int = 4,
                  block_size: int = 8, dry: bool = False):
    """serving.paged: the continuous_serving workload on a paged-KV engine.

    Reports the paged pool's *peak logical* block/byte usage against the
    dense engine's up-front ``n_slots × max_len`` reservation —
    ``hbm_saved`` is what a pool right-sized to this workload frees at
    equal slot count (the benchmark's own pool is provisioned generously,
    see ``pool_reserved``; sizing it down to peak is the operator's knob).
    """
    if dry:
        tok, cfg, params = _untrained_tiny()
        n_requests = 4
    else:
        tok, cfg, params = trained_tiny()
    max_len = 96
    from repro.serving.kv_pool import dense_kv_bytes

    eng = DecodeEngine(params, cfg, max_len=max_len, eos_id=tok.eos_id,
                       pad_id=tok.pad_id, paged=True, block_size=block_size,
                       n_blocks=1 + n_slots * (max_len // block_size))
    tasks = T.gen_dataset(77, n_requests, reasoning=False, max_terms=2)
    sched = ContinuousScheduler(eng, n_slots=n_slots, prompt_len=24,
                                stop_ids=(tok.eos_id,))
    for i, task in enumerate(tasks):
        sched.submit(Request(req_id=i,
                             prompt=jnp.asarray(tok.encode(task.prompt)),
                             max_new_tokens=4 + 8 * (i % 3)))
    sched.submit(Request(req_id=n_requests,
                         prompt=jnp.asarray(tok.encode(tasks[0].prompt)),
                         max_new_tokens=8, n_samples=4))
    sched.run(jax.random.key(0), SamplerConfig(greedy=True))
    s = sched.metrics.summary()
    kv = eng.pool.stats()
    dense_bytes = dense_kv_bytes(cfg, n_slots, max_len)
    assert kv["blocks_in_use"] == 0, "paged pool leaked blocks"
    emit("serving.paged", s["wall_s"] * 1e6,
         f"slots={s['n_slots']} block_size={block_size} "
         f"occupancy={s['avg_slot_occupancy']:.2f} "
         f"requests_per_s={s['requests_per_s']:.1f} "
         f"decode_tokens={s['decode_tokens']} "
         f"preemptions={s['preemptions']} "
         f"peak_blocks={kv['peak_blocks_in_use']} "
         f"cow_copies={kv['cow_copies']} "
         f"peak_kv_bytes={kv['peak_bytes_in_use']} "
         f"pool_reserved={kv['pool_reserved_bytes']} "
         f"dense_kv_bytes={dense_bytes} "
         f"hbm_saved_rightsized={dense_bytes - kv['peak_bytes_in_use']} "
         f"({(1 - kv['peak_bytes_in_use'] / dense_bytes) * 100:.0f}%)")


def prefix_cache_serving(n_requests: int = 10, n_slots: int = 3,
                         block_size: int = 8, dry: bool = False):
    """serving.prefix_cache: a shared-system-prompt workload with and
    without the cross-request prefix cache.

    Every request carries the same few-shot header (the paper's TTS
    traffic shape); the cached run serves that header from radix-tree
    pinned blocks and prefills only each request's unique question, which
    must cut prefilled prompt tokens by >= 50% vs the uncached paged
    baseline at bit-identical greedy outputs.
    """
    if dry:
        tok, cfg, params = _untrained_tiny()
        n_requests = 6
    else:
        tok, cfg, params = trained_tiny()
    max_len = 96
    tasks = T.shared_prefix_dataset(77, n_requests, n_shots=3,
                                    reasoning=False, max_terms=2)
    prompt_len = max(len(tok.encode(t.prompt)) for t in tasks)

    def run_once(with_cache):
        eng = DecodeEngine(params, cfg, max_len=max_len, eos_id=tok.eos_id,
                           pad_id=tok.pad_id, paged=True,
                           block_size=block_size,
                           n_blocks=1 + (n_slots + 2) * (max_len // block_size))
        cache = PrefixCache(eng.pool) if with_cache else None
        sched = ContinuousScheduler(eng, n_slots=n_slots,
                                    prompt_len=prompt_len,
                                    stop_ids=(tok.eos_id,),
                                    prefix_cache=cache)
        for i, task in enumerate(tasks):
            sched.submit(Request(req_id=i,
                                 prompt=jnp.asarray(tok.encode(task.prompt)),
                                 max_new_tokens=4 + 4 * (i % 3)))
        res = sched.run(jax.random.key(0), SamplerConfig(greedy=True))
        return res, sched.metrics.summary(), cache

    res_base, base, _ = run_once(False)
    res_cached, s, cache = run_once(True)
    assert res_base == res_cached, \
        "prefix cache changed greedy outputs (parity violation)"
    saved = 1 - s["prefill_tokens"] / base["prefill_tokens"]
    assert saved >= 0.5, \
        f"prefix cache saved only {saved:.0%} prefill tokens (< 50%)"
    # batched cache-aware admission: runs of same-header hits share one
    # partial prefill, so cache-aware admission makes strictly fewer
    # prefill calls than it admits requests (it was pinned at one call
    # per request before batched admission)
    cpr = s["prefill_calls_per_request"]
    assert cpr < 1.0, \
        (f"cache-aware admission made {s['prefill_calls']} prefill calls "
         f"for {s['admitted_requests']} requests (calls/request = "
         f"{cpr:.2f}, expected < 1: admission is not batching)")
    assert s["admission_batch_max"] > 1, \
        "no admission prefill carried more than one request"
    c = cache.stats()
    emit("serving.prefix_cache", s["wall_s"] * 1e6,
         f"slots={s['n_slots']} block_size={block_size} "
         f"requests={n_requests} "
         f"hit_rate={s['prefix_cache_hit_rate']:.2f} "
         f"prefill_tokens={s['prefill_tokens']} "
         f"baseline_prefill_tokens={base['prefill_tokens']} "
         f"prefill_reduction={saved * 100:.0f}% "
         f"prefill_tokens_saved={s['prefill_tokens_saved']} "
         f"prefill_calls={s['prefill_calls']} "
         f"calls_per_request={cpr:.2f} "
         f"admission_batch_max={s['admission_batch_max']} "
         f"cached_blocks={c['cached_blocks']} "
         f"evictions={c['evictions']} "
         f"preemptions={s['preemptions']}")


def kv_quant_serving(mode: str = "q8", n_requests: int = 10,
                     n_slots: int = 4, block_size: int = 8,
                     dry: bool = False):
    """serving.kv_quant: the paged workload with the pool's blocks stored
    tile-quantized, against the fp paged run at equal slots.

    Asserts the acceptance criterion: >= 40% lower *peak KV bytes* than
    the fp paged row (dtype-aware accounting — Q8 blocks are ~4x smaller
    than f32, Q4 ~7x, so this passes with margin), with the greedy
    accuracy drop on the verifiable math task bounded (quantized KV may
    legitimately flip near-tie samples; more than one flipped task means
    the dequant path is broken, not noisy).
    """
    if dry:
        tok, cfg, params = _untrained_tiny()
        n_requests = 4
    else:
        tok, cfg, params = trained_tiny()
    max_len = 96
    tasks = T.gen_dataset(77, n_requests, reasoning=False, max_terms=2)
    scorer = R.OracleVerifier()

    def run_once(kv_quant):
        eng = DecodeEngine(params, cfg, max_len=max_len, eos_id=tok.eos_id,
                           pad_id=tok.pad_id, paged=True,
                           block_size=block_size,
                           n_blocks=1 + n_slots * (max_len // block_size),
                           kv_quant=kv_quant)
        sched = ContinuousScheduler(eng, n_slots=n_slots, prompt_len=24,
                                    stop_ids=(tok.eos_id,))
        for i, task in enumerate(tasks):
            sched.submit(Request(req_id=i,
                                 prompt=jnp.asarray(tok.encode(task.prompt)),
                                 max_new_tokens=4 + 8 * (i % 3)))
        res = sched.run(jax.random.key(0), SamplerConfig(greedy=True))
        assert eng.pool.blocks_in_use == 0, "quantized pool leaked blocks"
        acc = sum(
            float(scorer.score_texts(t, [tok.decode(res[i])])[0])
            for i, t in enumerate(tasks)) / len(tasks)
        return sched.metrics.summary(), eng.pool.stats(), acc

    s_fp, kv_fp, acc_fp = run_once("none")
    s_q, kv_q, acc_q = run_once(mode)
    saved = 1 - s_q["peak_kv_bytes"] / s_fp["peak_kv_bytes"]
    assert saved >= 0.4, \
        f"{mode} saved only {saved:.0%} peak KV bytes (< 40%)"
    if not dry:
        assert acc_q >= acc_fp - 1.0 / n_requests - 1e-9, \
            (f"{mode} greedy accuracy dropped {acc_fp:.3f} -> {acc_q:.3f} "
             f"(more than one task)")
    emit("serving.kv_quant", s_q["wall_s"] * 1e6,
         f"mode={mode} slots={s_q['n_slots']} block_size={block_size} "
         f"peak_kv_bytes={s_q['peak_kv_bytes']} "
         f"fp_peak_kv_bytes={s_fp['peak_kv_bytes']} "
         f"kv_byte_reduction={saved * 100:.0f}% "
         f"block_bytes={kv_q['block_bytes']} "
         f"fp_block_bytes={kv_fp['block_bytes']} "
         f"accuracy={acc_q:.3f} fp_accuracy={acc_fp:.3f} "
         f"cow_copies={kv_q['cow_copies']} "
         f"preemptions={s_q['preemptions']}")


def beam_serving(n_tasks: int = 6, dry: bool = False):
    """serving.beam: step-level PRM beam search served as a scheduler
    workload (tree requests) vs the direct per-task ``core.beam_search``
    loop.

    Asserts the tentpole invariants before emitting: greedy scheduler
    outputs are bit-identical to the direct path, the pool drains to zero
    blocks after both (the direct path used to leak its tree), and PRM
    scoring is batched — exactly one scorer forward per scoring boundary /
    final selection (``n_forwards == prm_batches``), where the direct loop
    issues the same count per task sequentially."""
    from repro.core.beam_search import beam_search
    from repro.core.controller import serve_beam_search

    if dry:
        tok, cfg, params = _untrained_tiny()
        n_tasks = 2
    else:
        tok, cfg, params = trained_tiny()
    max_len = 96
    width, expand, step_tokens, max_steps = 2, 2, 6, 2
    prompt_len = 16
    fan = width * expand
    # dry runs an untrained model whose near-tied logits are sensitive to
    # batch-shape-dependent GEMM rounding: match the scheduler's decode
    # batch to the direct path's (one tree at a time) so greedy parity is
    # exact; the trained run keeps two trees in flight
    n_slots = fan if dry else 2 * fan
    eng = DecodeEngine(params, cfg, max_len=max_len, eos_id=tok.eos_id,
                       pad_id=tok.pad_id, paged=True, block_size=8,
                       n_blocks=1 + 2 * fan * (max_len // 8))
    tasks = T.gen_dataset(31, n_tasks, reasoning=True, max_terms=2)
    rcfg = R.reward_config(tok.vocab_size)
    prm = R.LearnedScorer(R.init_reward_params(jax.random.key(1), rcfg),
                          rcfg, tok)
    sc = SamplerConfig(greedy=True)

    direct = [beam_search(eng, tok, t, width=width, expand=expand,
                          max_steps=max_steps, step_tokens=step_tokens,
                          rng=jax.random.key(0), prm=prm, sc=sc,
                          prompt_len=prompt_len) for t in tasks]
    assert eng.pool.blocks_in_use == 0, "direct beam path leaked blocks"
    direct_tokens = sum(r.decode_tokens for r in direct)

    base_forwards = prm.n_forwards
    row = serve_beam_search(eng, tok, tasks, width=width, expand=expand,
                            step_tokens=step_tokens, max_steps=max_steps,
                            rng=jax.random.key(0), prm=prm,
                            n_slots=n_slots, prompt_len=prompt_len, sc=sc)
    assert eng.pool.blocks_in_use == 0, "scheduler beam path leaked blocks"
    s = row["serving"]
    assert prm.n_forwards - base_forwards == s["prm_batches"], \
        "PRM scoring is not batched (forwards != scoring boundaries)"
    for d, sv in zip(direct, row["results"]):
        assert sv.completions == d.completions and sv.chosen == d.chosen, \
            "scheduler beam outputs diverged from the direct path"
    emit("serving.beam", s["wall_s"] * 1e6,
         f"tasks={n_tasks} width={width} expand={expand} "
         f"slots={s['n_slots']} occupancy={s['avg_slot_occupancy']:.2f} "
         f"boundaries={s['beam_boundaries']} "
         f"expansions={s['beam_expansions']} prunes={s['beam_prunes']} "
         f"prm_batches={s['prm_batches']} "
         f"prm_candidates_per_batch={s['prm_candidates_per_batch']:.1f} "
         f"decode_tokens={s['decode_tokens']} "
         f"direct_decode_tokens={direct_tokens} "
         f"accuracy={row['accuracy']:.3f} parity=ok leak=0")


def latency_serving(n_requests: int = 10, n_slots: int = 4,
                    block_size: int = 8, dry: bool = False):
    """serving.latency: tail-latency percentiles for a mixed chat + BoN +
    beam workload on a deliberately tight paged pool (block pressure, so
    queueing and possibly preemption shape the tail).

    A :class:`~repro.serving.telemetry.Tracer` records the request
    lifecycle; the row emits the ``SchedulerMetrics.summary()``
    percentiles in ms — ``ttft_p50/p99``, ``itl_p50/p99``,
    ``queue_wait_p99``, ``step_time_p50/p99`` — which the snapshot
    check enforces under the generous latency envelope
    (``REPRO_BENCH_LAT_FACTOR`` × with a ``REPRO_BENCH_LAT_FLOOR_MS``
    floor).  The in-memory Chrome trace must validate before the row
    emits, so the exporter schema is exercised on every benchmark run,
    not just the serve.py CI smoke."""
    import numpy as np

    if dry:
        tok, cfg, params = _untrained_tiny()
        n_requests = 6
    else:
        tok, cfg, params = trained_tiny()
    max_len = 96
    width, expand = 2, 2
    n_slots = max(n_slots, width * expand)
    # tight pool: enough for any single request's worst case, not for
    # every slot at full length — admission waits and the tail shows it
    eng = DecodeEngine(params, cfg, max_len=max_len, eos_id=tok.eos_id,
                       pad_id=tok.pad_id, paged=True, block_size=block_size,
                       n_blocks=1 + (n_slots + 1) * 4)
    tasks = T.gen_dataset(77, n_requests, reasoning=False, max_terms=2)
    tracer = Tracer()
    sched = ContinuousScheduler(eng, n_slots=n_slots, prompt_len=24,
                                stop_ids=(tok.eos_id,), tracer=tracer)
    for i, task in enumerate(tasks):
        sched.submit(Request(req_id=i,
                             prompt=jnp.asarray(tok.encode(task.prompt)),
                             max_new_tokens=4 + 8 * (i % 3)))
    sched.submit(Request(req_id=n_requests,
                         prompt=jnp.asarray(tok.encode(tasks[0].prompt)),
                         max_new_tokens=8, n_samples=3))
    dot_id = int(tok.encode(".", bos=False)[0])
    sched.submit(Request(
        req_id=n_requests + 1,
        prompt=jnp.asarray(tok.encode(tasks[1].prompt)),
        search=BeamSpec(
            width=width, expand=expand, step_tokens=4, max_steps=2,
            step_stop_id=dot_id,
            score=lambda tl, lp, ng: np.asarray(lp)
            / np.maximum(np.asarray(ng), 1))))
    sched.run(jax.random.key(0), SamplerConfig(greedy=True))
    s = sched.metrics.summary()
    assert s["latency_requests"] == n_requests + 2, \
        (f"latency records cover {s['latency_requests']} of "
         f"{n_requests + 2} requests")
    assert s["ttft_p99"] >= s["ttft_p50"] > 0, "TTFT percentiles degenerate"
    assert s["itl_p99"] >= s["itl_p50"] > 0, "ITL percentiles degenerate"
    assert s["step_time_p99"] >= s["step_time_p50"] > 0, \
        "step-time percentiles degenerate"
    bad = validate_chrome_trace(tracer.to_chrome_trace())
    assert not bad, f"trace export failed schema validation: {bad[:3]}"
    emit("serving.latency", s["wall_s"] * 1e6,
         f"requests={s['latency_requests']} slots={s['n_slots']} "
         f"pool_blocks={eng.pool.capacity} "
         f"ttft_p50_ms={s['ttft_p50'] * 1e3:.2f} "
         f"ttft_p99_ms={s['ttft_p99'] * 1e3:.2f} "
         f"itl_p50_ms={s['itl_p50'] * 1e3:.2f} "
         f"itl_p99_ms={s['itl_p99'] * 1e3:.2f} "
         f"queue_wait_p99_ms={s['queue_wait_p99'] * 1e3:.2f} "
         f"step_time_p50_ms={s['step_time_p50'] * 1e3:.2f} "
         f"step_time_p99_ms={s['step_time_p99'] * 1e3:.2f} "
         f"preempt_delay_ms={s['preempt_delay_s'] * 1e3:.2f} "
         f"preemptions={s['preemptions']} "
         f"trace_events={len(tracer.events)}")


def profile_serving(n_requests: int = 8, n_slots: int = 4,
                    block_size: int = 8, dry: bool = False):
    """serving.profile: the paged q8 greedy workload under the
    :class:`~repro.serving.profiling.KernelProfiler`.

    Every step is sampled (roofline attribution + measured wall) and a
    quarter of steps run the exact-path canary.  Asserts before emitting:
    the report passes ``validate_profile_report``, at least one kernel
    was attributed, and the canary's argmax flip rate is exactly zero —
    under greedy decoding the exact replica of the production path must
    reproduce its logits bit-for-bit, so any flip means the canary or the
    production path drifted.  The emitted ``canary_max_logit_err`` /
    ``kv_roundtrip_err`` metrics carry ``err`` in the name on purpose:
    the snapshot check's error envelope (4x over a 0.0 snapshot, i.e.
    ~0) turns numerics rot into a ``--check`` failure."""
    from repro.serving.profiling import KernelProfiler, validate_profile_report

    if dry:
        tok, cfg, params = _untrained_tiny()
        n_requests = 4
    else:
        tok, cfg, params = trained_tiny()
    max_len = 96
    eng = DecodeEngine(params, cfg, max_len=max_len, eos_id=tok.eos_id,
                       pad_id=tok.pad_id, paged=True, block_size=block_size,
                       n_blocks=1 + n_slots * (max_len // block_size),
                       kv_quant="q8")
    prof = KernelProfiler(sample_rate=1.0, canary_rate=0.25)
    sched = ContinuousScheduler(eng, n_slots=n_slots, prompt_len=24,
                                stop_ids=(tok.eos_id,), profiler=prof)
    tasks = T.gen_dataset(77, n_requests, reasoning=False, max_terms=2)
    for i, task in enumerate(tasks):
        sched.submit(Request(req_id=i,
                             prompt=jnp.asarray(tok.encode(task.prompt)),
                             max_new_tokens=4 + 8 * (i % 3)))
    sched.run(jax.random.key(0), SamplerConfig(greedy=True))
    prof.uninstall()  # later benchmark sections must not record here
    s = sched.metrics.summary()
    report = prof.report()
    bad = validate_profile_report(report)
    assert not bad, f"profile report failed schema validation: {bad[:3]}"
    assert report["kernels"], "profiler attributed no kernel dispatches"
    assert s["profiled_steps"] > 0 and s["canary_samples"] > 0, \
        "profiler sampled no steps / canary never fired"
    assert s["canary_argmax_flip_rate"] == 0.0, \
        (f"greedy q8 canary flipped argmax on "
         f"{report['canary']['flips']}/{report['canary']['rows']} rows")
    top = max(report["kernels"].items(), key=lambda kv: kv[1]["bound_s"])
    emit("serving.profile", s["wall_s"] * 1e6,
         f"steps={s['profiled_steps']} kernels={len(report['kernels'])} "
         f"kernel_time_share={s['kernel_time_share']:.3f} "
         f"roofline_eff_p50={s['roofline_efficiency_p50']:.3g} "
         f"top_kernel={top[0]} top_eff={top[1]['efficiency']:.3g} "
         f"canary_samples={s['canary_samples']} "
         f"canary_max_logit_err={s['canary_max_logit_err']:.3g} "
         f"canary_flip_rate={s['canary_argmax_flip_rate']:.3g} "
         f"kv_roundtrip_err={s['canary_kv_roundtrip_err']:.3g}")


def speculative_serving(n_requests: int = 10, n_slots: int = 4,
                        block_size: int = 8, dry: bool = False):
    """serving.speculative: the paged mixed workload (chat + one Best-of-N
    group) decoded draft-then-verify against the plain greedy baseline.

    Self-drafting with k=4: each round the engine snapshots the eligible
    rows (a refcount bump per block — PR-2 fork semantics), drafts k-1
    tokens on the snapshot, releases it, and verifies all proposals in ONE
    batched target forward; the longest agreeing prefix commits.  Asserts
    the tentpole contract before emitting: greedy outputs bit-identical to
    the non-speculative run on BOTH the fp and q8 pools, zero leaked
    blocks after every run, ``spec_acceptance_rate`` > 0 and
    ``accepted_tokens_per_step`` > 1.  ``spec_accept_reduction`` (the
    acceptance rate as a percentage) is named for the snapshot check's
    reduction envelope: acceptance regressing more than 5 points below
    the recorded snapshot fails ``--check``."""
    if dry:
        tok, cfg, params = _untrained_tiny()
        n_requests = 4
    else:
        tok, cfg, params = trained_tiny()
    max_len = 96
    tasks = T.gen_dataset(77, n_requests, reasoning=False, max_terms=2)
    spec = SpecConfig(k=4, self_draft=True)

    def run_once(spec_cfg, kv_quant):
        eng = DecodeEngine(params, cfg, max_len=max_len, eos_id=tok.eos_id,
                           pad_id=tok.pad_id, paged=True,
                           block_size=block_size,
                           n_blocks=1 + (n_slots + 2) * (max_len // block_size),
                           kv_quant=kv_quant)
        sched = ContinuousScheduler(eng, n_slots=n_slots, prompt_len=24,
                                    stop_ids=(tok.eos_id,), spec=spec_cfg)
        for i, task in enumerate(tasks):
            sched.submit(Request(req_id=i,
                                 prompt=jnp.asarray(tok.encode(task.prompt)),
                                 max_new_tokens=4 + 8 * (i % 3)))
        # a Best-of-N group rides along: spec rounds must coexist with
        # forked TTS lanes, not just plain chat traffic
        sched.submit(Request(req_id=n_requests,
                             prompt=jnp.asarray(tok.encode(tasks[0].prompt)),
                             max_new_tokens=8, n_samples=2))
        res = sched.run(jax.random.key(0), SamplerConfig(greedy=True))
        assert eng.pool.blocks_in_use == 0, \
            "speculative run leaked pool blocks"
        return res, sched.metrics.summary()

    s = base = None
    for kv_quant in ("none", "q8"):
        res_base, base = run_once(None, kv_quant)
        res_spec, s = run_once(spec, kv_quant)
        assert res_base == res_spec, \
            (f"speculative greedy diverged from the plain path on the "
             f"{kv_quant} pool (parity violation)")
    assert s["spec_acceptance_rate"] > 0, "no drafted token was accepted"
    assert s["accepted_tokens_per_step"] > 1, \
        (f"speculation committed {s['accepted_tokens_per_step']:.2f} "
         f"tokens/row-step (expected > 1: verify is not amortizing)")
    emit("serving.speculative", s["wall_s"] * 1e6,
         f"k={spec.k} slots={s['n_slots']} requests={n_requests + 1} "
         f"spec_rounds={s['spec_rounds']} "
         f"draft_tokens={s['draft_tokens']} "
         f"spec_accept_reduction={s['spec_acceptance_rate'] * 100:.0f}% "
         f"accepted_tokens_per_step={s['accepted_tokens_per_step']:.2f} "
         f"decode_tokens={s['decode_tokens']} "
         f"baseline_steps={base['steps']} spec_steps={s['steps']} "
         f"preemptions={s['preemptions']} parity=ok leak=0")


def dry_rows():
    """The serving snapshot area (``benchmarks.run --record/--check``):
    the three paged-engine rows in dry mode — untrained tiny model, small
    workload, every built-in parity/saving assertion still armed.  Fast
    enough for CI while the emitted metrics (kv_byte_reduction,
    prefill_reduction, peak bytes) stay deterministic."""
    paged_serving(dry=True)
    prefix_cache_serving(dry=True)
    kv_quant_serving(mode="q8", dry=True)
    beam_serving(dry=True)
    latency_serving(dry=True)
    profile_serving(dry=True)
    speculative_serving(dry=True)


def run():
    fig8_attention_breakdown()
    fig11_decode_throughput()
    fig17_prompt_length()
    fig10_tts_scaling()
    continuous_serving()
    paged_serving()
    prefix_cache_serving()
    kv_quant_serving()
    beam_serving()
    latency_serving()
    profile_serving()
    speculative_serving()


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--paged", action="store_true",
                    help="run only the serving.paged section")
    ap.add_argument("--prefix-cache", action="store_true",
                    help="run only the serving.prefix_cache section")
    ap.add_argument("--kv-quant", default=None, choices=["q8", "q4"],
                    help="run only the serving.kv_quant section with this "
                         "KV quantization mode (the row itself compares "
                         "against the fp paged run)")
    ap.add_argument("--beam", action="store_true",
                    help="run only the serving.beam section (scheduler-"
                         "served tree search vs the direct beam loop)")
    ap.add_argument("--latency", action="store_true",
                    help="run only the serving.latency section (traced "
                         "mixed workload, tail-latency percentiles)")
    ap.add_argument("--profile", action="store_true",
                    help="run only the serving.profile section (roofline-"
                         "attributed kernel profiling + drift canary)")
    ap.add_argument("--speculative", action="store_true",
                    help="run only the serving.speculative section (draft-"
                         "then-verify decode vs the plain greedy baseline)")
    ap.add_argument("--dry", action="store_true",
                    help="smoke mode: untrained tiny model, small workload")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    if args.paged:
        paged_serving(dry=args.dry)
    elif args.prefix_cache:
        prefix_cache_serving(dry=args.dry)
    elif args.kv_quant:
        kv_quant_serving(mode=args.kv_quant, dry=args.dry)
    elif args.beam:
        beam_serving(dry=args.dry)
    elif args.latency:
        latency_serving(dry=args.dry)
    elif args.profile:
        profile_serving(dry=args.dry)
    elif args.speculative:
        speculative_serving(dry=args.dry)
    else:
        run()
