"""Quickstart: build a model from the registry, run one forward pass, one
train step, and a short greedy generation — all on CPU in under a minute.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.configs.registry import get_config
from repro.data.tokenizer import ByteTokenizer
from repro.models import api
from repro.serving.engine import DecodeEngine
from repro.serving.sampler import SamplerConfig
from repro.train.loop import lm_loss

tok = ByteTokenizer()

# 1. any assigned architecture is selectable; --smoke configs run on CPU
cfg = get_config("gemma3-1b", smoke=True).with_(vocab_size=tok.vocab_size)
model = api.get_model(cfg)
params = model.init_params(jax.random.key(0), cfg)
print(f"arch={cfg.name} params={sum(x.size for x in jax.tree.leaves(params)):,}")

# 2. forward + loss
tokens, lens = tok.encode_batch(["Q:2+3=?A:5."], 32)
tokens = jnp.asarray(tokens)
logits, _, _ = model.forward(params, tokens, cfg)
print("logits:", logits.shape)
loss, _ = lm_loss(params, (tokens, jnp.roll(tokens, -1, 1),
                           jnp.ones(tokens.shape, jnp.float32)), cfg, None)
print("loss:", float(loss))

# 3. batched greedy generation through the serving engine
eng = DecodeEngine(params, cfg, max_len=64, eos_id=tok.eos_id, pad_id=tok.pad_id)
state = eng.prefill(tokens, jnp.asarray(lens))
state, out = eng.generate(state, 8, jax.random.key(1), SamplerConfig(greedy=True))
print("generated token ids:", out[0].tolist())
print("decoded:", repr(tok.decode(out[0])))
