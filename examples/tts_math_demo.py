"""END-TO-END DRIVER (deliverable b): train a small LM on verifiable math,
then demonstrate the paper's headline claim — accuracy scales with the
parallel test-time budget, so a small model + TTS beats greedy decoding —
using the full stack: data pipeline -> AdamW training -> checkpoint ->
quantized serving -> Best-of-N / self-consistency / beam search.

    PYTHONPATH=src python examples/tts_math_demo.py [--steps 300] [--tasks 20]
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro.checkpoint.checkpointer import Checkpointer
from repro.configs.base import ModelConfig
from repro.core import reward as R
from repro.core.controller import TTSSpec, sweep
from repro.data import tasks as T
from repro.data.dataset import MathDataLoader
from repro.data.tokenizer import ByteTokenizer
from repro.models import api
from repro.quant.qlinear import quantize_model_params
from repro.serving.engine import DecodeEngine
from repro.train.loop import train_loop
from repro.train.optimizer import AdamWConfig

ap = argparse.ArgumentParser()
ap.add_argument("--steps", type=int, default=300)
ap.add_argument("--tasks", type=int, default=16)
ap.add_argument("--ckpt-dir", default="runs/tts_demo_ckpt")
args = ap.parse_args()

tok = ByteTokenizer()
cfg = ModelConfig(name="tts-demo", n_layers=3, d_model=96, n_heads=6,
                  n_kv_heads=2, d_ff=256, vocab_size=tok.vocab_size,
                  dtype="float32", param_dtype="float32", remat="none")
model = api.get_model(cfg)

# --- 1. train (few hundred steps, ~100k params-scale model) ---------------
print(f"[1/4] training {cfg.name} for {args.steps} steps ...")
params = model.init_params(jax.random.key(0), cfg)
loader = MathDataLoader(tok, batch_size=32, seq_len=64, seed=0,
                        max_terms=2, reasoning=False)
oc = AdamWConfig(lr=2e-3, warmup_steps=20, total_steps=args.steps)
t0 = time.time()
params, _ = train_loop(params, cfg, oc, iter(loader), n_steps=args.steps,
                       log_every=max(args.steps // 5, 1))
loader.close()
print(f"    trained in {time.time()-t0:.0f}s")

# --- 2. checkpoint + restore (fault-tolerance path) ------------------------
ck = Checkpointer(args.ckpt_dir)
ck.save(params, step=args.steps)
params, _ = ck.restore(jax.eval_shape(lambda: params))
print(f"[2/4] checkpoint round-trip at {args.ckpt_dir}")

# --- 3. quantize for deployment (paper §5.1: tile Q4_0 + Q8_0 down) --------
qparams = quantize_model_params(params, scheme="tile")
print("[3/4] weights quantized (tile-group Q4_0, Q8_0 down-proj)")

# --- 4. test-time scaling sweep (paper Figs. 5/10) --------------------------
engine = DecodeEngine(qparams, cfg, max_len=96, eos_id=tok.eos_id,
                      pad_id=tok.pad_id)
tasks = T.gen_dataset(1234, args.tasks, reasoning=False, max_terms=2)
specs = [TTSSpec("best_of_n", n, max_tokens=10) for n in (1, 2, 4, 8, 16)]
specs += [TTSSpec("self_consistency", n, max_tokens=10) for n in (4, 16)]
print(f"[4/4] TTS sweep over {args.tasks} held-out tasks:")
rows = sweep(engine, tok, tasks, specs, jax.random.key(7), R.OracleVerifier())
print(f"{'method':<18}{'budget':>7}{'accuracy':>10}{'decode_tokens':>15}")
for r in rows:
    print(f"{r['method']:<18}{r['budget']:>7}{r['accuracy']:>10.3f}"
          f"{r['decode_tokens']:>15}")
base = rows[0]["accuracy"]
best = max(r["accuracy"] for r in rows)
print(f"\nParallel TTS lifted accuracy {base:.3f} -> {best:.3f} "
      "on the same (quantized) model — the paper's Fig. 5/10 claim.")
