"""Continuous-batching server demo: submit a mixed queue of requests and
drain it through the slot-based scheduler (the production serving shape).

    PYTHONPATH=src python examples/serve_batch.py
"""
import jax
import jax.numpy as jnp

from repro.configs.registry import get_config
from repro.data.tokenizer import ByteTokenizer
from repro.models import api
from repro.serving.engine import ContinuousScheduler, DecodeEngine, Request
from repro.serving.sampler import SamplerConfig

tok = ByteTokenizer()
cfg = get_config("qwen2.5-1.5b", smoke=True).with_(vocab_size=tok.vocab_size)
model = api.get_model(cfg)
params = model.init_params(jax.random.key(0), cfg)
engine = DecodeEngine(params, cfg, max_len=96, eos_id=tok.eos_id,
                      pad_id=tok.pad_id)
sched = ContinuousScheduler(engine, n_slots=4, prompt_len=24)

prompts = [f"Q:{a}+{b}=?A:" for a, b in [(1, 2), (3, 4), (5, 6), (7, 8),
                                          (2, 9), (4, 4)]]
for i, p in enumerate(prompts):
    sched.submit(Request(req_id=i, prompt=jnp.asarray(tok.encode(p)),
                         max_new_tokens=6))
results = sched.run(jax.random.key(0), SamplerConfig(greedy=True))
for rid in sorted(results):
    print(f"req {rid}: {prompts[rid]!r} -> {tok.decode(results[rid])!r}")
print(f"drained {len(results)} requests through 4 slots")
