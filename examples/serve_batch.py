"""Continuous-batching server demo: submit a mixed queue of requests —
including a Best-of-N group that shares one prefill via fork — and drain it
through the slot-based scheduler (the production serving shape).  Requests
enter and leave the fixed decode batch independently; the step metrics show
how full the slots stayed.

The decode slots are backed by the *paged* KV pool: each slot holds block
ids instead of a dense max_len cache row, the Best-of-3 group's samples
share the prompt's blocks (fork = refcount bump, split lazily by
copy-on-write), and the pool stats printed at the end show the peak KV
footprint vs the dense reservation.  Every request carries the same
few-shot header, and the *cross-request prefix cache* (a radix tree over
the pool) keeps that header's KV pinned after the first prefill — later
requests prefill only their unique question, shown by the hit-rate /
prefill-tokens-saved stats.  Pass --dense to compare layouts (dense has
no block pool, hence no prefix cache).

    PYTHONPATH=src python examples/serve_batch.py [--dense]
"""
import sys

import jax
import jax.numpy as jnp

from repro.configs.registry import get_config
from repro.data.tasks import fewshot_header
from repro.data.tokenizer import ByteTokenizer
from repro.models import api
from repro.serving.engine import ContinuousScheduler, DecodeEngine, Request
from repro.serving.kv_pool import dense_kv_bytes
from repro.serving.prefix_cache import PrefixCache
from repro.serving.sampler import SamplerConfig
from repro.serving.telemetry import Tracer

PAGED = "--dense" not in sys.argv[1:]
tok = ByteTokenizer()
cfg = get_config("qwen2.5-1.5b", smoke=True).with_(vocab_size=tok.vocab_size)
model = api.get_model(cfg)
params = model.init_params(jax.random.key(0), cfg)
kv_kwargs = (dict(paged=True, block_size=8, n_blocks=73)  # 6 slots' worth
             if PAGED else {})
engine = DecodeEngine(params, cfg, max_len=96, eos_id=tok.eos_id,
                      pad_id=tok.pad_id, **kv_kwargs)
cache = PrefixCache(engine.pool) if PAGED else None

HEADER = fewshot_header(seed=3, n_shots=2)  # the shared cross-request prefix
prompts = [HEADER + f"Q:{a}+{b}=?A:" for a, b in [(1, 2), (3, 4), (5, 6),
                                                   (7, 8), (2, 9), (4, 4)]]
prompt_len = max(len(tok.encode(p)) for p in prompts) + 1
# the tracer records each request's lifecycle (enqueue/admit/first_token/
# token/release), which is where the TTFT / inter-token-latency
# percentiles below come from
sched = ContinuousScheduler(engine, n_slots=4, prompt_len=prompt_len,
                            prefix_cache=cache, tracer=Tracer())
for i, p in enumerate(prompts):
    # mixed budgets: short and long requests churn slots at different times
    sched.submit(Request(req_id=i, prompt=jnp.asarray(tok.encode(p)),
                         max_new_tokens=4 + 3 * (i % 2)))
# a Best-of-3 TTS request: one prefill, forked into 3 slots
sched.submit(Request(req_id=len(prompts),
                     prompt=jnp.asarray(tok.encode(HEADER + "Q:6+3=?A:")),
                     max_new_tokens=6, n_samples=3))

results = sched.run(jax.random.key(0), SamplerConfig(greedy=True))
print(f"shared header ({len(HEADER)} chars): {HEADER!r}")
for rid in sorted(results):
    if rid < len(prompts):
        q = prompts[rid][len(HEADER):]
        print(f"req {rid}: header+{q!r} -> {tok.decode(results[rid])!r}")
    else:
        outs = [tok.decode(s) for s in results[rid]]
        print(f"req {rid} (best-of-3 header+'Q:6+3=?A:'): {outs!r}")

m = sched.metrics.summary()
print(f"drained {m['completed_requests']} requests "
      f"({m['completed_samples']} samples) through {m['n_slots']} slots in "
      f"{m['steps']} steps; occupancy={m['avg_slot_occupancy']:.2f} "
      f"requests/s={m['requests_per_s']:.1f} "
      f"prefills={sched.n_prefills} "
      f"prefill_tokens={m['prefill_tokens']} "
      f"decode_tokens={m['decode_tokens']}")
# batched cache-aware admission: runs of same-header cache hits share one
# partial prefill, so calls-per-request drops below 1 on this workload
print(f"admission: prefill_calls={m['prefill_calls']} for "
      f"{m['admitted_requests']} requests "
      f"(calls/request={m['prefill_calls_per_request']:.2f}, "
      f"batch_max={m['admission_batch_max']})")
print(f"latency: ttft_p50={m['ttft_p50'] * 1e3:.1f}ms "
      f"ttft_p99={m['ttft_p99'] * 1e3:.1f}ms "
      f"itl_p50={m['itl_p50'] * 1e3:.1f}ms "
      f"itl_p99={m['itl_p99'] * 1e3:.1f}ms "
      f"queue_wait_p99={m['queue_wait_p99'] * 1e3:.1f}ms "
      f"step_time_p99={m['step_time_p99'] * 1e3:.1f}ms "
      f"over {m['latency_requests']} requests")
if PAGED:
    kv = engine.pool.stats()
    dense = dense_kv_bytes(cfg, 4, engine.max_len)
    print(f"paged kv: block_size={kv['block_size']} "
          f"peak_blocks={kv['peak_blocks_in_use']} "
          f"cow_copies={kv['cow_copies']} "
          f"peak_bytes={kv['peak_bytes_in_use']} vs dense {dense} "
          f"({(1 - kv['peak_bytes_in_use'] / dense) * 100:.0f}% saved "
          f"with a right-sized pool)")
    c = cache.stats()
    print(f"prefix cache: hit_rate={c['hit_rate']:.2f} "
          f"prefill_tokens_saved={m['prefill_tokens_saved']} "
          f"of {m['prefill_tokens'] + m['prefill_tokens_saved']} prompt "
          f"tokens; cached_blocks={c['cached_blocks']} "
          f"evictions={c['evictions']} "
          f"leaked={kv['blocks_in_use'] - c['cached_blocks']}")
