"""Continuous-batching server demo: submit a mixed queue of requests —
including a Best-of-N group that shares one prefill via fork — and drain it
through the slot-based scheduler (the production serving shape).  Requests
enter and leave the fixed decode batch independently; the step metrics show
how full the slots stayed.

    PYTHONPATH=src python examples/serve_batch.py
"""
import jax
import jax.numpy as jnp

from repro.configs.registry import get_config
from repro.data.tokenizer import ByteTokenizer
from repro.models import api
from repro.serving.engine import ContinuousScheduler, DecodeEngine, Request
from repro.serving.sampler import SamplerConfig

tok = ByteTokenizer()
cfg = get_config("qwen2.5-1.5b", smoke=True).with_(vocab_size=tok.vocab_size)
model = api.get_model(cfg)
params = model.init_params(jax.random.key(0), cfg)
engine = DecodeEngine(params, cfg, max_len=96, eos_id=tok.eos_id,
                      pad_id=tok.pad_id)
sched = ContinuousScheduler(engine, n_slots=4, prompt_len=24)

prompts = [f"Q:{a}+{b}=?A:" for a, b in [(1, 2), (3, 4), (5, 6), (7, 8),
                                          (2, 9), (4, 4)]]
for i, p in enumerate(prompts):
    # mixed budgets: short and long requests churn slots at different times
    sched.submit(Request(req_id=i, prompt=jnp.asarray(tok.encode(p)),
                         max_new_tokens=4 + 3 * (i % 2)))
# a Best-of-3 TTS request: one prefill, forked into 3 slots
sched.submit(Request(req_id=len(prompts),
                     prompt=jnp.asarray(tok.encode("Q:6+3=?A:")),
                     max_new_tokens=6, n_samples=3))

results = sched.run(jax.random.key(0), SamplerConfig(greedy=True))
for rid in sorted(results):
    if rid < len(prompts):
        print(f"req {rid}: {prompts[rid]!r} -> {tok.decode(results[rid])!r}")
    else:
        outs = [tok.decode(s) for s in results[rid]]
        print(f"req {rid} (best-of-3 'Q:6+3=?A:'): {outs!r}")

m = sched.metrics.summary()
print(f"drained {m['completed_requests']} requests "
      f"({m['completed_samples']} samples) through {m['n_slots']} slots in "
      f"{m['steps']} steps; occupancy={m['avg_slot_occupancy']:.2f} "
      f"requests/s={m['requests_per_s']:.1f} "
      f"prefills={sched.n_prefills} "
      f"prefill_tokens={m['prefill_tokens']} "
      f"decode_tokens={m['decode_tokens']}")
