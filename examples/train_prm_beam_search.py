"""Train a learned process-reward model (the Skywork-PRM stand-in, paper
§7.1) on the base model's own samples, then serve step-level beam search
with it — the paper's second TTS method (Fig. 1 right, Fig. 10 bottom).

Pipeline: train base LM -> sample N completions/task -> label with the
oracle verifier -> train the reward trunk+head on (sequence, correct)
pairs -> serve beam search end-to-end through the continuous-batching
scheduler (every task one tree request in a shared paged slot pool;
expansion = paged fork, pruning = block release, PRM scoring batched at
each boundary), learned PRM vs logprob PRM.

    PYTHONPATH=src python examples/train_prm_beam_search.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core import reward as R
from repro.core.controller import serve_beam_search
from repro.data import tasks as T
from repro.data.dataset import MathDataLoader
from repro.data.tokenizer import ByteTokenizer
from repro.models import api
from repro.serving.engine import DecodeEngine
from repro.serving.sampler import SamplerConfig
from repro.train.loop import train_loop
from repro.train.optimizer import AdamWConfig, adamw_update, init_opt_state

tok = ByteTokenizer()
cfg = ModelConfig(name="prm-demo", n_layers=3, d_model=96, n_heads=6,
                  n_kv_heads=2, d_ff=256, vocab_size=tok.vocab_size,
                  dtype="float32", param_dtype="float32", remat="none")
model = api.get_model(cfg)

# --- 1. base model --------------------------------------------------------
print("[1/3] training base LM (250 steps, reasoning-style targets) ...")
params = model.init_params(jax.random.key(0), cfg)
loader = MathDataLoader(tok, batch_size=32, seq_len=64, seed=3, max_terms=2,
                        reasoning=False)
params, _ = train_loop(params, cfg,
                       AdamWConfig(lr=2e-3, warmup_steps=20, total_steps=250),
                       iter(loader), n_steps=250, log_every=100)
loader.close()
engine = DecodeEngine(params, cfg, max_len=96, eos_id=tok.eos_id,
                      pad_id=tok.pad_id)

# --- 2. PRM data: sample + oracle-label -------------------------------------
print("[2/3] sampling PRM training data ...")
rng = jax.random.key(1)
texts, labels = [], []
for task in T.gen_dataset(55, 24, reasoning=False, max_terms=2):
    ids, lens = tok.encode_batch([task.prompt], 48)
    st = engine.fork(engine.prefill(jnp.asarray(ids), jnp.asarray(lens)), 6)
    rng, k = jax.random.split(rng)
    st, out = engine.generate(st, 10, k, SamplerConfig(temperature=0.9))
    for row in out.tolist():
        comp = tok.decode(row)
        texts.append(task.prompt + comp)
        labels.append(1.0 if T.verify(task, comp) else 0.0)
pos = sum(labels)
print(f"    {len(texts)} samples, {pos:.0f} positive")

rcfg = R.reward_config(tok.vocab_size, d_model=64, n_layers=2)
rparams = R.init_reward_params(jax.random.key(2), rcfg)
ids, lens = tok.encode_batch(texts, 64)
ids, lens = jnp.asarray(ids), jnp.asarray(lens)
lab = jnp.asarray(labels, jnp.float32)
opt = init_opt_state(rparams)
oc = AdamWConfig(lr=1e-3, warmup_steps=10, total_steps=120)
loss_fn = jax.jit(jax.value_and_grad(
    lambda p, i, l, y: R.reward_loss(p, i, l, y, rcfg)))
for step in range(120):
    loss, grads = loss_fn(rparams, ids, lens, lab)
    rparams, opt, _ = adamw_update(rparams, grads, opt, oc)
    if step % 40 == 0:
        print(f"    prm step {step}: bce={float(loss):.4f}")
scorer = R.LearnedScorer(rparams, rcfg, tok)

# --- 3. scheduler-served beam search: learned PRM vs self-certainty PRM ----
print("[3/3] serving step-level beam search on held-out tasks "
      "(continuous scheduler, paged KV pool):")
held = T.gen_dataset(77, 10, reasoning=False, max_terms=2)
width, expand = 2, 2
paged = DecodeEngine(params, cfg, max_len=96, eos_id=tok.eos_id,
                     pad_id=tok.pad_id, paged=True, block_size=8,
                     n_blocks=1 + 2 * width * expand * (96 // 8))
for name, prm in [("logprob-PRM", R.LogProbScorer()),
                  ("learned-PRM", scorer)]:
    row = serve_beam_search(paged, tok, held, width=width, expand=expand,
                            step_tokens=10, max_steps=2,
                            rng=jax.random.key(9), prm=prm,
                            n_slots=2 * width * expand)
    s = row["serving"]
    assert paged.pool.blocks_in_use == 0, "beam trees leaked pool blocks"
    print(f"    {name}: accuracy {row['accuracy']:.2f} "
          f"boundaries={s['beam_boundaries']} "
          f"expansions={s['beam_expansions']} prunes={s['beam_prunes']} "
          f"prm_batches={s['prm_batches']} "
          f"candidates_per_batch={s['prm_candidates_per_batch']:.1f} "
          f"occupancy={s['avg_slot_occupancy']:.2f} (pool clean)")
