"""The paper's deployment pipeline on one page: offline tile-group
quantization (pre-permute -> group-quantize -> coalesce/pack), LUT kernels,
then batched decode — with an accuracy check against the fp baseline.

    PYTHONPATH=src python examples/quantize_and_serve.py
"""
import jax
import jax.numpy as jnp

from repro.configs.registry import get_config
from repro.data.tokenizer import ByteTokenizer
from repro.kernels import ops
from repro.models import api
from repro.quant import tile_quant as TQ
from repro.quant.qlinear import quantize_model_params
from repro.serving.engine import DecodeEngine
from repro.serving.sampler import SamplerConfig

tok = ByteTokenizer()
cfg = get_config("llama3.2-1b", smoke=True).with_(vocab_size=tok.vocab_size)
model = api.get_model(cfg)
params = model.init_params(jax.random.key(0), cfg)

# --- offline quantization, weight level ------------------------------------
w = params["layers"]["ffn"]["gate"]["w"][0]
qw = TQ.quantize(w, scheme="tile", codebook="q4_0")
print(f"weight {w.shape}: {w.size * 4} bytes fp32 -> "
      f"{qw['codes'].size + qw['scales'].size * 2} bytes (codes+scales)")

# --- the LUT kernel consumes the packed codes directly ----------------------
x = jax.random.normal(jax.random.key(1), (8, w.shape[0]))
y_kernel = ops.lut_dequant_matmul(x, qw)
y_ref = x @ TQ.dequantize(qw)
print("Pallas LUT-dequant GEMM max err vs oracle:",
      float(jnp.abs(y_kernel - y_ref).max()))

# --- whole-model quantized serving ------------------------------------------
qparams = quantize_model_params(params)
eng_fp = DecodeEngine(params, cfg, max_len=48, eos_id=tok.eos_id)
eng_q4 = DecodeEngine(qparams, cfg, max_len=48, eos_id=tok.eos_id)
toks, lens = tok.encode_batch(["Q:1+2=?A:"] * 4, 16)
for name, eng in [("fp32", eng_fp), ("q4-tile", eng_q4)]:
    st = eng.prefill(jnp.asarray(toks), jnp.asarray(lens))
    st, out = eng.generate(st, 6, jax.random.key(2), SamplerConfig(greedy=True))
    print(f"{name:8s} greedy continuation: {tok.decode(out[0])!r}")
