"""Sequence-parallel (flash-decoding style) KV attention.

The KV cache is sharded along the *sequence* dimension over a configurable
mesh axis (``par.kv_seq_axis``); each shard computes a partial safe-softmax
attention (unnormalized out, running max m, running sum l) over its KV
slice and shards combine with the distributed softmax merge:

    m* = pmax(m);   l* = psum(l e^{m-m*});   o* = psum(o e^{m-m*}) / l*

Axis choice (configs/inputs.py):
  * decode_32k  — seq over **model** (batch occupies data); the per-step
    collective is one psum of (B,1,Hq,D) — bytes ~1000× smaller than the
    involuntary cache reshards GSPMD inserts otherwise;
  * long_500k   — seq over **data** (batch=1 cannot use it).

This is the TPU-native answer to the paper's concern that softmax/attention
dominates as context grows (§5.2.1): O(S) work spreads across an axis and
only O(heads·head_dim) crosses the interconnect.  Head projections stay
tensor-parallel outside the shard_map; only the tiny (B, 1) q/k/v rows
enter it, so no head-divisibility constraints apply (gemma3 has 1 KV head —
it cannot shard 16-way over ``model``).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.distributed.sharding import ParallelContext
from repro.models import layers as L

from repro.distributed.compat import shard_map


def _seq_shard_attention(q, new_k, new_v, cache_k, cache_v, cache_len, window,
                         *, axis: str, softcap: float, ring_size: int = 0):
    """Runs inside shard_map. cache_*: (B_loc, S_loc, Hkv, D) local slice.
    ``ring_size``: total ring slots (0 = linear cache)."""
    B, S_loc, Hkv, D = cache_k.shape
    shard = jax.lax.axis_index(axis)
    base = shard * S_loc  # global slot index of local row 0

    # -- write the current token's K/V into whichever shard owns the target
    #    slot (one scatter; other shards are masked no-ops).
    target = cache_len - 1  # (B,) position of the current token
    slot = target % ring_size if ring_size else target
    local_idx = jnp.clip(slot - base, 0, S_loc - 1)
    owns = (slot >= base) & (slot < base + S_loc)  # (B,)
    b_idx = jnp.arange(B)

    def write(cache, new):
        cur = cache[b_idx, local_idx]  # (B, Hkv, D)
        upd = jnp.where(owns[:, None, None], new[:, 0].astype(cache.dtype), cur)
        return cache.at[b_idx, local_idx].set(upd)

    ck = write(cache_k, new_k)
    cv = write(cache_v, new_v)

    # -- partial attention over the local slice
    slots = base + jnp.arange(S_loc)[None]           # (1, S_loc) global slots
    q_pos = target[:, None]
    if ring_size:
        kv_pos = L.ring_slot_positions(slots, cache_len[:, None], ring_size)
        valid = kv_pos >= 0
    else:
        kv_pos = slots
        valid = kv_pos < cache_len[:, None]
    w = jnp.asarray(window, jnp.int32)
    valid &= (w <= 0) | (q_pos - kv_pos < w)
    o, m, l = L.decode_attention_partial(q, ck, cv, valid=valid, softcap=softcap)

    # -- distributed softmax merge (§Perf iteration: one fused psum in the
    #    cache dtype — bf16 in production — instead of separate f32 psums:
    #    halves merge bytes on the wire; normalization stays local f32).
    m_star = jax.lax.pmax(m, axis)                   # (B, Hq)
    corr = jnp.exp(m - m_star)
    Bq, _, Hq, D = o.shape
    payload = jnp.concatenate(
        [(o * corr[:, None, :, None]).reshape(Bq, Hq * D),
         (l * corr).reshape(Bq, Hq)], axis=-1).astype(cache_k.dtype)
    merged = jax.lax.psum(payload, axis).astype(jnp.float32)
    o_star = merged[:, : Hq * D].reshape(Bq, 1, Hq, D)
    l_star = merged[:, Hq * D:].reshape(Bq, Hq)
    o_star = o_star / jnp.maximum(l_star[:, None, :, None], 1e-30)
    return o_star.astype(q.dtype), ck, cv


def seq_parallel_attention(q, new_k, new_v, cache_k, cache_v, cache_len,
                           window, cfg: ModelConfig, par: ParallelContext):
    """q: (B,1,Hq,D); new_k/v: (B,1,Hkv,D); cache: (B,S,Hkv,D) seq-sharded
    over par.kv_seq_axis; batch sharded over the remaining batch axes."""
    axis = par.kv_seq_axis
    ring = getattr(cfg, "ring_cache", False)
    ring_size = cache_k.shape[1] if ring else 0
    if par.mesh is None or axis is None or axis not in par.axes:
        # single-device fallback: behave like the dense decode path
        B = q.shape[0]
        idx = (cache_len - 1) % ring_size if ring else cache_len - 1
        b_idx = jnp.arange(B)
        ck = cache_k.at[b_idx, idx].set(new_k[:, 0].astype(cache_k.dtype))
        cv = cache_v.at[b_idx, idx].set(new_v[:, 0].astype(cache_v.dtype))
        o = L.decode_attention(q, ck, cv, cache_len=cache_len, window=window,
                               softcap=cfg.logit_softcap, ring=ring)
        return o, ck, cv

    B = q.shape[0]
    # batch axes must not collide with the seq axis
    batch_ax = par.batch_axes_for(B)
    if batch_ax is not None:
        bt = (batch_ax,) if isinstance(batch_ax, str) else tuple(batch_ax)
        bt = tuple(a for a in bt if a != axis)
        batch_ax = (bt if len(bt) > 1 else (bt[0] if bt else None))

    act4 = P(batch_ax, None, None, None)
    vec = P(batch_ax)
    cache_spec = P(batch_ax, axis, None, None)
    fn = shard_map(
        lambda *a: _seq_shard_attention(*a, axis=axis,
                                        softcap=cfg.logit_softcap,
                                        ring_size=ring_size),
        mesh=par.mesh,
        in_specs=(act4, act4, act4, cache_spec, cache_spec, vec, P()),
        out_specs=(act4, cache_spec, cache_spec),
        check_vma=False,
    )
    return fn(q, new_k, new_v, cache_k, cache_v, cache_len,
              jnp.asarray(window, jnp.int32))


def seq_parallel_decode_layer(lp, x, cfg: ModelConfig, par: ParallelContext,
                              *, cache_k, cache_v, cache_len, window):
    """Full transformer layer for the sequence-parallel decode path.

    Mirrors models.transformer._layer but routes attention through the
    seq-sharded cache. Returns (x, new_cache_k, new_cache_v).
    """
    from repro.models.moe import moe_ffn

    B, S, _ = x.shape
    hd = cfg.resolved_head_dim()
    hn = L.rmsnorm(lp["attn_norm"], x, cfg.norm_eps)
    q = L.linear(lp["attn"]["wq"], hn).reshape(B, S, cfg.n_heads, hd)
    k = L.linear(lp["attn"]["wk"], hn).reshape(B, S, cfg.n_kv_heads, hd)
    v = L.linear(lp["attn"]["wv"], hn).reshape(B, S, cfg.n_kv_heads, hd)
    if cfg.rope_theta:
        positions = (cache_len - 1)[:, None]
        q = L.rope(q, positions, cfg.rope_theta)
        k = L.rope(k, positions, cfg.rope_theta)
    o, ck, cv = seq_parallel_attention(q, k, v, cache_k, cache_v, cache_len,
                                       window, cfg, par)
    x = x + L.linear(lp["attn"]["wo"], o.reshape(B, S, cfg.n_heads * hd))
    hn = L.rmsnorm(lp["ffn_norm"], x, cfg.norm_eps)
    if cfg.moe:
        h, _ = moe_ffn(lp["moe"], hn, cfg, par)
    else:
        h = L.swiglu(lp["ffn"], hn)
    return x + h, ck, cv
