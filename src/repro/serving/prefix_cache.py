"""Cross-request prefix cache: a radix tree over the KV block pool.

PR 2's copy-on-write sharing only covers *intra-request* forks (Best-of-N
samples sharing one prompt's blocks).  The paper's test-time-scaling
workloads, however, hammer the same system prompts and few-shot headers
across *requests* — and prefill is exactly the phase worth eliminating on
a fixed hardware budget.  This module keeps completed prompt prefixes
alive in the pool after their requests finish, so the next request that
shares a prefix skips re-prefilling it:

* the tree is keyed on **token-id chunks at block granularity**: one node
  per KV block, children keyed by the ``block_size``-token chunk that
  produced the block.  A root-to-node path therefore spells out an exact
  token prefix whose KV lives in the nodes' pool blocks;
* every node **owns one reference** to its block in the shared
  :class:`~repro.serving.kv_pool.KVPool` — cached blocks are pinned by
  refcount exactly like a live row's blocks, so fork/CoW/release semantics
  compose unchanged (a cached block used by a live row simply has
  refcount >= 2 and is never written: full prompt blocks sit below every
  row's write frontier);
* :meth:`match` walks the longest cached prefix of a prompt and *leases*
  the matched blocks to the caller (refcount +1 per block, transferred to
  the admitted row), so eviction between match and prefill can never free
  them.  A trailing partial-chunk match reuses a cached block's first
  ``r`` positions (their KV depends only on the agreed token prefix); the
  engine copy-on-writes that tail block before overwriting its remainder;
* :meth:`insert` records a finished prefill's full prompt blocks (partial
  trailing blocks are never cached — their remaining slots would be
  clobbered by decode writes).  Inserting an already-cached prefix is an
  idempotent LRU touch;
* :meth:`evict` frees least-recently-used **unreferenced leaves** (blocks
  the tree is the sole owner of) and is registered as the pool's
  ``pressure_hook``, so allocation pressure reclaims cache space *before*
  the scheduler falls back to out-of-blocks preemption;
* :meth:`probe` (a stats- and lease-free lookup) and :meth:`insert_batch`
  support the scheduler's **batched** cache-aware admission: probe plans
  which queued requests can lease now versus wait one round for a
  same-batch insert, and one ``insert_batch`` records a whole admitted
  batch's prompts before the next round matches.

Accounting is host-side and single-threaded, matching the scheduler's
step discipline; KV bytes never move on insert/match/evict — only
refcounts do.
"""
from __future__ import annotations

import heapq
import warnings
from typing import Iterable, Optional

from repro.serving.kv_pool import KVPool


class _Node:
    """One cached KV block: ``chunk`` (block_size token ids) -> ``block``."""

    __slots__ = ("chunk", "block", "parent", "children", "last_used")

    def __init__(self, chunk: Optional[tuple], block: int,
                 parent: Optional["_Node"]):
        self.chunk = chunk
        self.block = block
        self.parent = parent
        self.children: dict[tuple, "_Node"] = {}
        self.last_used = 0


class PrefixCache:
    """Radix tree of cached prompt prefixes over one engine's block pool.

    ``capacity_blocks`` caps how many pool blocks the cache may pin
    (admission control); ``None`` leaves it bounded only by pool pressure
    (the eviction hook).  Constructing the cache registers its
    :meth:`evict` as ``pool.pressure_hook``.
    """

    def __init__(self, pool: KVPool, *,
                 capacity_blocks: Optional[int] = None):
        if capacity_blocks is not None and capacity_blocks < 0:
            raise ValueError("capacity_blocks must be >= 0 or None")
        self.pool = pool
        self.block_size = pool.block_size
        self.capacity = capacity_blocks
        self.root = _Node(chunk=None, block=-1, parent=None)
        self._clock = 0
        self.n_cached_blocks = 0
        # lifetime counters (see stats())
        self.lookups = 0
        self.hits = 0
        self.tokens_matched = 0
        self.insertions = 0
        self.evictions = 0
        prev = getattr(pool.pressure_hook, "__self__", None)
        if pool.pressure_hook is not None and not (
                isinstance(prev, PrefixCache) and prev.n_cached_blocks == 0):
            # replacing a cache that still pins blocks strands them: they
            # can no longer be reclaimed under pool pressure
            warnings.warn(
                "replacing this KVPool's pressure hook while the previous "
                "prefix cache still pins blocks — clear() the old cache "
                "first so its blocks return to the free list",
                RuntimeWarning, stacklevel=2)
        pool.pressure_hook = self.evict

    # -- lookup --------------------------------------------------------------
    def _walk(self, toks: list, *, touch: bool) -> tuple[list[int], int]:
        """Longest-cached-prefix walk shared by :meth:`match` and
        :meth:`probe`: full-block chunk descent plus the partial
        trailing-chunk rule.  ``touch=True`` LRU-touches visited nodes."""
        bs = self.block_size
        node = self.root
        blocks: list[int] = []
        i = 0
        while i + bs <= len(toks):
            child = node.children.get(tuple(toks[i:i + bs]))
            if child is None:
                break
            if touch:
                child.last_used = self._clock
            blocks.append(child.block)
            node = child
            i += bs
        # partial trailing chunk: a cached block whose chunk agrees on the
        # remaining r tokens serves positions [i, i + r) verbatim
        r = len(toks) - i
        if 0 < r < bs:
            for child in node.children.values():
                if list(child.chunk[:r]) == toks[i:]:
                    if touch:
                        child.last_used = self._clock
                    blocks.append(child.block)
                    i += r
                    break
        return blocks, i

    def match(self, tokens) -> tuple[list[int], int]:
        """Longest-cached-prefix lookup.  Returns ``(blocks, cached_len)``.

        ``blocks`` covers positions ``[0, cached_len)`` in table order and
        arrives with **one extra reference per block owned by the caller**
        (the lease the admitted row will hold; release it if admission is
        abandoned).  ``cached_len`` is a multiple of ``block_size`` except
        when a trailing partial-chunk match reuses the first ``cached_len
        % block_size`` positions of a cached block — the engine's partial
        prefill copy-on-writes that tail before extending it.  Callers cap
        the searched prefix themselves (typically ``prompt[:-1]`` so at
        least one token is recomputed for the next-token logits).

        Counts toward :meth:`stats` (one lookup; a hit when any block
        matched) and LRU-touches the matched path.  Use :meth:`probe` for
        planning passes that must not take a lease or skew the stats.
        """
        toks = [int(t) for t in tokens]
        self._clock += 1
        self.lookups += 1
        blocks, i = self._walk(toks, touch=True)
        if blocks:
            self.pool.retain(blocks)  # the caller's lease
            self.hits += 1
            self.tokens_matched += i
        return blocks, i

    def probe(self, tokens) -> int:
        """Length of the longest cached prefix of ``tokens`` *without*
        taking a lease, LRU-touching nodes, or counting a lookup.

        The planning half of batched admission: the scheduler probes a
        candidate to decide whether to lease now (:meth:`match`) or defer
        it until an earlier request in the same batch has inserted a
        longer shared prefix.  Purely read-only on tree and pool."""
        blocks, i = self._walk([int(t) for t in tokens], touch=False)
        return i

    def potential_match(self, tokens, prompt) -> int:
        """Length :meth:`match`/:meth:`probe` of ``tokens`` would return
        against a tree holding only :meth:`insert` of ``prompt`` — no
        tree access, pure token arithmetic.

        This is batched admission's deferral estimate: a same-run earlier
        request with ``prompt`` has not prefilled yet, so its blocks
        cannot be leased, but once it inserts, the union-tree match is
        the max of :meth:`probe` and this over the run's prompts (radix
        chains only merge on identical chunks, so the longest prefix in
        the union is the max over individual chains).  Mirrors the match
        rules exactly: the full-block walk stops at the common prefix,
        at ``tokens``'s own last full block, and at the full blocks
        ``prompt`` actually inserts; the partial-trailing-chunk rule
        applies when the remaining ``r < block_size`` query tokens agree
        with the next inserted block.  Callers cap the searched prefix
        as they do for match (typically ``prompt[:-1]``)."""
        toks = [int(t) for t in tokens]
        other = [int(t) for t in prompt]
        bs = self.block_size
        cap = len(toks)
        limit = (len(other) // bs) * bs  # tokens the insert records
        raw = 0
        for a, b in zip(toks, other):
            if a != b:
                break
            raw += 1
        i = min((raw // bs) * bs, (cap // bs) * bs, limit)
        r = cap - i
        if 0 < r < bs and raw >= cap and i < limit:
            return cap                   # partial tail serves [i, cap)
        return i

    # -- insertion -----------------------------------------------------------
    def insert(self, tokens, blocks) -> int:
        """Record a prefilled prompt's blocks; returns blocks newly pinned.

        ``tokens`` is the full prompt; ``blocks[j]`` must hold positions
        ``[j*bs, (j+1)*bs)`` of it (a row's table prefix).  Only full
        blocks are inserted.  Existing nodes are LRU-touched, missing ones
        pinned with a fresh pool reference; insertion stops (rather than
        evicting its own path) when the capacity cap cannot be honored.
        """
        toks = [int(t) for t in tokens]
        bs = self.block_size
        self._clock += 1
        node = self.root
        added = 0
        path_ids = {id(self.root)}
        for j in range(len(toks) // bs):
            chunk = tuple(toks[j * bs:(j + 1) * bs])
            child = node.children.get(chunk)
            if child is None:
                if (self.capacity is not None
                        and self.n_cached_blocks >= self.capacity
                        and not self.evict(1, avoid=path_ids)):
                    break  # full and nothing evictable outside our path
                blk = int(blocks[j])
                self.pool.retain([blk])
                child = _Node(chunk=chunk, block=blk, parent=node)
                node.children[chunk] = child
                self.n_cached_blocks += 1
                self.insertions += 1
                added += 1
            child.last_used = self._clock
            node = child
            path_ids.add(id(child))
        return added

    def insert_batch(self, items) -> int:
        """Record a *batch* of prefilled prompts in one call.

        ``items`` iterates ``(tokens, blocks)`` pairs with the
        :meth:`insert` contract each.  This is the insert half of batched
        admission: after one batched partial prefill admits N rows, all N
        prompts land in the tree before the next admission round matches
        against it (order within the batch is preserved, so shared paths
        dedup exactly as sequential inserts would).  Returns the total
        number of blocks newly pinned."""
        return sum(self.insert(toks, blocks) for toks, blocks in items)

    # -- eviction ------------------------------------------------------------
    def _evictable_leaves(self, avoid) -> list[_Node]:
        out = []
        stack = list(self.root.children.values())
        while stack:
            n = stack.pop()
            if n.children:
                stack.extend(n.children.values())
            elif id(n) not in avoid and self.pool.refcount[n.block] == 1:
                out.append(n)  # tree is the sole owner: freeing frees HBM
        return out

    def evict(self, n: int, avoid: Iterable[int] = ()) -> int:
        """Free up to ``n`` pool blocks by dropping LRU unreferenced
        leaves (refcount 1 = pinned by the tree alone; blocks leased to
        live rows are skipped — releasing them would reclaim nothing).
        One tree walk seeds a min-heap on ``last_used``; evicting a leaf
        pushes its parent when that becomes the next candidate.  Returns
        the number of blocks actually freed."""
        avoid = set(avoid)
        freed = 0
        heap = [(nd.last_used, id(nd), nd)
                for nd in self._evictable_leaves(avoid)]
        heapq.heapify(heap)
        while heap and freed < n:
            _, _, victim = heapq.heappop(heap)
            self.pool.release([victim.block])
            parent = victim.parent
            del parent.children[victim.chunk]
            self.n_cached_blocks -= 1
            self.evictions += 1
            freed += 1
            if (parent is not self.root and not parent.children
                    and id(parent) not in avoid
                    and self.pool.refcount[parent.block] == 1):
                heapq.heappush(heap, (parent.last_used, id(parent), parent))
        return freed

    def clear(self) -> int:
        """Drop every cached prefix (releases all pinned blocks)."""
        freed = 0
        stack = list(self.root.children.values())
        while stack:
            n = stack.pop()
            stack.extend(n.children.values())
            self.pool.release([n.block])
            freed += 1
        self.root.children.clear()
        self.n_cached_blocks = 0
        self.evictions += freed
        return freed

    # -- introspection -------------------------------------------------------
    def cached_block_ids(self) -> set[int]:
        """Pool block ids currently pinned by the tree (leak checks)."""
        out = set()
        stack = list(self.root.children.values())
        while stack:
            n = stack.pop()
            stack.extend(n.children.values())
            out.add(n.block)
        return out

    def stats(self) -> dict:
        return {
            "lookups": self.lookups,
            "hits": self.hits,
            "hit_rate": self.hits / self.lookups if self.lookups else 0.0,
            "tokens_matched": self.tokens_matched,
            "cached_blocks": self.n_cached_blocks,
            "cached_tokens": self.n_cached_blocks * self.block_size,
            "cached_bytes": self.n_cached_blocks * self.pool.block_bytes(),
            "capacity_blocks": self.capacity,
            "insertions": self.insertions,
            "evictions": self.evictions,
        }
