"""Roofline-attributed kernel profiling + an online numerics-drift canary.

PR 8's :mod:`~repro.serving.telemetry` made *host-side* request life
observable; this module adds the device-level half: where does a decode
step's time actually go, and are the paper's two approximations (§5.1
tile quantization, §5.2 LUT softmax/dequant) still numerically honest
under real serving load?  Two halves, one recorder:

* **Roofline attribution.**  The kernel wrappers in
  :mod:`repro.kernels.ops` (plus the XLA fallback branch of
  ``layers.paged_decode_attention``) report every dispatch to an
  installable hook with the analytic ``(flops, hbm_bytes)`` cost from
  the single-sourced models in :mod:`repro.kernels.autotune`.  Those
  wrappers run inside the engine's jitted step functions, so the hook
  fires at *trace* time only — the profiler therefore brackets each
  jitted call in a named **phase** (``prefill``/``decode``), caches the
  op roster a phase records when it traces, and replays the cached
  roster on every later cached-executable invocation.  Measured wall
  time is *sampled*: on sampled steps the phase end blocks
  (``jax.block_until_ready``) so the wall covers real device work, and
  the analytic roofline bound ``max(flops/PEAK, bytes/BW)`` divided by
  that wall is the phase's achieved-vs-peak efficiency.  Per-kernel
  efficiency attributes each sampled phase wall across its ops in
  proportion to their analytic bounds.

* **Numerics-drift canary.**  On a configurable fraction of decode
  steps the scheduler re-runs the live rows through the *exact* path —
  the XLA paged-attention impl: table gather, reference fp dequant,
  exact f32 softmax — and compares logits against the production step:
  max logit error, argmax flip rate, plus the per-layer KV quant
  round-trip error (dequantize → re-quantize → dequantize) of the pool
  blocks the rows read.  Crossing a threshold records a warning; under
  the default XLA impl the exact path *is* the production path and the
  flip rate must be exactly 0 (the CI benchmark asserts it).

**Clock semantics / zero overhead.**  Same contract as the tracer:
``clock`` is injectable (tests pass a deterministic counter), all times
are ``clock() - epoch`` seconds, and ``profiler=None`` everywhere means
no hook, no phases, no allocations — bit-identical scheduler outputs,
asserted in ``tests/test_profiling.py``.

``launch/serve.py --profile report.json`` writes the JSON report
(schema ``repro.profile.v1``); ``python -m repro.serving.profiling
report.json`` validates it (the CI check — see
:func:`validate_profile_report`).
"""
from __future__ import annotations

import json
import math
import sys
import time
from typing import Callable, Optional

import jax

from repro.kernels import autotune as _autotune
from repro.serving.telemetry import percentile

SCHEMA = "repro.profile.v1"

# op name -> cost-breakdown category (unknown ops land in "other")
OP_CATEGORIES = {
    "flash_attention": "softmax",
    "paged_flash_decode": "softmax",
    "paged_attention_xla": "softmax",
    "lut_dequant_matmul": "dequant",
    "lut_dequant_kv": "dequant",
    "tile_quantize": "quantize",
}

# phase names the engine brackets its jitted calls with; anything
# recorded outside an open phase lands in "untimed" (no wall attribution)
PHASE_NAMES = ("prefill", "decode", "untimed")


# the profiler keys SchedulerMetrics.summary() reports; a scheduler with
# no profiler attached emits exactly these zeros, so the summary key set
# is identical with and without profiling (the null-parity contract)
NULL_PROFILE_METRICS = {
    "profiled_steps": 0,
    "kernel_time_share": 0.0,
    "roofline_efficiency_p50": 0.0,
    "canary_samples": 0,
    "canary_max_logit_err": 0.0,
    "canary_argmax_flip_rate": 0.0,
    "canary_kv_roundtrip_err": 0.0,
}


def _interval(rate: float) -> int:
    """Fraction -> deterministic every-Nth-step interval (0 disables)."""
    if rate <= 0.0:
        return 0
    return max(1, int(round(1.0 / min(rate, 1.0))))


class KernelProfiler:
    """Records per-kernel analytic cost, sampled measured wall time and
    canary drift gauges.  One instance per serving run; install on a
    scheduler via ``ContinuousScheduler(profiler=...)`` (which binds the
    engine slot and the ops dispatch hook).

    ``sample_rate`` is the fraction of scheduler steps whose phase walls
    are measured (``block_until_ready`` at the phase boundary — the only
    place the profiler ever syncs); ``canary_rate`` the fraction of
    steps re-run through the exact path.  Both are deterministic
    every-Nth-step schedules, so profiled runs are reproducible.
    """

    def __init__(self, *, sample_rate: float = 1.0,
                 canary_rate: float = 0.0,
                 clock: Callable[[], float] = time.perf_counter,
                 logit_err_warn: float = 0.05,
                 flip_rate_warn: float = 0.01,
                 kv_err_warn: float = 0.25):
        self.clock = clock
        self._t0 = clock()
        self.sample_rate = float(sample_rate)
        self.canary_rate = float(canary_rate)
        self.sample_interval = _interval(sample_rate)
        self.canary_interval = _interval(canary_rate)
        self.logit_err_warn = logit_err_warn
        self.flip_rate_warn = flip_rate_warn
        self.kv_err_warn = kv_err_warn
        # phase machinery
        self._stack: list[str] = []           # open phases (innermost last)
        self._trace_buf: dict[str, list] = {}  # ops seen while tracing
        self._roster: dict[str, list] = {}     # phase -> cached op roster
        # accumulators
        self._ops: dict[str, dict] = {}        # per-kernel totals
        self._phases: dict[str, dict] = {}     # per-phase totals
        self._eff_samples: list[float] = []    # per sampled phase
        self._step_idx = 0
        self._sampled_steps = 0
        self._in_step = False
        self._sample_this_step = True          # standalone phases sample
        self._step_wall = 0.0                  # sampled phase walls, this step
        self._step_bound = 0.0
        self._step_walls: list[float] = []     # scheduler wall of sampled steps
        self._kernel_walls: list[float] = []   # phase-wall sum of sampled steps
        self.last_step_gauges: dict[str, float] = {}
        # canary
        self._canary_samples = 0
        self._canary_rows = 0
        self._canary_flips = 0
        self._canary_max_err = 0.0
        self._kv_err_per_layer: list[float] = []
        self.warnings: list[str] = []
        self._prev_hook = None
        self._installed = False

    # -- clock ---------------------------------------------------------------
    def now(self) -> float:
        """Seconds since the profiler's epoch."""
        return self.clock() - self._t0

    # -- ops dispatch hook ----------------------------------------------------
    def install(self) -> None:
        """Bind :meth:`record_op` as the kernels' dispatch hook."""
        from repro.kernels import ops

        if not self._installed:
            self._prev_hook = ops.set_op_hook(self.record_op)
            self._installed = True

    def uninstall(self) -> None:
        """Restore the dispatch hook that was installed before us."""
        from repro.kernels import ops

        if self._installed:
            ops.set_op_hook(self._prev_hook)
            self._installed = False

    def record_op(self, name: str, flops: float, hbm_bytes: float) -> None:
        """Dispatch-hook target: one kernel call's analytic cost.  Fires
        at trace time for jitted callers; buffered into the innermost
        open phase (accumulated directly when no phase is open)."""
        if self._stack:
            self._trace_buf[self._stack[-1]].append(
                (name, float(flops), float(hbm_bytes)))
        else:
            self._account(name, float(flops), float(hbm_bytes))
            ph = self._phases.setdefault(
                "untimed", {"calls": 0, "sampled": 0, "wall_s": 0.0,
                            "bound_s": 0.0})
            ph["bound_s"] += _autotune.roofline_bound_s(flops, hbm_bytes)

    def _account(self, name: str, flops: float, hbm_bytes: float) -> float:
        op = self._ops.setdefault(
            name, {"calls": 0, "flops": 0.0, "hbm_bytes": 0.0,
                   "bound_s": 0.0, "wall_s": 0.0, "sampled_bound_s": 0.0})
        bound = _autotune.roofline_bound_s(flops, hbm_bytes)
        op["calls"] += 1
        op["flops"] += flops
        op["hbm_bytes"] += hbm_bytes
        op["bound_s"] += bound
        return bound

    # -- phases (engine brackets its jitted calls with these) ----------------
    def phase_begin(self, name: str) -> float:
        """Open phase ``name``; returns the t0 to pass to
        :meth:`phase_end`."""
        self._stack.append(name)
        self._trace_buf[name] = []
        return self.now()

    def phase_end(self, name: str, t0: float, outputs=None) -> None:
        """Close phase ``name``.  Replays the phase's cached op roster
        into the analytic totals (refreshing the cache if this
        invocation retraced), and — on sampled steps, when ``outputs``
        is given — blocks on ``outputs`` and records the measured wall
        time against the roster's roofline bound."""
        if self._stack and self._stack[-1] == name:
            self._stack.pop()
        buf = self._trace_buf.pop(name, [])
        if buf:  # this invocation traced: the roster is fresh
            self._roster[name] = buf
        roster = self._roster.get(name, [])
        bound = 0.0
        for op_name, flops, hbm in roster:
            bound += self._account(op_name, flops, hbm)
        ph = self._phases.setdefault(
            name, {"calls": 0, "sampled": 0, "wall_s": 0.0,
                   "bound_s": 0.0, "_effs": []})
        ph["calls"] += 1
        ph["bound_s"] += bound
        if not (self._sample_this_step and outputs is not None):
            return
        jax.block_until_ready(outputs)
        wall = self.now() - t0
        ph["sampled"] += 1
        ph["wall_s"] += wall
        self._step_wall += wall
        self._step_bound += bound
        if wall > 0.0 and bound > 0.0:
            eff = bound / wall
            ph.setdefault("_effs", []).append(eff)
            self._eff_samples.append(eff)
            # attribute the phase wall across its ops by bound share
            for op_name, flops, hbm in roster:
                op_bound = _autotune.roofline_bound_s(flops, hbm)
                self._ops[op_name]["wall_s"] += wall * op_bound / bound
                self._ops[op_name]["sampled_bound_s"] += op_bound

    # -- per-scheduler-step sampling ------------------------------------------
    def begin_step(self) -> None:
        """Scheduler step start: decide whether this step's phases get
        measured walls and whether it is a canary step."""
        self._in_step = True
        self._sample_this_step = (
            self.sample_interval > 0
            and self._step_idx % self.sample_interval == 0)
        self._step_wall = 0.0
        self._step_bound = 0.0

    def want_canary(self) -> bool:
        """True when the current step should re-run rows through the
        exact path (deterministic every-Nth-step schedule)."""
        return (self.canary_interval > 0
                and self._step_idx % self.canary_interval == 0)

    def end_step(self, wall_s: float) -> None:
        """Scheduler step end; ``wall_s`` is the scheduler's own step
        wall (tracer-clocked).  Exposes the step's kernel-time gauges in
        :attr:`last_step_gauges` for the tracer's counter tracks."""
        if self._sample_this_step:
            self._sampled_steps += 1
            self._step_walls.append(wall_s)
            self._kernel_walls.append(self._step_wall)
            self.last_step_gauges = {
                "kernel_time_s": self._step_wall,
                "roofline_bound_s": self._step_bound,
            }
        else:
            self.last_step_gauges = {}
        self._step_idx += 1
        self._in_step = False
        self._sample_this_step = True  # standalone phases keep sampling

    # -- canary ----------------------------------------------------------------
    def record_canary(self, *, max_logit_err: float, flips: int, rows: int,
                      kv_err_per_layer=None) -> None:
        """One canary sample: ``rows`` live rows compared against the
        exact path, ``flips`` of them with a different argmax,
        ``max_logit_err`` the worst |logit delta| across them.
        ``kv_err_per_layer`` is the per-layer KV quant round-trip error
        (max |dequant(quant(dequant(pool))) - dequant(pool)|)."""
        self._canary_samples += 1
        self._canary_rows += int(rows)
        self._canary_flips += int(flips)
        self._canary_max_err = max(self._canary_max_err,
                                   float(max_logit_err))
        if kv_err_per_layer is not None:
            errs = [float(e) for e in kv_err_per_layer]
            if len(self._kv_err_per_layer) < len(errs):
                self._kv_err_per_layer += [0.0] * (
                    len(errs) - len(self._kv_err_per_layer))
            for i, e in enumerate(errs):
                self._kv_err_per_layer[i] = max(self._kv_err_per_layer[i],
                                                e)
            if errs and max(errs) > self.kv_err_warn:
                self._warn(f"kv round-trip error {max(errs):.4g} exceeds "
                           f"threshold {self.kv_err_warn:.4g} "
                           f"(layer {errs.index(max(errs))})")
        if float(max_logit_err) > self.logit_err_warn:
            self._warn(f"max logit error {float(max_logit_err):.4g} "
                       f"exceeds threshold {self.logit_err_warn:.4g} "
                       f"at step {self._step_idx}")
        rate = self._canary_flips / max(1, self._canary_rows)
        if rate > self.flip_rate_warn:
            self._warn(f"argmax flip rate {rate:.4g} exceeds threshold "
                       f"{self.flip_rate_warn:.4g} "
                       f"({self._canary_flips}/{self._canary_rows} rows)")

    def _warn(self, msg: str) -> None:
        if msg not in self.warnings:
            self.warnings.append(msg)

    # -- derivation ------------------------------------------------------------
    def summary_metrics(self) -> dict:
        """The profiler keys ``SchedulerMetrics.summary()`` merges in.
        Every key is 0.0-safe on an empty run."""
        step_wall = sum(self._step_walls)
        return {
            "profiled_steps": self._sampled_steps,
            "kernel_time_share": (sum(self._kernel_walls) / step_wall
                                  if step_wall > 0 else 0.0),
            "roofline_efficiency_p50": percentile(self._eff_samples, 50),
            "canary_samples": self._canary_samples,
            "canary_max_logit_err": self._canary_max_err,
            "canary_argmax_flip_rate": (
                self._canary_flips / self._canary_rows
                if self._canary_rows else 0.0),
            "canary_kv_roundtrip_err": (max(self._kv_err_per_layer)
                                        if self._kv_err_per_layer else 0.0),
        }

    def report(self) -> dict:
        """The full JSON-serializable profile report (``--profile``)."""
        kernels = {}
        for name, op in sorted(self._ops.items()):
            wall = op["wall_s"]
            kernels[name] = {
                "calls": op["calls"],
                "flops": op["flops"],
                "hbm_bytes": op["hbm_bytes"],
                "bound_s": op["bound_s"],
                "wall_s": wall,
                "category": OP_CATEGORIES.get(name, "other"),
                # achieved-vs-peak over *sampled* invocations only, so a
                # sub-1.0 sample rate doesn't skew the ratio
                "efficiency": (op["sampled_bound_s"] / wall
                               if wall > 0 else 0.0),
            }
        phases = {}
        for name, ph in sorted(self._phases.items()):
            phases[name] = {
                "calls": ph["calls"],
                "sampled": ph.get("sampled", 0),
                "wall_s": ph.get("wall_s", 0.0),
                "bound_s": ph["bound_s"],
                "efficiency_p50": percentile(ph.get("_effs", []), 50),
            }
        total_bound = sum(op["bound_s"] for op in self._ops.values())
        breakdown: dict[str, float] = {}
        for name, op in self._ops.items():
            cat = OP_CATEGORIES.get(name, "other")
            breakdown[cat] = breakdown.get(cat, 0.0) + (
                op["bound_s"] / total_bound if total_bound > 0 else 0.0)
        return {
            "schema": SCHEMA,
            "constants": {"peak_flops": _autotune.PEAK_FLOPS,
                          "hbm_bw": _autotune.HBM_BW},
            "sample_rate": self.sample_rate,
            "canary_rate": self.canary_rate,
            "steps": self._step_idx,
            "sampled_steps": self._sampled_steps,
            "kernels": kernels,
            "phases": phases,
            "breakdown": breakdown,
            "summary": self.summary_metrics(),
            "canary": {
                "samples": self._canary_samples,
                "rows": self._canary_rows,
                "flips": self._canary_flips,
                "max_logit_err": self._canary_max_err,
                "argmax_flip_rate": (
                    self._canary_flips / self._canary_rows
                    if self._canary_rows else 0.0),
                "kv_roundtrip_err_per_layer": list(self._kv_err_per_layer),
                "thresholds": {"logit_err": self.logit_err_warn,
                               "flip_rate": self.flip_rate_warn,
                               "kv_err": self.kv_err_warn},
                "warnings": list(self.warnings),
            },
        }

    def write_report(self, path: str) -> str:
        rep = self.report()
        bad = validate_profile_report(rep)
        if bad:  # never write a file the validator would reject
            raise ValueError(f"refusing to write invalid report: {bad[:3]}")
        with open(path, "w") as f:
            json.dump(rep, f, indent=2, sort_keys=True)
            f.write("\n")
        return path


# ---------------------------------------------------------------------------
# Report schema validation (the CI check)
# ---------------------------------------------------------------------------

_TOP_REQUIRED = ("schema", "steps", "sampled_steps", "kernels", "phases",
                 "breakdown", "summary", "canary")
_KERNEL_REQUIRED = ("calls", "flops", "hbm_bytes", "bound_s", "wall_s",
                    "efficiency")
_SUMMARY_REQUIRED = ("profiled_steps", "kernel_time_share",
                     "roofline_efficiency_p50", "canary_samples",
                     "canary_max_logit_err", "canary_argmax_flip_rate",
                     "canary_kv_roundtrip_err")
_CANARY_REQUIRED = ("samples", "rows", "flips", "max_logit_err",
                    "argmax_flip_rate", "kv_roundtrip_err_per_layer",
                    "warnings")


def _num(x) -> bool:
    return isinstance(x, (int, float)) and math.isfinite(x)


def validate_profile_report(obj) -> list[str]:
    """Structural validation of a ``repro.profile.v1`` report.  Returns
    violation strings (empty = valid):

    * top level: an object with ``schema == "repro.profile.v1"`` and all
      of ``steps/sampled_steps/kernels/phases/breakdown/summary/canary``;
    * every kernel entry carries finite, non-negative
      ``calls/flops/hbm_bytes/bound_s/wall_s/efficiency``;
    * breakdown shares are in [0, 1] and sum to at most 1 (+eps);
    * the summary carries every key the scheduler merges (all finite);
    * the canary block is complete, its per-layer errors numeric and its
      warnings strings.
    """
    bad: list[str] = []
    if not isinstance(obj, dict):
        return ["top level must be an object"]
    if obj.get("schema") != SCHEMA:
        bad.append(f"schema must be {SCHEMA!r} (got {obj.get('schema')!r})")
    missing = [k for k in _TOP_REQUIRED if k not in obj]
    if missing:
        bad.append(f"missing top-level keys {missing}")
        return bad
    if not _num(obj["steps"]) or obj["steps"] < 0:
        bad.append(f"steps: bad value {obj['steps']!r}")
    if not _num(obj["sampled_steps"]) or obj["sampled_steps"] < 0:
        bad.append(f"sampled_steps: bad value {obj['sampled_steps']!r}")
    if not isinstance(obj["kernels"], dict):
        bad.append("kernels must be an object")
    else:
        for name, op in obj["kernels"].items():
            if not isinstance(op, dict):
                bad.append(f"kernel {name}: not an object")
                continue
            for k in _KERNEL_REQUIRED:
                v = op.get(k)
                if not _num(v) or v < 0:
                    bad.append(f"kernel {name}: bad {k} {v!r}")
    if not isinstance(obj["phases"], dict):
        bad.append("phases must be an object")
    if not isinstance(obj["breakdown"], dict):
        bad.append("breakdown must be an object")
    else:
        total = 0.0
        for cat, share in obj["breakdown"].items():
            if not _num(share) or not (0.0 <= share <= 1.0 + 1e-6):
                bad.append(f"breakdown {cat}: bad share {share!r}")
            else:
                total += share
        if total > 1.0 + 1e-6:
            bad.append(f"breakdown shares sum to {total} > 1")
    summary = obj["summary"]
    if not isinstance(summary, dict):
        bad.append("summary must be an object")
    else:
        for k in _SUMMARY_REQUIRED:
            if not _num(summary.get(k)):
                bad.append(f"summary: bad {k} {summary.get(k)!r}")
    canary = obj["canary"]
    if not isinstance(canary, dict):
        bad.append("canary must be an object")
    else:
        for k in _CANARY_REQUIRED:
            if k not in canary:
                bad.append(f"canary: missing {k}")
        for k in ("samples", "rows", "flips", "max_logit_err",
                  "argmax_flip_rate"):
            if k in canary and not _num(canary[k]):
                bad.append(f"canary: bad {k} {canary[k]!r}")
        errs = canary.get("kv_roundtrip_err_per_layer", [])
        if not isinstance(errs, list) or not all(_num(e) for e in errs):
            bad.append("canary: kv_roundtrip_err_per_layer must be a "
                       "list of finite numbers")
        warns = canary.get("warnings", [])
        if not isinstance(warns, list) or not all(
                isinstance(w, str) for w in warns):
            bad.append("canary: warnings must be a list of strings")
    return bad


def main(argv=None) -> int:
    """``python -m repro.serving.profiling report.json [...]`` — validate
    profile reports; exits non-zero listing the violations."""
    argv = list(sys.argv[1:] if argv is None else argv)
    if not argv:
        print("usage: python -m repro.serving.profiling REPORT.json [...]",
              file=sys.stderr)
        return 2
    rc = 0
    for path in argv:
        try:
            with open(path) as f:
                obj = json.load(f)
        except (OSError, json.JSONDecodeError) as e:
            print(f"{path}: unreadable ({e})", file=sys.stderr)
            rc = 1
            continue
        bad = validate_profile_report(obj)
        if bad:
            for msg in bad:
                print(f"{path}: {msg}", file=sys.stderr)
            rc = 1
        else:
            s = obj["summary"]
            print(f"{path}: OK ({len(obj['kernels'])} kernels, "
                  f"{obj['sampled_steps']}/{obj['steps']} steps sampled, "
                  f"eff_p50={s['roofline_efficiency_p50']:.3g}, "
                  f"flip_rate={s['canary_argmax_flip_rate']:.3g})")
    return rc


if __name__ == "__main__":
    sys.exit(main())
