"""Quantized KV block pool: tile-quantized Q8/Q4 blocks over the paged pool.

The paper's §5.1 tile quantization and §5.2 LUT dequantization are applied
to *weights* elsewhere in this repo (``repro.quant.tile_quant``,
``kernels/lut_dequant_gemm``).  This module applies the same geometry to
the KV cache — the actual memory ceiling for Best-of-N serving on a fixed
hardware budget: a :class:`QuantKVPool` is a drop-in for
:class:`~repro.serving.kv_pool.KVPool` whose ``k``/``v`` device leaves
store Q8 or packed Q4 *codes* plus per-tile *scales* instead of fp values.

Tile-scale layout
-----------------
One token's KV slab is an ``(Hkv, D)`` matrix written atomically (prefill
scatters whole tokens; a decode step writes one token per row).  Groups
therefore never span tokens — quantize-on-write never re-touches old KV —
and within the slab they follow the paper's register-tile geometry
(Fig. 4a mapped exactly as ``tile_quant`` maps it for weights):

* a group is a ``(gr, gc)`` rectangle of ``gr = 2`` adjacent KV heads ×
  ``gc = group_size // 2 = 16`` contiguous head dims — the (2, 16)
  sub-tile of the HMX layout, a lane-contiguous strip of a VREG tile;
* per leaf and block the storage is::

      codes : (n_blocks, bs, Hkv, D)      int8          (q8)
              (n_blocks, bs, Hkv, D//2)   uint8 packed  (q4, two codes per
                                          byte along D, low nibble = even)
      scales: (n_blocks, bs, Hkv//gr, D//gc)  float16

  so a block-table gather of codes *and* scales is unit-stride in both —
  the Fig. 6 scatter mismatch is designed away for KV exactly as for
  weights (dequant = one cheap repeat along heads + one along dims);
* q8 codes are symmetric ints (``clip(round(x/s), -127, 127)``,
  ``s = absmax/127``); q4 codes index the ``q4_0`` 16-entry codebook
  (``repro.quant.codebooks``), dequantized via the same LUT story as the
  weight kernels.

Configs with an odd ``Hkv`` fall back to ``gr = 1`` (scales per head); a
``D`` not divisible by 16 halves ``gc`` until it divides.  All shape
metadata is recoverable from the leaf shapes/dtypes alone
(:func:`kv_geometry`), so every consumer — the engine's scatter jits, the
XLA gather fallback, the Pallas kernel — stays shape-polymorphic with no
static spec threading.

Accuracy vs bytes (measured by ``benchmarks/serving_scaling.py
--kv-quant``, trained tiny model, greedy Best-of-N math workload,
float32 fp baseline):

==========  ==================  =====================  ==================
mode        bytes per KV value  peak-KV-byte reduction greedy accuracy
==========  ==================  =====================  ==================
fp (f32)    4.0                 —                      baseline
q8          1.0625 (1 + 2/32)   ~73%                   == baseline
q4          0.5625 (0.5 + 2/32) ~86%                   <= 1 task drop
==========  ==================  =====================  ==================

Copy-on-write, fork refcounts and prefix-cache pinning operate on *block
ids* and move whole blocks, so they compose unchanged over code+scale
payloads — :meth:`KVPool.cow` device-copies every leaf of a block via the
same tree-mapped scatter, and the radix tree pins quantized blocks exactly
like fp ones.  That includes *batched* CoW plans: one ``cow(list)`` call
commits every pending copy (e.g. all misaligned cached-tail blocks of a
batched partial-prefill admission) in a single device scatter over the
code and scale leaves alike.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.quant.codebooks import codebook_absmax, get_codebook
from repro.serving.kv_pool import KVPool

KV_QUANT_MODES = ("none", "q8", "q4")
# the q4 codebook is fixed (the symmetric integer grid): KV statistics are
# near-gaussian but the write path must be cheap — nearest-entry over an
# affine grid is a round, not a 16-way argmin
Q4_CODEBOOK = "q4_0"


def kv_tile_geometry(n_kv_heads: int, head_dim: int,
                     group_size: int = 32) -> tuple[int, int]:
    """(gr, gc) tile shape for an ``(Hkv, D)`` token slab.

    Canonical shape is ``(2, group_size // 2)`` — the paper's register
    tile; odd head counts drop to one head per tile and a non-dividing
    head dim halves ``gc`` until it divides."""
    gr = 2 if n_kv_heads % 2 == 0 else 1
    gc = max(1, group_size // 2)
    while head_dim % gc:
        gc //= 2
    return gr, gc


def kv_geometry(leaf: dict) -> tuple[str, int, int, int]:
    """Recover (mode, gr, gc, head_dim) from a quantized leaf's shapes.

    ``leaf`` is {"codes", "scales"} with token-slab trailing dims
    ``codes (..., Hkv, Dc)`` / ``scales (..., Hkv//gr, D//gc)``.
    """
    codes, scales = leaf["codes"], leaf["scales"]
    mode = "q8" if codes.dtype == jnp.int8 else "q4"
    hkv = codes.shape[-2]
    d = codes.shape[-1] * (2 if mode == "q4" else 1)
    gr = hkv // scales.shape[-2]
    gc = d // scales.shape[-1]
    return mode, gr, gc, d


def _pack_q4(codes: jnp.ndarray) -> jnp.ndarray:
    """(..., D) uint8 in [0,15] -> (..., D//2): low nibble = even dim."""
    return (codes[..., 0::2] | (codes[..., 1::2] << 4)).astype(jnp.uint8)


def _unpack_q4(packed: jnp.ndarray) -> jnp.ndarray:
    """(..., D//2) uint8 -> (..., D) uint8 in [0,15]."""
    lo = packed & 0xF
    hi = packed >> 4
    return jnp.stack([lo, hi], axis=-1).reshape(*packed.shape[:-1],
                                                packed.shape[-1] * 2)


def _tile_scales(x: jnp.ndarray, gr: int, gc: int):
    """Per-(gr, gc)-tile absmax of (..., H, D) -> (..., H//gr, D//gc)."""
    *lead, H, D = x.shape
    t = x.reshape(*lead, H // gr, gr, D // gc, gc)
    return jnp.max(jnp.abs(t), axis=(-3, -1))


def _broadcast_scales(scales: jnp.ndarray, gr: int, gc: int) -> jnp.ndarray:
    """(..., H//gr, D//gc) f32 -> (..., H, D): the two cheap repeats."""
    return jnp.repeat(jnp.repeat(scales, gr, axis=-2), gc, axis=-1)


def quantize_kv(x: jnp.ndarray, *, mode: str, gr: int, gc: int,
                scale_dtype=jnp.float16) -> dict:
    """Tile-quantize KV values.  x: (..., Hkv, D) fp; the trailing two
    dims are one token's slab (leading dims are free: (L, B, S, ...) for
    prefill scatters, (B, ...) for the per-step decode write).

    Returns {"codes", "scales"} in the pool leaf layout (see module
    docstring).  Pure jnp and shape-polymorphic: fuses into the engine's
    jitted scatter paths.
    """
    assert mode in ("q8", "q4"), mode
    xf = x.astype(jnp.float32)
    qmax = 127.0 if mode == "q8" else codebook_absmax(Q4_CODEBOOK)
    scales = (_tile_scales(xf, gr, gc) / qmax).astype(scale_dtype)
    sc = jnp.maximum(_broadcast_scales(scales.astype(jnp.float32), gr, gc),
                     1e-8)
    wn = xf / sc
    if mode == "q8":
        codes = jnp.clip(jnp.round(wn), -127, 127).astype(jnp.int8)
    else:
        # q4_0 is the affine grid [-8, 7]: nearest entry == shifted round
        codes = (jnp.clip(jnp.round(wn), -8, 7) + 8).astype(jnp.uint8)
        codes = _pack_q4(codes)
    return {"codes": codes, "scales": scales}


def dequantize_kv(q: dict, *, dtype=jnp.float32) -> jnp.ndarray:
    """Inverse of :func:`quantize_kv` (reference / XLA-fallback dequant).

    Leading dims are free, so this serves the per-block kernel oracle,
    the gathered (B, S, Hkv, D) decode path and the (L, B, P, Hkv, D)
    prefix gather alike."""
    mode, gr, gc, _ = kv_geometry(q)
    if mode == "q8":
        vals = q["codes"].astype(jnp.float32)
    else:
        idx = _unpack_q4(q["codes"]).astype(jnp.int32)
        vals = get_codebook(Q4_CODEBOOK)[idx]  # 16-entry LUT (§5.2.2)
    sc = _broadcast_scales(q["scales"].astype(jnp.float32), gr, gc)
    return (vals * sc).astype(dtype)


def quantize_for_pool(x: jnp.ndarray, pool_leaf) -> jnp.ndarray | dict:
    """Quantize ``x`` to match a pool leaf's storage (identity on fp
    pools) — the single write-path hook the scatter sites call."""
    if not isinstance(pool_leaf, dict):
        return x
    mode, gr, gc, _ = kv_geometry(pool_leaf)
    return quantize_kv(x, mode=mode, gr=gr, gc=gc,
                       scale_dtype=pool_leaf["scales"].dtype)


def dequantize_for_pool(gathered) -> jnp.ndarray:
    """Dequantize a gathered pool view (identity on fp pools) — the
    single read-path hook for XLA gather fallbacks."""
    if not isinstance(gathered, dict):
        return gathered
    return dequantize_kv(gathered)


def pool_block_size(pool_leaf, axis: int = 1) -> int:
    """Token block size of a pool leaf (fp array or quantized dict):
    ``axis`` 1 of a per-layer (n_blocks, bs, ...) leaf, 2 of a stacked
    (L, n_blocks, bs, ...) one."""
    leaf = pool_leaf["codes"] if isinstance(pool_leaf, dict) else pool_leaf
    return leaf.shape[axis]


class QuantKVPool(KVPool):
    """Refcounted block pool whose blocks store tile-quantized KV.

    Drop-in for :class:`~repro.serving.kv_pool.KVPool`: every host-side
    operation (alloc/retain/release, CoW, pressure hook, prefix-cache
    pinning) is inherited unchanged because blocks move as opaque
    code+scale payloads; only the device storage and the byte accounting
    differ.  ``mode``: "q8" (int8 codes) or "q4" (packed q4_0 codes),
    both with per-(2, 16)-tile float16 scales.
    """

    def __init__(self, cfg, n_blocks: int, block_size: int, *,
                 mode: str = "q8", group_size: int = 32,
                 scale_dtype=jnp.float16):
        if mode not in ("q8", "q4"):
            raise ValueError(f"kv_quant mode must be q8 or q4, got {mode!r}")
        hd = cfg.resolved_head_dim()
        if mode == "q4" and hd % 2:
            raise ValueError(f"q4 KV packing needs an even head_dim "
                             f"(got {hd})")
        self.mode = mode
        self.group_size = group_size
        self.scale_dtype = jnp.dtype(scale_dtype)
        self.gr, self.gc = kv_tile_geometry(cfg.n_kv_heads, hd, group_size)
        super().__init__(cfg, n_blocks, block_size)

    def _init_storage(self, cfg, n_blocks: int, block_size: int,
                      dtype) -> dict:
        hd = cfg.resolved_head_dim()
        dc = hd // 2 if self.mode == "q4" else hd
        code_dtype = jnp.uint8 if self.mode == "q4" else jnp.int8
        cshape = (cfg.n_layers, n_blocks, block_size, cfg.n_kv_heads, dc)
        sshape = (cfg.n_layers, n_blocks, block_size,
                  cfg.n_kv_heads // self.gr, hd // self.gc)

        def leaf():
            return {"codes": jnp.zeros(cshape, code_dtype),
                    "scales": jnp.zeros(sshape, self.scale_dtype)}

        return {"k": leaf(), "v": leaf()}
