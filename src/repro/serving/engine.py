"""Batched decode engine.

This is the system layer that realizes the paper's core observation: decode
is weight-bandwidth-bound, so the matrix units have idle rows that parallel
test-time-scaling samples can occupy for ~free.  The engine therefore
treats *batch* as the first-class resource:

* ``prefill`` runs the prompt once per unique prompt and yields the
  next-token logits at each sequence's true last position;
* ``fork`` replicates cache rows so N samples share one prompt's prefill
  (Best-of-N / beam-search fan-out without re-prefilling);
* ``reorder`` gathers the cache batch dim (beam-search survivor commit);
* ``generate`` runs a jit'd lax.scan over decode steps with done-masking.

The state carries ``pending_logits``: the logits the *next* token must be
sampled from. Each step samples, feeds the token through decode_step
(writing its KV at position cache_len), and replaces pending_logits — so no
KV row is ever written twice and the first generated token is sampled from
the prefill logits exactly.
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.sharding import ParallelContext
from repro.models import api
from repro.serving.sampler import SamplerConfig, logprobs_of, sample


@dataclass
class GenState:
    """Decoding state for a batch of sequences (a jax pytree)."""

    cache: dict
    cache_len: jnp.ndarray       # (B,) int32 — prompt + generated so far
    pending_logits: jnp.ndarray  # (B, V) f32 — next token sampled from these
    done: jnp.ndarray            # (B,) bool
    logprob_sum: jnp.ndarray     # (B,) f32 cumulative sampled logprob
    n_gen: jnp.ndarray           # (B,) int32


jax.tree_util.register_dataclass(
    GenState,
    data_fields=["cache", "cache_len", "pending_logits", "done",
                 "logprob_sum", "n_gen"],
    meta_fields=[])


class DecodeEngine:
    def __init__(self, params, cfg: ModelConfig,
                 par: Optional[ParallelContext] = None, *, max_len: int = 512,
                 eos_id: int = 1, pad_id: int = 0):
        self.params = params
        self.cfg = cfg
        self.par = par
        self.max_len = max_len
        self.eos_id = eos_id
        self.pad_id = pad_id
        self.model = api.get_model(cfg)
        self._prefill_jit = jax.jit(self._prefill_impl)
        self._gen_jit = jax.jit(self._generate_impl,
                                static_argnames=("n_steps", "sc", "stop_ids"))
        self._step_jit = jax.jit(self._step_impl,
                                 static_argnames=("sc", "stop_ids"))

    # -- prefill ------------------------------------------------------------
    def _prefill_impl(self, params, tokens, lengths, embeddings=None):
        logits, cache = self.model.prefill(
            params, tokens, self.cfg, self.par, max_len=self.max_len,
            lengths=lengths,
            **({"embeddings": embeddings} if embeddings is not None else {}))
        return logits, cache

    def prefill(self, tokens: jnp.ndarray, lengths: Optional[jnp.ndarray] = None,
                embeddings=None) -> GenState:
        """tokens: (B, S) right-padded prompts; lengths: (B,) true lengths."""
        B, S = tokens.shape
        if lengths is None:
            lengths = jnp.full((B,), S, jnp.int32)
        logits, cache = self._prefill_jit(self.params, tokens, lengths,
                                          embeddings)
        return GenState(
            cache=cache,
            cache_len=lengths.astype(jnp.int32),
            pending_logits=logits.astype(jnp.float32),
            done=jnp.zeros((B,), bool),
            logprob_sum=jnp.zeros((B,), jnp.float32),
            n_gen=jnp.zeros((B,), jnp.int32),
        )

    # -- fork / reorder (TTS batch fan-out) ----------------------------------
    def fork(self, state: GenState, n: int) -> GenState:
        """Replicate each sequence n times (prompt-shared Best-of-N).
        Row i maps to rows [i*n, (i+1)*n)."""

        def rep(x, axis):
            return jnp.repeat(x, n, axis=axis)

        return GenState(
            cache=jax.tree.map(lambda x: rep(x, 1), state.cache),
            cache_len=rep(state.cache_len, 0),
            pending_logits=rep(state.pending_logits, 0),
            done=rep(state.done, 0),
            logprob_sum=rep(state.logprob_sum, 0),
            n_gen=rep(state.n_gen, 0),
        )

    def reorder(self, state: GenState, idx: jnp.ndarray) -> GenState:
        """Gather sequences by ``idx`` (beam-search survivor commit)."""
        return GenState(
            cache=jax.tree.map(lambda x: x[:, idx], state.cache),
            cache_len=state.cache_len[idx],
            pending_logits=state.pending_logits[idx],
            done=state.done[idx],
            logprob_sum=state.logprob_sum[idx],
            n_gen=state.n_gen[idx],
        )

    # -- decode -------------------------------------------------------------
    def _step_impl(self, params, state: GenState, rng, *, sc: SamplerConfig,
                   stop_ids: tuple = ()):
        stop_ids = tuple(stop_ids) or (self.eos_id,)
        tok = sample(state.pending_logits, rng, sc)
        lp = logprobs_of(state.pending_logits, tok)
        tok = jnp.where(state.done, self.pad_id, tok).astype(jnp.int32)
        new_done = state.done
        for s in stop_ids:
            new_done = new_done | (tok == s)
        new_len = jnp.where(state.done, state.cache_len, state.cache_len + 1)
        # Done rows must not clobber their last real KV slot: route their
        # (discarded) write to the reserved scratch slot max_len-1.  Usable
        # sequence length is therefore max_len - 1.
        model_len = jnp.where(state.done, self.max_len, new_len)
        logits, cache = self.model.decode_step(
            params, tok[:, None], state.cache, model_len, self.cfg, self.par)
        # Recurrent (non-positional) states have no scratch slot — restore
        # them for done rows.  These leaves are small (SSM/conv states).
        for key in ("conv", "ssm"):
            if key in cache:
                d = state.done.reshape((1, -1) + (1,) * (cache[key].ndim - 2))
                cache[key] = jnp.where(d, state.cache[key], cache[key])
        # Freeze pending logits on done rows so that resume() continues from
        # the logits that followed the stop token, not scratch-slot garbage.
        pending = jnp.where(state.done[:, None], state.pending_logits,
                            logits.astype(jnp.float32))
        new_state = GenState(
            cache=cache,
            cache_len=new_len,
            pending_logits=pending,
            done=new_done,
            logprob_sum=state.logprob_sum + jnp.where(state.done, 0.0, lp),
            n_gen=state.n_gen + jnp.where(state.done, 0, 1),
        )
        return new_state, tok

    def step(self, state: GenState, rng, sc: SamplerConfig = SamplerConfig()):
        """One decode step. Returns (new_state, sampled tokens (B,))."""
        return self._step_jit(self.params, state, rng, sc=sc)

    def _generate_impl(self, params, state: GenState, rng, *, n_steps: int,
                       sc: SamplerConfig, stop_ids: tuple = ()):
        def body(st, key):
            st, tok = self._step_impl(params, st, key, sc=sc, stop_ids=stop_ids)
            return st, tok

        keys = jax.random.split(rng, n_steps)
        state, toks = jax.lax.scan(body, state, keys)
        return state, toks.T  # (B, n_steps)

    def generate(self, state: GenState, n_steps: int, rng,
                 sc: SamplerConfig = SamplerConfig(), stop_ids: tuple = ()):
        """Decode up to n_steps tokens (stopping per-row at any id in
        ``stop_ids``, default EOS). Returns (final_state, (B, n_steps) tokens,
        pad_id after stop)."""
        return self._gen_jit(self.params, state, rng, n_steps=n_steps, sc=sc,
                             stop_ids=tuple(stop_ids))

    def resume(self, state: GenState) -> GenState:
        """Clear done flags (used by step-level beam search to continue
        beams after a step-delimiter stop)."""
        return GenState(
            cache=state.cache, cache_len=state.cache_len,
            pending_logits=state.pending_logits,
            done=jnp.zeros_like(state.done),
            logprob_sum=state.logprob_sum, n_gen=state.n_gen)


# ---------------------------------------------------------------------------
# Continuous batching scheduler (slot-based)
# ---------------------------------------------------------------------------


@dataclass
class Request:
    req_id: int
    prompt: jnp.ndarray          # (S,) int32
    max_new_tokens: int = 64
    out_tokens: Optional[list] = None


class ContinuousScheduler:
    """Slot-based continuous batching on top of DecodeEngine.

    Fixed decode batch of ``n_slots``; finished sequences release their slot
    which is refilled from the queue at the next prefill opportunity.  This
    is the engine shape a production server uses; TTS workloads submit N
    samples of one prompt as N requests sharing a prefill via fork.
    """

    def __init__(self, engine: DecodeEngine, n_slots: int = 8,
                 prompt_len: int = 32):
        self.engine = engine
        self.n_slots = n_slots
        self.prompt_len = prompt_len
        self.queue: list[Request] = []
        self.active: dict[int, Request] = {}

    def submit(self, req: Request):
        self.queue.append(req)

    def _pad(self, prompt):
        S = self.prompt_len
        out = jnp.full((S,), self.engine.pad_id, jnp.int32)
        return out.at[: prompt.shape[0]].set(prompt), prompt.shape[0]

    def run(self, rng, sc: SamplerConfig = SamplerConfig(), max_rounds: int = 64):
        """Drain the queue. Returns {req_id: token list}."""
        results = {}
        round_ = 0
        while (self.queue or self.active) and round_ < max_rounds:
            round_ += 1
            # fill free slots
            take = min(self.n_slots - len(self.active), len(self.queue))
            batch = [self.queue.pop(0) for _ in range(take)]
            if not batch and not self.active:
                break
            if batch:
                toks, lens = zip(*[self._pad(r.prompt) for r in batch])
                state = self.engine.prefill(jnp.stack(toks),
                                            jnp.array(lens, jnp.int32))
                steps = max(r.max_new_tokens for r in batch)
                rng, k = jax.random.split(rng)
                state, out = self.engine.generate(state, steps, k, sc)
                for i, r in enumerate(batch):
                    toks_i = out[i][: r.max_new_tokens]
                    # trim at EOS
                    lst = []
                    for t in toks_i.tolist():
                        if t == self.engine.eos_id:
                            break
                        lst.append(t)
                    results[r.req_id] = lst
        return results
