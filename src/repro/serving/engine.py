"""Batched decode engine.

This is the system layer that realizes the paper's core observation: decode
is weight-bandwidth-bound, so the matrix units have idle rows that parallel
test-time-scaling samples can occupy for ~free.  The engine therefore
treats *batch* as the first-class resource:

* ``prefill`` runs the prompt once per unique prompt and yields the
  next-token logits at each sequence's true last position;
* ``fork`` replicates cache rows so N samples share one prompt's prefill
  (Best-of-N / beam-search fan-out without re-prefilling);
* ``reorder`` gathers the cache batch dim (beam-search survivor commit);
* ``generate`` runs a jit'd lax.scan over decode steps with done-masking.

The state carries ``pending_logits``: the logits the *next* token must be
sampled from. Each step samples, feeds the token through decode_step
(writing its KV at position cache_len), and replaces pending_logits — so no
KV row is ever written twice and the first generated token is sampled from
the prefill logits exactly.

On top of the engine sits :class:`ContinuousScheduler`, a slot-based
continuous-batching loop: one persistent ``GenState`` of ``n_slots`` rows
decodes every step; each step admits queued requests into free rows
(prefill → ``merge_rows`` scatter; TTS groups prefill once and ``fork``),
then releases any row that sampled a stop id or exhausted its token budget.
Requests enter and leave the batch independently mid-flight — the decode
batch stays full under mixed-length traffic, which is what makes parallel
test-time-scaling samples ride along for free.

The engine runs in one of two KV layouts:

* **dense** (default): every slot owns a ``(max_len, Hkv, D)`` cache row
  per layer, reserved up front.  ``fork`` physically replicates the
  prompt's KV rows N times and ``reorder`` copies whole rows — O(N·prompt)
  duplicated bytes for Best-of-N, plus a full ``max_len`` reservation per
  slot regardless of actual sequence length.
* **paged** (``paged=True``): KV lives in a shared, refcounted block pool
  (``repro.serving.kv_pool``) and each row holds a block *table*.
  ``fork`` becomes a refcount bump on the prompt's blocks (zero KV bytes
  copied — samples share the prefix until copy-on-write triggers on their
  first divergent write), ``reorder`` a table gather, and a slot only ever
  holds blocks for tokens it has actually produced.  ``prepare_decode``
  does the host-side block bookkeeping before each decode step and raises
  :class:`~repro.serving.kv_pool.OutOfBlocks` when the pool is exhausted,
  which the scheduler converts into preempting the youngest request.
  Paged states reference pool blocks by id, so they must be used linearly
  (step/merge/fork/release consume the state they are given); the dense
  path keeps full functional semantics.

On the paged layout the engine also supports the **cross-request prefix
cache** (``repro.serving.prefix_cache``): ``prefill(suffix_tokens, ...,
cached_table=, cached_lens=)`` is a *partial prefill* that runs the
transformer only over a prompt's uncached suffix while attending over the
cached prefix blocks (gathered from the pool through the row's table).
The row takes ownership of the caller's per-block lease on the cached
blocks (``PrefixCache.match`` retains them), a misaligned cached length
copy-on-writes the partially-used tail block before the suffix extends
it, and ``release_rows`` later drops exactly the row's references — the
tree's own pins keep cached prefixes alive across requests.  The
scheduler drives the full loop: longest-prefix-match at admission,
insertion of completed prompt prefixes back into the tree, and LRU
eviction of unreferenced cached blocks under pool pressure (via the
pool's ``pressure_hook``) *before* falling back to out-of-blocks
preemption.  ``SchedulerMetrics`` reports the hit rate and the prefill
tokens the cache saved.  The prefix gather is *bucketed*: only the table
columns covering the batch's longest cached prefix are gathered (block
granular), not the full ``max_len`` width.

``kv_quant`` ("q8" | "q4", paged only) swaps the pool for a
:class:`~repro.serving.kv_quant.QuantKVPool`: blocks store tile-quantized
codes plus per-(2, 16)-tile scales, quantization is fused into the
prefill/suffix/decode scatters (KV never lands in HBM at full precision)
and dequantization into every read path — the paged-attention gather, the
Pallas kernel's per-block VMEM dequant, and the partial-prefill prefix
gather.  Fork/CoW/prefix-cache semantics are unchanged (blocks move as
opaque code+scale payloads); the same ``n_blocks`` budget simply costs
2–4× fewer HBM bytes, or equivalently a fixed byte budget holds
proportionally more concurrent TTS streams.
"""
from __future__ import annotations

import dataclasses
import time
import warnings
from collections import deque
from dataclasses import dataclass, field
from functools import partial
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.distributed.sharding import ParallelContext
from repro.models import api
from repro.serving.kv_pool import KVPool, OutOfBlocks, blocks_for
from repro.serving.prefix_cache import PrefixCache
from repro.serving.profiling import NULL_PROFILE_METRICS
from repro.serving.sampler import SamplerConfig, logprobs_of, sample
from repro.serving.telemetry import RequestLatency, Tracer, percentile


@dataclass
class GenState:
    """Decoding state for a batch of sequences (a jax pytree).

    ``cache`` is layout-dependent: dense states carry the full KV arrays
    ({"k", "v"} of (L, B, S, Hkv, D), plus recurrent leaves for SSMs);
    paged states carry only the per-row indexing — {"table": (B, W) int32
    block ids, "n_blocks": (B,) int32 owned-block counts} — while the KV
    bytes live in the engine's shared :class:`~repro.serving.kv_pool.
    KVPool`.
    """

    cache: dict
    cache_len: jnp.ndarray       # (B,) int32 — prompt + generated so far
    pending_logits: jnp.ndarray  # (B, V) f32 — next token sampled from these
    done: jnp.ndarray            # (B,) bool
    logprob_sum: jnp.ndarray     # (B,) f32 cumulative sampled logprob
    n_gen: jnp.ndarray           # (B,) int32


jax.tree_util.register_dataclass(
    GenState,
    data_fields=["cache", "cache_len", "pending_logits", "done",
                 "logprob_sum", "n_gen"],
    meta_fields=[])


class DecodeEngine:
    def __init__(self, params, cfg: ModelConfig,
                 par: Optional[ParallelContext] = None, *, max_len: int = 512,
                 eos_id: int = 1, pad_id: int = 0, paged: bool = False,
                 block_size: int = 16, n_blocks: Optional[int] = None,
                 kv_quant: str = "none"):
        self.params = params
        self.cfg = cfg
        self.par = par
        self.max_len = max_len
        self.eos_id = eos_id
        self.pad_id = pad_id
        self.model = api.get_model(cfg)
        self.paged = paged
        self.kv_quant = kv_quant
        self.pool: Optional[KVPool] = None
        # phase-span telemetry (repro.serving.telemetry.Tracer); installed
        # by ContinuousScheduler(tracer=...).  None = zero overhead: every
        # touchpoint is behind an `is not None` guard.
        self.tracer: Optional[Tracer] = None
        # roofline/canary profiler (repro.serving.profiling.
        # KernelProfiler); installed by ContinuousScheduler(profiler=...)
        # under the same `is not None` zero-overhead discipline.  The
        # canary jit is built lazily on the first canary step (traced
        # under the exact "xla" paged-attention impl, whatever the
        # production impl is).
        self.profiler = None
        self._canary_jit = None
        self.last_canary_logits = None
        if kv_quant != "none" and not paged:
            raise ValueError("kv_quant requires the paged KV layout "
                             "(DecodeEngine(paged=True))")
        if paged:
            if cfg.family != "transformer":
                raise ValueError(
                    f"paged KV cache supports the transformer family only "
                    f"(got {cfg.family!r})")
            if max_len % block_size:
                raise ValueError(
                    f"max_len ({max_len}) must be a multiple of "
                    f"block_size ({block_size})")
            if n_blocks is None:
                # scratch + eight full-length sequences' worth by default;
                # servers should size this to their slot count / traffic
                n_blocks = 1 + 8 * (max_len // block_size)
            if kv_quant != "none":
                from repro.serving.kv_quant import QuantKVPool

                self.pool = QuantKVPool(cfg, n_blocks, block_size,
                                        mode=kv_quant)
            else:
                self.pool = KVPool(cfg, n_blocks, block_size)
        self._prefill_jit = jax.jit(self._prefill_impl)
        self._prefill_paged_jit = jax.jit(self._prefill_paged_impl,
                                          donate_argnums=(4, 5))
        self._prefill_cached_jit = jax.jit(self._prefill_cached_impl,
                                           donate_argnums=(5, 6),
                                           static_argnames=("prefix_w",))
        self._gen_jit = jax.jit(self._generate_impl,
                                static_argnames=("n_steps", "sc", "stop_ids"))
        self._gen_paged_jit = jax.jit(
            self._gen_paged_impl, donate_argnums=(2, 3),
            static_argnames=("n_steps", "sc", "stop_ids"))
        self._step_jit = jax.jit(self._step_impl,
                                 static_argnames=("sc", "stop_ids"))
        self._step_paged_jit = jax.jit(self._step_paged_impl,
                                       donate_argnums=(2, 3),
                                       static_argnames=("sc", "stop_ids"))
        self._merge_jit = jax.jit(self._merge_impl)
        self._merge_donate_jit = jax.jit(self._merge_impl,
                                         donate_argnums=(0,))
        self._merge_paged_jit = jax.jit(self._merge_paged_impl)
        self._merge_paged_donate_jit = jax.jit(self._merge_paged_impl,
                                               donate_argnums=(0,))
        self._spec_verify_jit = jax.jit(
            self._spec_verify_impl, donate_argnums=(5, 6),
            static_argnames=("prefix_w", "stop_ids"))
        self._forced_jit = jax.jit(self._forced_step_impl)

    @property
    def table_width(self) -> int:
        """Block-table slots per row (= max_len / block_size)."""
        return self.max_len // self.pool.block_size

    # -- prefill ------------------------------------------------------------
    def _prefill_impl(self, params, tokens, lengths, embeddings=None):
        logits, cache = self.model.prefill(
            params, tokens, self.cfg, self.par, max_len=self.max_len,
            lengths=lengths,
            **({"embeddings": embeddings} if embeddings is not None else {}))
        return logits, cache

    def _prefill_paged_impl(self, params, tokens, lengths, table, pool_k,
                            pool_v, embeddings=None):
        logits, cache = self.model.prefill(
            params, tokens, self.cfg, self.par, max_len=self.max_len,
            lengths=lengths,
            paged={"k": pool_k, "v": pool_v, "table": table},
            **({"embeddings": embeddings} if embeddings is not None else {}))
        return logits, cache["k"], cache["v"]

    def _prefill_cached_impl(self, params, tokens, lengths, cached_lens,
                             table, pool_k, pool_v, *, prefix_w: int):
        """Partial prefill: gather the rows' cached prefix KV through their
        (already fully planned) block tables, run the transformer over the
        suffix tokens only, and scatter the suffix KV in at the per-row
        offset.  Invalid gather slots (table padding, freshly allocated
        suffix blocks) are masked inside ``forward`` via ``cached_lens``.

        ``prefix_w`` (static) is the *bucketed* gather width: only the
        first ``ceil(max(cached_lens)/bs)`` table columns are gathered —
        block-granular, so short cached prefixes stop paying attention
        FLOPs over the full ``max_len`` table width.  Quantized pools
        gather code+scale leaves and dequantize the (L, B, P, Hkv, D)
        prefix view through the vlut16 dequant kernel
        (``repro.kernels.ops.lut_dequant_gather`` — bit-identical to the
        XLA ``dequantize_for_pool`` path it replaces).
        """
        prefix = self._gather_prefix(table, pool_k, pool_v, cached_lens,
                                     prefix_w=prefix_w)
        logits, cache = self.model.prefill(
            params, tokens, self.cfg, self.par, max_len=self.max_len,
            lengths=lengths,
            paged={"k": pool_k, "v": pool_v, "table": table},
            prefix=prefix)
        return logits, cache["k"], cache["v"]

    def _gather_prefix(self, table, pool_k, pool_v, lens, *, prefix_w: int):
        """Dequant-gather the first ``prefix_w`` table columns of every row
        into a dense (L, B, prefix_w*bs, Hkv, D) prefix view — the shared
        read path of the partial prefill and the speculative verify
        forward.  Invalid slots (rows shorter than the bucket) are masked
        downstream by ``lens`` inside ``forward``."""
        from repro.kernels import ops as kops

        bs = self.pool.block_size
        ptab = jax.lax.slice_in_dim(table, 0, prefix_w, axis=1)

        def gather(pool):
            def leaf(a):
                g = a[:, ptab]  # (L, B, Wc, bs, *slab)
                return g.reshape(g.shape[0], g.shape[1], prefix_w * bs,
                                 *g.shape[4:])

            return kops.lut_dequant_gather(jax.tree.map(leaf, pool))

        return {"k": gather(pool_k), "v": gather(pool_v), "len": lens}

    def prefill(self, tokens: jnp.ndarray, lengths: Optional[jnp.ndarray] = None,
                embeddings=None, *, cached_table=None,
                cached_lens=None) -> GenState:
        """tokens: (B, S) right-padded prompts; lengths: (B,) true lengths.

        Partial prefill (paged engines only): ``cached_table`` (B, Wc)
        block ids covering each row's cached prompt prefix plus
        ``cached_lens`` (B,) cached lengths switch ``tokens``/``lengths``
        to describing the *uncached suffix* only.  The transformer runs
        over the suffix while attending over the cached blocks; each row
        must arrive holding one reference per cached block (the lease
        ``PrefixCache.match`` takes), which the resulting state owns and
        ``release_rows`` later drops.  A cached length that is not a
        block multiple has its partially-used tail block copy-on-written
        before the suffix extends it, so shared cache blocks are never
        written.  Every row needs at least one suffix token (the
        next-token logits come from the suffix's last position).

        B > 1 rows may carry *ragged* prefixes: per-row cached lengths
        (aligned or mid-block), per-row suffix lengths (right-padded to a
        common width via ``lengths``), and per-row tables.  Each row's
        positions are offset by its own cached length, the prefix gather
        covers ``ceil(max(cached_lens)/block_size)`` table columns
        (invalid slots masked per row), and tail CoWs across the batch
        commit in one device scatter — this is the device half of the
        scheduler's batched cache-aware admission.  One compile per
        distinct (batch, suffix width, gather width) triple; the
        scheduler buckets admissions by gather width so rows in one call
        pay no masked attention over columns none of them use.
        """
        B, S = tokens.shape
        if lengths is None:
            lengths = jnp.full((B,), S, jnp.int32)
        tr = self.tracer
        t0 = tr.now() if tr is not None else 0.0
        prof = self.profiler
        pt0 = prof.phase_begin("prefill") if prof is not None else 0.0
        if cached_table is not None:
            if not self.paged:
                raise ValueError(
                    "cached-prefix prefill requires a paged engine "
                    "(DecodeEngine(paged=True))")
            if embeddings is not None:
                raise NotImplementedError(
                    "cached-prefix prefill does not support modality-stub "
                    "embeddings")
            st = self._prefill_with_prefix(tokens, lengths, cached_table,
                                           cached_lens)
        elif self.paged:
            st = self._prefill_paged(tokens, lengths, embeddings)
        else:
            logits, cache = self._prefill_jit(self.params, tokens, lengths,
                                              embeddings)
            st = GenState(
                cache=cache,
                cache_len=lengths.astype(jnp.int32),
                pending_logits=logits.astype(jnp.float32),
                done=jnp.zeros((B,), bool),
                logprob_sum=jnp.zeros((B,), jnp.float32),
                n_gen=jnp.zeros((B,), jnp.int32),
            )
        if prof is not None:
            # sampled: blocks on the new state's logits so the wall spans
            # the device work this prefill dispatched
            prof.phase_end("prefill", pt0, outputs=st.pending_logits)
        if tr is not None:
            tr.span("prefill", t0, batch=int(B),
                    cached=cached_table is not None)
        return st

    def _prefill_with_prefix(self, tokens, lengths, cached_table,
                             cached_lens) -> GenState:
        """Host-side planning for a cached-prefix partial prefill: build
        each row's full block table (cached blocks + tail CoW + fresh
        suffix blocks), then run the suffix-only device pass.

        The plan is *batched across rows*: every misaligned row's
        partially-used cached tail block is copy-on-written in ONE
        ``pool.cow`` call (one tree-mapped device scatter for the whole
        batch — quantized code+scale payloads move the same way) and all
        fresh suffix blocks come from one ``pool.alloc``, so a B-row
        admission costs O(1) device launches for block bookkeeping, not
        O(B).  The whole need (tail CoWs + fresh blocks) is reserved up
        front, so an :class:`OutOfBlocks` raise leaves pool and leases
        untouched."""
        B = tokens.shape[0]
        bs = self.pool.block_size
        lens_h = np.asarray(jax.device_get(lengths), np.int64)
        cach_h = np.asarray(cached_lens, np.int64).ravel()
        if cach_h.shape[0] != B:
            raise ValueError(f"cached_lens has {cach_h.shape[0]} rows for a "
                             f"batch of {B}")
        if (lens_h < 1).any():
            raise ValueError("cached-prefix prefill needs >= 1 suffix token "
                             "per row (the next-token logits come from the "
                             "suffix)")
        totals = cach_h + lens_h
        if (totals > self.max_len - 1).any():
            raise ValueError(
                f"cached + suffix length ({int(totals.max())}) overruns the "
                f"usable sequence length {self.max_len - 1}")
        ctab = np.asarray(cached_table, np.int64)
        n_full = cach_h // bs
        rem = cach_h % bs
        n_tot = np.array([blocks_for(t, bs) for t in totals])
        # tail CoW (one per misaligned row) + fresh suffix blocks
        n_new = n_tot - (n_full + (rem > 0))
        needed = int(n_new.sum() + (rem > 0).sum())
        if not self.pool.reserve(needed):
            raise OutOfBlocks(needed, self.pool.free_blocks)
        table = np.zeros((B, self.table_width), np.int32)
        for i in range(B):
            table[i, :n_full[i]] = ctab[i, :n_full[i]]
        # private copies of the partially-used cached tail blocks: each
        # row's lease on its original moves to the copy (cow drops one
        # source reference per block), and the suffix scatter may then
        # extend offsets [rem, bs) without touching shared KV.  One cow
        # call copies every misaligned row's tail in a single device
        # scatter.
        cow_rows = [i for i in range(B) if rem[i]]
        new_tails = self.pool.cow(
            [int(ctab[i, n_full[i]]) for i in cow_rows])
        for i, nt in zip(cow_rows, new_tails):
            table[i, n_full[i]] = nt
        fresh = self.pool.alloc(int(n_new.sum())) if n_new.any() else []
        off = 0
        for i in range(B):
            if n_new[i]:
                have = int(n_full[i] + (1 if rem[i] else 0))
                table[i, have:n_tot[i]] = fresh[off:off + int(n_new[i])]
                off += int(n_new[i])
        table_dev = jnp.asarray(table)
        # bucket the prefix gather to the blocks actually cached (batch
        # max): recompiles once per distinct width, saves the full
        # table-width gather + masked attention over max_len prefix slots
        prefix_w = max(1, int(-(-int(cach_h.max()) // bs)))
        logits, pk, pv = self._prefill_cached_jit(
            self.params, tokens, lengths, jnp.asarray(cach_h, jnp.int32),
            table_dev, self.pool.k, self.pool.v, prefix_w=prefix_w)
        self.pool.adopt(pk, pv)
        return GenState(
            cache={"table": table_dev,
                   "n_blocks": jnp.asarray(n_tot.astype(np.int32))},
            cache_len=jnp.asarray(totals.astype(np.int32)),
            pending_logits=logits.astype(jnp.float32),
            done=jnp.zeros((B,), bool),
            logprob_sum=jnp.zeros((B,), jnp.float32),
            n_gen=jnp.zeros((B,), jnp.int32),
        )

    def _prefill_paged(self, tokens, lengths, embeddings=None) -> GenState:
        """Allocate prompt blocks (host) and scatter prefill KV into them."""
        B = tokens.shape[0]
        bs = self.pool.block_size
        lens_h = np.asarray(jax.device_get(lengths))
        per_row = [blocks_for(l, bs) for l in lens_h]
        if not self.pool.reserve(sum(per_row)):
            raise OutOfBlocks(sum(per_row), self.pool.free_blocks)
        table = np.zeros((B, self.table_width), np.int32)
        n_blocks = np.zeros((B,), np.int32)
        for i, n in enumerate(per_row):
            table[i, :n] = self.pool.alloc(n)
            n_blocks[i] = n
        table_dev = jnp.asarray(table)
        logits, pk, pv = self._prefill_paged_jit(
            self.params, tokens, lengths, table_dev, self.pool.k,
            self.pool.v, embeddings)
        self.pool.adopt(pk, pv)
        return GenState(
            cache={"table": table_dev, "n_blocks": jnp.asarray(n_blocks)},
            cache_len=lengths.astype(jnp.int32),
            pending_logits=logits.astype(jnp.float32),
            done=jnp.zeros((B,), bool),
            logprob_sum=jnp.zeros((B,), jnp.float32),
            n_gen=jnp.zeros((B,), jnp.int32),
        )

    def empty_state(self, batch: int) -> GenState:
        """An all-free decoding state of ``batch`` rows (every row done).

        The continuous-batching scheduler keeps one of these alive for the
        server's lifetime and scatters admitted requests into its rows with
        :meth:`merge_rows`.  Done rows route their KV writes to the scratch
        slot, so idle rows cost one wasted lane of batched compute and no
        correctness hazards.  In paged mode an empty row holds zero blocks
        (its table is all scratch), so idle slots reserve no KV memory.
        """
        if self.paged:
            cache = {"table": jnp.zeros((batch, self.table_width), jnp.int32),
                     "n_blocks": jnp.zeros((batch,), jnp.int32)}
        else:
            cache = self.model.init_cache(self.cfg, batch, self.max_len)
        return GenState(
            cache=cache,
            cache_len=jnp.zeros((batch,), jnp.int32),
            pending_logits=jnp.zeros((batch, self.cfg.vocab_size),
                                     jnp.float32),
            done=jnp.ones((batch,), bool),
            logprob_sum=jnp.zeros((batch,), jnp.float32),
            n_gen=jnp.zeros((batch,), jnp.int32),
        )

    # -- row scatter (continuous-batching admission) -------------------------
    @staticmethod
    def _merge_impl(dst: GenState, src: GenState, rows) -> GenState:
        cache = jax.tree.map(
            lambda d, s: d.at[:, rows].set(s.astype(d.dtype)),
            dst.cache, src.cache)
        return dataclasses.replace(
            DecodeEngine._merge_vectors(dst, src, rows), cache=cache)

    @staticmethod
    def _merge_paged_impl(dst: GenState, src: GenState, rows) -> GenState:
        # paged cache leaves (table, n_blocks) carry batch on axis 0
        cache = jax.tree.map(lambda d, s: d.at[rows].set(s),
                             dst.cache, src.cache)
        return dataclasses.replace(
            DecodeEngine._merge_vectors(dst, src, rows), cache=cache)

    @staticmethod
    def _merge_vectors(dst: GenState, src: GenState, rows) -> GenState:
        return GenState(
            cache=None,
            cache_len=dst.cache_len.at[rows].set(src.cache_len),
            pending_logits=dst.pending_logits.at[rows].set(
                src.pending_logits),
            done=dst.done.at[rows].set(src.done),
            logprob_sum=dst.logprob_sum.at[rows].set(src.logprob_sum),
            n_gen=dst.n_gen.at[rows].set(src.n_gen),
        )

    def merge_rows(self, dst: GenState, src: GenState, rows: jnp.ndarray,
                   *, donate: bool = False) -> GenState:
        """Scatter ``src``'s batch rows into ``dst`` at indices ``rows``.

        ``rows`` is (B_src,) int32; dense cache leaves carry batch on
        axis 1 (axis 0 is the stacked layer dim), paged table leaves and
        per-sequence vectors on axis 0.  This is the admission primitive:
        prefill a new request into a small B_src state, then graft its
        cache/logits/length rows onto the live n_slots decode state without
        touching other rows.  Jitted so the per-leaf scatters fuse into one
        executable (recompiles once per distinct B_src).  ``donate=True``
        donates ``dst``'s buffers so the scatter happens in place — the
        scheduler hot path uses this since it immediately rebinds the
        state; callers that still need ``dst`` afterwards must keep the
        default.  Paged: the overwritten ``dst`` rows must already have
        been released (their blocks freed) — block ownership moves from
        ``src`` rows to ``dst`` rows without touching refcounts.
        """
        if self.paged:
            fn = (self._merge_paged_donate_jit if donate
                  else self._merge_paged_jit)
        else:
            fn = self._merge_donate_jit if donate else self._merge_jit
        return fn(dst, src, jnp.asarray(rows, jnp.int32))

    def release_rows(self, state: GenState, rows) -> GenState:
        """Mark ``rows`` done (slot release without a sampled stop token,
        e.g. a request hitting its max_new_tokens budget).  Paged: also
        frees the rows' blocks back to the pool and re-points their tables
        at the scratch block."""
        rows = np.asarray(rows, np.int64).ravel()
        if self.paged and rows.size:
            table, n_blocks = (np.array(a) for a in jax.device_get(
                (state.cache["table"], state.cache["n_blocks"])))
            for r in rows:
                self.pool.release(table[r, :n_blocks[r]])
                table[r] = 0
                n_blocks[r] = 0
            state = dataclasses.replace(
                state, cache={"table": jnp.asarray(table),
                              "n_blocks": jnp.asarray(n_blocks)})
        rows = jnp.asarray(rows, jnp.int32)
        return dataclasses.replace(state, done=state.done.at[rows].set(True))

    # -- fork / reorder (TTS batch fan-out) ----------------------------------
    _dense_fork_warned = False  # class-level: warn once per process

    def fork(self, state: GenState, n: int) -> GenState:
        """Replicate each sequence n times (prompt-shared Best-of-N).
        Row i maps to rows [i*n, (i+1)*n).

        Dense: physically copies each row's KV n times.  Paged: bumps the
        refcount of every owned block and repeats the table row — zero KV
        blocks are allocated or copied; the samples share the prompt's
        blocks until copy-on-write splits them at their first divergent
        write (see :meth:`prepare_decode`)."""
        if not self.paged and n > 1 and not DecodeEngine._dense_fork_warned:
            DecodeEngine._dense_fork_warned = True
            warnings.warn(
                "DecodeEngine.fork on the dense KV layout physically "
                "replicates each row's prompt KV n times (O(n*prompt) "
                "duplicated bytes); construct the engine with paged=True "
                "for zero-copy prefix sharing via the refcounted block "
                "pool", RuntimeWarning, stacklevel=2)

        def rep(x, axis):
            return jnp.repeat(x, n, axis=axis)

        if self.paged:
            table, n_blocks = jax.device_get(
                (state.cache["table"], state.cache["n_blocks"]))
            for i in range(table.shape[0]):
                if n > 1:
                    self.pool.retain(table[i, :n_blocks[i]], times=n - 1)
            cache = jax.tree.map(lambda x: rep(x, 0), state.cache)
        else:
            cache = jax.tree.map(lambda x: rep(x, 1), state.cache)
        return GenState(
            cache=cache,
            cache_len=rep(state.cache_len, 0),
            pending_logits=rep(state.pending_logits, 0),
            done=rep(state.done, 0),
            logprob_sum=rep(state.logprob_sum, 0),
            n_gen=rep(state.n_gen, 0),
        )

    def reorder(self, state: GenState, idx: jnp.ndarray) -> GenState:
        """Gather sequences by ``idx`` (beam-search survivor commit).

        Dense: copies the gathered cache rows.  Paged: gathers the block
        tables and fixes refcounts — rows dropped by ``idx`` release their
        blocks, rows duplicated k times gain k-1 references (their copies
        then diverge via copy-on-write)."""
        if self.paged:
            idx_h = np.asarray(jax.device_get(idx)).ravel()
            table, n_blocks = jax.device_get(
                (state.cache["table"], state.cache["n_blocks"]))
            counts = np.bincount(idx_h, minlength=table.shape[0])
            for r in range(table.shape[0]):
                owned = table[r, :n_blocks[r]]
                if counts[r] == 0:
                    self.pool.release(owned)
                elif counts[r] > 1:
                    self.pool.retain(owned, times=int(counts[r]) - 1)
            cache = jax.tree.map(lambda x: x[idx], state.cache)
        else:
            cache = jax.tree.map(lambda x: x[:, idx], state.cache)
        return GenState(
            cache=cache,
            cache_len=state.cache_len[idx],
            pending_logits=state.pending_logits[idx],
            done=state.done[idx],
            logprob_sum=state.logprob_sum[idx],
            n_gen=state.n_gen[idx],
        )

    # -- paged block bookkeeping ---------------------------------------------
    def prepare_decode(self, state: GenState, n_steps: int = 1,
                       clamp: bool = False) -> GenState:
        """Host-side paged bookkeeping before decoding ``n_steps`` tokens.

        For every live (not-done) row: allocate the blocks its next
        ``n_steps`` writes will land in, and copy-on-write any still-shared
        block at or past the write frontier (post-fork tail blocks).  The
        whole request is planned first and committed only if the free list
        covers it, so an :class:`OutOfBlocks` raise leaves the pool and the
        state untouched — the scheduler's preemption hook.  No-op in dense
        mode.

        ``clamp=True`` caps each row's plan at the usable sequence length
        instead of raising: a speculative verify plans ``k`` positions for
        every row, and a row near its budget simply has its over-length
        proposals routed to the scratch offset (never committed — the
        scheduler caps its proposal count to the remaining budget anyway).
        """
        if not self.paged:
            return state
        table, n_blocks, clen, done = jax.device_get(
            (state.cache["table"], state.cache["n_blocks"],
             state.cache_len, state.done))
        table = np.array(table)
        n_blocks = np.array(n_blocks)
        bs = self.pool.block_size
        plan_new: list[tuple] = []     # (row, slot)
        plan_cow: list[tuple] = []     # (row, slot, old_block)
        # planned CoWs drop a reference each, so the *last* planner of a
        # shared block sees an effective refcount of 1 and writes in place
        # (n-way fork costs n-1 copies, not n)
        pending_drops: dict[int, int] = {}
        for i in range(table.shape[0]):
            if done[i]:
                continue
            last = int(clen[i]) + n_steps - 1   # final position written
            if clamp:
                last = min(last, self.max_len - 2)
            if last > self.max_len - 2:
                raise ValueError(
                    f"row {i}: decoding {n_steps} steps from length "
                    f"{int(clen[i])} overruns the usable sequence length "
                    f"{self.max_len - 1} (last slot is KV scratch)")
            first_slot = int(clen[i]) // bs     # block of the first write
            for s in range(first_slot, int(n_blocks[i])):
                blk = int(table[i, s])
                if self.pool.refcount[blk] - pending_drops.get(blk, 0) > 1:
                    plan_cow.append((i, s, blk))
                    pending_drops[blk] = pending_drops.get(blk, 0) + 1
            for s in range(int(n_blocks[i]), last // bs + 1):
                plan_new.append((i, s))
        needed = len(plan_new) + len(plan_cow)
        if not needed:
            return state
        if not self.pool.reserve(needed):
            raise OutOfBlocks(needed, self.pool.free_blocks)
        new_ids = self.pool.cow([b for _, _, b in plan_cow])
        for (i, s, _), bid in zip(plan_cow, new_ids):
            table[i, s] = bid
        for (i, s), bid in zip(plan_new, self.pool.alloc(len(plan_new))):
            table[i, s] = bid
            n_blocks[i] = max(n_blocks[i], s + 1)
        return dataclasses.replace(
            state, cache={"table": jnp.asarray(table),
                          "n_blocks": jnp.asarray(n_blocks)})

    # -- decode -------------------------------------------------------------
    def _step_core(self, params, state: GenState, cache_in, rng,
                   sc: SamplerConfig, stop_ids: tuple, row_stops=None):
        stop_ids = tuple(stop_ids) or (self.eos_id,)
        tok = sample(state.pending_logits, rng, sc)
        lp = logprobs_of(state.pending_logits, tok)
        tok = jnp.where(state.done, self.pad_id, tok).astype(jnp.int32)
        new_done = state.done
        for s in stop_ids:
            new_done = new_done | (tok == s)
        if row_stops is not None:
            # per-row extra stop id (-1 = none): beam rows stop at their
            # step delimiter while chat rows in the same batch do not
            new_done = new_done | (tok == row_stops)
        new_len = jnp.where(state.done, state.cache_len, state.cache_len + 1)
        # Done rows must not clobber their last real KV slot: route their
        # (discarded) write to the reserved scratch slot max_len-1.  Usable
        # sequence length is therefore max_len - 1.  (The paged path maps
        # the same max_len-1 position through the block table — it lands in
        # the scratch block or an un-attended final offset.)
        model_len = jnp.where(state.done, self.max_len, new_len)
        logits, cache = self.model.decode_step(
            params, tok[:, None], cache_in, model_len, self.cfg, self.par)
        # Recurrent (non-positional) states have no scratch slot — restore
        # them for done rows.  These leaves are small (SSM/conv states).
        for key in ("conv", "ssm"):
            if key in cache:
                d = state.done.reshape((1, -1) + (1,) * (cache[key].ndim - 2))
                cache[key] = jnp.where(d, cache_in[key], cache[key])
        # Freeze pending logits on done rows so that resume() continues from
        # the logits that followed the stop token, not scratch-slot garbage.
        pending = jnp.where(state.done[:, None], state.pending_logits,
                            logits.astype(jnp.float32))
        new_state = GenState(
            cache=None,  # caller installs the layout-appropriate cache
            cache_len=new_len,
            pending_logits=pending,
            done=new_done,
            logprob_sum=state.logprob_sum + jnp.where(state.done, 0.0, lp),
            n_gen=state.n_gen + jnp.where(state.done, 0, 1),
        )
        return new_state, tok, cache

    def _step_impl(self, params, state: GenState, rng, row_stops=None, *,
                   sc: SamplerConfig, stop_ids: tuple = ()):
        st, tok, cache = self._step_core(params, state, state.cache, rng,
                                         sc, stop_ids, row_stops)
        return dataclasses.replace(st, cache=cache), tok

    def _step_paged_impl(self, params, state: GenState, pool_k, pool_v, rng,
                         row_stops=None, *, sc: SamplerConfig,
                         stop_ids: tuple = ()):
        cache_in = {"k": pool_k, "v": pool_v,
                    "table": state.cache["table"]}
        st, tok, cache = self._step_core(params, state, cache_in, rng,
                                         sc, stop_ids, row_stops)
        st = dataclasses.replace(st, cache=state.cache)
        return st, tok, cache["k"], cache["v"]

    def step(self, state: GenState, rng, sc: SamplerConfig = SamplerConfig(),
             stop_ids: tuple = (), row_stops=None, canary: bool = False):
        """One decode step. Returns (new_state, sampled tokens (B,)).

        ``row_stops`` (B,) int32 adds one *per-row* stop id on top of the
        shared ``stop_ids`` (-1 disables a row) — the scheduler uses it to
        stop beam-search rows at their step delimiter while plain chat
        rows in the same batch decode through it.

        Paged: runs :meth:`prepare_decode` first (may raise
        :class:`OutOfBlocks`), then scatters this step's KV into pool
        blocks in place.

        ``canary=True`` (paged only) additionally re-runs the step
        through the *exact* path — XLA paged attention, reference fp
        dequant, exact softmax — on the same post-plan state and
        pre-step pool (no donation), stashing the resulting logits in
        :attr:`last_canary_logits` for the scheduler's drift comparison.
        Under the default "xla" impl the exact path is the production
        path, so the comparison must be exact."""
        prof = self.profiler
        if self.paged:
            tr = self.tracer
            if tr is not None:
                t0 = tr.now()
                state = self.prepare_decode(state)
                tr.span("plan", t0)  # CoW/alloc host planning
            else:
                state = self.prepare_decode(state)
            if canary:
                self.last_canary_logits = self._canary_step(
                    state, rng, row_stops, sc, tuple(stop_ids))
            pt0 = prof.phase_begin("decode") if prof is not None else 0.0
            st, tok, pk, pv = self._step_paged_jit(
                self.params, state, self.pool.k, self.pool.v, rng,
                row_stops, sc=sc, stop_ids=tuple(stop_ids))
            if prof is not None:
                prof.phase_end("decode", pt0,
                               outputs=(tok, st.pending_logits))
            self.pool.adopt(pk, pv)
            return st, tok
        pt0 = prof.phase_begin("decode") if prof is not None else 0.0
        st, tok = self._step_jit(self.params, state, rng, row_stops, sc=sc,
                                 stop_ids=tuple(stop_ids))
        if prof is not None:
            prof.phase_end("decode", pt0, outputs=(tok, st.pending_logits))
        return st, tok

    def _canary_step(self, state: GenState, rng, row_stops, sc, stop_ids):
        """Exact-path replica of the paged decode step (no donation, no
        state commit): a dedicated jit of :meth:`_step_paged_impl` traced
        with the paged-attention impl forced to "xla" — table gather +
        reference ``dequantize_for_pool`` + exact f32 softmax — so its
        logits are the drift-free reference for whatever approximated
        path production runs.  The impl switch is trace-time-only state
        (``layers._PAGED_ATTN_IMPL`` is read when the jit traces), so it
        is set around every call and restored in ``finally``."""
        from repro.models import layers

        from repro.kernels import ops as _kops

        if self._canary_jit is None:
            impl = self._step_paged_impl

            # Distinct wrapper function, not ``jax.jit(impl)`` again: jax
            # caches the traced jaxpr per underlying callable, so jitting
            # the same bound method twice would let whichever jit runs
            # first (the canary, on step 0) satisfy the other's trace from
            # cache — and the production trace would never fire the op
            # hook inside the profiler's "decode" phase.
            def _canary_impl(params, state, pool_k, pool_v, rng,
                             row_stops=None, *, sc, stop_ids=()):
                return impl(params, state, pool_k, pool_v, rng, row_stops,
                            sc=sc, stop_ids=stop_ids)

            self._canary_jit = jax.jit(_canary_impl,
                                       static_argnames=("sc", "stop_ids"))
        prev = layers.set_paged_attention_impl("xla")
        # Canary work is verification overhead, not production compute —
        # mute the dispatch hook so its trace doesn't pollute attribution.
        prev_hook = _kops.set_op_hook(None)
        try:
            st, _tok, _pk, _pv = self._canary_jit(
                self.params, state, self.pool.k, self.pool.v, rng,
                row_stops, sc=sc, stop_ids=stop_ids)
        finally:
            _kops.set_op_hook(prev_hook)
            layers.set_paged_attention_impl(prev)
        return st.pending_logits

    def kv_roundtrip_error(self, max_blocks: int = 4):
        """Per-layer KV quantization round-trip error over a sample of
        live pool blocks: ``max |dequant(quant(dequant(pool))) -
        dequant(pool)|`` per layer, K and V leaves combined.  A stable
        quantizer round-trips its own output exactly (error 0.0); drift
        here means the stored codes sit on decision boundaries the
        re-quantization resolves differently — the online proxy for §5.1
        drift when no fp reference exists.  Returns None on fp pools."""
        from repro.serving.kv_quant import (dequantize_kv, kv_geometry,
                                            quantize_kv)

        pool = self.pool
        if pool is None or not isinstance(pool.k, dict):
            return None
        live = np.nonzero(pool.refcount > 0)[0][:max_blocks]
        if live.size == 0:
            return None
        per_layer = None
        for leaf in (pool.k, pool.v):
            sub = jax.tree.map(lambda a: a[:, live], leaf)
            mode, gr, gc, _ = kv_geometry(sub)
            x = dequantize_kv(sub)
            x2 = dequantize_kv(quantize_kv(x, mode=mode, gr=gr, gc=gc))
            err = jnp.max(jnp.abs(x2 - x),
                          axis=tuple(range(1, x.ndim)))  # (L,)
            per_layer = err if per_layer is None \
                else jnp.maximum(per_layer, err)
        return [float(e) for e in jax.device_get(per_layer)]

    def _generate_impl(self, params, state: GenState, rng, *, n_steps: int,
                       sc: SamplerConfig, stop_ids: tuple = ()):
        def body(st, key):
            st, tok = self._step_impl(params, st, key, sc=sc, stop_ids=stop_ids)
            return st, tok

        keys = jax.random.split(rng, n_steps)
        state, toks = jax.lax.scan(body, state, keys)
        return state, toks.T  # (B, n_steps)

    def _gen_paged_impl(self, params, state: GenState, pool_k, pool_v, rng,
                        *, n_steps: int, sc: SamplerConfig,
                        stop_ids: tuple = ()):
        def body(carry, key):
            st, pk, pv = carry
            st, tok, pk, pv = self._step_paged_impl(params, st, pk, pv, key,
                                                    sc=sc, stop_ids=stop_ids)
            return (st, pk, pv), tok

        keys = jax.random.split(rng, n_steps)
        (state, pk, pv), toks = jax.lax.scan(body, (state, pool_k, pool_v),
                                             keys)
        return state, toks.T, pk, pv

    def generate(self, state: GenState, n_steps: int, rng,
                 sc: SamplerConfig = SamplerConfig(), stop_ids: tuple = ()):
        """Decode up to n_steps tokens (stopping per-row at any id in
        ``stop_ids``, default EOS). Returns (final_state, (B, n_steps) tokens,
        pad_id after stop).

        Paged: blocks covering the whole n_steps horizon are allocated (and
        shared tails CoW'd) up front so the scan writes purely in place;
        rows that stop early keep their surplus blocks until released."""
        if self.paged:
            state = self.prepare_decode(state, n_steps)
            state, toks, pk, pv = self._gen_paged_jit(
                self.params, state, self.pool.k, self.pool.v, rng,
                n_steps=n_steps, sc=sc, stop_ids=tuple(stop_ids))
            self.pool.adopt(pk, pv)
            return state, toks
        return self._gen_jit(self.params, state, rng, n_steps=n_steps, sc=sc,
                             stop_ids=tuple(stop_ids))

    def resume(self, state: GenState) -> GenState:
        """Clear done flags (used by step-level beam search to continue
        beams after a step-delimiter stop)."""
        return GenState(
            cache=state.cache, cache_len=state.cache_len,
            pending_logits=state.pending_logits,
            done=jnp.zeros_like(state.done),
            logprob_sum=state.logprob_sum, n_gen=state.n_gen)

    def freeze_rows(self, state: GenState, rows) -> GenState:
        """Mark ``rows`` done *without* freeing paged blocks: the rows stop
        advancing (writes routed to scratch, pending logits frozen) but
        keep their KV.  The scheduler freezes beam rows that exhaust a
        reasoning step's token budget until the whole tree reaches its
        scoring boundary; :meth:`resume_rows` re-arms them."""
        rows = jnp.asarray(np.asarray(rows, np.int64).ravel(), jnp.int32)
        return dataclasses.replace(state, done=state.done.at[rows].set(True))

    def resume_rows(self, state: GenState, rows) -> GenState:
        """Clear done flags for ``rows`` only (the per-row counterpart of
        :meth:`resume` — other rows, e.g. idle scheduler slots, keep their
        done state)."""
        rows = jnp.asarray(np.asarray(rows, np.int64).ravel(), jnp.int32)
        return dataclasses.replace(state,
                                   done=state.done.at[rows].set(False))

    # -- speculative decoding (draft-then-verify) ----------------------------
    def _spec_verify_impl(self, params, state: GenState, xs, n_prop,
                          row_stops, pool_k, pool_v, *, prefix_w: int,
                          stop_ids: tuple):
        """Verify ``W`` proposed tokens per row in ONE target forward.

        ``xs`` (B, W): column 0 is the target's own pending-logits argmax
        (always correct under greedy), columns 1.. are draft proposals.
        The forward consumes all W tokens while attending over each row's
        committed prefix (gathered through its block table, exactly the
        partial-prefill read path) and returns logits at every position;
        position j's argmax is what greedy decoding *would* sample after
        ``xs[:, :j+1]`` — agreement with ``xs[:, j+1]`` extends the
        accepted prefix, the first disagreement cuts it.  Committed stop
        tokens are consumed (KV written, counted) exactly like
        ``_step_core``; everything past the acceptance point is masked
        out of lengths/logprobs and its already-scattered KV is reclaimed
        host-side by :meth:`trim_rows` (a block free, never a copy)."""
        from repro.models import transformer

        B, W = xs.shape
        bs = self.pool.block_size
        table = state.cache["table"]
        clen = state.cache_len
        prefix = self._gather_prefix(table, pool_k, pool_v, clen,
                                     prefix_w=prefix_w)
        logits, kvs, _ = self.model.forward(
            params, xs, self.cfg, self.par, return_kv=True, prefix=prefix)
        logits = logits.astype(jnp.float32)          # (B, W, V)
        # scatter all W proposal KVs at each row's write frontier; done
        # (frozen) rows route to the scratch clamp like _step_core
        start = jnp.where(state.done, self.max_len, clen)
        pk = transformer._scatter_suffix_blocks(pool_k, kvs[0], table, bs,
                                                start)
        pv = transformer._scatter_suffix_blocks(pool_v, kvs[1], table, bs,
                                                start)
        # greedy longest-agreeing-prefix acceptance: token 0 always
        # commits (it was sampled from the real pending logits), token
        # j >= 1 commits iff every earlier proposal agreed with the
        # target's argmax — and a committed stop truncates the run
        tgt = jnp.argmax(logits[:, :-1, :], axis=-1).astype(jnp.int32)
        agree = (xs[:, 1:] == tgt).astype(jnp.int32)
        jidx = jnp.arange(W, dtype=jnp.int32)[None, :]
        ok = jnp.concatenate(
            [jnp.ones((B, 1), jnp.int32), jnp.cumprod(agree, axis=1)],
            axis=1)
        ok = ok * (jidx < n_prop[:, None]).astype(jnp.int32)
        is_stop = jnp.zeros((B, W), bool)
        for s in stop_ids:
            is_stop = is_stop | (xs == s)
        if row_stops is not None:
            is_stop = is_stop | (xs == row_stops[:, None])
        stop_commit = ok * is_stop.astype(jnp.int32)
        before = jnp.cumsum(stop_commit, axis=1) - stop_commit
        commit = ok * (before == 0).astype(jnp.int32)
        commit = commit * (~state.done).astype(jnp.int32)[:, None]
        a = jnp.sum(commit, axis=1).astype(jnp.int32)      # accepted count
        new_done = state.done | jnp.any(commit.astype(bool) & is_stop,
                                        axis=1)
        # next pending logits = the target's distribution after the last
        # committed token (frozen for done rows, like _step_core)
        idx = jnp.clip(a - 1, 0, W - 1)
        q_next = jnp.take_along_axis(logits, idx[:, None, None],
                                     axis=1)[:, 0]
        pending = jnp.where(state.done[:, None], state.pending_logits,
                            q_next)
        # per-token logprobs under the distribution each was sampled
        # from: column 0 under the old pending logits, column j under
        # the verify logits at j-1 — committed columns only
        dists = jnp.concatenate(
            [state.pending_logits[:, None, :], logits[:, :-1, :]], axis=1)
        lps = jax.vmap(logprobs_of, in_axes=(1, 1), out_axes=1)(dists, xs)
        new_state = GenState(
            cache=state.cache,
            cache_len=clen + a,
            pending_logits=pending,
            done=new_done,
            logprob_sum=state.logprob_sum
            + jnp.sum(lps * commit.astype(jnp.float32), axis=1),
            n_gen=state.n_gen + a,
        )
        return new_state, commit, pk, pv

    def spec_verify(self, state: GenState, xs, n_prop, row_stops=None,
                    stop_ids: tuple = ()):
        """Speculative verify step: commit the longest greedy-agreeing
        prefix of ``xs`` (B, W) per row in one batched target forward.

        ``xs[:, 0]`` must be the argmax of ``state.pending_logits`` (the
        token a plain greedy step would emit — so a round always commits
        at least one token per live row) and ``n_prop`` (B,) the number
        of valid columns per row; padding beyond it is ignored.  Returns
        ``(new_state, commit)`` with ``commit`` a (B, W) host 0/1 prefix
        mask — row i committed ``xs[i, :commit[i].sum()]``.  Blocks for
        the full W-token horizon are planned up front (may raise
        :class:`OutOfBlocks` — state and pool untouched, the scheduler's
        preemption hook) and the rejected suffix's blocks are reclaimed
        by :meth:`trim_rows`.  Paged only."""
        if not self.paged:
            raise ValueError("spec_verify requires the paged KV layout "
                             "(DecodeEngine(paged=True))")
        W = int(xs.shape[1])
        stop_ids = tuple(stop_ids) or (self.eos_id,)
        tr = self.tracer
        t0 = tr.now() if tr is not None else 0.0
        state = self.prepare_decode(state, W, clamp=True)
        if tr is not None:
            tr.span("plan", t0)
        bs = self.pool.block_size
        clen_h, done_h = (np.asarray(a) for a in jax.device_get(
            (state.cache_len, state.done)))
        live = ~done_h
        # bucket the prefix gather like the partial prefill: block
        # granular, so a recompile costs one new shape per block of
        # context growth, not one per round
        top = int(clen_h[live].max()) if live.any() else 1
        prefix_w = max(1, -(-top // bs))
        prof = self.profiler
        t1 = tr.now() if tr is not None else 0.0
        pt0 = prof.phase_begin("spec_verify") if prof is not None else 0.0
        st, commit, pk, pv = self._spec_verify_jit(
            self.params, state, jnp.asarray(xs, jnp.int32),
            jnp.asarray(n_prop, jnp.int32), row_stops, self.pool.k,
            self.pool.v, prefix_w=prefix_w, stop_ids=stop_ids)
        if prof is not None:
            prof.phase_end("spec_verify", pt0,
                           outputs=(commit, st.pending_logits))
        self.pool.adopt(pk, pv)
        commit_h = np.asarray(jax.device_get(commit))
        if tr is not None:
            tr.span("spec_verify", t1, batch=int(xs.shape[0]), width=W)
        return st, commit_h

    def trim_rows(self, state: GenState, rows) -> GenState:
        """Free the planned-but-unused tail blocks of ``rows`` after a
        speculative round: blocks past ``blocks_for(cache_len)`` were
        allocated (or copy-on-written — either way private, refcount 1)
        for proposals the verify rejected, so releasing them *is* the
        cost of rejection — a free-list append, zero KV bytes moved.
        Callers pass only rows that were live at verify time (frozen
        beam lanes keep their surplus blocks like any frozen row).
        No-op in dense mode."""
        if not self.paged:
            return state
        rows = np.asarray(rows, np.int64).ravel()
        if not rows.size:
            return state
        bs = self.pool.block_size
        table, n_blocks, clen = (np.array(a) for a in jax.device_get(
            (state.cache["table"], state.cache["n_blocks"],
             state.cache_len)))
        changed = False
        for r in rows:
            keep = blocks_for(int(clen[r]), bs)
            if n_blocks[r] > keep:
                self.pool.release(table[r, keep:n_blocks[r]])
                table[r, keep:n_blocks[r]] = 0
                n_blocks[r] = keep
                changed = True
        if not changed:
            return state
        return dataclasses.replace(
            state, cache={"table": jnp.asarray(table),
                          "n_blocks": jnp.asarray(n_blocks)})

    def spec_snapshot(self, state: GenState, rows) -> GenState:
        """Self-drafting draft lane: a second state aliasing ``rows``'
        blocks via a refcount bump — the draft lane IS a paged fork.  The
        draft's first divergent write copy-on-writes its frontier block
        (``prepare_decode`` sees refcount > 1), so the target's KV is
        never touched, and ``release_rows`` on the snapshot undoes the
        bump: rejection frees blocks, never copies KV.  Rows not in
        ``rows`` come back done with empty tables (idle draft lanes)."""
        if not self.paged:
            raise ValueError("spec_snapshot requires the paged KV layout "
                             "(DecodeEngine(paged=True))")
        rows = [int(r) for r in np.asarray(rows, np.int64).ravel()]
        table, n_blocks = (np.array(a) for a in jax.device_get(
            (state.cache["table"], state.cache["n_blocks"])))
        mask = np.zeros(table.shape[0], bool)
        mask[rows] = True
        for r in rows:
            self.pool.retain(table[r, :n_blocks[r]])
        table[~mask] = 0
        n_blocks[~mask] = 0
        return GenState(
            cache={"table": jnp.asarray(table),
                   "n_blocks": jnp.asarray(n_blocks)},
            cache_len=state.cache_len,
            pending_logits=state.pending_logits,
            done=state.done | jnp.asarray(~mask),
            logprob_sum=state.logprob_sum,
            n_gen=state.n_gen)

    def _forced_step_impl(self, params, state: GenState, tok):
        tok = jnp.where(state.done, self.pad_id, tok).astype(jnp.int32)
        new_len = jnp.where(state.done, state.cache_len,
                            state.cache_len + 1)
        model_len = jnp.where(state.done, self.max_len, new_len)
        logits, cache = self.model.decode_step(
            params, tok[:, None], state.cache, model_len, self.cfg,
            self.par)
        for key in ("conv", "ssm"):
            if key in cache:
                d = state.done.reshape((1, -1)
                                       + (1,) * (cache[key].ndim - 2))
                cache[key] = jnp.where(d, state.cache[key], cache[key])
        pending = jnp.where(state.done[:, None], state.pending_logits,
                            logits.astype(jnp.float32))
        return dataclasses.replace(state, cache=cache, cache_len=new_len,
                                   pending_logits=pending)

    def forced_step(self, state: GenState, tok) -> GenState:
        """Feed a *given* token per row (no sampling): the scheduler's
        draft-model engine consumes the target's already-committed token
        before proposing its continuation.  Logprob/n_gen bookkeeping is
        untouched — draft-side counts never reach scheduler metrics.
        Dense layout only (the draft engine is dense; its whole state is
        scratch that the next round resyncs)."""
        if self.paged:
            raise ValueError("forced_step supports the dense KV layout "
                             "only (the speculative draft engine)")
        return self._forced_jit(self.params, state,
                                jnp.asarray(tok, jnp.int32))


# ---------------------------------------------------------------------------
# Continuous batching scheduler (slot-based)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class BeamSpec:
    """Step-level tree search as a scheduler request class (paper §2.1).

    A request carrying a ``BeamSpec`` occupies ``width * expand`` slots
    ("lanes") and decodes like any other row of the continuous batch.  A
    lane stops at ``step_stop_id`` (the reasoning-step delimiter) or after
    ``step_tokens`` tokens; once every lane has stopped the tree hits a
    *scoring boundary*: ``score`` ranks all ``width * expand`` candidate
    prefixes in ONE batched call, the top ``width`` survive, and one
    ``DecodeEngine.reorder`` commits the prune + re-expansion — on a paged
    pool, losing lanes' blocks free (refcount to zero) and each survivor's
    blocks gain ``expand - 1`` references (zero KV bytes copied).  After
    ``max_steps`` boundaries (or ``finished`` returning True on the
    survivors) ``final_score`` picks the answer and the request completes
    with ``width`` samples.

    The callbacks keep the scheduler tokenizer-agnostic; the controller
    builds them (decode token lists -> texts -> PRM):

    * ``score(token_lists, logprob_sum, n_gen) -> (n,) scores`` — batched
      candidate scoring at each boundary (required);
    * ``final_score`` — final-beam selection (defaults to ``score``);
    * ``finished(token_lists) -> bool`` — early-exit check on the
      survivors (e.g. every beam contains a final answer).
    """

    width: int                   # surviving beams per boundary
    expand: int                  # candidates per surviving beam
    step_tokens: int = 16        # token budget per reasoning step
    max_steps: int = 8           # scoring boundaries before final selection
    step_stop_id: int = -1       # step delimiter token id (e.g. '.')
    score: Optional[Callable] = None
    final_score: Optional[Callable] = None
    finished: Optional[Callable] = None

    @property
    def fan(self) -> int:
        """Slots (lanes) the request occupies while decoding."""
        return self.width * self.expand


@dataclass(frozen=True)
class SpecConfig:
    """Speculative decoding mode for the continuous scheduler.

    Each scheduler step becomes a draft-then-verify *round*: a cheap
    drafter proposes up to ``k`` tokens per eligible row and ONE batched
    target forward verifies all of them, committing the longest prefix
    that agrees with what plain greedy decoding would have produced — so
    speculative greedy output is bit-identical to the direct path, the
    only thing that changes is tokens per step.  Exactly one draft source
    must be chosen:

    * ``self_draft=True`` — the target drafts for itself on a forked
      (refcount-bumped) snapshot of its own paged state: zero extra
      params, and the draft always agrees, so every round commits all
      ``k`` tokens.  This is the machinery-exercising / upper-bound mode.
    * ``draft_model="<arch>"`` — a small model from the configs registry
      (smoke config, vocab aligned to the target) runs k-1 cheap dense
      decode steps per round; acceptance then depends on how often the
      draft's greedy argmax matches the target's.

    Speculation applies under greedy sampling on a paged engine only;
    beam lanes and ``Request(no_spec=True)`` rows ride along in the same
    verify at one token per round (plain-step-equivalent)."""

    k: int = 4                   # max tokens committed per row per round
    draft_model: str = ""        # configs-registry arch of the drafter
    self_draft: bool = False     # target drafts on a forked snapshot

    def __post_init__(self):
        if self.k < 2:
            raise ValueError(f"SpecConfig.k must be >= 2 (k={self.k} "
                             f"proposes nothing beyond the plain step)")
        if bool(self.draft_model) == bool(self.self_draft):
            raise ValueError("SpecConfig needs exactly one draft source: "
                             "draft_model=<arch> or self_draft=True")


@dataclass
class Request:
    req_id: int
    prompt: jnp.ndarray          # (S,) int32
    max_new_tokens: int = 64
    n_samples: int = 1           # >1: TTS fan-out sharing one prefill (fork)
    search: Optional[BeamSpec] = None  # beam-search tree request class
    no_spec: bool = False        # opt out of speculative decoding rounds


@dataclass
class CompletedSample:
    """One finished slot occupancy (one sample of one request)."""

    req_id: int
    sample_idx: int
    tokens: list                 # generated ids, stop token excluded
    logprob_sum: float           # cumulative sampled logprob (TTS scoring)
    n_gen: int                   # tokens sampled incl. any stop token — the
                                 # denominator matching logprob_sum
    finish_reason: str           # "stop" | "length"
    admitted_step: int           # scheduler step the slot was filled
    first_decode_step: int       # first step this sample decoded in batch
    finished_step: int           # step the slot was released


@dataclass
class _Slot:
    """Host-side bookkeeping for one occupied decode row."""

    req: Request
    sample_idx: int
    admitted_step: int
    tokens: list = field(default_factory=list)
    first_decode_step: int = -1


@dataclass
class _BeamRun:
    """Host-side bookkeeping for one in-flight beam-search request.

    ``rows`` are the ``fan`` slot indices the tree occupies (fixed for the
    request's lifetime — boundary reorders move KV *between* these rows,
    never out of them).  Lane ``j`` accumulates its candidate prefix in
    ``tokens[j]`` (step-delimiter stops included, like the direct path's
    decode of the generate output); ``step_gen``/``stopped`` track each
    lane's progress toward the current scoring boundary."""

    req: Request
    spec: BeamSpec
    rows: list
    tokens: list                 # per-lane generated ids since admission
    step_gen: list               # per-lane tokens sampled this beam step
    stopped: list                # per-lane: reached delimiter/budget
    beam_step: int = 0           # boundaries completed


@dataclass
class StepRecord:
    step: int
    occupancy: int               # rows decoding this step
    admitted: int                # requests admitted this step
    prefill_tokens: int          # prompt tokens prefilled this step
    wall_s: float = 0.0          # host wall time of this step_once call
    # tokens committed this step; None = one per occupied row (plain
    # decode).  Speculative rounds commit several per row, so occupancy
    # alone would under-count throughput.
    decode_tokens: Optional[int] = None


class SchedulerMetrics:
    """Step-level metrics of the continuous batching loop."""

    def __init__(self, n_slots: int):
        self.n_slots = n_slots
        self.records: list[StepRecord] = []
        self.completed_requests = 0
        self.completed_samples = 0
        self.preemptions = 0
        self.wall_s = 0.0
        # cross-request prefix cache (zero unless a cache is attached):
        # one lookup per admitted request; a hit means some prefix of the
        # prompt was served from cached blocks, and prefill_tokens_saved
        # counts the prompt tokens whose prefill compute was skipped
        self.cache_lookups = 0
        self.cache_hits = 0
        self.prefill_tokens_saved = 0
        # paged KV accounting in *bytes* (dtype-aware: a quantized pool's
        # blocks are smaller, so block counts alone would overstate its
        # footprint); updated by the scheduler each step, 0 when dense
        self.peak_kv_bytes = 0
        self.kv_quant = "none"
        # admission batching: one entry per engine.prefill call made at
        # admission, holding the number of requests that call admitted.
        # prefill_calls_per_request < 1 is the batched-admission win the
        # serving benchmark asserts (it was pinned at 1 for cache-aware
        # admission before batched partial prefill).
        self.admission_batch_sizes: list[int] = []
        # beam-search (tree) workload counters: a boundary is one
        # prune+expand commit; expansions/prunes count lanes forked /
        # released there (fan - width each); prm_batches counts batched
        # score-callback calls and prm_candidates the candidates they
        # covered — candidates_per_batch > 1 is the batched-scoring win
        # (the pre-scheduler path scored per-candidate at batch 1)
        self.beam_boundaries = 0
        self.beam_expansions = 0
        self.beam_prunes = 0
        self.prm_batches = 0
        self.prm_candidates = 0
        # speculative decoding: one "round" is one draft+verify cycle.
        # draft_tokens counts proposals beyond the mandatory first token
        # per eligible row, accepted those the verify committed beyond
        # it; committed/row_steps counts every committed token over every
        # (row, round) pair, so accepted_tokens_per_step > 1 iff
        # speculation beat one-token-per-step decoding.
        self.spec_rounds = 0
        self.spec_draft_tokens = 0
        self.spec_accepted_tokens = 0
        self.spec_committed_tokens = 0
        self.spec_row_steps = 0
        # per-request latency records (telemetry.RequestLatency), appended
        # by the scheduler at request completion when a Tracer is attached
        # — the histogram behind the summary's ttft/itl/queue_wait
        # percentiles.  Always empty without a tracer (the keys then
        # report 0.0); step_time_* comes from StepRecord.wall_s and needs
        # no tracer.
        self.latencies: list[RequestLatency] = []
        # roofline/canary profiler (profiling.KernelProfiler) bound by
        # ContinuousScheduler(profiler=...); summary() merges its
        # kernel_time_share / roofline_efficiency / canary drift keys
        # (all 0.0 when no profiler is attached, so the key set is
        # stable either way)
        self.profiler = None

    def record(self, rec: StepRecord):
        self.records.append(rec)

    def record_prefill(self, batch_size: int):
        """Account one admission prefill call covering ``batch_size``
        requests (a TTS group counts as one request: one prefill, forked)."""
        self.admission_batch_sizes.append(batch_size)

    @property
    def prefill_calls(self) -> int:
        return len(self.admission_batch_sizes)

    def summary(self) -> dict:
        steps = len(self.records)
        decode = sum(r.occupancy if r.decode_tokens is None
                     else r.decode_tokens for r in self.records)
        occ_rows = sum(r.occupancy for r in self.records)
        prefill = sum(r.prefill_tokens for r in self.records)
        occ = (occ_rows / (steps * self.n_slots)) if steps else 0.0
        admitted = sum(r.admitted for r in self.records)
        sizes = self.admission_batch_sizes
        # tail latency (seconds).  Every key below must survive an
        # admitted == 0 drain: `percentile` returns 0.0 on empty input and
        # the list comprehensions are empty-safe, so a scheduler that
        # never admitted anything still yields the full key set.
        lat = self.latencies
        ttfts = [l.ttft for l in lat]
        waits = [l.queue_wait for l in lat]
        gaps = [g for l in lat for g in l.gaps]
        step_ts = [r.wall_s for r in self.records]
        return {
            "admitted_requests": admitted,
            "prefill_calls": self.prefill_calls,
            "prefill_calls_per_request": (self.prefill_calls / admitted
                                          if admitted else 0.0),
            "admission_batch_max": max(sizes, default=0),
            "admission_batch_avg": (sum(sizes) / len(sizes)
                                    if sizes else 0.0),
            "steps": steps,
            "n_slots": self.n_slots,
            "avg_slot_occupancy": occ,
            "decode_tokens": decode,
            "prefill_tokens": prefill,
            "completed_requests": self.completed_requests,
            "completed_samples": self.completed_samples,
            "preemptions": self.preemptions,
            "wall_s": self.wall_s,
            "requests_per_s": (self.completed_requests / self.wall_s
                               if self.wall_s > 0 else 0.0),
            "decode_tok_per_s": (decode / self.wall_s
                                 if self.wall_s > 0 else 0.0),
            "prefix_cache_lookups": self.cache_lookups,
            "prefix_cache_hits": self.cache_hits,
            "prefix_cache_hit_rate": (self.cache_hits / self.cache_lookups
                                      if self.cache_lookups else 0.0),
            "prefill_tokens_saved": self.prefill_tokens_saved,
            "peak_kv_bytes": self.peak_kv_bytes,
            "kv_quant": self.kv_quant,
            "beam_boundaries": self.beam_boundaries,
            "beam_expansions": self.beam_expansions,
            "beam_prunes": self.beam_prunes,
            "prm_batches": self.prm_batches,
            "prm_candidates": self.prm_candidates,
            "prm_candidates_per_batch": (self.prm_candidates
                                         / self.prm_batches
                                         if self.prm_batches else 0.0),
            "spec_rounds": self.spec_rounds,
            "draft_tokens": self.spec_draft_tokens,
            "spec_acceptance_rate": (self.spec_accepted_tokens
                                     / self.spec_draft_tokens
                                     if self.spec_draft_tokens else 0.0),
            "accepted_tokens_per_step": (self.spec_committed_tokens
                                         / self.spec_row_steps
                                         if self.spec_row_steps else 0.0),
            "latency_requests": len(lat),
            "ttft_p50": percentile(ttfts, 50),
            "ttft_p90": percentile(ttfts, 90),
            "ttft_p99": percentile(ttfts, 99),
            "itl_p50": percentile(gaps, 50),
            "itl_p99": percentile(gaps, 99),
            "queue_wait_p50": percentile(waits, 50),
            "queue_wait_p99": percentile(waits, 99),
            "preempt_delay_s": sum(l.preempt_delay for l in lat),
            "step_time_p50": percentile(step_ts, 50),
            "step_time_p99": percentile(step_ts, 99),
            **(self.profiler.summary_metrics()
               if self.profiler is not None
               else dict(NULL_PROFILE_METRICS)),
        }


class ContinuousScheduler:
    """Slot-based continuous batching on top of :class:`DecodeEngine`.

    The scheduler owns one persistent ``GenState`` of ``n_slots`` rows that
    decodes **every step**; requests flow through slots independently:

    1. **Admit** — while free slots remain, the queue head is prefilled
       (one prefill per request, batch 1) and its cache/logits/length rows
       are scattered into the live state with ``DecodeEngine.merge_rows``.
       A TTS request (``n_samples > 1``) does *one* prefill and ``fork``\\ s
       the prefilled row into ``n_samples`` slots, so Best-of-N rides along
       with exactly one prompt pass.
    2. **Decode** — one batched ``DecodeEngine.step`` over all rows.  Free
       rows are ``done`` and cost an idle lane, never a correctness hazard.
    3. **Release** — a row that samples a stop id, or reaches its request's
       ``max_new_tokens``, releases its slot *immediately*; the next step's
       admission refills it while other rows keep decoding.  Nothing ever
       waits for a whole batch to drain.

    Late-arriving work therefore starts decoding as soon as any earlier
    request finishes (true continuous admission); per-step occupancy,
    prefill/decode token counts and requests/s are recorded in
    ``self.metrics``.  ``step_once`` exposes the admit→decode→release cycle
    so callers can interleave ``submit`` with a running drain.

    With a paged engine the scheduler also budgets KV *blocks*: admission
    only proceeds while the pool can cover the head request's prompt
    blocks, and when a decode step cannot get the blocks it needs
    (:class:`OutOfBlocks`), the **youngest** live request is preempted —
    its slots released, its blocks freed, the request requeued at the
    queue head to rerun from scratch — and the step retried.  Preemptions
    are counted in ``self.metrics.preemptions``; under greedy sampling a
    preempted request's final tokens are unchanged (it simply re-prefills
    later).

    With a :class:`~repro.serving.prefix_cache.PrefixCache` attached
    (paged engines only), admission becomes **cache-aware**: each request
    does a longest-prefix-match against the radix tree, leases the
    matched blocks, and prefills only the uncached suffix (the engine's
    partial-prefill path); block budgeting counts only the *new* blocks a
    request needs.  Right after its prefill the request's full prompt
    blocks are inserted into the tree — so the very next admission (even
    in the same step) can hit, and a preempted request re-prefills almost
    for free — and completed rows re-touch their prefix on release.
    Because the cache registers itself as the pool's pressure hook, block
    shortages first evict LRU unreferenced cached leaves and only then
    fall back to preemption.  Hit rate and prefill-tokens-saved land in
    ``self.metrics``.

    Cache-aware admission is **batched**: a run of consecutive plain
    requests at the queue head is matched/leased together, bucketed by
    cached-block-column width (``ceil(cached_len / block_size)``, the
    PR-4 gather bucketing — padded suffix shapes are uniform at
    ``prompt_len`` already), and each bucket runs through ONE batched
    partial prefill + merge, recovering the one-prefill-per-step shape
    discipline the uncached path has.  A candidate whose prompt shares a
    longer full-block prefix with an *earlier request in the same run*
    than the tree currently holds is deferred to the next collection
    round (same step, after that request's insert), so a cold shared
    header still costs exactly one full prefill and every follower
    admits as a hit — identical hits, leases and prefill-token counts to
    one-at-a-time admission, and bit-identical greedy outputs.
    ``max_admission_batch=1`` restores the sequential behavior (the
    parity baseline); ``SchedulerMetrics.admission_batch_sizes`` records
    the per-call request counts, driving the benchmark's
    ``prefill_calls_per_request < 1`` assertion.

    **Tree search** is a first-class request class: a request carrying
    ``search=``:class:`BeamSpec` admits through the same (cache-aware)
    path — one prefill, ``fork`` into ``width * expand`` lanes — and its
    lanes decode inside the shared batch alongside chat/BoN traffic,
    stopping per-row at the spec's step delimiter via ``row_stops``.
    When every lane has stopped, the tree hits a scoring boundary: the
    spec's ``score`` callback ranks all candidates in one batched call
    (PRM forwards batch with the tree's fan instead of the pre-scheduler
    per-candidate B=1 loop) and one ``engine.reorder`` commits the
    prune+expansion (block frees + refcount bumps on the paged pool).
    Finished trees emit ``width`` samples plus a ``beam_results`` entry
    and free every lane's blocks; ``OutOfBlocks`` preemption treats a
    tree like any group (all lanes released, the search restarts on
    re-admission).  Boundary/expansion/prune and PRM batching counters
    land in ``SchedulerMetrics``.

    **Speculative decoding** (``spec=``:class:`SpecConfig`, paged engines
    under greedy sampling): each decode step becomes a draft-then-verify
    round — a drafter (the target itself on a refcount-bumped snapshot,
    or a small dense registry model) proposes up to ``spec.k`` tokens per
    eligible row, one batched ``engine.spec_verify`` forward checks all
    of them, and the longest greedy-agreeing prefix commits, so outputs
    stay bit-identical to plain greedy decoding.  Draft lanes are pure
    fork/CoW bookkeeping and a rejected suffix is a block *free* (never a
    KV copy, reclaimed by ``engine.trim_rows``).  Beam lanes and
    ``Request(no_spec=True)`` rows ride the verify at one token per
    round; canary steps and non-greedy samplers fall back to the plain
    step.  ``OutOfBlocks`` anywhere in the round aborts it cleanly (the
    snapshot's references are dropped first) and retries after
    preemption.  Round/acceptance counters land in ``SchedulerMetrics``
    (``spec_acceptance_rate``, ``accepted_tokens_per_step``), a
    ``spec_verify`` span and ``spec_accepted_tokens`` gauge in the
    tracer, and the verify forward is attributed as its own profiler
    phase.
    """

    def __init__(self, engine: DecodeEngine, n_slots: int = 8,
                 prompt_len: int = 32, stop_ids: tuple = (),
                 prefix_cache: Optional[PrefixCache] = None,
                 max_admission_batch: Optional[int] = None,
                 tracer: Optional[Tracer] = None,
                 profiler=None,
                 spec: Optional[SpecConfig] = None):
        self.engine = engine
        # request-lifecycle telemetry (None = default: zero overhead, no
        # events, bit-identical scheduling).  The scheduler owns its
        # engine's tracer slot — constructing a scheduler (re)binds it, so
        # engine-level prefill/plan spans land in the same trace.  The
        # tracer's injectable clock also drives the per-step wall_s
        # measurement, keeping latency tests deterministic.
        self.tracer = tracer
        engine.tracer = tracer
        # roofline/canary profiler (profiling.KernelProfiler), same
        # ownership discipline as the tracer: constructing a scheduler
        # (re)binds the engine's profiler slot and installs the kernel
        # dispatch hook.  None = zero overhead, bit-identical outputs.
        # The step-wall clock prefers the tracer's, then the profiler's
        # (both injectable), so profiled runs are clock-deterministic.
        self._clock = (tracer.now if tracer is not None
                       else profiler.now if profiler is not None
                       else time.perf_counter)
        self.profiler = profiler
        engine.profiler = profiler
        if profiler is not None:
            profiler.install()
        self._preempted: set = set()   # req_ids awaiting re-admission
        self._ft_emitted: set = set()  # req_ids whose first_token fired
        self.paged = engine.paged
        self.n_slots = n_slots
        self.prompt_len = prompt_len
        self.stop_ids = tuple(stop_ids) or (engine.eos_id,)
        if max_admission_batch is not None and max_admission_batch < 1:
            raise ValueError("max_admission_batch must be >= 1 or None")
        # cap on requests sharing one admission prefill call (None = the
        # free-slot count); 1 recovers strict one-at-a-time admission
        self.max_admission_batch = max_admission_batch
        if prefix_cache is not None:
            if not engine.paged:
                raise ValueError("prefix_cache requires a paged engine "
                                 "(DecodeEngine(paged=True))")
            if prefix_cache.pool is not engine.pool:
                raise ValueError("prefix_cache is bound to a different "
                                 "KVPool than the engine's")
        self.cache = prefix_cache
        self.spec = spec
        # draft-model mode: one persistent dense engine whose KV shadows
        # the target's committed context (prompts prefilled at admission,
        # cache_len resynced each round, proposals rolled back to the
        # verify's acceptance point).  Untrained smoke params by default —
        # callers wanting a *useful* drafter swap self._draft.params.
        self._draft: Optional[DecodeEngine] = None
        self._draft_state: Optional[GenState] = None
        if spec is not None:
            if not engine.paged:
                raise ValueError(
                    "speculative decoding requires a paged engine "
                    "(DecodeEngine(paged=True)): draft lanes and rejected "
                    "suffixes are refcount operations on the block pool")
            if spec.draft_model:
                from repro.configs.registry import get_config

                dcfg = get_config(spec.draft_model, smoke=True)
                if dcfg.vocab_size != engine.cfg.vocab_size:
                    dcfg = dcfg.with_(vocab_size=engine.cfg.vocab_size)
                dparams = api.get_model(dcfg).init_params(
                    jax.random.key(0), dcfg)
                self._draft = DecodeEngine(
                    dparams, dcfg, max_len=engine.max_len,
                    eos_id=engine.eos_id, pad_id=engine.pad_id)
                self._draft_state = self._draft.empty_state(n_slots)
        self.queue: deque[Request] = deque()
        self.slots: list[Optional[_Slot]] = [None] * n_slots
        self.state: Optional[GenState] = None   # built on first admission
        self.step_count = 0
        self.n_prefills = 0
        self.completed: dict[int, list[CompletedSample]] = {}
        self._n_samples: dict[int, int] = {}
        self._beams: dict[int, _BeamRun] = {}   # req_id -> in-flight tree
        self.beam_results: dict[int, dict] = {}  # req_id -> final selection
        self.metrics = SchedulerMetrics(n_slots)
        self.metrics.profiler = profiler
        if self.paged:
            # bytes, not blocks-equivalent: quantized pools have smaller
            # blocks, and this is the number a byte-budgeted operator sizes
            self._block_bytes = engine.pool.block_bytes()
            self.metrics.kv_quant = engine.pool.mode

    # -- submission ----------------------------------------------------------
    def submit(self, req: Request):
        if req.req_id in self._n_samples:
            raise ValueError(
                f"request id {req.req_id} already submitted to this "
                f"scheduler (results are keyed by req_id)")
        if req.search is not None:
            spec = req.search
            if req.n_samples != 1:
                raise ValueError(
                    f"request {req.req_id}: search and n_samples > 1 are "
                    f"mutually exclusive (the tree owns its fan-out)")
            if min(spec.width, spec.expand, spec.step_tokens,
                   spec.max_steps) < 1:
                raise ValueError(
                    f"request {req.req_id}: BeamSpec width/expand/"
                    f"step_tokens/max_steps must all be >= 1")
            if spec.score is None:
                raise ValueError(
                    f"request {req.req_id}: BeamSpec.score is required "
                    f"(batched candidate scoring callback)")
            if spec.step_stop_id < 0:
                raise ValueError(
                    f"request {req.req_id}: BeamSpec.step_stop_id must be "
                    f"a valid token id (the reasoning-step delimiter)")
            if spec.fan > self.n_slots:
                raise ValueError(
                    f"request {req.req_id}: beam fan-out width*expand="
                    f"{spec.fan} exceeds n_slots={self.n_slots}")
        elif req.max_new_tokens < 1:
            raise ValueError(
                f"request {req.req_id}: max_new_tokens must be >= 1, got "
                f"{req.max_new_tokens}")
        if req.n_samples > self.n_slots:
            raise ValueError(
                f"request {req.req_id}: n_samples={req.n_samples} exceeds "
                f"n_slots={self.n_slots}")
        if req.prompt.shape[0] > self.prompt_len:
            raise ValueError(
                f"request {req.req_id}: prompt length {req.prompt.shape[0]} "
                f"exceeds prompt_len={self.prompt_len}")
        # usable sequence length is max_len - 1 (the engine reserves the
        # last slot as the done-row KV scratch position)
        budget = int(req.prompt.shape[0]) + self._max_new(req)
        if budget > self.engine.max_len - 1:
            raise ValueError(
                f"request {req.req_id}: prompt ({req.prompt.shape[0]}) + "
                f"worst-case new tokens ({self._max_new(req)}) = {budget} "
                f"exceeds engine max_len - 1 = {self.engine.max_len - 1}")
        if self.paged:
            worst = self._worst_case_blocks(req)
            if worst > self.engine.pool.capacity:
                raise ValueError(
                    f"request {req.req_id}: worst-case KV footprint "
                    f"({worst} blocks) exceeds pool capacity "
                    f"({self.engine.pool.capacity} blocks) — the request "
                    f"could never run even alone")
        self._n_samples[req.req_id] = (req.search.width if req.search
                                       else max(1, req.n_samples))
        self.queue.append(req)
        if self.tracer is not None:
            self.tracer.event("enqueue", req.req_id, step=self.step_count)

    @staticmethod
    def _fan(req: Request) -> int:
        """Slots the request occupies: beam fan-out, TTS samples, or 1."""
        return req.search.fan if req.search is not None \
            else max(1, req.n_samples)

    @staticmethod
    def _max_new(req: Request) -> int:
        """Worst-case tokens one of the request's rows can generate."""
        if req.search is not None:
            return req.search.max_steps * req.search.step_tokens
        return req.max_new_tokens

    def _worst_case_blocks(self, req: Request) -> int:
        """Blocks the request needs when running alone at full divergence:
        shared full prompt blocks + per-sample tail-CoW and growth."""
        bs = self.engine.pool.block_size
        plen = int(req.prompt.shape[0])
        n = self._fan(req)
        shared = plen // bs  # full prompt blocks stay shared
        per_sample = blocks_for(plen + self._max_new(req), bs) - shared
        return shared + n * per_sample

    def _pad(self, prompt):
        S = self.prompt_len
        out = jnp.full((S,), self.engine.pad_id, jnp.int32)
        return out.at[: prompt.shape[0]].set(prompt), prompt.shape[0]

    # -- admission -----------------------------------------------------------
    def _merge(self, st: GenState, rows: list):
        if self.state is None:
            self.state = self.engine.empty_state(self.n_slots)
        self.state = self.engine.merge_rows(self.state, st,
                                            jnp.array(rows, jnp.int32),
                                            donate=True)

    def _count_prefill(self, batch_size: int):
        """Account one admission prefill call (``n_prefills`` is the
        lifetime scalar, metrics keep the per-call batch sizes)."""
        self.n_prefills += 1
        self.metrics.record_prefill(batch_size)

    def _batch_cap(self, free: list) -> int:
        """Requests one admission prefill may carry this round."""
        if self.max_admission_batch is None:
            return len(free)
        return min(len(free), self.max_admission_batch)

    def _trace_admit(self, req: Request, rows: list, cached_tokens: int = 0):
        """Emit the request's admit/readmit event (readmit when it was
        previously preempted) carrying its slot rows and, on the
        cache-aware path, the lease width it admitted with."""
        tr = self.tracer
        if tr is None:
            return
        kind = "readmit" if req.req_id in self._preempted else "admit"
        self._preempted.discard(req.req_id)
        tr.event(kind, req.req_id, step=self.step_count,
                 rows=[int(r) for r in rows],
                 cache_hit=bool(cached_tokens),
                 lease_tokens=int(cached_tokens))

    def _admit_plain(self, reqs: list, free: list) -> int:
        """One batched prefill + one merge for a run of plain requests
        (prompts share the fixed prompt_len padding)."""
        padded = [self._pad(r.prompt) for r in reqs]
        st = self.engine.prefill(
            jnp.stack([t for t, _ in padded]),
            jnp.array([ln for _, ln in padded], jnp.int32))
        self._count_prefill(len(reqs))
        rows = [free.pop(0) for _ in reqs]
        self._merge(st, rows)
        for req, r in zip(reqs, rows):
            self.slots[r] = _Slot(req=req, sample_idx=0,
                                  admitted_step=self.step_count)
            self._trace_admit(req, [r])
        return sum(ln for _, ln in padded)

    def _admit_group(self, req: Request, free: list) -> int:
        """TTS group or beam tree: one batch-1 prefill forked into
        ``_fan(req)`` slots (samples, or beam lanes sharing the prompt's
        blocks until their first divergent write)."""
        n = self._fan(req)
        toks, length = self._pad(req.prompt)
        st = self.engine.prefill(toks[None], jnp.array([length], jnp.int32))
        self._count_prefill(1)
        if n > 1:
            st = self.engine.fork(st, n)
        rows = [free.pop(0) for _ in range(n)]
        self._merge(st, rows)
        for j, r in enumerate(rows):
            self.slots[r] = _Slot(req=req, sample_idx=j,
                                  admitted_step=self.step_count)
        self._trace_admit(req, rows)
        if req.search is not None:
            self._start_beam(req, rows)
        return int(length)

    def _start_beam(self, req: Request, rows: list) -> None:
        n = len(rows)
        self._beams[req.req_id] = _BeamRun(
            req=req, spec=req.search, rows=list(rows),
            tokens=[[] for _ in range(n)], step_gen=[0] * n,
            stopped=[False] * n)

    def _prompt_blocks(self, req: Request) -> int:
        return blocks_for(int(req.prompt.shape[0]),
                          self.engine.pool.block_size)

    def _insert_prompt(self, toks: list, table_row) -> None:
        """Record a prompt's full blocks in the prefix cache (the single
        insert contract shared by admission and release)."""
        n_ins = len(toks) // self.engine.pool.block_size
        if n_ins:
            self.cache.insert(toks, np.asarray(table_row)[:n_ins])

    def _host_prompt(self, req: Request) -> list:
        return [int(t) for t in np.asarray(jax.device_get(req.prompt)).ravel()]

    def _admit_cached_group(self, req: Request, free: list) -> int:
        """Cache-aware admission of one TTS group or beam tree:
        longest-prefix-match, lease, one partial prefill of the uncached
        suffix, insert the full prompt's blocks back into the tree, fork
        into ``_fan(req)`` slots.  Returns the suffix tokens prefilled,
        or -1 when the pool
        cannot cover the group's *new* blocks even after cache eviction —
        the head then waits (FIFO), holding no lease."""
        toks = self._host_prompt(req)
        plen = len(toks)
        bs = self.engine.pool.block_size
        # cap the match at plen - 1: at least one suffix token must be
        # recomputed to produce the row's next-token logits
        blocks, clen = self.cache.match(toks[:plen - 1])
        need = blocks_for(plen, bs) - clen // bs  # tail CoW + fresh blocks
        if not self.engine.pool.reserve(need):
            if blocks:
                self.engine.pool.release(blocks)  # abandon the lease
            return -1
        # scheduler-level hit accounting covers *admitted* requests only
        # (an abandoned attempt re-matches next step; the cache's own
        # stats() still count every raw lookup)
        self.metrics.cache_lookups += 1
        suffix = toks[clen:]
        padded, _ = self._pad(jnp.asarray(suffix, jnp.int32))
        if clen:
            ctab = np.zeros((1, self.engine.table_width), np.int32)
            ctab[0, :len(blocks)] = blocks
            st = self.engine.prefill(padded[None],
                                     jnp.array([len(suffix)], jnp.int32),
                                     cached_table=ctab,
                                     cached_lens=np.array([clen], np.int64))
        else:
            # miss: the plain paged prefill skips the (masked) full-width
            # prefix gather the partial path would pay for nothing
            st = self.engine.prefill(padded[None],
                                     jnp.array([len(suffix)], jnp.int32))
        self._count_prefill(1)
        if clen:
            self.metrics.cache_hits += 1
            self.metrics.prefill_tokens_saved += clen
        self._insert_prompt(toks, np.asarray(jax.device_get(
            st.cache["table"]))[0])
        n = self._fan(req)
        if n > 1:
            st = self.engine.fork(st, n)
        rows = [free.pop(0) for _ in range(n)]
        self._merge(st, rows)
        for j, r in enumerate(rows):
            self.slots[r] = _Slot(req=req, sample_idx=j,
                                  admitted_step=self.step_count)
        self._trace_admit(req, rows, cached_tokens=clen)
        if req.search is not None:
            self._start_beam(req, rows)
        return len(suffix)

    def _collect_cached_run(self, free: list) -> list:
        """Pop a run of consecutive plain requests off the queue head for
        one batched cache-aware admission round, taking each request's
        lease as it is collected.  Entries are ``{"req", "toks",
        "blocks", "clen"}``.

        Stops at: a TTS group (admitted separately), the batch cap, a
        request the pool cannot cover even after cache eviction (FIFO —
        it stays at the head holding no lease), or a *deferral*: a
        candidate that would match a longer prefix after an earlier
        same-run request's insert than the tree holds now (probed
        lease-free; see ``PrefixCache.potential_match`` — deferral
        preserves one-at-a-time admission's hits, leases and token
        counts exactly, duplicate prompts included: they defer once,
        then batch as partial-tail hits).  Deferred
        candidates admit next round — same step, after this run's
        inserts — with exactly the sequential path's match, so batching
        never shortens a lease or turns a hit into a miss.  Block
        reservations are cumulative across the run: every collected
        lease's new-block need is counted before the next candidate
        reserves."""
        bs = self.engine.pool.block_size
        cap = self._batch_cap(free)
        entries: list[dict] = []
        pending = 0  # new blocks already promised to earlier entries
        while (self.queue and self.queue[0].n_samples <= 1
               and self.queue[0].search is None
               and len(entries) < cap):
            req = self.queue[0]
            toks = self._host_prompt(req)
            plen = len(toks)
            if entries:
                probe = self.cache.probe(toks[:plen - 1])
                if any(self.cache.potential_match(toks[:plen - 1],
                                                  e["toks"]) > probe
                       for e in entries):
                    break  # defer: a same-run insert will serve it better
            blocks, clen = self.cache.match(toks[:plen - 1])
            need = blocks_for(plen, bs) - clen // bs
            if not self.engine.pool.reserve(pending + need):
                if blocks:
                    self.engine.pool.release(blocks)  # abandon the lease
                break  # FIFO: the head waits for blocks
            pending += need
            self.queue.popleft()
            entries.append({"req": req, "toks": toks, "blocks": blocks,
                            "clen": clen})
        return entries

    def _admit_cached_rows(self, entries: list, free: list) -> int:
        """Admit one collected run: bucket the entries by cached-block
        column width (``ceil(clen / block_size)`` — the partial prefill's
        static gather width, so one bucket is one compile shape) and run
        ONE batched prefill per bucket: misses (width 0) through the
        plain paged prefill, hits through the batched partial prefill
        with ragged per-row cached lengths.  All admitted prompts then
        land in the tree via one ``insert_batch``.  Returns the suffix
        tokens prefilled."""
        bs = self.engine.pool.block_size
        buckets: dict[int, list[dict]] = {}
        for e in entries:
            buckets.setdefault(-(-e["clen"] // bs), []).append(e)
        suffix_tokens = 0
        for wc in sorted(buckets):
            group = buckets[wc]
            B = len(group)
            suffixes = [e["toks"][e["clen"]:] for e in group]
            toks = jnp.stack([self._pad(jnp.asarray(s, jnp.int32))[0]
                              for s in suffixes])
            lens = jnp.array([len(s) for s in suffixes], jnp.int32)
            if wc:
                ctab = np.zeros((B, self.engine.table_width), np.int32)
                for i, e in enumerate(group):
                    ctab[i, :len(e["blocks"])] = e["blocks"]
                st = self.engine.prefill(
                    toks, lens, cached_table=ctab,
                    cached_lens=np.array([e["clen"] for e in group],
                                         np.int64))
            else:
                st = self.engine.prefill(toks, lens)
            self._count_prefill(B)
            table = np.asarray(jax.device_get(st.cache["table"]))
            self.cache.insert_batch(
                (e["toks"], table[i, :len(e["toks"]) // bs])
                for i, e in enumerate(group))
            rows = [free.pop(0) for _ in range(B)]
            self._merge(st, rows)
            for e, r in zip(group, rows):
                self.slots[r] = _Slot(req=e["req"], sample_idx=0,
                                      admitted_step=self.step_count)
                self._trace_admit(e["req"], [r], cached_tokens=e["clen"])
                self.metrics.cache_lookups += 1
                if e["clen"]:
                    self.metrics.cache_hits += 1
                    self.metrics.prefill_tokens_saved += e["clen"]
            suffix_tokens += sum(len(s) for s in suffixes)
        return suffix_tokens

    def _admit(self) -> tuple:
        """Fill free slots from the queue (FIFO). Consecutive plain
        requests admitted in the same step share one batched prefill; a
        TTS group prefills once and forks. Returns (requests admitted,
        prompt tokens prefilled).

        Paged: admission additionally stops (FIFO, no skipping) when the
        pool cannot cover the head request's prompt blocks — decode-time
        growth is handled by preemption, not reservation.  With a prefix
        cache attached, runs of plain requests admit through the batched
        cache-aware partial-prefill path (one prefill per cached-width
        bucket; see :meth:`_collect_cached_run`), TTS groups one at a
        time."""
        free = [i for i, s in enumerate(self.slots) if s is None]
        admitted = prefill_tokens = 0
        if self.cache is not None:
            while self.queue and free:
                if self._fan(self.queue[0]) > len(free):
                    break  # FIFO: the group waits for enough free slots
                if (self.queue[0].n_samples > 1
                        or self.queue[0].search is not None):
                    got = self._admit_cached_group(self.queue[0], free)
                    if got < 0:
                        break  # FIFO: the head waits for blocks
                    self.queue.popleft()
                    admitted += 1
                    prefill_tokens += got
                    continue
                entries = self._collect_cached_run(free)
                if not entries:
                    break  # FIFO: the head waits for blocks
                prefill_tokens += self._admit_cached_rows(entries, free)
                admitted += len(entries)
            return admitted, prefill_tokens
        blk_budget = self.engine.pool.free_blocks if self.paged else None
        while self.queue and free:
            if self._fan(self.queue[0]) > len(free):
                break  # FIFO: the group waits for enough free slots
            if self.paged and self._prompt_blocks(self.queue[0]) > blk_budget:
                break  # FIFO: the head waits for blocks to free up
            if self.queue[0].n_samples > 1 or self.queue[0].search is not None:
                req = self.queue.popleft()
                if self.paged:
                    blk_budget -= self._prompt_blocks(req)
                prefill_tokens += self._admit_group(req, free)
                admitted += 1
                continue
            plain = []
            while (self.queue and self.queue[0].n_samples <= 1
                   and self.queue[0].search is None
                   and len(plain) < self._batch_cap(free)):
                if self.paged:
                    need = self._prompt_blocks(self.queue[0])
                    if need > blk_budget:
                        break
                    blk_budget -= need
                plain.append(self.queue.popleft())
            if not plain:
                break
            prefill_tokens += self._admit_plain(plain, free)
            admitted += len(plain)
        return admitted, prefill_tokens

    # -- release -------------------------------------------------------------
    def _release(self, row: int, reason: str, logprob_sum: float,
                 n_gen: int):
        slot = self.slots[row]
        sample = CompletedSample(
            req_id=slot.req.req_id, sample_idx=slot.sample_idx,
            tokens=slot.tokens, logprob_sum=logprob_sum, n_gen=n_gen,
            finish_reason=reason, admitted_step=slot.admitted_step,
            first_decode_step=slot.first_decode_step,
            finished_step=self.step_count)
        done = self.completed.setdefault(slot.req.req_id, [])
        done.append(sample)
        self.metrics.completed_samples += 1
        tr = self.tracer
        if tr is not None:
            tr.event("release", slot.req.req_id, step=self.step_count,
                     rows=[int(row)], reason=reason)
        if len(done) == max(1, slot.req.n_samples):
            self.metrics.completed_requests += 1
            if tr is not None:
                # the request is complete: derive its latency record now,
                # after its final release event (e2e closes on it)
                self.metrics.latencies.append(
                    tr.request_latency(slot.req.req_id))
        self.slots[row] = None

    # -- preemption (paged out-of-blocks) ------------------------------------
    def _preempt_youngest(self):
        """Free the youngest live request's slots and blocks and requeue it
        at the queue head (it reruns from scratch).  Raises when only one
        live request remains — preempting it could never unblock decoding,
        the pool is simply too small for the workload."""
        by_req: dict[int, list[int]] = {}
        for i, s in enumerate(self.slots):
            if s is not None:
                by_req.setdefault(s.req.req_id, []).append(i)
        if len(by_req) <= 1:
            raise RuntimeError(
                "KV pool exhausted with a single live request — pool too "
                "small to make progress (raise n_blocks)")
        victim = max(by_req, key=lambda rid: (
            self.slots[by_req[rid][0]].admitted_step, rid))
        rows = by_req[victim]
        req = self.slots[rows[0]].req
        self.state = self.engine.release_rows(self.state, rows)
        for r in rows:
            self.slots[r] = None
        # discard any already-finished samples of the victim; the rerun
        # regenerates every sample (deterministic under greedy sampling).
        # A beam victim drops its whole in-flight tree the same way: its
        # lanes' blocks just freed above, and re-admission restarts the
        # search from the prompt.
        self._beams.pop(victim, None)
        dropped = self.completed.pop(victim, [])
        self.metrics.completed_samples -= len(dropped)
        self.queue.appendleft(req)
        self.metrics.preemptions += 1
        if self.tracer is not None:
            self.tracer.event("preempt", victim, step=self.step_count,
                              rows=[int(r) for r in rows])
            self._preempted.add(victim)
            # the rerun decodes its first token afresh: re-arm the event
            self._ft_emitted.discard(victim)

    # -- beam-search (tree) workload -----------------------------------------
    def _row_stops(self):
        """Per-row extra stop ids for the decode step: each in-flight
        tree's lanes stop at its step delimiter; every other row gets -1
        (no extra stop).  None when no tree is in flight (keeps the
        row_stops-free jit trace for pure chat/BoN traffic)."""
        if not self._beams:
            return None
        stops = np.full((self.n_slots,), -1, np.int32)
        for run in self._beams.values():
            stops[run.rows] = run.spec.step_stop_id
        return jnp.asarray(stops)

    def _beam_track(self, toks_h, done_h) -> tuple:
        """Advance every in-flight tree's host bookkeeping after a decode
        step.  Returns (rows to freeze, runs at their scoring boundary):
        a lane that exhausts its step token budget without sampling the
        delimiter is *frozen* (done on device, blocks kept) so it stops
        advancing while sibling lanes finish their step."""
        tr = self.tracer
        to_freeze: list = []
        boundaries: list = []
        for run in self._beams.values():
            advanced = False
            for j, r in enumerate(run.rows):
                if run.stopped[j]:
                    continue
                advanced = True
                run.tokens[j].append(int(toks_h[r]))
                run.step_gen[j] += 1
                if bool(done_h[r]):      # sampled '.'/eos this step
                    run.stopped[j] = True
                elif run.step_gen[j] >= run.spec.step_tokens:
                    run.stopped[j] = True
                    to_freeze.append(r)
            if advanced and tr is not None:
                tr.event("token", run.req.req_id, step=self.step_count)
            if all(run.stopped):
                boundaries.append(run)
        return to_freeze, boundaries

    def _beam_boundary(self, run: _BeamRun):
        """Scoring boundary: one batched score call over all fan
        candidates, then either final selection or a prune+expand commit.

        The commit is ONE ``engine.reorder`` whose index is identity
        outside the tree's rows and maps lane j to survivor ``keep[j //
        expand]`` inside them — on the paged pool the reorder's refcount
        fixup *is* the tree update: losing lanes' blocks drop to refcount
        zero and free (prune), each survivor's blocks gain ``expand - 1``
        references (expansion, zero KV bytes copied) and diverge later
        via copy-on-write."""
        spec, rows = run.spec, run.rows
        tr = self.tracer
        lp, ng = (np.asarray(a) for a in jax.device_get(
            (self.state.logprob_sum, self.state.n_gen)))
        if tr is not None:
            t0 = tr.now()
        scores = np.asarray(
            spec.score([list(t) for t in run.tokens], lp[rows], ng[rows]),
            np.float64).ravel()
        if tr is not None:
            tr.span("prm", t0, step=self.step_count, candidates=len(rows))
        self.metrics.prm_batches += 1
        self.metrics.prm_candidates += len(rows)
        # stable sort: ties keep the lowest lane index, matching the
        # direct path's jnp.argsort over -scores
        keep = np.argsort(-scores, kind="stable")[:spec.width]
        run.beam_step += 1
        self.metrics.beam_boundaries += 1
        if tr is not None:
            tr.event("beam_boundary", run.req.req_id, step=self.step_count,
                     boundary=run.beam_step)
        survivors = [list(run.tokens[int(k)]) for k in keep]
        if run.beam_step >= spec.max_steps or (
                spec.finished is not None and spec.finished(survivors)):
            self._finish_beam(run, keep, survivors, lp, ng)
            return
        idx = np.arange(self.n_slots, dtype=np.int32)
        for j in range(len(rows)):
            idx[rows[j]] = rows[int(keep[j // spec.expand])]
        self.state = self.engine.reorder(self.state, jnp.asarray(idx))
        self.metrics.beam_expansions += len(rows) - spec.width
        self.metrics.beam_prunes += len(rows) - spec.width
        run.tokens = [list(survivors[j // spec.expand])
                      for j in range(len(rows))]
        run.step_gen = [0] * len(rows)
        run.stopped = [False] * len(rows)
        self.state = self.engine.resume_rows(self.state, rows)
        if tr is not None:
            tr.event("resume", run.req.req_id, step=self.step_count,
                     rows=[int(r) for r in rows])

    def _finish_beam(self, run: _BeamRun, keep, survivors, lp, ng):
        """Final selection: score the ``width`` survivors, record the
        choice in ``beam_results``, emit one ``CompletedSample`` per
        survivor and release every lane's blocks."""
        spec, rows, req = run.spec, run.rows, run.req
        tr = self.tracer
        final = spec.final_score or spec.score
        krows = [rows[int(k)] for k in keep]
        if tr is not None:
            t0 = tr.now()
        final_scores = np.asarray(
            final(survivors, lp[krows], ng[krows]), np.float64).ravel()
        if tr is not None:
            tr.span("prm", t0, step=self.step_count,
                    candidates=len(survivors))
        self.metrics.prm_batches += 1
        self.metrics.prm_candidates += len(survivors)
        if self.cache is not None:
            # the tree's full prompt blocks sit below every lane's write
            # frontier (never CoW'd) — reusable by later requests
            table = np.asarray(jax.device_get(self.state.cache["table"]))
            self._insert_prompt(self._host_prompt(req), table[rows[0]])
        first = self.slots[rows[0]]
        self.state = self.engine.release_rows(self.state, rows)
        done_list = self.completed.setdefault(req.req_id, [])
        for j, k in enumerate(keep):
            r = rows[int(k)]
            done_list.append(CompletedSample(
                req_id=req.req_id, sample_idx=j, tokens=list(survivors[j]),
                logprob_sum=float(lp[r]), n_gen=int(ng[r]),
                finish_reason="beam", admitted_step=first.admitted_step,
                first_decode_step=first.first_decode_step,
                finished_step=self.step_count))
        self.beam_results[req.req_id] = {
            "scores": [float(s) for s in final_scores],
            "chosen": int(np.argmax(final_scores)),
            "beam_steps": run.beam_step,
        }
        self.metrics.completed_samples += len(survivors)
        self.metrics.completed_requests += 1
        if tr is not None:
            tr.event("release", req.req_id, step=self.step_count,
                     rows=[int(r) for r in rows], reason="beam")
            self.metrics.latencies.append(tr.request_latency(req.req_id))
        for r in rows:
            self.slots[r] = None
        del self._beams[req.req_id]

    # -- the admit -> decode -> release cycle --------------------------------
    def _record_canary(self, live: list) -> None:
        """Drift comparison for a canary step: the production step's new
        logits vs the engine's exact-path logits, over the live rows.
        Frozen/done rows carry identically-frozen pending logits in both
        paths, so every live row is comparable.  Under the default "xla"
        paged-attention impl the two jits compile the same HLO and the
        comparison must be exact (flip rate 0 — the CI row asserts it);
        under kernel/kernel_lut impls this measures the fused kernels'
        LUT-softmax/dequant drift online."""
        prof = self.profiler
        exact = self.engine.last_canary_logits
        self.engine.last_canary_logits = None
        ex, pr = jax.device_get((exact, self.state.pending_logits))
        rows = np.asarray(live, np.int64)
        ex = np.asarray(ex)[rows]
        pr = np.asarray(pr)[rows]
        max_err = float(np.max(np.abs(ex - pr))) if rows.size else 0.0
        flips = (int(np.sum(np.argmax(ex, -1) != np.argmax(pr, -1)))
                 if rows.size else 0)
        prof.record_canary(
            max_logit_err=max_err, flips=flips, rows=int(rows.size),
            kv_err_per_layer=self.engine.kv_roundtrip_error())
        if self.tracer is not None:
            self.tracer.gauge("canary_max_logit_err", max_err)
            self.tracer.gauge("canary_flips", flips)

    # -- speculative rounds --------------------------------------------------
    def _spec_eligible(self, slot: _Slot) -> bool:
        """Rows speculation may commit > 1 token for: plain chat/BoN rows
        that did not opt out.  Beam lanes stay one-token-per-round (their
        freeze/boundary bookkeeping is stepwise)."""
        return slot.req.search is None and not slot.req.no_spec

    def _sync_draft_admissions(self, live: list) -> None:
        """Prefill newly admitted rows' prompts into the persistent dense
        draft engine (draft-model mode) so its KV shadows the target's
        committed context from the prompt on.  Rows admitted for beam or
        opted-out requests are skipped — the drafter never proposes for
        them."""
        rows = [i for i in live
                if self.slots[i].first_decode_step < 0
                and self._spec_eligible(self.slots[i])]
        if not rows:
            return
        padded = [self._pad(self.slots[i].req.prompt) for i in rows]
        st = self._draft.prefill(
            jnp.stack([t for t, _ in padded]),
            jnp.array([ln for _, ln in padded], jnp.int32))
        self._draft_state = self._draft.merge_rows(
            self._draft_state, st, jnp.array(rows, jnp.int32), donate=True)

    def _draft_proposals_self(self, xs, n_prop, eligible, W, rng, sc):
        """Self-drafting: run W-1 plain greedy steps on a refcount-bumped
        snapshot of the target state (the draft lane is a fork; its
        divergent writes CoW, its release frees — target KV untouched).
        Fills ``xs[:, 1:]`` in place; returns False when the snapshot ran
        out of blocks mid-draft (round falls back to a plain step)."""
        eng = self.engine
        snap = eng.spec_snapshot(self.state, eligible)
        try:
            dts = []
            for m in range(1, W):
                frz = [i for i in eligible if int(n_prop[i]) == m]
                if frz:
                    snap = eng.freeze_rows(snap, frz)
                snap, dt = eng.step(snap, rng, sc, stop_ids=self.stop_ids)
                dts.append(dt)
            # each row's last proposal comes from its (possibly frozen)
            # pending logits — the distribution after its final sampled
            # draft token
            final = jnp.argmax(snap.pending_logits, axis=-1)
            dts_h, final_h = (np.asarray(a) for a in jax.device_get(
                (jnp.stack(dts), final)))
        except OutOfBlocks:
            # mid-draft exhaustion: drop the snapshot's references and
            # let the caller fall back to a plain step (which has its own
            # preemption path) — nothing leaks, target state untouched
            eng.release_rows(snap, eligible)
            return False
        eng.release_rows(snap, eligible)
        for i in eligible:
            npi = int(n_prop[i])
            for c in range(1, npi - 1):
                xs[i, c] = int(dts_h[c][i])  # step c+1 sampled column c
            xs[i, npi - 1] = int(final_h[i])
        return True

    def _draft_proposals_model(self, xs, n_prop, eligible, W, t0, clen_h,
                               rng, sc):
        """Draft-model proposals: resync the dense drafter's lengths to
        the target's committed context, force-feed the round's first
        token, then run W-1 cheap greedy steps — every proposal column is
        *written* to draft KV so a fully-accepted round leaves no hole.
        Returns the advanced draft state (rolled back to the acceptance
        point by the caller only after the verify succeeds)."""
        de = self._draft
        dn = np.ones(self.n_slots, bool)
        dn[eligible] = False
        ds = dataclasses.replace(
            self._draft_state,
            cache_len=jnp.asarray(clen_h.astype(np.int32)),
            done=jnp.asarray(dn))
        ds = de.forced_step(ds, t0)
        dts = []
        for m in range(1, W):
            frz = [i for i in eligible if int(n_prop[i]) == m]
            if frz:
                ds = de.freeze_rows(ds, frz)
            ds, dt = de.step(ds, rng, sc, stop_ids=self.stop_ids)
            dts.append(dt)
        dts_h = np.asarray(jax.device_get(jnp.stack(dts)))
        for i in eligible:
            for c in range(1, int(n_prop[i])):
                xs[i, c] = int(dts_h[c - 1][i])  # step c sampled column c
        return ds

    def _spec_step(self, rng, sc: SamplerConfig):
        """One draft-then-verify round over the live batch.  Returns
        ``(xs, a)`` — proposals and per-row accepted counts — or None to
        fall back to a plain step (no row can use > 1 proposal, or the
        self-draft ran out of blocks).  An :class:`OutOfBlocks` from the
        verify plan propagates to ``step_once``'s preempt-retry loop; the
        whole round reruns after preemption, and any draft snapshot was
        already released, so an aborted round leaks nothing."""
        eng = self.engine
        live = [i for i, s in enumerate(self.slots) if s is not None]
        done_h, clen_h = (np.asarray(a) for a in jax.device_get(
            (self.state.done, self.state.cache_len)))
        n_prop = np.zeros(self.n_slots, np.int32)
        for i in live:
            if done_h[i]:
                continue  # frozen beam lane: rides along, commits nothing
            slot = self.slots[i]
            if not self._spec_eligible(slot):
                n_prop[i] = 1  # plain-step-equivalent lane in the verify
            else:
                rem = slot.req.max_new_tokens - len(slot.tokens)
                n_prop[i] = max(1, min(self.spec.k, rem))
        W = int(n_prop.max(initial=0))
        if W < 2:
            return None
        eligible = [i for i in live if n_prop[i] > 1]
        # column 0: the token a plain greedy step would commit right now
        t0 = np.asarray(jax.device_get(
            jnp.argmax(self.state.pending_logits, axis=-1))).astype(
                np.int32)
        xs = np.full((self.n_slots, W), eng.pad_id, np.int32)
        for i in live:
            if n_prop[i]:
                xs[i, 0] = t0[i]
        ds = None
        if self.spec.self_draft:
            if not self._draft_proposals_self(xs, n_prop, eligible, W,
                                              rng, sc):
                return None
        else:
            ds = self._draft_proposals_model(
                xs, n_prop, eligible, W, jnp.asarray(t0), clen_h, rng, sc)
        self.state, commit_h = eng.spec_verify(
            self.state, xs, n_prop, row_stops=self._row_stops(),
            stop_ids=self.stop_ids)
        a = commit_h.sum(axis=1).astype(np.int64)
        # reclaim the rejected suffixes' blocks (rows live at verify time
        # only — frozen lanes keep their blocks like any frozen row)
        self.state = eng.trim_rows(
            self.state, [i for i in live if not done_h[i]])
        if ds is not None:
            # roll the drafter back to the acceptance point: lengths to
            # the target's new lengths, all rows idle until the next
            # round resyncs.  Only committed once the verify succeeded —
            # a verify OutOfBlocks keeps the pre-round _draft_state.
            self._draft_state = dataclasses.replace(
                ds,
                cache_len=jnp.asarray((clen_h + a).astype(np.int32)),
                done=jnp.ones((self.n_slots,), bool))
        m = self.metrics
        m.spec_rounds += 1
        for i in eligible:
            m.spec_draft_tokens += int(n_prop[i]) - 1
            m.spec_accepted_tokens += int(a[i]) - 1
            m.spec_committed_tokens += int(a[i])
            m.spec_row_steps += 1
        if self.tracer is not None:
            self.tracer.gauge("spec_accepted_tokens",
                              int(a[np.asarray(eligible, np.int64)].sum())
                              if eligible else 0)
        return xs, a

    def step_once(self, rng, sc: SamplerConfig = SamplerConfig()) -> bool:
        """One scheduler step. Returns False when idle (nothing admitted,
        nothing decoding).

        Wall time is measured *here* (not in :meth:`run`), so callers
        driving ``step_once`` directly — controller loops, tests — get
        real ``wall_s``/throughput numbers: each step's host time lands
        in ``StepRecord.wall_s`` and accumulates into
        ``metrics.wall_s``."""
        tr = self.tracer
        prof = self.profiler
        t_wall = self._clock()
        if tr is not None:
            t_step = tr.now()
        if prof is not None:
            prof.begin_step()
        admitted, prefill_tokens = self._admit()
        if tr is not None:
            tr.span("admit", t_step, step=self.step_count,
                    admitted=admitted, prefill_tokens=prefill_tokens)
        live = [i for i, s in enumerate(self.slots) if s is not None]
        if not live:
            return False
        if self._draft is not None:
            self._sync_draft_admissions(live)
        for i in live:
            if self.slots[i].first_decode_step < 0:
                self.slots[i].first_decode_step = self.step_count
        canary = (prof is not None and self.paged and prof.want_canary())
        # speculative rounds need greedy sampling (acceptance compares
        # argmaxes) and skip canary steps, whose exact-path replica is
        # defined over the single-token decode step
        spec_round = (self.spec is not None and sc.greedy and self.paged
                      and not canary)
        spec_out = None
        while True:
            try:
                if tr is not None:
                    t_dec = tr.now()
                if spec_round:
                    spec_out = self._spec_step(rng, sc)
                if spec_out is None:
                    self.state, toks = self.engine.step(
                        self.state, rng, sc, stop_ids=self.stop_ids,
                        row_stops=self._row_stops(), canary=canary)
                break
            except OutOfBlocks:
                # atomic: the failed prepare touched neither pool nor state
                self._preempt_youngest()
                live = [i for i, s in enumerate(self.slots) if s is not None]
        if canary and self.engine.last_canary_logits is not None:
            self._record_canary(live)
        if spec_out is not None:
            xs_h, a_h = spec_out
            toks_h = xs_h[:, 0]  # beam tracking sees the stepwise token
            done_h, lp_h, ng_h = jax.device_get(
                (self.state.done, self.state.logprob_sum,
                 self.state.n_gen))
        else:
            a_h = None
            toks_h, done_h, lp_h, ng_h = jax.device_get(
                (toks, self.state.done, self.state.logprob_sum,
                 self.state.n_gen))
        if tr is not None:
            # closes after the device_get sync above, so the span is the
            # host-visible latency of this decode step
            tr.span("decode", t_dec, step=self.step_count, batch=len(live))
            seen: set = set()
            for i in live:
                rid = self.slots[i].req.req_id
                if (self.slots[i].first_decode_step == self.step_count
                        and rid not in self._ft_emitted):
                    self._ft_emitted.add(rid)
                    tr.event("first_token", rid, step=self.step_count)
                # every live non-beam row sampled a token this step (stop
                # tokens included); beam lanes are tracked in _beam_track
                if self.slots[i].req.search is None and rid not in seen:
                    seen.add(rid)
                    tr.event("token", rid, step=self.step_count)
        released = []
        over_budget = []
        released_reqs: list[tuple] = []
        for i in live:
            slot = self.slots[i]
            if slot.req.search is not None:
                continue  # beam lanes: tracked per-tree below
            # the tokens this row committed this step: the accepted
            # prefix of its proposals on a speculative round, else the
            # one sampled token
            run = ([int(t) for t in xs_h[i, :int(a_h[i])]]
                   if a_h is not None else [int(toks_h[i])])
            if bool(done_h[i]):          # committed a stop id this step
                slot.tokens.extend(run[:-1])  # stop token excluded
                released_reqs.append((i, slot.req))
                self._release(i, "stop", float(lp_h[i]), int(ng_h[i]))
                released.append(i)
                continue
            slot.tokens.extend(run)
            if len(slot.tokens) >= slot.req.max_new_tokens:
                over_budget.append(i)
                released.append(i)
                released_reqs.append((i, slot.req))
                self._release(i, "length", float(lp_h[i]), int(ng_h[i]))
        if self.paged and released:
            if self.cache is not None:
                # re-insert completed prompt prefixes before the rows'
                # blocks go back to the pool: normally an idempotent LRU
                # touch (admission already inserted), but it restores
                # entries that pool pressure evicted mid-flight — the
                # blocks still hold valid prompt KV (full prompt blocks
                # sit below the write frontier and are never CoW'd)
                table = np.asarray(jax.device_get(self.state.cache["table"]))
                seen: set = set()
                for r, req in released_reqs:
                    if req.req_id in seen:  # one insert per group, not row
                        continue
                    seen.add(req.req_id)
                    toks = [int(t) for t in
                            np.asarray(jax.device_get(req.prompt)).ravel()]
                    self._insert_prompt(toks, table[r])
            # return every released row's blocks to the pool (stop rows
            # included — done alone doesn't free paged memory)
            self.state = self.engine.release_rows(self.state, released)
        elif over_budget:
            # freeze the rows so they stop growing until a new occupant
            # overwrites them at admission
            self.state = self.engine.release_rows(self.state, over_budget)
        if self._beams:
            to_freeze, boundaries = self._beam_track(toks_h, done_h)
            if to_freeze:
                self.state = self.engine.freeze_rows(self.state, to_freeze)
                if tr is not None:
                    by_req: dict = {}
                    for r in to_freeze:
                        by_req.setdefault(self.slots[r].req.req_id,
                                          []).append(int(r))
                    for rid, rs in by_req.items():
                        tr.event("freeze", rid, step=self.step_count,
                                 rows=rs)
            for run in boundaries:
                self._beam_boundary(run)
        if self.paged:
            # pool.peak_in_use also sees intra-step highs (CoW before
            # release), so this is the true byte high-water mark
            self.metrics.peak_kv_bytes = max(
                self.metrics.peak_kv_bytes,
                self.engine.pool.peak_in_use * self._block_bytes)
        if tr is not None:
            tr.gauge("occupancy", len(live))
            if self.paged:
                tr.gauge("free_blocks", self.engine.pool.free_blocks)
                # device-memory watermark: the storage this pool physically
                # backs vs the bytes its live blocks actually hold — the
                # counter-track pair that shows memory pressure alongside
                # occupancy in Perfetto
                tr.gauge("pool_reserved_bytes",
                         self.engine.pool.n_blocks * self._block_bytes)
                tr.gauge("kv_bytes_in_use",
                         self.engine.pool.blocks_in_use * self._block_bytes)
                if self.cache is not None:
                    tr.gauge("cache_pinned_blocks",
                             self.cache.n_cached_blocks)
        wall = self._clock() - t_wall
        if prof is not None:
            prof.end_step(wall)
            if tr is not None:
                # attributed device cost as counter tracks, so host spans
                # and kernel time line up on one Perfetto timeline
                for k, v in prof.last_step_gauges.items():
                    tr.gauge(k, v)
        self.metrics.wall_s += wall
        self.metrics.record(StepRecord(
            step=self.step_count, occupancy=len(live), admitted=admitted,
            prefill_tokens=prefill_tokens, wall_s=wall,
            decode_tokens=(int(a_h[np.asarray(live, np.int64)].sum())
                           if a_h is not None else None)))
        if tr is not None:
            tr.span("step", t_step, step=self.step_count,
                    occupancy=len(live))
        self.step_count += 1
        return True

    # -- drain ---------------------------------------------------------------
    def run(self, rng, sc: SamplerConfig = SamplerConfig(),
            max_steps: int = 4096):
        """Drain the queue.  Returns ``{req_id: tokens}`` for plain requests
        and ``{req_id: [tokens] * n_samples}`` for TTS requests (sample
        order).  Rich per-sample records stay in ``self.completed``.

        Raises ``RuntimeError`` if ``max_steps`` elapses with work still
        queued or decoding (finished requests remain in ``self.completed``
        and the drain can be resumed with another ``run`` call)."""
        steps = 0
        while steps < max_steps:
            rng, key = jax.random.split(rng)
            # step_once accumulates per-step wall time into metrics.wall_s
            if not self.step_once(key, sc):
                break
            steps += 1
        live = sum(1 for s in self.slots if s is not None)
        if self.queue or live:
            raise RuntimeError(
                f"scheduler truncated at max_steps={max_steps}: "
                f"{len(self.queue)} queued + {live} decoding requests "
                f"unfinished ({len(self.completed)} request ids completed; "
                f"re-run to continue)")
        results = {}
        for req_id, samples in self.completed.items():
            ordered = sorted(samples, key=lambda s: s.sample_idx)
            if self._n_samples.get(req_id, 1) == 1:
                results[req_id] = ordered[0].tokens
            else:
                results[req_id] = [s.tokens for s in ordered]
        return results
