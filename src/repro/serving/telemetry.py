"""Request-lifecycle tracing and tail-latency derivation for the serving
stack.

The ROADMAP's SLA item says it outright: throughput rows exist, tail
latency is invisible.  This module makes every stage of a request's life
observable without touching the hot path when disabled:

* :class:`Tracer` — an append-only recorder of **typed, monotonic-clocked
  lifecycle events** (``enqueue -> admit[cache hit / lease width] ->
  first_token -> per-step token -> freeze/resume -> beam_boundary ->
  preempt/readmit -> release``), **phase spans** inside the scheduler and
  engine (``step ⊃ {admit ⊃ prefill, decode ⊃ plan, prm}`` — admission
  planning, prefill calls, the decode step, the paged CoW/alloc host
  planning, PRM score callbacks) and **per-step gauges** (free pool
  blocks, prefix-cache pinned blocks, slot occupancy).
* :meth:`Tracer.request_latency` — derives one
  :class:`RequestLatency` record per request from the event stream:
  queue wait (enqueue -> first admit), TTFT (enqueue -> first token),
  inter-token gaps, preemption-added delay (preempt -> readmit), and
  end-to-end time.  ``SchedulerMetrics`` aggregates these into
  ``ttft_p50/p90/p99``, ``itl_p50/p99``, ``queue_wait_p50/p99`` and
  ``step_time_p50/p99`` summary keys.
* :meth:`Tracer.to_chrome_trace` — exports a **Chrome trace-event JSON**
  loadable in Perfetto (https://ui.perfetto.dev): phase spans as nested
  slices on a ``phases`` track, each decode slot as its own track whose
  slices are the requests occupying it (lifecycle instants riding on
  top), and the gauges as counter tracks.  ``launch/serve.py --trace
  out.json`` writes one; ``python -m repro.serving.telemetry out.json``
  validates it (the CI schema check — see :func:`validate_chrome_trace`).

**Clock semantics.**  Every timestamp is ``clock() - t0`` seconds where
``clock`` is injectable (default ``time.perf_counter`` — monotonic,
sub-microsecond).  Tests inject a deterministic counter so latency
derivations are exact; the scheduler uses the same clock for its per-step
``wall_s``, so ``step_time_*`` percentiles are deterministic under an
injected clock too.  Spans measure *host-side* time: the decode span
closes after the scheduler's device sync (``jax.device_get`` of the
step's tokens), so it reflects real step latency, while the prefill span
closes at dispatch return (jax is async; the next sync absorbs the
device tail).

**Zero overhead when disabled.**  The scheduler and engine hold
``tracer=None`` by default and guard every touchpoint with ``if tracer
is not None`` — no events, no allocations, bit-identical outputs.
"""
from __future__ import annotations

import json
import sys
import time
from dataclasses import dataclass, field
from typing import Callable, Optional

import numpy as np

# Event kinds the scheduler/engine emit, in (one possible) lifecycle
# order.  ``token`` is per request per decode step; the rest are
# transitions.  Exporters and tests should treat unknown kinds as valid
# (forward compatibility), but everything the stack emits today is here.
EVENT_KINDS = (
    "enqueue",        # Request.submit; args: —
    "admit",          # slots filled; args: rows, cache_hit, lease_tokens
    "readmit",        # admit of a previously preempted request
    "first_token",    # the request's first decode token this admission
    "token",          # >= 1 of the request's rows sampled a token
    "freeze",         # beam lanes parked at their step budget; args: rows
    "resume",         # frozen lanes re-armed after a boundary; args: rows
    "beam_boundary",  # one prune+expand commit; args: boundary
    "preempt",        # out-of-blocks victim; args: rows
    "release",        # rows freed; args: rows, reason
)

SPAN_NAMES = ("step", "admit", "prefill", "decode", "plan", "prm")


@dataclass
class Event:
    """One lifecycle event: ``kind`` at monotonic time ``t`` (seconds
    since the tracer's epoch), attributed to ``req_id`` (-1 = none) at
    scheduler step ``step`` (-1 = outside the step loop)."""

    kind: str
    t: float
    req_id: int = -1
    step: int = -1
    args: dict = field(default_factory=dict)


@dataclass
class Span:
    """One completed phase span ``[t0, t1]`` on the scheduler's phase
    timeline (spans nest: ``step`` contains ``admit``/``decode``/``prm``,
    ``admit`` contains ``prefill``, ``decode`` contains ``plan``)."""

    name: str
    t0: float
    t1: float
    step: int = -1
    args: dict = field(default_factory=dict)


@dataclass
class Gauge:
    """One sample of a per-step gauge (counter track in the export)."""

    name: str
    t: float
    value: float


@dataclass(frozen=True)
class RequestLatency:
    """Per-request latency record derived from the event stream.

    All values in seconds.  ``gaps`` are the inter-token intervals
    (diffs of consecutive ``token`` event times — across a preemption
    they include the requeue wait, which *is* the latency the client
    saw); ``preempt_delay`` is the total time spent requeued between
    ``preempt`` and the matching ``readmit``."""

    req_id: int
    queue_wait: float            # enqueue -> first admit
    ttft: float                  # enqueue -> first decode token
    gaps: tuple                  # inter-token intervals
    itl_mean: float
    itl_p99: float
    preempt_delay: float         # sum of preempt -> readmit waits
    e2e: float                   # enqueue -> last release


def percentile(xs, q: float) -> float:
    """Linear-interpolated percentile; 0.0 on empty input (so summary
    keys are safe on drains that admitted nothing)."""
    xs = [x for x in xs]
    if not xs:
        return 0.0
    return float(np.percentile(np.asarray(xs, np.float64), q))


class Tracer:
    """Append-only recorder of events, phase spans and gauges.

    ``clock`` is any zero-arg callable returning monotonically
    non-decreasing floats (seconds).  All recorded times are relative to
    the clock's value at construction, so traces start at t=0.
    """

    def __init__(self, clock: Callable[[], float] = time.perf_counter):
        self.clock = clock
        self._t0 = clock()
        self.events: list[Event] = []
        self.spans: list[Span] = []
        self.gauges: list[Gauge] = []
        self._by_req: dict[int, list[Event]] = {}

    # -- recording -----------------------------------------------------------
    def now(self) -> float:
        """Seconds since the tracer's epoch (monotonic)."""
        return self.clock() - self._t0

    def event(self, kind: str, req_id: int = -1, step: int = -1,
              **args) -> Event:
        ev = Event(kind=kind, t=self.now(), req_id=req_id, step=step,
                   args=args)
        self.events.append(ev)
        if req_id >= 0:
            self._by_req.setdefault(req_id, []).append(ev)
        return ev

    def span(self, name: str, t0: float, step: int = -1, **args) -> Span:
        """Record a completed span that started at ``t0`` (a prior
        :meth:`now` value) and ends now."""
        sp = Span(name=name, t0=t0, t1=self.now(), step=step, args=args)
        self.spans.append(sp)
        return sp

    def gauge(self, name: str, value) -> None:
        self.gauges.append(Gauge(name=name, t=self.now(),
                                 value=float(value)))

    # -- derivation ----------------------------------------------------------
    def request_events(self, req_id: int) -> list[Event]:
        return list(self._by_req.get(req_id, ()))

    def request_latency(self, req_id: int) -> RequestLatency:
        """Derive the request's latency record from its events.  Requires
        at least an ``enqueue``; missing downstream events yield 0.0 for
        the intervals they would bound."""
        evs = self._by_req.get(req_id)
        if not evs:
            raise ValueError(f"no events recorded for request {req_id}")
        t_enq = t_admit = t_first = t_rel = None
        toks: list[float] = []
        pending_preempt: Optional[float] = None
        preempt_delay = 0.0
        for ev in evs:
            if ev.kind == "enqueue" and t_enq is None:
                t_enq = ev.t
            elif ev.kind in ("admit", "readmit"):
                if t_admit is None:
                    t_admit = ev.t
                if ev.kind == "readmit" and pending_preempt is not None:
                    preempt_delay += ev.t - pending_preempt
                    pending_preempt = None
            elif ev.kind == "first_token" and t_first is None:
                t_first = ev.t
            elif ev.kind == "token":
                toks.append(ev.t)
            elif ev.kind == "preempt" and pending_preempt is None:
                pending_preempt = ev.t
            elif ev.kind == "release":
                t_rel = ev.t
        if t_enq is None:
            raise ValueError(f"request {req_id} has no enqueue event")
        gaps = tuple(b - a for a, b in zip(toks, toks[1:]))
        return RequestLatency(
            req_id=req_id,
            queue_wait=(t_admit - t_enq) if t_admit is not None else 0.0,
            ttft=(t_first - t_enq) if t_first is not None else 0.0,
            gaps=gaps,
            itl_mean=(sum(gaps) / len(gaps)) if gaps else 0.0,
            itl_p99=percentile(gaps, 99),
            preempt_delay=preempt_delay,
            e2e=(t_rel - t_enq) if t_rel is not None else 0.0,
        )

    # -- Chrome trace-event export -------------------------------------------
    # One process ("repro-serving"); tid 0 is the phase timeline, tid 1
    # the queue (enqueue/preempt/readmit instants), tid 2+s decode slot s
    # (request occupancies as slices, lifecycle instants on top); gauges
    # are counter events.  Load the file at https://ui.perfetto.dev or
    # chrome://tracing.
    _PID = 1
    _TID_PHASES = 0
    _TID_QUEUE = 1
    _TID_SLOT0 = 2

    def to_chrome_trace(self) -> dict:
        us = 1e6
        out: list[dict] = []

        def meta(tid, name, sort_index):
            out.append({"name": "thread_name", "ph": "M", "ts": 0,
                        "pid": self._PID, "tid": tid,
                        "args": {"name": name}})
            out.append({"name": "thread_sort_index", "ph": "M", "ts": 0,
                        "pid": self._PID, "tid": tid,
                        "args": {"sort_index": sort_index}})

        out.append({"name": "process_name", "ph": "M", "ts": 0,
                    "pid": self._PID, "tid": 0,
                    "args": {"name": "repro-serving"}})
        meta(self._TID_PHASES, "phases", 0)
        meta(self._TID_QUEUE, "queue", 1)
        for sp in self.spans:
            out.append({"name": sp.name, "ph": "X",
                        "ts": round(sp.t0 * us, 3),
                        "dur": round(max(0.0, sp.t1 - sp.t0) * us, 3),
                        "pid": self._PID, "tid": self._TID_PHASES,
                        "args": {"step": sp.step, **sp.args}})
        # slot occupancy slices: open per row at admit/readmit, close at
        # release/preempt; anything still open closes at the trace end
        open_rows: dict[int, tuple] = {}     # slot -> (req_id, t0)
        used_slots: set = set()
        end_t = max((ev.t for ev in self.events), default=0.0)
        end_t = max(end_t, max((sp.t1 for sp in self.spans), default=0.0))

        def close(slot, t1):
            rid, t0 = open_rows.pop(slot)
            out.append({"name": f"req{rid}", "ph": "X",
                        "ts": round(t0 * us, 3),
                        "dur": round(max(0.0, t1 - t0) * us, 3),
                        "pid": self._PID, "tid": self._TID_SLOT0 + slot,
                        "args": {"req_id": rid}})

        def instant(name, ev, tid):
            out.append({"name": name, "ph": "i", "s": "t",
                        "ts": round(ev.t * us, 3),
                        "pid": self._PID, "tid": tid,
                        "args": {"req_id": ev.req_id, "step": ev.step,
                                 **ev.args}})

        req_rows: dict[int, list] = {}       # req -> rows last admitted
        for ev in self.events:
            if ev.kind in ("admit", "readmit"):
                rows = ev.args.get("rows", ())
                req_rows[ev.req_id] = list(rows)
                for r in rows:
                    if r in open_rows:       # defensive: close stale span
                        close(r, ev.t)
                    open_rows[r] = (ev.req_id, ev.t)
                    used_slots.add(r)
                instant(ev.kind, ev, self._TID_QUEUE)
            elif ev.kind in ("release", "preempt"):
                for r in ev.args.get("rows", ()):
                    if r in open_rows:
                        close(r, ev.t)
                if ev.kind == "preempt":
                    instant(ev.kind, ev, self._TID_QUEUE)
            elif ev.kind == "enqueue":
                instant(ev.kind, ev, self._TID_QUEUE)
            elif ev.kind == "beam_boundary":
                instant(ev.kind, ev, self._TID_PHASES)
            elif ev.kind in ("first_token", "token", "freeze", "resume"):
                rows = ev.args.get("rows") or req_rows.get(ev.req_id, ())
                tid = (self._TID_SLOT0 + rows[0]) if rows \
                    else self._TID_QUEUE
                instant(ev.kind, ev, tid)
        for slot in sorted(open_rows):
            close(slot, end_t)
        for g in self.gauges:
            out.append({"name": g.name, "ph": "C",
                        "ts": round(g.t * us, 3),
                        "pid": self._PID, "tid": self._TID_PHASES,
                        "args": {g.name: g.value}})
        for s in sorted(used_slots):
            meta(self._TID_SLOT0 + s, f"slot {s}", 2 + s)
        # metadata first, then by timestamp; at equal ts the longer span
        # sorts first so a parent that opens at the same instant as its
        # child precedes it (the balanced-nesting invariant the validator
        # checks)
        out.sort(key=lambda e: (e["ph"] != "M", e["ts"],
                                -e.get("dur", 0.0)))
        return {"traceEvents": out, "displayTimeUnit": "ms"}

    def write_chrome_trace(self, path: str) -> str:
        trace = self.to_chrome_trace()
        bad = validate_chrome_trace(trace)
        if bad:  # never write a file the validator would reject
            raise ValueError(f"refusing to write invalid trace: {bad[:3]}")
        with open(path, "w") as f:
            json.dump(trace, f)
            f.write("\n")
        return path


# ---------------------------------------------------------------------------
# Chrome trace-event schema validation (the CI check)
# ---------------------------------------------------------------------------

_REQUIRED = ("name", "ph", "ts", "pid", "tid")
_PHASES_OK = {"M", "X", "i", "C"}


def validate_chrome_trace(obj) -> list[str]:
    """Structural validation of a Chrome trace-event JSON object.
    Returns violation strings (empty = valid):

    * top level: an object with a ``traceEvents`` list (non-empty);
    * every event carries ``name/ph/ts/pid/tid``, ``ph`` is one of
      M/X/i/C, ``ts`` is a non-negative number and ``X`` events carry a
      non-negative ``dur``;
    * non-metadata events are sorted by ``ts`` (monotone timeline);
    * per track (pid, tid), ``X`` spans are *balanced*: they nest or are
      disjoint, never partially overlap (a request's occupancy slices
      and the scheduler's phase slices must open and close in order);
    * every counter (``C``) event carries at least one numeric arg.
    """
    bad: list[str] = []
    if not isinstance(obj, dict) or not isinstance(
            obj.get("traceEvents"), list):
        return ["top level must be an object with a 'traceEvents' list"]
    evs = obj["traceEvents"]
    if not evs:
        bad.append("traceEvents is empty")
    last_ts = 0.0
    tracks: dict[tuple, list] = {}
    for i, ev in enumerate(evs):
        if not isinstance(ev, dict):
            bad.append(f"event {i}: not an object")
            continue
        missing = [k for k in _REQUIRED if k not in ev]
        if missing:
            bad.append(f"event {i}: missing keys {missing}")
            continue
        ph = ev["ph"]
        if ph not in _PHASES_OK:
            bad.append(f"event {i}: unknown phase {ph!r}")
            continue
        ts = ev["ts"]
        if not isinstance(ts, (int, float)) or ts < 0:
            bad.append(f"event {i} ({ev['name']}): bad ts {ts!r}")
            continue
        if ph == "M":
            continue
        if ts < last_ts - 1e-9:
            bad.append(f"event {i} ({ev['name']}): ts {ts} < previous "
                       f"{last_ts} (timeline not monotone)")
        last_ts = max(last_ts, ts)
        if ph == "X":
            dur = ev.get("dur")
            if not isinstance(dur, (int, float)) or dur < 0:
                bad.append(f"event {i} ({ev['name']}): X without "
                           f"non-negative dur (got {dur!r})")
            else:
                tracks.setdefault((ev["pid"], ev["tid"]), []).append(
                    (ts, ts + dur, ev["name"]))
        elif ph == "C":
            args = ev.get("args", {})
            if not any(isinstance(v, (int, float))
                       for v in args.values()):
                bad.append(f"event {i} ({ev['name']}): counter without "
                           f"a numeric arg")
    eps = 1e-3  # µs; guards float round-off in the containment check
    for (pid, tid), spans in tracks.items():
        stack: list[tuple] = []
        for t0, t1, name in spans:  # already ts-sorted per the check above
            while stack and t0 >= stack[-1][1] - eps:
                stack.pop()
            if stack and t1 > stack[-1][1] + eps:
                bad.append(
                    f"track ({pid},{tid}): span {name!r} [{t0},{t1}] "
                    f"partially overlaps {stack[-1][2]!r} "
                    f"[{stack[-1][0]},{stack[-1][1]}] (unbalanced)")
            stack.append((t0, t1, name))
    return bad


def main(argv=None) -> int:
    """``python -m repro.serving.telemetry trace.json [...]`` — validate
    Chrome trace files; exits non-zero listing the violations."""
    argv = list(sys.argv[1:] if argv is None else argv)
    if not argv:
        print("usage: python -m repro.serving.telemetry TRACE.json [...]",
              file=sys.stderr)
        return 2
    rc = 0
    for path in argv:
        try:
            with open(path) as f:
                obj = json.load(f)
        except (OSError, json.JSONDecodeError) as e:
            print(f"{path}: unreadable ({e})", file=sys.stderr)
            rc = 1
            continue
        bad = validate_chrome_trace(obj)
        if bad:
            for msg in bad:
                print(f"{path}: {msg}", file=sys.stderr)
            rc = 1
        else:
            n = len(obj["traceEvents"])
            print(f"{path}: OK ({n} trace events)")
    return rc


if __name__ == "__main__":
    sys.exit(main())
