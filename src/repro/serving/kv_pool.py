"""Paged KV-cache block pool with copy-on-write prefix sharing.

The dense decode cache reserves ``batch × max_len`` KV rows up front and
``DecodeEngine.fork`` physically replicates the prompt's rows N times —
exactly the waste the paper's fixed-hardware-budget argument targets
(Best-of-N decode streams share one prompt).  This module carves the KV
cache into fixed-size *blocks* instead:

* device storage is one pool per engine: ``k``/``v`` of shape
  ``(L, n_blocks, block_size, Hkv, D)`` — batch and max_len disappear;
* each sequence row holds a *block table* (position-ordered block ids), so
  block ``w`` of a row stores positions ``[w·bs, (w+1)·bs)``;
* blocks are refcounted: ``fork`` bumps the refcount of every prompt block
  (zero KV copies), and the first divergent write to a shared block
  triggers copy-on-write (allocate + one-block device copy);
* block 0 is reserved as the *scratch* block: table padding points at it
  and done rows route their (discarded) decode writes there, mirroring the
  dense engine's ``max_len - 1`` scratch-slot convention.

Device storage is a pytree per leaf: one fp array for the plain pool, or
{"codes", "scales"} dicts for the tile-quantized pool
(:class:`~repro.serving.kv_quant.QuantKVPool` — Q8/Q4 codes plus
per-(2, 16)-tile scales; see that module's docstring for the layout and
the accuracy-vs-bytes tradeoff).  Everything below the storage layer —
refcounts, CoW, prefix-cache pinning — moves blocks as opaque payloads,
so the two layouts share all pool semantics; byte accounting
(:meth:`KVPool.block_bytes`) measures the actual leaves and is therefore
dtype-aware.

Accounting (free list, refcounts, peak usage) is host-side — the scheduler
already syncs per step — while bulk KV bytes only ever move on device
(block copies via a jitted scatter).  The pool object is *mutable shared
state*: paged ``GenState``\\ s reference pool blocks by id, so states must
be used linearly (the continuous scheduler's natural discipline); stale
pre-fork states are no longer backed once their blocks are CoW'd or freed.
"""
from __future__ import annotations

from functools import partial
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig

SCRATCH_BLOCK = 0


class OutOfBlocks(RuntimeError):
    """The free list cannot satisfy an allocation.

    Carries ``needed``/``free`` so the scheduler can turn exhaustion into a
    preemption decision instead of a crash.
    """

    def __init__(self, needed: int, free: int):
        super().__init__(f"KV pool exhausted: need {needed} blocks, "
                         f"{free} free")
        self.needed = needed
        self.free = free


def blocks_for(n_tokens: int, block_size: int) -> int:
    """Blocks needed to hold ``n_tokens`` positions."""
    return -(-int(n_tokens) // block_size)


@partial(jax.jit, donate_argnums=(0, 1))
def _copy_blocks(k, v, src, dst):
    """Device copy of whole blocks (CoW commit): pool[:, dst] = pool[:, src].

    ``k``/``v`` are pytrees: one fp array each for the plain pool, or
    {"codes", "scales"} leaf dicts for the quantized pool
    (:class:`~repro.serving.kv_quant.QuantKVPool`) — every leaf carries
    blocks on axis 1, so one tree-mapped scatter moves whole payloads and
    CoW semantics are identical for code+scale blocks."""

    def cp(a):
        return a.at[:, dst].set(a[:, src])

    return jax.tree.map(cp, k), jax.tree.map(cp, v)


class KVPool:
    """Refcounted block pool backing every paged sequence of one engine."""

    mode = "none"  # KV storage quantization (QuantKVPool overrides)

    def __init__(self, cfg: ModelConfig, n_blocks: int, block_size: int,
                 dtype=None):
        if n_blocks < 2:
            raise ValueError("KVPool needs >= 2 blocks (block 0 is the "
                             "reserved scratch block)")
        self.cfg = cfg
        self.n_blocks = n_blocks
        self.block_size = block_size
        storage = self._init_storage(cfg, n_blocks, block_size, dtype)
        self.k = storage["k"]
        self.v = storage["v"]
        self.refcount = np.zeros((n_blocks,), np.int32)
        # block 0 is never handed out: scratch for done-row writes + padding
        self._free: list[int] = list(range(n_blocks - 1, 0, -1))
        self.peak_in_use = 0
        self.cow_copies = 0
        # Called with the shortfall (blocks still needed) when reserve()
        # finds the free list short; the cross-request prefix cache
        # registers its LRU eviction here so cached-but-unreferenced blocks
        # are reclaimed *before* allocation failures escalate to scheduler
        # preemption.  Must only release blocks it owns a reference to.
        self.pressure_hook: Optional[Callable[[int], int]] = None

    def _init_storage(self, cfg: ModelConfig, n_blocks: int,
                      block_size: int, dtype) -> dict:
        """Device storage for the pool; subclasses swap the leaf layout
        (the quantized pool stores code+scale dicts per leaf)."""
        from repro.models.transformer import init_paged_cache

        return init_paged_cache(cfg, n_blocks, block_size, dtype)

    # -- accounting ----------------------------------------------------------
    @property
    def free_blocks(self) -> int:
        return len(self._free)

    @property
    def blocks_in_use(self) -> int:
        return self.n_blocks - 1 - len(self._free)

    @property
    def capacity(self) -> int:
        """Allocatable blocks (total minus the scratch block)."""
        return self.n_blocks - 1

    def block_bytes(self) -> int:
        """HBM bytes of one block across all layers (K + V), measured on
        the actual device leaves — dtype- and layout-aware, so the
        quantized pool's code+scale blocks report their true (smaller)
        footprint and ``peak_bytes``/``hbm_saved`` stay honest."""
        total = sum(leaf.nbytes
                    for leaf in jax.tree_util.tree_leaves((self.k, self.v)))
        return total // self.n_blocks

    def reset_peak(self):
        """Start a fresh peak-tracking interval.

        ``peak_in_use`` and ``cow_copies`` are lifetime counters; callers
        attributing :meth:`stats` to a single run over a shared pool
        (e.g. one sweep row per TTS spec) must snapshot an interval —
        this rebases the peak to the current occupancy and returns the
        ``cow_copies`` watermark to subtract from the interval's end
        value."""
        self.peak_in_use = self.blocks_in_use
        return self.cow_copies

    def stats(self) -> dict:
        """Pool accounting.  ``peak_bytes_in_use`` is the *logical* peak
        (blocks actually holding live KV): it is what a right-sized pool
        must provision, and the number to compare against the dense
        engine's batch×max_len reservation.  The storage physically
        allocated by *this* pool is ``pool_reserved_bytes`` (all
        ``n_blocks`` are backed up front)."""
        return {
            "n_blocks": self.n_blocks,
            "block_size": self.block_size,
            "kv_quant": self.mode,
            "blocks_in_use": self.blocks_in_use,
            "peak_blocks_in_use": self.peak_in_use,
            "free_blocks": self.free_blocks,
            "cow_copies": self.cow_copies,
            "block_bytes": self.block_bytes(),
            "bytes_in_use": self.blocks_in_use * self.block_bytes(),
            "peak_bytes_in_use": self.peak_in_use * self.block_bytes(),
            "pool_reserved_bytes": self.n_blocks * self.block_bytes(),
        }

    # -- alloc / free / share ------------------------------------------------
    def reserve(self, n: int) -> bool:
        """Try to ensure ``n`` free blocks, invoking the pressure hook to
        reclaim evictable blocks when the free list is short.  Returns
        whether the free list now covers ``n``; callers raise
        :class:`OutOfBlocks` (or preempt) themselves on failure — the pool
        never evicts on its own, it only asks the registered cache to.

        This is the cross-layer admission gate: the scheduler reserves a
        request's (or batch's — reservations must be *cumulative* across
        a multi-request plan) new-block need before leasing or
        prefilling, the engine re-reserves each plan (partial-prefill
        tables, per-step decode growth) before mutating anything, and the
        hook ordering is the eviction-before-preemption guarantee — a
        shortage first reclaims LRU cached blocks and only then surfaces
        as ``OutOfBlocks``/preemption.  ``reserve`` itself never
        allocates; a successful reserve is only a promise that an
        immediately following :meth:`alloc`/:meth:`cow` of ``n`` blocks
        cannot fail (single-threaded host discipline)."""
        if n <= len(self._free):
            return True
        if self.pressure_hook is not None:
            self.pressure_hook(n - len(self._free))
        return n <= len(self._free)

    def alloc(self, n: int = 1) -> list[int]:
        """Take ``n`` blocks off the free list (refcount 1 each)."""
        if n > len(self._free):
            raise OutOfBlocks(n, len(self._free))
        out = [self._free.pop() for _ in range(n)]
        for b in out:
            self.refcount[b] = 1
        self.peak_in_use = max(self.peak_in_use, self.blocks_in_use)
        return out

    def retain(self, blocks, times: int = 1):
        """Bump refcounts (fork: prompt blocks gain one owner per sample)."""
        for b in np.asarray(blocks, np.int64).ravel():
            b = int(b)
            if b == SCRATCH_BLOCK:
                continue
            if self.refcount[b] <= 0:
                raise ValueError(f"retain of unallocated block {b}")
            self.refcount[b] += times

    def release(self, blocks):
        """Drop one reference per block; blocks at refcount 0 return to the
        free list."""
        for b in np.asarray(blocks, np.int64).ravel():
            b = int(b)
            if b == SCRATCH_BLOCK:
                continue
            if self.refcount[b] <= 0:
                raise ValueError(f"release of unallocated block {b}")
            self.refcount[b] -= 1
            if self.refcount[b] == 0:
                self._free.append(b)

    def shared(self, block: int) -> bool:
        return self.refcount[int(block)] > 1

    def adopt(self, k: jnp.ndarray, v: jnp.ndarray):
        """Rebind the device arrays after a jitted update returned new
        buffers (the functional-update handshake with the engine)."""
        self.k, self.v = k, v

    def cow(self, blocks) -> list[int]:
        """Copy-on-write: give each (shared) block a private copy.

        Allocates one fresh block per input, device-copies the contents,
        and drops one reference on each source.  Returns the new ids.
        Raises :class:`OutOfBlocks` before any mutation if the free list
        cannot cover the request.
        """
        blocks = [int(b) for b in blocks]
        if not blocks:
            return []
        if len(blocks) > len(self._free):
            raise OutOfBlocks(len(blocks), len(self._free))
        new = self.alloc(len(blocks))
        self.k, self.v = _copy_blocks(self.k, self.v,
                                      jnp.asarray(blocks, jnp.int32),
                                      jnp.asarray(new, jnp.int32))
        self.release(blocks)
        self.cow_copies += len(blocks)
        return new


def dense_kv_bytes(cfg: ModelConfig, batch: int, max_len: int,
                   dtype=None) -> int:
    """What the dense engine reserves for ``batch`` slots (comparison
    baseline for the paged pool's accounting)."""
    dtype = jnp.dtype(dtype or cfg.dtype)
    per = cfg.n_layers * max_len * cfg.n_kv_heads * cfg.resolved_head_dim()
    return 2 * batch * per * dtype.itemsize
