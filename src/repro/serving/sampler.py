"""Token sampling, including distributed vocab-sharded sampling.

The paper keeps the lm_head on the CPU because full logits do not fit the
NPU's 32-bit address space and notes (§7.2.2) that at batch 16 this costs
>50% of step time.  The TPU-native fix implemented here: the lm_head stays
vocab-sharded on the ``model`` axis and sampling happens *per shard* (local
top-k / local gumbel-max), followed by one tiny psum-style merge — full
logits are never materialized or gathered.
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.distributed.compat import shard_map
from repro.distributed.sharding import ParallelContext


@dataclass(frozen=True)
class SamplerConfig:
    temperature: float = 1.0
    top_k: int = 0          # 0 = no top-k
    top_p: float = 1.0      # 1 = no nucleus
    greedy: bool = False


def _mask_top_k(logits, k):
    vals, _ = jax.lax.top_k(logits, k)
    thresh = vals[..., -1:]
    return jnp.where(logits >= thresh, logits, -jnp.inf)


def _mask_top_p(logits, p):
    # argsort is stable, so among tied logits lower token ids sort first
    order = jnp.argsort(-logits, axis=-1)
    sorted_logits = jnp.take_along_axis(logits, order, axis=-1)
    probs = jax.nn.softmax(sorted_logits, axis=-1)
    cum = jnp.cumsum(probs, axis=-1)
    # keep smallest prefix with cumulative prob >= p (always keep first);
    # mask by sorted *rank*, not by value: a value cutoff would keep every
    # token tied with the nucleus boundary and overshoot the target mass
    cutoff_idx = jnp.sum(cum < p, axis=-1, keepdims=True)
    ranks = jnp.argsort(order, axis=-1)
    return jnp.where(ranks <= cutoff_idx, logits, -jnp.inf)


def sample(logits: jnp.ndarray, rng, sc: SamplerConfig) -> jnp.ndarray:
    """logits: (B, V) f32 -> tokens (B,) int32."""
    if sc.greedy:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    x = logits / jnp.maximum(sc.temperature, 1e-6)
    if sc.top_k:
        x = _mask_top_k(x, sc.top_k)
    if sc.top_p < 1.0:
        x = _mask_top_p(x, sc.top_p)
    return jax.random.categorical(rng, x, axis=-1).astype(jnp.int32)


def logprobs_of(logits: jnp.ndarray, tokens: jnp.ndarray) -> jnp.ndarray:
    """Per-token log-probabilities (used by TTS scoring). (B,V),(B,)->(B,)."""
    lp = jax.nn.log_softmax(logits, axis=-1)
    return jnp.take_along_axis(lp, tokens[:, None], axis=-1)[:, 0]


# ---------------------------------------------------------------------------
# Vocab-sharded sampling (beyond-paper: removes the paper's lm_head wall)
# ---------------------------------------------------------------------------


def _merge_shard_winners(loc_max, loc_arg, axis):
    """Global argmax across shards with unsharded-``jnp.argmax`` tie
    semantics: among shards achieving the global max, the *lowest* global
    index wins (pmin over winner candidates; losers contribute INT32_MAX).
    A pmax merge would pick the highest index and diverge from the
    reference single-device decode on tied logits."""
    glob_max = jax.lax.pmax(loc_max, axis)
    winner = jnp.where(loc_max >= glob_max, loc_arg,
                       jnp.iinfo(jnp.int32).max)
    return jax.lax.pmin(winner, axis).astype(jnp.int32)


def _local_gumbel_max(logits_loc, rng, temperature, axis, vocab_per_shard):
    shard = jax.lax.axis_index(axis)
    # per-shard iid gumbel noise: fold the shard id into the key
    g = -jnp.log(-jnp.log(
        jax.random.uniform(jax.random.fold_in(rng, shard),
                           logits_loc.shape, minval=1e-20, maxval=1.0)))
    y = logits_loc / jnp.maximum(temperature, 1e-6) + g
    loc_max = jnp.max(y, axis=-1)
    loc_arg = jnp.argmax(y, axis=-1) + shard * vocab_per_shard
    return _merge_shard_winners(loc_max, loc_arg, axis)


def _local_greedy(logits_loc, axis, vocab_per_shard):
    shard = jax.lax.axis_index(axis)
    loc_max = jnp.max(logits_loc, axis=-1)
    loc_arg = jnp.argmax(logits_loc, axis=-1) + shard * vocab_per_shard
    return _merge_shard_winners(loc_max, loc_arg, axis)


def distributed_sample(logits: jnp.ndarray, rng, sc: SamplerConfig,
                       par: ParallelContext) -> jnp.ndarray:
    """Sample from (B, V) logits sharded over the ``model`` axis without
    gathering them.  Greedy = distributed argmax; stochastic = distributed
    Gumbel-max (exact categorical sample, temperature folded in)."""
    if par.mesh is None or "model" not in par.axes:
        return sample(logits, rng, sc)
    V = logits.shape[-1]
    n_model = par.mesh.shape["model"]
    if V % n_model:  # odd vocab (e.g. internvl2's 151655): gather + sample
        return sample(logits, rng, sc)
    vps = V // n_model

    def local_fn(lg, key):
        if sc.greedy:
            return _local_greedy(lg, "model", vps)
        return _local_gumbel_max(lg, key, sc.temperature, "model", vps)

    batch_axes = par.batch_axes_for(logits.shape[0])
    fn = shard_map(
        local_fn, mesh=par.mesh,
        in_specs=(P(batch_axes, "model"), P()),
        out_specs=P(batch_axes),
        check_vma=False,
    )
    return fn(logits, rng)
