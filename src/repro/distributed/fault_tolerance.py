"""Fault tolerance: preemption handling, restart-from-checkpoint, straggler
detection.

At 1000+ node scale the failure model is: (a) planned preemption (SIGTERM
with a grace period), (b) hard node loss (job reschedules, restarts from
the latest checkpoint), (c) stragglers (slow host degrades the whole
synchronous step).  This module provides the pieces launch/train.py wires
together:

* ``PreemptionHandler`` — SIGTERM/SIGINT triggers one emergency checkpoint
  before exit;
* ``resume_or_init`` — restart logic: restore the latest checkpoint if one
  exists, else fresh init (idempotent re-launch);
* ``StragglerMonitor`` — rolling step-time statistics; flags steps slower
  than ``threshold ×`` the rolling median and keeps a slow-host counter the
  launcher can act on (re-shard / evict in a real deployment; here: logged
  and surfaced in metrics).
"""
from __future__ import annotations

import collections
import signal
import statistics
import time
from typing import Callable, Optional

import jax


class PreemptionHandler:
    """Install SIGTERM/SIGINT hooks that run an emergency checkpoint."""

    def __init__(self, save_fn: Callable[[], None]):
        self.save_fn = save_fn
        self.preempted = False
        self._orig = {}

    def __enter__(self):
        for sig in (signal.SIGTERM, signal.SIGINT):
            self._orig[sig] = signal.signal(sig, self._handler)
        return self

    def _handler(self, signum, frame):
        if not self.preempted:
            self.preempted = True
            try:
                self.save_fn()
            finally:
                pass

    def __exit__(self, *exc):
        for sig, orig in self._orig.items():
            signal.signal(sig, orig)
        return False


def resume_or_init(checkpointer, abstract_tree, init_fn,
                   shardings=None, log_fn=print):
    """Restore the latest checkpoint or initialize fresh.

    Returns (tree, start_step). This is the restart path after any failure:
    relaunching the identical command continues from the last save.
    """
    step = checkpointer.latest_step()
    if step is not None:
        tree, step = checkpointer.restore(abstract_tree, step=step,
                                          shardings=shardings)
        log_fn(f"[ft] restored checkpoint at step {step}")
        return tree, step
    log_fn("[ft] no checkpoint found — fresh init")
    return init_fn(), 0


class StragglerMonitor:
    def __init__(self, window: int = 50, threshold: float = 2.0,
                 log_fn=print):
        self.times = collections.deque(maxlen=window)
        self.threshold = threshold
        self.slow_steps = 0
        self.log_fn = log_fn

    def record_step(self, dt: float):
        if len(self.times) >= 5:
            med = statistics.median(self.times)
            if dt > self.threshold * med:
                self.slow_steps += 1
                self.log_fn(f"[straggler] step took {dt:.3f}s "
                            f"(median {med:.3f}s, x{dt / med:.1f})")
        self.times.append(dt)

    @property
    def median(self) -> Optional[float]:
        return statistics.median(self.times) if self.times else None

    def summary(self) -> dict:
        return {"median_step_s": self.median, "slow_steps": self.slow_steps,
                "window": len(self.times)}
