"""Elastic scaling: move live state between meshes.

Because checkpoints store *global* arrays (checkpoint/checkpointer.py) and
sharding is derived from the param tree + a ParallelContext (distributed/
sharding.py), scaling up/down is: build the new mesh, recompute shardings,
``remesh`` (live) or ``restore`` (from disk).  No resharding-aware file
format is needed — the manifest is mesh-agnostic by construction.
"""
from __future__ import annotations

from typing import Optional

import jax

from repro.distributed.sharding import ParallelContext, param_shardings


def remesh(tree, new_par: ParallelContext, *, stacked_prefixes=("layers",)):
    """Re-device_put a live pytree onto a new mesh's shardings."""
    if new_par.mesh is None:
        return jax.tree.map(lambda x: jax.device_get(x), tree)
    sh = param_shardings(tree, new_par, stacked_prefixes=stacked_prefixes)
    return jax.tree.map(lambda x, s: jax.device_put(x, s), tree, sh)


def elastic_restore(checkpointer, abstract_tree, new_par: ParallelContext,
                    step: Optional[int] = None):
    """Restore a checkpoint written under any previous mesh onto ``new_par``."""
    sh = (param_shardings(abstract_tree, new_par)
          if new_par.mesh is not None else None)
    return checkpointer.restore(abstract_tree, step=step, shardings=sh)
