"""Gradient compression for bandwidth-bound reductions.

Two mechanisms:

* ``compressed_psum`` — a drop-in collective: reduce-scatter at full (or
  bf16) precision for exact summation, then int8-quantize the *scattered*
  shard and all-gather it compressed.  Per-device bytes vs plain f32
  all-reduce (ring):  RS_f32 + AG_int8 = 1.25×size  vs  2×size  (1.6×
  reduction; 2.7× with bf16 RS).  Intended deployment: the cross-pod
  ("pod" axis) gradient reduction, where inter-pod links are the scarce
  resource at 1000+ node scale.

* ``ef_quantize`` — error-feedback int8 quantize/dequantize used as a
  ``grad_transform`` hook in the train step to study compression's effect
  on convergence without rewiring XLA's automatic intra-pod reductions.
"""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.distributed.compat import axis_size, shard_map


def _quantize_int8(x):
    scale = jnp.max(jnp.abs(x)) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def _dequantize_int8(q, scale):
    return q.astype(jnp.float32) * scale


def compressed_psum_local(x, axis: str, *, rs_dtype=jnp.float32):
    """Runs inside shard_map. x: any shape, identical on all shards of
    ``axis`` only in *shape*. Returns the full psum result (replicated)."""
    n = axis_size(axis)
    flat = x.reshape(-1).astype(rs_dtype)
    pad = (-flat.shape[0]) % n
    flat = jnp.pad(flat, (0, pad))
    # exact-sum reduce-scatter (each shard owns 1/n of the summed vector)
    shard = jax.lax.psum_scatter(flat, axis, scatter_dimension=0, tiled=True)
    # compress the broadcast half: int8 + one scale per shard
    q, scale = _quantize_int8(shard.astype(jnp.float32))
    q_all = jax.lax.all_gather(q, axis, axis=0, tiled=True)      # int8 bytes
    s_all = jax.lax.all_gather(scale, axis, axis=0)              # n scalars
    idx = jnp.repeat(jnp.arange(n), shard.shape[0])
    full = q_all.astype(jnp.float32) * s_all[idx]
    full = full[: flat.shape[0] - pad] if pad else full
    return full.reshape(x.shape).astype(x.dtype)


def compressed_psum(tree, mesh, axis: str = "pod", *, rs_dtype=jnp.float32):
    """Apply compressed_psum_local leaf-wise under shard_map (inputs
    replicated along ``axis``; result = sum over that axis)."""

    def local(args):
        return jax.tree.map(
            lambda x: compressed_psum_local(x, axis, rs_dtype=rs_dtype), args)

    fn = shard_map(local, mesh=mesh,
                       in_specs=(P(),), out_specs=P(), check_vma=False)
    return fn(tree)


# ---------------------------------------------------------------------------
# Error-feedback quantization (train-step grad_transform hook)
# ---------------------------------------------------------------------------


def make_ef_state(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def ef_quantize(grads, ef_state=None):
    """int8 quantize/dequantize with error feedback.

    Returns (compressed_grads, new_ef_state). With ef_state=None behaves as
    stateless quantization.
    """

    def one(g, e):
        x = g.astype(jnp.float32) + (e if e is not None else 0.0)
        q, s = _quantize_int8(x)
        deq = _dequantize_int8(q, s)
        return deq.astype(g.dtype), x - deq

    if ef_state is None:
        out = jax.tree.map(lambda g: one(g, None)[0], grads)
        return out, None
    pairs = jax.tree.map(one, grads, ef_state)
    comp = jax.tree.map(lambda t: t[0], pairs,
                        is_leaf=lambda x: isinstance(x, tuple))
    new_ef = jax.tree.map(lambda t: t[1], pairs,
                          is_leaf=lambda x: isinstance(x, tuple))
    return comp, new_ef
