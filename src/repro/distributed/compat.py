"""Version-compat shims for the jax API surface this repo uses.

The repo targets the modern jax API (``jax.shard_map`` with ``check_vma``,
``jax.sharding.AxisType``), but must also run on jax 0.4.x where shard_map
still lives in ``jax.experimental`` (with ``check_rep``) and meshes have no
axis types.  All call sites import from here instead of feature-testing jax
themselves.
"""
from __future__ import annotations

import jax

try:  # jax >= 0.5
    from jax.sharding import AxisType  # type: ignore
except ImportError:  # jax 0.4.x: meshes have no axis types
    AxisType = None


def mesh_axis_types_kw(n_axes: int) -> dict:
    """kwargs for ``jax.make_mesh`` requesting Auto axis types, or {} when
    the installed jax predates axis types (its meshes are Auto already)."""
    if AxisType is None:
        return {}
    return {"axis_types": (AxisType.Auto,) * n_axes}


def axis_size(axis_name: str):
    """``jax.lax.axis_size`` (jax >= 0.5); on 0.4.x a psum of ones gives the
    same static value inside shard_map."""
    if hasattr(jax.lax, "axis_size"):
        return jax.lax.axis_size(axis_name)
    return jax.lax.psum(1, axis_name)


def cost_analysis_dict(compiled) -> dict:
    """``Compiled.cost_analysis()`` returns a dict on modern jax and a
    one-element list of dicts on 0.4.x — normalize to a dict."""
    ca = compiled.cost_analysis() or {}
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return ca


if hasattr(jax, "shard_map"):  # jax >= 0.6

    def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool = True):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=check_vma)

else:  # jax 0.4.x: experimental namespace, check_vma was called check_rep
    from jax.experimental.shard_map import shard_map as _shard_map

    def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool = True):
        return _shard_map(f, mesh=mesh, in_specs=in_specs,
                          out_specs=out_specs, check_rep=check_vma)
