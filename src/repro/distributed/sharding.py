"""Sharding rules: logical axes -> PartitionSpec on the production mesh.

Mesh axes (launch/mesh.py):
  pod   — data-parallel across pods (multi-pod only)
  data  — data-parallel / FSDP / sequence-parallel axis within a pod
  model — tensor-parallel axis (heads, d_ff, vocab, experts' ff)

Parameters carry *logical* axis names; ``spec_for`` maps them to mesh axes.
This is the single place the parallelism layout is defined, so hillclimbing
sharding changes (EXPERIMENTS.md §Perf) is a one-file edit.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# Logical axis -> mesh axes (None = replicated).
LOGICAL_RULES = {
    "batch": ("pod", "data"),
    "seq": None,
    "kv_seq": "data",        # sequence-parallel KV cache (long-context decode)
    "heads": "model",
    "kv_heads": "model",
    "embed": None,           # d_model replicated in TP...
    "embed_fsdp": ("pod", "data"),  # ...but FSDP-sharded for storage
    "act_seq": "model",      # Megatron-style sequence-sharded activations
    "mlp": "model",
    "vocab": "model",
    "expert": None,
    "stack": None,           # scan-stacked layer dim
}


@dataclass(frozen=True)
class ParallelContext:
    """Carried through model code; None mesh => single-device semantics."""

    mesh: Optional[Mesh] = None
    fsdp: bool = True              # shard params/optimizer over data axis too
    # Flash-decoding: shard the KV-cache *sequence* dim over this axis and
    # merge per-shard partial softmaxes with one tiny psum.  decode_* cells
    # use "model" (batch occupies data); long_500k (batch=1) uses "data".
    kv_seq_axis: Optional[str] = None
    quantized: bool = False        # weights stored as int4 tile-quant
    # Megatron-style sequence parallelism for the residual stream: the
    # remat-saved layer inputs are sharded over ``model`` along seq, which
    # divides saved-activation memory by the TP degree (training only).
    shard_activations_seq: bool = False
    # §Perf layout option for small models: tp=False turns the "model" axis
    # into a second FSDP axis (no tensor parallelism): per-layer activation
    # psums disappear and params/optimizer shard over all chips; collective
    # cost becomes 3× params of all-gather/reduce-scatter instead of
    # 2·L·B·S·d of psums — a large win when d_model is small (zamba2,
    # mamba2) and a loss for 35B models. See EXPERIMENTS.md §Perf H2.
    tp: bool = True

    @property
    def axes(self) -> Tuple[str, ...]:
        return tuple(self.mesh.axis_names) if self.mesh is not None else ()

    def _filter(self, axes):
        """Drop mesh axes that do not exist (e.g. no 'pod' on single pod)."""
        if axes is None:
            return None
        if isinstance(axes, str):
            return axes if axes in self.axes else None
        got = tuple(a for a in axes if a in self.axes)
        return got if got else None

    def spec(self, *logical) -> P:
        parts = []
        for name in logical:
            if name is None:
                parts.append(None)
            elif name == "embed_fsdp" and not self.fsdp:
                parts.append(None)  # serving: keep d_model replicated
            elif name == "kv_seq" and self.kv_seq_axis is None:
                parts.append(None)
            elif name == "act_seq" and not self.shard_activations_seq:
                parts.append(None)
            elif not self.tp and name in ("heads", "kv_heads", "mlp",
                                          "vocab", "act_seq"):
                parts.append(None)  # fsdp-only layout: no tensor parallelism
            elif not self.tp and name in ("embed_fsdp", "batch"):
                # fsdp-only: params AND batch shard over every axis
                parts.append(self._filter(("pod", "data", "model")))
            else:
                parts.append(self._filter(LOGICAL_RULES[name]))
        return P(*parts)

    def sharding(self, *logical) -> Optional[NamedSharding]:
        if self.mesh is None:
            return None
        return NamedSharding(self.mesh, self.spec(*logical))

    def constrain(self, x, *logical):
        if self.mesh is None:
            return x
        spec = self.spec(*logical)
        # drop axis assignments that don't divide the dim (e.g. batch 2 on
        # a 16-way data axis during small-batch decode)
        parts = []
        for dim, entry in zip(x.shape,
                              tuple(spec) + (None,) * (x.ndim - len(spec))):
            if entry is None:
                parts.append(None)
                continue
            axes = (entry,) if isinstance(entry, str) else tuple(entry)
            size = 1
            for a in axes:
                size *= self.mesh.shape[a]
            parts.append(entry if dim % size == 0 else None)
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(self.mesh, P(*parts)))

    def batch_axes_for(self, batch_size: int):
        """Mesh axes to shard a batch dim over, dropping axes (pod first)
        until the batch divides — small decode batches fall back toward
        replication instead of failing shard_map divisibility."""
        import math as _math

        axes = self._filter(("pod", "data"))
        while axes is not None:
            axes_t = (axes,) if isinstance(axes, str) else tuple(axes)
            size = _math.prod(self.mesh.shape[a] for a in axes_t)
            if batch_size % size == 0:
                return axes
            axes = axes_t[1:] if len(axes_t) > 1 else None
        return None

    @property
    def n_data(self) -> int:
        if self.mesh is None:
            return 1
        return self.mesh.shape.get("data", 1)

    @property
    def n_model(self) -> int:
        if self.mesh is None:
            return 1
        return self.mesh.shape.get("model", 1)


# ---------------------------------------------------------------------------
# Parameter partition rules (path regex -> logical axes per dim).
#
# Param pytrees are nested dicts; paths look like
# "layers/attn/wq/w", "layers/ffn/experts/gate", "embedding/table", ...
# Stacked (scanned) layer params have a leading "stack" dim.
# ---------------------------------------------------------------------------

PARAM_RULES = [
    # embeddings / lm head: vocab-sharded (beyond-paper: distributed sampling)
    (r".*embedding/table$", ("vocab", "embed")),
    (r".*lm_head/table$", ("vocab", "embed")),
    (r".*patch_proj/w$", ("embed_fsdp", None)),
    # attention projections
    (r".*w[qkv]/w$", ("embed_fsdp", "heads")),
    (r".*wo/w$", ("heads", "embed_fsdp")),
    (r".*w[qkv]/b$", ("heads",)),
    # dense FFN
    (r".*(gate|up|fc1)/w$", ("embed_fsdp", "mlp")),
    (r".*(down|fc2)/w$", ("mlp", "embed_fsdp")),
    (r".*fc1/b$", ("mlp",)),
    (r".*fc2/b$", (None,)),
    # MoE
    (r".*router/w$", (None, None)),
    (r".*experts/(gate|up)$", ("expert", "embed_fsdp", "mlp")),
    (r".*experts/down$", ("expert", "mlp", "embed_fsdp")),
    # mamba2
    (r".*in_proj/w$", ("embed_fsdp", "mlp")),
    (r".*out_proj/w$", ("mlp", "embed_fsdp")),
    (r".*conv/w$", (None, "mlp")),
    (r".*conv/b$", ("mlp",)),
    (r".*(A_log|dt_bias|D)$", ("mlp",)),
    # norms / scalars
    (r".*(scale|bias)$", (None,)),
]


def _path_str(path) -> str:
    return "/".join(str(getattr(k, "key", k)) for k in path)


def logical_axes_for(path: str, ndim: int, stacked: bool) -> Tuple:
    base = None
    for pat, axes in PARAM_RULES:
        if re.match(pat, path):
            base = axes
            break
    if base is None:
        base = (None,) * (ndim - (1 if stacked else 0))
    if stacked:
        base = ("stack",) + tuple(base)
    # pad/trim to ndim
    base = tuple(base)[:ndim]
    base = base + (None,) * (ndim - len(base))
    return base


def param_specs(params, par: ParallelContext, stacked_prefixes=("layers",)):
    """PartitionSpec pytree matching ``params`` (works on ShapeDtypeStructs)."""

    def _divisible(spec: P, shape) -> P:
        """Drop axis assignments that do not evenly divide the dim (e.g. a
        151655 vocab cannot 16-way shard; GSPMD-with-SDS rejects padding)."""
        if par.mesh is None:
            return spec
        parts = []
        for dim, entry in zip(shape, tuple(spec) + (None,) * (len(shape) - len(spec))):
            if entry is None:
                parts.append(None)
                continue
            axes = (entry,) if isinstance(entry, str) else tuple(entry)
            size = 1
            for a in axes:
                size *= par.mesh.shape[a]
            parts.append(entry if dim % size == 0 else None)
        return P(*parts)

    def one(path, leaf):
        ps = _path_str(path)
        # Quantized leaves live under the original weight path
        # (".../wq/w/codes"): shard codes/scales like the weight itself,
        # extra (tile) dims replicated; codebooks replicated.
        qsuffix = None
        for suf in ("/codes", "/scales", "/codebook", "/meta"):
            if ps.endswith(suf):
                qsuffix = suf
                ps = ps[: -len(suf)]
                break
        if qsuffix in ("/codebook", "/meta"):
            return par.spec(*([None] * leaf.ndim))
        stacked = any(ps.startswith(pref) or f"/{pref}/" in ps for pref in stacked_prefixes)
        axes = logical_axes_for(ps, leaf.ndim, stacked)
        return _divisible(par.spec(*axes), leaf.shape)

    return jax.tree_util.tree_map_with_path(one, params)


def param_shardings(params, par: ParallelContext, **kw):
    if par.mesh is None:
        return None
    specs = param_specs(params, par, **kw)
    return jax.tree.map(lambda s: NamedSharding(par.mesh, s), specs,
                        is_leaf=lambda x: isinstance(x, P))
