"""llama3.2-1b — the paper's second model family (§7.1)."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llama3.2-1b", family="transformer",
    n_layers=16, d_model=2048, n_heads=32, n_kv_heads=8, head_dim=64,
    d_ff=8192, vocab_size=128256, rope_theta=500000.0, tie_embeddings=True,
)

SMOKE = ModelConfig(
    name="llama3.2-1b-smoke", family="transformer",
    n_layers=2, d_model=64, n_heads=8, n_kv_heads=2, head_dim=8,
    d_ff=160, vocab_size=512, rope_theta=500000.0, tie_embeddings=True,
    dtype="float32",
)
