"""internvl2-1b [vlm]: 24L d=896 14H (GQA kv=2) d_ff=4864 vocab=151655 —
InternViT + InternLM2/Qwen2 backbone.  The ViT frontend is a STUB:
input_specs supplies precomputed patch embeddings occupying the first
``n_patches`` positions. [arXiv:2404.16821; hf]"""
from repro.configs.base import ModelConfig

N_PATCHES = 256

CONFIG = ModelConfig(
    name="internvl2-1b", family="transformer",
    n_layers=24, d_model=896, n_heads=14, n_kv_heads=2, head_dim=64,
    d_ff=4864, vocab_size=151655, qkv_bias=True, frontend="patch_stub",
)

SMOKE = ModelConfig(
    name="internvl2-1b-smoke", family="transformer",
    n_layers=2, d_model=56, n_heads=7, n_kv_heads=1, head_dim=8,
    d_ff=128, vocab_size=512, qkv_bias=True, frontend="patch_stub",
    dtype="float32",
)
