"""command-r-35b [dense]: 40L d=8192 64H (GQA kv=8) d_ff=22528 vocab=256000 —
GQA, no bias. [hf:CohereForAI/c4ai-command-r-v01; unverified]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="command-r-35b", family="transformer",
    n_layers=40, d_model=8192, n_heads=64, n_kv_heads=8, head_dim=128,
    d_ff=22528, vocab_size=256000,
)

SMOKE = ModelConfig(
    name="command-r-35b-smoke", family="transformer",
    n_layers=2, d_model=128, n_heads=8, n_kv_heads=1, head_dim=16,
    d_ff=256, vocab_size=512, dtype="float32",
)
