"""qwen2.5-14b [dense]: 48L d=5120 40H (GQA kv=8) d_ff=13824 vocab=152064 —
GQA with QKV bias. [hf:Qwen/Qwen2.5-0.5B; hf]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2.5-14b", family="transformer",
    n_layers=48, d_model=5120, n_heads=40, n_kv_heads=8, head_dim=128,
    d_ff=13824, vocab_size=152064, qkv_bias=True,
)

SMOKE = ModelConfig(
    name="qwen2.5-14b-smoke", family="transformer",
    n_layers=2, d_model=80, n_heads=5, n_kv_heads=1, head_dim=16,
    d_ff=192, vocab_size=512, qkv_bias=True, dtype="float32",
)
