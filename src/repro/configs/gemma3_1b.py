"""gemma3-1b [dense]: 26L d=1152 4H (GQA kv=1) d_ff=6912 vocab=262144 —
5:1 local:global sliding-window attention, 128k ctx.
[hf:google/gemma-3-1b-pt; unverified]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="gemma3-1b", family="transformer",
    n_layers=26, d_model=1152, n_heads=4, n_kv_heads=1, head_dim=256,
    d_ff=6912, vocab_size=262144,
    attn_pattern="local_global:5", window_size=512,
    rope_theta=1_000_000.0, tie_embeddings=True,
)

SMOKE = ModelConfig(
    name="gemma3-1b-smoke", family="transformer",
    n_layers=6, d_model=64, n_heads=4, n_kv_heads=1, head_dim=16,
    d_ff=128, vocab_size=512,
    attn_pattern="local_global:5", window_size=8,
    rope_theta=1_000_000.0, tie_embeddings=True, dtype="float32",
)
