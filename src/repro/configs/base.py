"""Model / run configuration system.

Every architecture in the assigned pool is expressed as a ``ModelConfig``.
A config is a frozen dataclass so it can be hashed into jit static args and
serialized into checkpoints / launch manifests.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple

# ---------------------------------------------------------------------------
# Input shapes assigned to the LM family (see system brief):  every arch is
# exercised against all four shapes (long_500k only for sub-quadratic archs).
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


TRAIN_4K = InputShape("train_4k", 4096, 256, "train")
PREFILL_32K = InputShape("prefill_32k", 32768, 32, "prefill")
DECODE_32K = InputShape("decode_32k", 32768, 128, "decode")
LONG_500K = InputShape("long_500k", 524288, 1, "decode")

ALL_SHAPES: Tuple[InputShape, ...] = (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)
SHAPES_BY_NAME = {s.name: s for s in ALL_SHAPES}


@dataclass(frozen=True)
class MoEConfig:
    n_experts: int = 0
    top_k: int = 0
    expert_d_ff: int = 0
    capacity_factor: float = 1.25
    router_jitter: float = 0.0
    aux_loss_weight: float = 0.01


@dataclass(frozen=True)
class SSMConfig:
    d_state: int = 128
    head_dim: int = 64
    expand: int = 2
    conv_width: int = 4
    chunk_size: int = 256
    ngroups: int = 1

    def d_inner(self, d_model: int) -> int:
        return self.expand * d_model

    def n_heads(self, d_model: int) -> int:
        return self.d_inner(d_model) // self.head_dim


@dataclass(frozen=True)
class ModelConfig:
    """Unified configuration covering the full architecture pool."""

    name: str = "model"
    family: str = "transformer"  # transformer | mamba2 | hybrid | encdec
    n_layers: int = 4
    d_model: int = 256
    n_heads: int = 4
    n_kv_heads: int = 4
    d_ff: int = 1024
    vocab_size: int = 512
    head_dim: int = 0  # 0 => d_model // n_heads

    # Attention variants -----------------------------------------------------
    attn_pattern: str = "global"  # "global" | "local_global:5" | "window"
    window_size: int = 0          # sliding window (0 = unbounded)
    qkv_bias: bool = False
    logit_softcap: float = 0.0
    rope_theta: float = 10000.0

    # MoE ---------------------------------------------------------------------
    moe: Optional[MoEConfig] = None

    # SSM / hybrid -------------------------------------------------------------
    ssm: Optional[SSMConfig] = None
    hybrid_attn_every: int = 0  # zamba2-style shared attention block cadence

    # Encoder-decoder ------------------------------------------------------------
    n_encoder_layers: int = 0
    encoder_seq_len: int = 1500  # whisper audio frames after conv frontend

    # Modality frontend: "none" | "patch_stub" | "audio_stub"
    frontend: str = "none"

    # Numerics -------------------------------------------------------------------
    norm_eps: float = 1e-6
    dtype: str = "bfloat16"       # activation/compute dtype
    param_dtype: str = "float32"  # master param dtype
    tie_embeddings: bool = False
    max_seq_len: int = 524288

    # Paper technique knobs --------------------------------------------------------
    quantization: Optional[str] = None  # None | "q4_tile" | "q4_common" | "q8_tile"
    quant_group_size: int = 32
    lut_attention: bool = False  # use the LUT-softmax Pallas path on TPU

    # Distribution ------------------------------------------------------------------
    remat: str = "full"  # "none" | "full" | "dots"
    kv_partition: str = "batch"  # "batch" | "sequence" (sequence-parallel decode)
    # Ring (circular) KV cache for uniformly-windowed attention (mixtral
    # SWA): cache holds only `window_size` slots, slot = pos % window.
    ring_cache: bool = False

    def resolved_head_dim(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // self.n_heads if self.n_heads else 0

    @property
    def sub_quadratic(self) -> bool:
        """True if the arch can run long_500k (bounded or linear state)."""
        if self.family in ("mamba2", "hybrid"):
            return True
        if self.window_size > 0:
            return True
        if self.attn_pattern.startswith("local_global"):
            return True
        return False

    @property
    def has_decode(self) -> bool:
        return True  # every assigned arch (incl. enc-dec) has a decode step

    def supports_shape(self, shape: InputShape) -> bool:
        if shape.name == "long_500k":
            return self.sub_quadratic
        return True

    def with_(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    # -- Parameter count (for roofline MODEL_FLOPS = 6*N*D) --------------------
    def param_count(self, active_only: bool = False) -> int:
        d, f, v, L = self.d_model, self.d_ff, self.vocab_size, self.n_layers
        hd = self.resolved_head_dim()
        nq, nkv = self.n_heads, self.n_kv_heads
        emb = v * d * (1 if self.tie_embeddings else 2)
        total = emb

        def attn_params() -> int:
            return d * hd * nq + 2 * d * hd * nkv + hd * nq * d

        def dense_ffn() -> int:
            return 3 * d * f  # gate/up/down (SwiGLU)

        def moe_ffn(active: bool) -> int:
            m = self.moe
            n_e = m.top_k if active else m.n_experts
            return 3 * d * m.expert_d_ff * n_e + d * m.n_experts  # + router

        def mamba_params() -> int:
            s = self.ssm
            di = s.d_inner(d)
            nh = s.n_heads(d)
            conv_dim = di + 2 * s.ngroups * s.d_state
            return (
                d * (2 * di + 2 * s.ngroups * s.d_state + nh)  # in_proj
                + conv_dim * s.conv_width
                + 2 * nh  # A_log, dt_bias
                + nh      # D
                + di * d  # out_proj
            )

        if self.family == "transformer":
            if self.moe:
                total += L * (attn_params() + moe_ffn(active_only) + 2 * d)
            else:
                total += L * (attn_params() + dense_ffn() + 2 * d)
        elif self.family == "mamba2":
            total += L * (mamba_params() + d)
        elif self.family == "hybrid":
            total += L * (mamba_params() + d)
            if self.hybrid_attn_every:
                total += attn_params() + dense_ffn() + 2 * d  # one shared block
        elif self.family == "encdec":
            enc = self.n_encoder_layers * (attn_params() + 2 * d * f + 2 * d)
            dec = L * (2 * attn_params() + 2 * d * f + 3 * d)
            total += enc + dec
        return total
