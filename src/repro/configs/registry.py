"""Architecture registry: ``--arch <id>`` resolution for every entrypoint."""
from __future__ import annotations

import importlib
from typing import Dict, List

from repro.configs.base import ALL_SHAPES, SHAPES_BY_NAME, InputShape, ModelConfig

_ARCH_MODULES = {
    "gemma3-1b": "gemma3_1b",
    "stablelm-3b": "stablelm_3b",
    "qwen2.5-14b": "qwen2_5_14b",
    "command-r-35b": "command_r_35b",
    "internvl2-1b": "internvl2_1b",
    "mamba2-130m": "mamba2_130m",
    "olmoe-1b-7b": "olmoe_1b_7b",
    "mixtral-8x7b": "mixtral_8x7b",
    "zamba2-1.2b": "zamba2_1_2b",
    "whisper-base": "whisper_base",
    # the paper's own models
    "qwen2.5-1.5b": "qwen2_5_1_5b",
    "llama3.2-1b": "llama3_2_1b",
}

ASSIGNED_ARCHS: List[str] = list(_ARCH_MODULES)[:10]
PAPER_ARCHS: List[str] = list(_ARCH_MODULES)[10:]


def _module(arch: str):
    if arch not in _ARCH_MODULES:
        raise KeyError(f"unknown arch {arch!r}; known: {list(_ARCH_MODULES)}")
    return importlib.import_module(f"repro.configs.{_ARCH_MODULES[arch]}")


def get_config(arch: str, *, smoke: bool = False) -> ModelConfig:
    mod = _module(arch)
    return mod.SMOKE if smoke else mod.CONFIG


def list_archs() -> List[str]:
    return list(_ARCH_MODULES)


def cells(archs=None):
    """All (arch, shape) dry-run cells incl. documented skips.

    Yields (arch, shape, runnable, reason)."""
    for arch in archs or ASSIGNED_ARCHS:
        cfg = get_config(arch)
        for shape in ALL_SHAPES:
            if cfg.supports_shape(shape):
                yield arch, shape, True, ""
            else:
                yield arch, shape, False, (
                    "pure full attention — long_500k needs sub-quadratic "
                    "attention (see DESIGN.md §Arch-applicability)")
