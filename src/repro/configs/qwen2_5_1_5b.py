"""qwen2.5-1.5b — the paper's primary on-device model (§7.1)."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2.5-1.5b", family="transformer",
    n_layers=28, d_model=1536, n_heads=12, n_kv_heads=2, head_dim=128,
    d_ff=8960, vocab_size=151936, qkv_bias=True, tie_embeddings=True,
)

SMOKE = ModelConfig(
    name="qwen2.5-1.5b-smoke", family="transformer",
    n_layers=2, d_model=48, n_heads=6, n_kv_heads=1, head_dim=8,
    d_ff=128, vocab_size=512, qkv_bias=True, tie_embeddings=True,
    dtype="float32",
)
