"""Per-(arch × shape) input specs and step builders for the dry-run.

``input_specs`` returns weak-type-correct, shardable ShapeDtypeStruct
stand-ins for every model input (no device allocation).  ``build_cell``
returns the jit-able step function + abstract args + shardings for one
dry-run cell:

  train_*   -> full train_step (fwd + bwd + AdamW update)
  prefill_* -> prefill (prompt forward + KV-cache build)
  decode_* / long_* -> serve_step (one decode step + distributed sampling)
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import InputShape, ModelConfig
from repro.configs.registry import get_config
from repro.distributed.sharding import ParallelContext, param_specs
from repro.models import api
from repro.serving.sampler import SamplerConfig, distributed_sample
from repro.train.optimizer import AdamWConfig, adamw_update, init_opt_state


def _sds(shape, dtype, sharding=None):
    return jax.ShapeDtypeStruct(shape, dtype, sharding=sharding)


def _maybe_axis(par: ParallelContext, axis, dim: int):
    """Shard ``dim`` over ``axis`` only when it divides (GSPMD would pad;
    shard_map would reject)."""
    if par.mesh is None or axis is None:
        return None
    axes = (axis,) if isinstance(axis, str) else tuple(axis)
    axes = tuple(a for a in axes if a in par.axes)
    if not axes:
        return None
    import math
    size = math.prod(par.mesh.shape[a] for a in axes)
    return (axes if len(axes) > 1 else axes[0]) if dim % size == 0 else None


# Per-arch gradient-accumulation factors for the train_4k dry-run: cells
# whose single-shot activation working set exceeds v5e HBM split the
# global batch into sequential microbatches (the standard memory/compute
# trade; semantics tested in test_microbatch_close_to_full_batch).
TRAIN_MICROBATCHES = {
    "command-r-35b": 8,
    "internvl2-1b": 1,  # microbatch scan regressed temp — see §Dry-run fit note
    "mamba2-130m": 1,   # microbatch scan regressed temp — see §Dry-run fit note
    "mixtral-8x7b": 4,
    "olmoe-1b-7b": 4,
    "qwen2.5-14b": 2,
    "whisper-base": 1,  # microbatch scan regressed temp — see §Dry-run fit note
}


def _ns(par, *spec_parts):
    if par.mesh is None:
        return None
    return NamedSharding(par.mesh, P(*spec_parts))


# ---------------------------------------------------------------------------
# Input specs (the model-input stand-ins)
# ---------------------------------------------------------------------------


def input_specs(cfg: ModelConfig, shape: InputShape, par: ParallelContext,
                *, n_patches: int = 256) -> dict:
    B, S = shape.global_batch, shape.seq_len
    baxes = ("pod", "data") if par.tp else ("pod", "data", "model")
    batch_ax = _maybe_axis(par, baxes, B)
    tok_sh = _ns(par, batch_ax, None)
    vec_sh = _ns(par, batch_ax)
    emb_sh = _ns(par, batch_ax, None, None)
    dtype = jnp.dtype(cfg.dtype)

    if shape.kind == "train":
        specs = {
            "tokens": _sds((B, S), jnp.int32, tok_sh),
            "targets": _sds((B, S), jnp.int32, tok_sh),
            "mask": _sds((B, S), jnp.float32, tok_sh),
        }
        if cfg.frontend == "patch_stub":
            specs["embeddings"] = _sds((B, n_patches, cfg.d_model), dtype, emb_sh)
        if cfg.family == "encdec":
            specs["embeddings"] = _sds((B, cfg.encoder_seq_len, cfg.d_model),
                                       dtype, emb_sh)
        return specs

    if shape.kind == "prefill":
        specs = {
            "tokens": _sds((B, S), jnp.int32, tok_sh),
            "lengths": _sds((B,), jnp.int32, vec_sh),
        }
        if cfg.frontend == "patch_stub":
            specs["embeddings"] = _sds((B, n_patches, cfg.d_model), dtype, emb_sh)
        if cfg.family == "encdec":
            specs["embeddings"] = _sds((B, cfg.encoder_seq_len, cfg.d_model),
                                       dtype, emb_sh)
        return specs

    # decode: one new token against a cache of S
    return {
        "tokens": _sds((B, 1), jnp.int32, tok_sh),
        "cache_len": _sds((B,), jnp.int32, vec_sh),
    }


# ---------------------------------------------------------------------------
# Cache sharding specs
# ---------------------------------------------------------------------------


def cache_specs(cfg: ModelConfig, par: ParallelContext, abstract_cache: dict,
                batch: int):
    """PartitionSpec pytree for a decode cache."""
    seq_ax_name = par.kv_seq_axis
    batch_ax = _maybe_axis(par, ("pod", "data"), batch)
    if seq_ax_name is not None and batch_ax is not None:
        bt = (batch_ax,) if isinstance(batch_ax, str) else tuple(batch_ax)
        bt = tuple(a for a in bt if a != seq_ax_name)
        batch_ax = bt if len(bt) > 1 else (bt[0] if bt else None)

    def one(path, leaf):
        key = str(getattr(path[-1], "key", path[-1]))
        if key in ("k", "v", "attn_k", "attn_v", "cross_k", "cross_v"):
            # (L, B, S, Hkv, D) — flash-decoding shards seq over kv_seq_axis
            if seq_ax_name is not None:
                seq_ax = _maybe_axis(par, seq_ax_name, leaf.shape[2])
                return P(None, batch_ax, seq_ax, None, None)
            heads_ax = _maybe_axis(par, "model", leaf.shape[3])
            # GQA head counts (8, 1) rarely divide the 16-way model axis:
            # fall back to sharding head_dim (contraction splits into
            # partials GSPMD psums — tiny at decode batch sizes).
            hd_ax = (None if heads_ax is not None
                     else _maybe_axis(par, "model", leaf.shape[4]))
            return P(None, batch_ax, None, heads_ax, hd_ax)
        if key == "conv":      # (L, B, W-1, conv_dim)
            return P(None, batch_ax, None, _maybe_axis(par, "model", leaf.shape[3]))
        if key == "ssm":       # (L, B, H, P, N)
            return P(None, batch_ax, _maybe_axis(par, "model", leaf.shape[2]),
                     None, None)
        return P(*([None] * leaf.ndim))

    return jax.tree_util.tree_map_with_path(one, abstract_cache)


# ---------------------------------------------------------------------------
# Cell builders
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class Cell:
    name: str
    fn: object                 # function to jit
    args: tuple                # abstract args (SDS pytrees)
    in_shardings: tuple
    out_shardings: object      # None => let GSPMD choose
    static: dict


def _to_shardings(par, spec_tree):
    return jax.tree.map(
        lambda s: NamedSharding(par.mesh, s) if par.mesh is not None else None,
        spec_tree, is_leaf=lambda x: isinstance(x, P))


def build_cell(arch: str, shape: InputShape, par: ParallelContext,
               *, smoke: bool = False, quantized: bool = False,
               microbatches: int = 0) -> Cell:
    """``quantized``: serve cells lower with tile-Q4_0 weight leaves
    (Q8_0 down-proj) — the paper's §5.1 deployment — so the dry-run's
    cost/memory analysis sees real int4/int8 byte traffic."""
    cfg = get_config(arch, smoke=smoke)
    cache_len_cap = shape.seq_len
    if shape.kind == "decode" and cfg.family != "encdec":
        # flash-decoding: KV seq over "model" for batched decode (batch
        # occupies the data axis); over "data" for batch-1 long context.
        # (whisper's decoder keeps the head/head_dim sharding path.)
        axis = "data" if shape.name == "long_500k" else "model"
        cfg = cfg.with_(kv_partition="sequence")
        par = dataclasses.replace(par, kv_seq_axis=axis)
        # uniformly-windowed archs (mixtral SWA) use a ring cache of
        # window_size slots — 128× less KV memory at 500k context.
        if (cfg.window_size and not cfg.attn_pattern.startswith("local_global")
                and cfg.window_size < shape.seq_len):
            cfg = cfg.with_(ring_cache=True)
            cache_len_cap = cfg.window_size
    if shape.kind != "train":
        # serving: weights replicated over data (no per-layer all-gathers
        # on the decode critical path); TP over model only.
        par = dataclasses.replace(par, fsdp=False)
    else:
        # training: sequence-shard the remat-saved residual stream over the
        # model axis (Megatron SP) — divides activation memory by TP degree.
        par = dataclasses.replace(par, shard_activations_seq=True)
    model = api.get_model(cfg)
    aparams = model.abstract_params(cfg)
    if shape.kind != "train":
        # serving streams weights at bf16 (the paper's fp16-weights analog);
        # the f32 master copies exist only in training.
        aparams = jax.tree.map(
            lambda a: jax.ShapeDtypeStruct(
                a.shape, jnp.bfloat16 if a.dtype == jnp.float32 else a.dtype),
            aparams)
        if quantized:
            from repro.quant.qlinear import quantize_model_params

            aparams = jax.eval_shape(
                lambda p: quantize_model_params(p), aparams)
    pspecs = param_specs(aparams, par,
                         stacked_prefixes=("layers", "enc_layers", "dec_layers"))
    pshard = _to_shardings(par, pspecs)
    aparams = jax.tree.map(
        lambda a, s: jax.ShapeDtypeStruct(a.shape, a.dtype, sharding=s),
        aparams, pshard)
    specs = input_specs(cfg, shape, par)
    B, S = shape.global_batch, shape.seq_len

    if shape.kind == "train":
        from repro.train.loop import make_train_step

        oc = AdamWConfig()
        mb = microbatches or TRAIN_MICROBATCHES.get(arch, 1)
        step = make_train_step(cfg, oc, par, microbatches=mb)
        aopt = jax.eval_shape(init_opt_state, aparams)
        scalar_sh = (NamedSharding(par.mesh, P())
                     if par.mesh is not None else None)
        opt_sh = {"m": pshard, "v": pshard, "step": scalar_sh}
        if par.mesh is not None:
            aopt = {
                "m": jax.tree.map(lambda a, s: jax.ShapeDtypeStruct(
                    a.shape, a.dtype, sharding=s), aopt["m"], pshard),
                "v": jax.tree.map(lambda a, s: jax.ShapeDtypeStruct(
                    a.shape, a.dtype, sharding=s), aopt["v"], pshard),
                "step": jax.ShapeDtypeStruct((), jnp.int32, sharding=scalar_sh),
            }
        batch_keys = ["tokens", "targets", "mask"]
        if "embeddings" in specs:
            batch_keys.append("embeddings")
        batch = tuple(specs[k] for k in batch_keys)

        def train_fn(params, opt_state, *batch):
            return step(params, opt_state, batch)

        in_sh = (pshard, opt_sh, *[b.sharding for b in batch])
        return Cell(name=f"{arch}:{shape.name}", fn=train_fn,
                    args=(aparams, aopt, *batch), in_shardings=in_sh,
                    out_shardings=None, static={"donate": (0, 1)})

    if shape.kind == "prefill":
        def prefill_fn(params, tokens, lengths, embeddings=None):
            kw = {"embeddings": embeddings} if embeddings is not None else {}
            return model.prefill(params, tokens, cfg, par, max_len=S,
                                 lengths=lengths, **kw)

        args = [aparams, specs["tokens"], specs["lengths"]]
        in_sh = [pshard, specs["tokens"].sharding, specs["lengths"].sharding]
        if "embeddings" in specs:
            args.append(specs["embeddings"])
            in_sh.append(specs["embeddings"].sharding)
        return Cell(name=f"{arch}:{shape.name}", fn=prefill_fn,
                    args=tuple(args), in_shardings=tuple(in_sh),
                    out_shardings=None, static={})

    # decode / long-context decode: serve_step = decode + sample
    t_enc = cfg.encoder_seq_len if cfg.family == "encdec" else 0
    acache = (model.abstract_cache(cfg, B, S, t_enc=t_enc)
              if cfg.family == "encdec"
              else model.abstract_cache(cfg, B, cache_len_cap))
    cspecs = cache_specs(cfg, par, acache, B)
    cshard = _to_shardings(par, cspecs)
    acache = jax.tree.map(
        lambda a, s: jax.ShapeDtypeStruct(a.shape, a.dtype, sharding=s),
        acache, cshard)
    sc = SamplerConfig(temperature=0.8)

    def serve_fn(params, cache, tokens, cache_len, rng):
        logits, new_cache = model.decode_step(params, tokens, cache,
                                              cache_len, cfg, par)
        tok = distributed_sample(logits.astype(jnp.float32), rng, sc, par)
        return tok, new_cache

    key = jax.random.key(0)  # concrete (tiny) — lower() accepts mixed
    return Cell(
        name=f"{arch}:{shape.name}", fn=serve_fn,
        args=(aparams, acache, specs["tokens"], specs["cache_len"], key),
        in_shardings=(pshard, cshard, specs["tokens"].sharding,
                      specs["cache_len"].sharding, None),
        out_shardings=None, static={"donate": (1,)})
