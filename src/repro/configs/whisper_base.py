"""whisper-base [audio]: 6L enc + 6L dec, d=512 8H d_ff=2048 vocab=51865 —
encoder-decoder; the conv audio frontend is a STUB (input_specs supplies
precomputed frame embeddings). [arXiv:2212.04356; unverified]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-base", family="encdec",
    n_layers=6, n_encoder_layers=6, d_model=512, n_heads=8, n_kv_heads=8,
    head_dim=64, d_ff=2048, vocab_size=51865, qkv_bias=True,
    rope_theta=0.0, encoder_seq_len=1500, max_seq_len=33024,
    tie_embeddings=True,
)

SMOKE = ModelConfig(
    name="whisper-base-smoke", family="encdec",
    n_layers=2, n_encoder_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
    head_dim=16, d_ff=128, vocab_size=512, qkv_bias=True,
    rope_theta=0.0, encoder_seq_len=24, max_seq_len=128,
    tie_embeddings=True, dtype="float32",
)
