"""mamba2-130m [ssm]: 24L d=768 (attention-free) vocab=50280, ssm_state=128 —
SSD (state-space duality). [arXiv:2405.21060; unverified]"""
from repro.configs.base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="mamba2-130m", family="mamba2",
    n_layers=24, d_model=768, n_heads=0, n_kv_heads=0, d_ff=0,
    vocab_size=50280, tie_embeddings=True,
    ssm=SSMConfig(d_state=128, head_dim=64, expand=2, conv_width=4,
                  chunk_size=128),
)

SMOKE = ModelConfig(
    name="mamba2-130m-smoke", family="mamba2",
    n_layers=2, d_model=64, n_heads=0, n_kv_heads=0, d_ff=0, vocab_size=512,
    tie_embeddings=True,
    ssm=SSMConfig(d_state=16, head_dim=16, expand=2, conv_width=4,
                  chunk_size=8),
    dtype="float32",
)
