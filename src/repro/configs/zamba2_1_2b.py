"""zamba2-1.2b [hybrid]: 38L d=2048 32H (kv=32) d_ff=8192 vocab=32000,
ssm_state=64 — Mamba2 backbone + one *shared* attention block applied every
6 layers. [arXiv:2411.15242; hf]"""
from repro.configs.base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="zamba2-1.2b", family="hybrid",
    n_layers=38, d_model=2048, n_heads=32, n_kv_heads=32, head_dim=64,
    d_ff=8192, vocab_size=32000, hybrid_attn_every=6,
    ssm=SSMConfig(d_state=64, head_dim=64, expand=2, conv_width=4,
                  chunk_size=256),
)

SMOKE = ModelConfig(
    name="zamba2-1.2b-smoke", family="hybrid",
    n_layers=5, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
    d_ff=128, vocab_size=512, hybrid_attn_every=2,
    ssm=SSMConfig(d_state=16, head_dim=16, expand=2, conv_width=4,
                  chunk_size=8),
    dtype="float32",
)
