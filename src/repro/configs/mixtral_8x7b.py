"""mixtral-8x7b [moe]: 32L d=4096 32H (GQA kv=8) expert d_ff=14336
vocab=32000 — 8 experts top-2, sliding-window attention.
[arXiv:2401.04088; hf]"""
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="mixtral-8x7b", family="transformer",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8, head_dim=128,
    d_ff=0, vocab_size=32000, window_size=4096,
    moe=MoEConfig(n_experts=8, top_k=2, expert_d_ff=14336),
)

SMOKE = ModelConfig(
    name="mixtral-8x7b-smoke", family="transformer",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
    d_ff=0, vocab_size=512, window_size=16,
    moe=MoEConfig(n_experts=4, top_k=2, expert_d_ff=96),
    dtype="float32",
)
