"""Training step + loop: masked LM loss, microbatch gradient accumulation,
mixed precision, MoE aux loss, and the distributed hooks (sharded step,
optional int8 gradient-compression all-reduce)."""
from __future__ import annotations

import time
from dataclasses import dataclass
from functools import partial
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.sharding import ParallelContext, param_shardings
from repro.models import api
from repro.train.optimizer import AdamWConfig, adamw_update, init_opt_state


def lm_loss(params, batch, cfg: ModelConfig, par: Optional[ParallelContext],
            *, aux_weight: float = 0.01):
    """batch = (tokens, targets, mask[, embeddings]).

    The NLL is computed as logsumexp(logits) − ⟨logits, onehot(target)⟩:
    both terms are *contractions over the vocab dim*, so when logits are
    vocab-sharded on the ``model`` axis GSPMD keeps them sharded (local
    partial reduce + small all-reduce) instead of all-gathering a
    (B, S, V) tensor per device, which is what a take_along_axis gather
    would force.
    """
    tokens, targets, mask = batch[:3]
    kw = {"embeddings": batch[3]} if len(batch) > 3 else {}
    model = api.get_model(cfg)
    logits, _, aux = model.forward(params, tokens, cfg, par, **kw)
    logits = logits.astype(jnp.float32)
    V = logits.shape[-1]
    if par is not None:  # keep both (B,S,V) tensors vocab-sharded
        logits = par.constrain(logits, "batch", None, "vocab")
    lse = jax.nn.logsumexp(logits, axis=-1)
    onehot = jax.nn.one_hot(targets, V, dtype=logits.dtype)
    if par is not None:
        onehot = par.constrain(onehot, "batch", None, "vocab")
    tgt = jnp.einsum("bsv,bsv->bs", logits, onehot)
    nll = lse - tgt
    denom = jnp.maximum(jnp.sum(mask), 1.0)
    loss = jnp.sum(nll * mask) / denom
    return loss + aux_weight * aux, {"loss": loss, "aux": aux,
                                     "tokens": denom}


def make_train_step(cfg: ModelConfig, oc: AdamWConfig,
                    par: Optional[ParallelContext] = None,
                    *, microbatches: int = 1,
                    grad_transform: Optional[Callable] = None):
    """Returns train_step(params, opt_state, batch) -> (params, opt, metrics).

    ``microbatches`` > 1 splits the batch along dim 0 and accumulates grads
    with a lax.scan (sequential microbatching — the standard memory/compute
    trade).  ``grad_transform`` hooks gradient compression
    (distributed.compression) between accumulation and the optimizer.
    """

    def loss_fn(p, mb):
        return lm_loss(p, mb, cfg, par)

    def train_step(params, opt_state, batch):
        if microbatches == 1:
            (loss, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params, batch)
        else:
            def split(x):
                return x.reshape(microbatches, x.shape[0] // microbatches,
                                 *x.shape[1:])

            mbs = jax.tree.map(split, batch)

            def acc_body(carry, mb):
                g_acc, l_acc = carry
                (l, m), g = jax.value_and_grad(loss_fn, has_aux=True)(params, mb)
                g_acc = jax.tree.map(jnp.add, g_acc, g)
                return (g_acc, l_acc + l), m

            g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (grads, loss_sum), ms = jax.lax.scan(acc_body, (g0, 0.0), mbs)
            grads = jax.tree.map(lambda g: g / microbatches, grads)
            loss = loss_sum / microbatches
            metrics = jax.tree.map(lambda x: x.mean(), ms)
        if grad_transform is not None:
            grads = grad_transform(grads)
        params, opt_state, om = adamw_update(params, grads, opt_state, oc)
        return params, opt_state, {**metrics, **om, "total_loss": loss}

    return train_step


def make_sharded_train_step(cfg: ModelConfig, oc: AdamWConfig,
                            par: ParallelContext, abstract_params,
                            *, microbatches: int = 1, donate: bool = True):
    """jit the train step with explicit in/out shardings on the mesh."""
    step = make_train_step(cfg, oc, par, microbatches=microbatches)
    p_sh = param_shardings(abstract_params, par)
    opt_sh = {"m": p_sh, "v": p_sh,
              "step": jax.sharding.NamedSharding(par.mesh,
                                                 jax.sharding.PartitionSpec())}
    batch_sh = jax.sharding.NamedSharding(par.mesh, par.spec("batch", None))
    return jax.jit(
        step,
        in_shardings=(p_sh, opt_sh, (batch_sh,) * 3),
        out_shardings=(p_sh, opt_sh, None),
        donate_argnums=(0, 1) if donate else (),
    )


def train_loop(params, cfg: ModelConfig, oc: AdamWConfig, data_iter,
               *, n_steps: int, par: Optional[ParallelContext] = None,
               microbatches: int = 1, log_every: int = 20,
               checkpointer=None, ckpt_every: int = 0,
               monitor=None, log_fn=print):
    """Simple driver used by examples and the launch/train.py entrypoint."""
    step_fn = jax.jit(make_train_step(cfg, oc, par, microbatches=microbatches))
    opt_state = init_opt_state(params)
    t0 = time.time()
    for i in range(n_steps):
        batch = next(data_iter)
        batch = tuple(jnp.asarray(b) for b in batch)
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        if monitor is not None:
            monitor.record_step(time.time() - t0)
            t0 = time.time()
        if log_every and (i % log_every == 0 or i == n_steps - 1):
            log_fn(f"step {i:5d} loss {float(metrics['loss']):.4f} "
                   f"lr {float(metrics['lr']):.2e} "
                   f"gnorm {float(metrics['grad_norm']):.2f}")
        if checkpointer is not None and ckpt_every and (i + 1) % ckpt_every == 0:
            checkpointer.save(params, opt_state, step=i + 1)
    return params, opt_state
