"""Hand-rolled AdamW + LR schedules (no external optimizer deps).

Optimizer state is a pytree congruent with params, so the sharding rules in
``distributed.sharding`` apply verbatim — m/v shard exactly like their
parameter (ZeRO-style when FSDP is on).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.01
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10000
    schedule: str = "cosine"  # "cosine" | "linear" | "constant"


def lr_at(step, oc: AdamWConfig):
    warm = jnp.minimum(step / jnp.maximum(oc.warmup_steps, 1), 1.0)
    if oc.schedule == "constant":
        decay = 1.0
    else:
        t = jnp.clip((step - oc.warmup_steps) /
                     jnp.maximum(oc.total_steps - oc.warmup_steps, 1), 0.0, 1.0)
        decay = (0.5 * (1 + jnp.cos(jnp.pi * t)) if oc.schedule == "cosine"
                 else 1.0 - t)
    return oc.lr * warm * decay


def init_opt_state(params) -> dict:
    zeros = lambda p: jnp.zeros_like(p, dtype=jnp.float32)
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def global_norm(tree) -> jnp.ndarray:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def clip_by_global_norm(grads, max_norm):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: g * scale, grads), norm


_NO_DECAY = ("scale", "bias", "A_log", "dt_bias", "D")


def _decay_mask(path) -> bool:
    last = str(getattr(path[-1], "key", path[-1]))
    return last not in _NO_DECAY


def adamw_update(params, grads, opt_state, oc: AdamWConfig):
    """One AdamW step. Returns (new_params, new_opt_state, metrics)."""
    grads = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
    if oc.grad_clip:
        grads, gnorm = clip_by_global_norm(grads, oc.grad_clip)
    else:
        gnorm = global_norm(grads)
    step = opt_state["step"] + 1
    lr = lr_at(step, oc)
    b1c = 1 - oc.b1 ** step.astype(jnp.float32)
    b2c = 1 - oc.b2 ** step.astype(jnp.float32)

    def upd(path, p, g, m, v):
        m = oc.b1 * m + (1 - oc.b1) * g
        v = oc.b2 * v + (1 - oc.b2) * g * g
        mh = m / b1c
        vh = v / b2c
        delta = mh / (jnp.sqrt(vh) + oc.eps)
        if oc.weight_decay and _decay_mask(path):
            delta = delta + oc.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

    flat = jax.tree_util.tree_map_with_path(
        lambda path, p, g, m, v: upd(path, p, g, m, v),
        params, grads, opt_state["m"], opt_state["v"])
    new_params = jax.tree.map(lambda t: t[0], flat,
                              is_leaf=lambda x: isinstance(x, tuple))
    new_m = jax.tree.map(lambda t: t[1], flat,
                         is_leaf=lambda x: isinstance(x, tuple))
    new_v = jax.tree.map(lambda t: t[2], flat,
                         is_leaf=lambda x: isinstance(x, tuple))
    return new_params, {"m": new_m, "v": new_v, "step": step}, {
        "lr": lr, "grad_norm": gnorm}
