"""Block-size autotuner for the Pallas kernel wrappers.

The wrappers in ``repro.kernels.ops`` used to hard-code block-size targets
(128/256) and pick the largest dividing block under them.  This module
keeps that shape discipline but chooses among *valid candidates* with an
analytic roofline model (the same v5e constants ``benchmarks/roofline.py``
reports against), and lets ``benchmarks/kernel_ablation.py`` overwrite the
analytic choice with a *measured* one: its autotune section times the
candidate set through the real kernels and records the winner in a cached
per-shape table (``runs/autotune.json`` by default, override with
``REPRO_AUTOTUNE_CACHE``).  Lookup order per shape key:

1. in-process memo;
2. measured entry in the cache file;
3. analytic roofline score over the candidate set.

``REPRO_AUTOTUNE=0`` opts out entirely and restores the legacy fixed
targets (still via :func:`pick_block`, so the divisibility contracts are
enforced either way).

Scoring is deterministic: ``max(flops/peak, bytes/bw)`` plus a per-grid-
step launch overhead, with a hard penalty for blocks whose VMEM footprint
exceeds the budget.  For the small shapes the repo's tests use, the
largest valid blocks win — i.e. the analytic tuner reproduces the legacy
choices exactly and only diverges where a measured entry says otherwise.
"""
from __future__ import annotations

import json
import os
from typing import Callable, Sequence

# TPU v5e — single-sourced here; benchmarks/roofline.py imports these.
PEAK_FLOPS = 197e12   # bf16 MXU FLOP/s
HBM_BW = 819e9        # HBM bytes/s
LINK_BW = 50e9        # ICI bytes/s per link

GRID_STEP_OVERHEAD_S = 2e-6     # per-grid-step issue/DMA-setup cost
VMEM_BUDGET_BYTES = 16 * 2**20  # working-set budget per kernel instance

_MEMO: dict = {}
_FILE_CACHE: dict | None = None


def enabled() -> bool:
    return os.environ.get("REPRO_AUTOTUNE", "1") != "0"


def cache_path() -> str:
    return os.environ.get("REPRO_AUTOTUNE_CACHE", "runs/autotune.json")


def reset() -> None:
    """Drop the in-process memo and the loaded cache file (tests)."""
    global _FILE_CACHE
    _MEMO.clear()
    _FILE_CACHE = None


# ---------------------------------------------------------------------------
# Valid block enumeration (the divisibility contracts live here)
# ---------------------------------------------------------------------------


def pick_block(n: int, target: int, multiple_of: int = 1) -> int:
    """Largest divisor of ``n`` that is <= ``target`` and a multiple of
    ``multiple_of``; falls back to ``n`` itself when no smaller divisor
    qualifies.

    Raises ``ValueError`` when no valid block exists at all — i.e. ``n``
    itself violates ``multiple_of`` (this used to be returned silently,
    truncating downstream BlockSpec shapes like the tile-scheme scales
    block ``bn // (group_size // 2)``).
    """
    b = min(n, target)
    while b > 1 and (n % b or b % multiple_of):
        b -= 1
    if b > 1:
        return b
    if n % multiple_of:
        raise ValueError(
            f"no valid block size for an axis of size {n}: blocks must "
            f"divide {n} and be a multiple of {multiple_of} (target "
            f"{target}), but {n} itself is not a multiple of {multiple_of}")
    return n


def block_candidates(n: int, target: int, multiple_of: int = 1,
                     max_candidates: int = 4) -> list[int]:
    """Valid block sizes (divisors of ``n``, multiples of ``multiple_of``),
    largest-first starting at ``min(n, target)``, at most
    ``max_candidates``.  Always contains :func:`pick_block`'s choice; same
    ``ValueError`` contract when no valid block exists."""
    out = []
    b = min(n, target)
    while b >= 1 and len(out) < max_candidates:
        if n % b == 0 and b % multiple_of == 0:
            out.append(b)
        b -= 1
    if not out:
        out = [pick_block(n, target, multiple_of)]  # n itself, or raises
    return out


# ---------------------------------------------------------------------------
# Cache file (measured entries recorded by benchmarks/kernel_ablation.py)
# ---------------------------------------------------------------------------


def _load_cache() -> dict:
    global _FILE_CACHE
    if _FILE_CACHE is None:
        path = cache_path()
        try:
            with open(path) as f:
                _FILE_CACHE = json.load(f).get("entries", {})
        except (OSError, ValueError):
            _FILE_CACHE = {}
    return _FILE_CACHE


def record(key: str, blocks: Sequence[int], us: float) -> None:
    """Record a measured block choice for ``key`` in the cache file (and
    the in-process view, so subsequent picks use it immediately)."""
    entries = dict(_load_cache())
    entries[key] = {"blocks": [int(b) for b in blocks], "us": float(us),
                    "source": "measured"}
    path = cache_path()
    d = os.path.dirname(path)
    if d:
        os.makedirs(d, exist_ok=True)
    with open(path, "w") as f:
        json.dump({"version": 1, "entries": entries}, f, indent=2,
                  sort_keys=True)
        f.write("\n")
    global _FILE_CACHE
    _FILE_CACHE = entries
    _MEMO.pop((key, True), None)


# ---------------------------------------------------------------------------
# Choice machinery
# ---------------------------------------------------------------------------


def roofline_bound_s(flops: float, hbm_bytes: float) -> float:
    """Analytic lower bound on wall seconds: compute- or bandwidth-bound,
    whichever is worse.  The profiler divides this by measured wall time
    to get achieved-vs-peak efficiency."""
    return max(flops / PEAK_FLOPS, hbm_bytes / HBM_BW)


def _roofline_score(flops: float, hbm_bytes: float, grid_steps: int,
                    vmem_bytes: float) -> float:
    t = roofline_bound_s(flops, hbm_bytes)
    t += grid_steps * GRID_STEP_OVERHEAD_S
    if vmem_bytes > VMEM_BUDGET_BYTES:
        t *= 1e3  # does not fit: effectively reject
    return t


def choose(key: str, axes: Sequence[tuple[int, int, int]],
           score_fn: Callable[[Sequence[int]], float]) -> tuple[int, ...]:
    """Pick one block size per ``(n, target, multiple_of)`` axis.

    With autotuning off this is exactly the legacy per-axis
    :func:`pick_block`.  Otherwise a measured cache entry for ``key``
    wins; failing that, the lowest ``score_fn`` over the cartesian
    candidate set (ties to the largest blocks)."""
    memo_key = (key, enabled())
    if memo_key in _MEMO:
        return _MEMO[memo_key]
    if not enabled():
        blocks = tuple(pick_block(*a) for a in axes)
    else:
        ent = _load_cache().get(key)
        if ent and len(ent.get("blocks", ())) == len(axes):
            blocks = tuple(int(b) for b in ent["blocks"])
        else:
            import itertools

            cands = [block_candidates(*a) for a in axes]
            blocks = min(itertools.product(*cands),
                         key=lambda bl: (score_fn(bl),
                                         tuple(-b for b in bl)))
    _MEMO[memo_key] = blocks
    return blocks


# ---------------------------------------------------------------------------
# Per-kernel shape keys, constraints and cost models
# ---------------------------------------------------------------------------
#
# Each ``*_cost`` function returns ``(flops, hbm_bytes)`` for one kernel
# invocation.  Called without block sizes it gives the *ideal single-pass*
# traffic — the roofline lower bound ``serving/profiling.KernelProfiler``
# attributes measured wall time against; with block sizes it gives the
# *streamed* traffic (operands re-read once per block of the other
# operand) that the score closures below rank candidates by.  Keeping
# both behind one function is what "single-sourced cost models" means:
# the tuner and the profiler can never disagree about what a kernel
# should cost.


def gemm_cost(M: int, K: int, N: int, *, bm: int | None = None,
              bn: int | None = None) -> tuple[float, float]:
    """LUT-dequant GEMM: x (M,K) f32 @ 4-bit codes (K,N) -> (M,N) f32."""
    m_rep = 1 if bm is None else M // bm
    n_rep = 1 if bn is None else N // bn
    # x streams once per N-block, codes once per M-block, out once
    hbm = (M * K * 4) * n_rep + (K * N // 2) * m_rep + M * N * 4
    return 2.0 * M * N * K, float(hbm)


def attn_cost(BH: int, Sq: int, Skv: int, D: int, *,
              bq: int | None = None) -> tuple[float, float]:
    """LUT-softmax flash attention over (BH, Sq|Skv, D) fp16 operands."""
    q_rep = 1 if bq is None else Sq // bq
    hbm = BH * (Sq * D * 2 + 2 * Skv * D * 2 * q_rep + Sq * D * 2)
    return 4.0 * BH * Sq * Skv * D, float(hbm)


def paged_attn_cost(B: int, Hq: int, W: int, bs: int, D: int, *,
                    slab_bytes: float) -> tuple[float, float]:
    """Paged decode attention: q (B,1,Hq,D) against W blocks of bs
    tokens per row.  ``slab_bytes`` is one token's (Hkv, D) K-slab in
    pool storage (codes+scales for quantized pools), so the bound is
    layout-aware: a q8 pool moves ~4x fewer KV bytes than fp32."""
    skv = W * bs
    hbm = B * (Hq * D * 2 + 2 * skv * slab_bytes + Hq * D * 4)
    return 4.0 * B * Hq * skv * D, float(hbm)


def quantize_cost(K: int, N: int) -> tuple[float, float]:
    """Tile quantization of a (K, N) f32 weight to 4-bit codes."""
    return 4.0 * K * N, float(K * N * 4 + K * N // 2)


def dequant_kv_cost(R: int, H: int, D: int,
                    mode: str) -> tuple[float, float]:
    """vlut16 KV-slab dequant: R token slabs of (H, D) codes -> f32."""
    slab_in = H * (D // 2 if mode == "q4" else D) + H * D // 8
    slab_out = H * D * 4
    return 2.0 * R * H * D, float(R * (slab_in + slab_out))


def gemm_key(M: int, K: int, N: int, scheme: str, group_size: int) -> str:
    return f"gemm:{M}x{K}x{N}:{scheme}:g{group_size}"


def gemm_blocks(M: int, K: int, N: int, *, scheme: str,
                group_size: int = 32) -> tuple[int, int, int]:
    """(bm, bn, bk) for ``lut_dequant_gemm`` under the scheme's scale-
    block divisibility constraints."""
    if scheme == "tile":
        mk, mn = 2, group_size // 2
    else:
        mk, mn = group_size, 2
    axes = [(M, 128, 1), (N, 256, mn), (K, 128, mk)]

    def score(bl):
        bm, bn, bk = bl
        steps = (M // bm) * (N // bn) * (K // bk)
        vmem = (bm * bk + 2 * bk * bn + 2 * bm * bn) * 4
        flops, hbm = gemm_cost(M, K, N, bm=bm, bn=bn)
        return _roofline_score(flops, hbm, steps, vmem)

    return choose(gemm_key(M, K, N, scheme, group_size), axes, score)


def attn_key(BH: int, Sq: int, Skv: int, D: int, bq_target: int = 128,
             bkv_target: int = 128) -> str:
    return f"attn:{BH}x{Sq}x{Skv}x{D}:t{bq_target}x{bkv_target}"


def attn_blocks(BH: int, Sq: int, Skv: int, D: int, *, bq_target: int = 128,
                bkv_target: int = 128) -> tuple[int, int]:
    """(bq, bkv) for ``lut_softmax_attention``."""
    axes = [(Sq, bq_target, 1), (Skv, bkv_target, 1)]

    def score(bl):
        bq, bkv = bl
        steps = BH * (Sq // bq) * (Skv // bkv)
        vmem = (bq * D + 2 * bkv * D) * 2 + bq * D * 4 + bq * bkv * 4
        flops, hbm = attn_cost(BH, Sq, Skv, D, bq=bq)
        return _roofline_score(flops, hbm, steps, vmem)

    return choose(attn_key(BH, Sq, Skv, D, bq_target, bkv_target), axes,
                  score)


def quantize_key(K: int, N: int) -> str:
    return f"quantize:{K}x{N}"


def quantize_blocks(K: int, N: int) -> tuple[int, int]:
    """(bk, bn) for ``tile_quantize``."""
    axes = [(K, 128, 1), (N, 256, 1)]

    def score(bl):
        bk, bn = bl
        steps = (K // bk) * (N // bn)
        vmem = bk * bn * 6
        flops, hbm = quantize_cost(K, N)
        return _roofline_score(flops, hbm, steps, vmem)

    return choose(quantize_key(K, N), axes, score)


def dequant_key(R: int, H: int, D: int, mode: str) -> str:
    return f"dequant_kv:{R}x{H}x{D}:{mode}"


def dequant_rows(R: int, H: int, D: int, mode: str) -> int:
    """Row-block size for ``lut_dequant_kv`` (token-slab dequant)."""
    axes = [(R, 256, 1)]
    slab_in = H * (D // 2 if mode == "q4" else D) + H * D // 8
    slab_out = H * D * 4

    def score(bl):
        (br,) = bl
        steps = R // br
        flops, hbm = dequant_kv_cost(R, H, D, mode)
        return _roofline_score(flops, hbm, steps,
                               br * (slab_in + slab_out))

    (br,) = choose(dequant_key(R, H, D, mode), axes, score)
    return br
