"""Pallas TPU kernel: on-device tile-group quantization (Q4_0 grid).

The paper quantizes offline; this kernel exists for the cases where weights
are produced on-device (e.g. checkpoint-load-time quantization of a trained
model) so the fp weights never have to round-trip through HBM twice.
Geometry matches ``quant.tile_quant.quantize(scheme='tile')``: (2, 16)
groups, scale = absmax/8, codes packed two-per-byte along N.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(w_ref, codes_ref, scales_ref, *, group_size: int):
    w = w_ref[...].astype(jnp.float32)           # (bk, bn)
    bk, bn = w.shape
    gr, gc = 2, group_size // 2
    wg = w.reshape(bk // gr, gr, bn // gc, gc)
    absmax = jnp.max(jnp.abs(wg), axis=(1, 3))   # (bk//2, bn//16)
    scales = absmax / 8.0
    scales_ref[...] = scales.astype(scales_ref.dtype)
    sc = jnp.repeat(jnp.repeat(jnp.maximum(scales, 1e-8), gr, axis=0), gc, axis=1)
    q = jnp.clip(jnp.round(w / sc), -8, 7) + 8   # [0, 15]
    q = q.astype(jnp.uint8)
    lo = q[:, 0::2]
    hi = q[:, 1::2]
    codes_ref[...] = lo | (hi << 4)


@functools.partial(jax.jit, static_argnames=("group_size", "bk", "bn", "interpret"))
def tile_quantize(w, *, group_size: int = 32, bk: int = 128, bn: int = 256,
                  interpret: bool = True):
    """w: (K, N) -> (codes (K, N//2) uint8, scales (K//2, N//16) f16)."""
    K, N = w.shape
    bk, bn = min(bk, K), min(bn, N)
    assert K % bk == 0 and N % bn == 0
    g = group_size
    return pl.pallas_call(
        functools.partial(_kernel, group_size=g),
        grid=(K // bk, N // bn),
        in_specs=[pl.BlockSpec((bk, bn), lambda i, j: (i, j))],
        out_specs=[
            pl.BlockSpec((bk, bn // 2), lambda i, j: (i, j)),
            pl.BlockSpec((bk // 2, bn // (g // 2)), lambda i, j: (i, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((K, N // 2), jnp.uint8),
            jax.ShapeDtypeStruct((K // 2, N // (g // 2)), jnp.float16),
        ],
        interpret=interpret,
    )(w)
