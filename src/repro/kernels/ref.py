"""Pure-jnp oracles for every Pallas kernel (per-kernel allclose targets)."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.quant import tile_quant as TQ
from repro.kernels.lut_softmax_attention import NEG_CAP, build_exp_lut, LUT_SIZE


def dequant_matmul_ref(x, codes, scales, codebook, *, group_size: int = 32):
    """Oracle for lut_dequant_gemm: dequantize-then-matmul in plain jnp."""
    qw = {"codes": codes, "scales": scales, "codebook": codebook}
    w = TQ.dequantize(qw, dtype=jnp.float32, group_size=group_size)
    return (x.astype(jnp.float32) @ w).astype(x.dtype)


def tile_quantize_ref(w, *, group_size: int = 32):
    """Oracle for tile_quantize: the offline quantizer with the q4_0 grid."""
    qw = TQ.quantize(w, scheme="tile", codebook="q4_0", group_size=group_size)
    return qw["codes"], qw["scales"]


def _lut_exp_ref(lut, x16):
    bits = jax.lax.bitcast_convert_type(x16, jnp.uint16)
    idx = jnp.bitwise_and(bits.astype(jnp.int32), 0x7FFF)
    return jnp.take(lut[0], idx, axis=0)


def lut_flash_attention_ref(q, k, v, lut=None, *, causal: bool = True,
                            bkv: int = 128, exp_mode: str = "lut"):
    """Bit-faithful oracle for lut_softmax_attention.

    Runs the same FP16 online-softmax recurrence (Alg. 1) with the same KV
    blocking in plain jnp (python loop over KV blocks), so the kernel must
    match to ~fp16 resolution.
    q/k/v: (BH, S, D) fp16.
    """
    if lut is None:
        lut = build_exp_lut()
    BH, Sq, D = q.shape
    Skv = k.shape[1]
    bkv = min(bkv, Skv)
    scale = 1.0 / math.sqrt(D)
    nkv = Skv // bkv

    m = jnp.full((BH, Sq, 1), NEG_CAP, jnp.float16)
    l = jnp.zeros((BH, Sq, 1), jnp.float32)
    acc = jnp.zeros((BH, Sq, D), jnp.float32)
    qpos = jnp.arange(Sq)[:, None]

    for j in range(nkv):
        kj = k[:, j * bkv:(j + 1) * bkv]
        vj = v[:, j * bkv:(j + 1) * bkv]
        s = jnp.einsum("bqd,bkd->bqk", q, kj,
                       preferred_element_type=jnp.float32) * scale
        if causal:
            kpos = j * bkv + jnp.arange(bkv)[None]
            s = jnp.where(kpos <= qpos, s, NEG_CAP)
        s16 = s.astype(jnp.float16)
        m_new = jnp.maximum(m, jnp.max(s16, axis=-1, keepdims=True))
        x = s16 - m_new
        if exp_mode == "lut":
            p = _lut_exp_ref(lut, x)
            corr = _lut_exp_ref(lut, m - m_new)
        else:
            p = jnp.exp(x.astype(jnp.float32)).astype(jnp.float16)
            corr = jnp.exp((m - m_new).astype(jnp.float32)).astype(jnp.float16)
        corr_f = corr.astype(jnp.float32)
        l = l * corr_f + jnp.sum(p.astype(jnp.float32), axis=-1, keepdims=True)
        acc = acc * corr_f + jnp.einsum(
            "bqk,bkd->bqd", p, vj.astype(jnp.float16),
            preferred_element_type=jnp.float32)
        m = m_new
    return (acc / jnp.maximum(l, 1e-30)).astype(jnp.float16)


def paged_decode_attention_ref(q, k_pool, v_pool, table, lengths, *,
                               window: int = 0, softcap: float = 0.0):
    """Oracle for paged_attention: materialize the block-table gather and
    run plain masked f32 softmax attention.

    q: (B, Hkv, G, D); pools: (n_blocks, bs, Hkv, D); table: (B, W) int32
    (block w of a row holds positions [w*bs, (w+1)*bs)); lengths: (B,)
    int32 including the current token.  Returns (B, Hkv, G, D).
    """
    B, Hkv, G, D = q.shape
    bs = k_pool.shape[1]
    W = table.shape[1]
    k_seq = k_pool[table].reshape(B, W * bs, Hkv, D)  # (B, S, Hkv, D)
    v_seq = v_pool[table].reshape(B, W * bs, Hkv, D)
    s = jnp.einsum("bhgd,bkhd->bhgk", q.astype(jnp.float32),
                   k_seq.astype(jnp.float32)) / math.sqrt(D)
    if softcap:
        s = jnp.tanh(s / softcap) * softcap
    kv_pos = jnp.arange(W * bs)[None]                 # (1, S)
    valid = kv_pos < lengths[:, None]
    if window > 0:
        valid &= (lengths[:, None] - 1) - kv_pos < window
    s = jnp.where(valid[:, None, None], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhgk,bkhd->bhgd", p, v_seq.astype(jnp.float32))


def lut_paged_decode_attention_ref(q, k_pool, v_pool, table, lengths,
                                   lut=None, *, window: int = 0,
                                   softcap: float = 0.0):
    """Oracle for ``paged_attention(..., exp_mode='lut')``: the fp16
    Alg. 1 recurrence walked block-by-block through the table in plain
    jnp, mirroring the kernel's masking/guard order so it must match to
    ~fp16 resolution.  Fully-masked rows (``lengths == 0``) return 0.

    q: (B, Hkv, G, D); pools: (n_blocks, bs, Hkv, D) fp; table (B, W);
    lengths (B,).  Returns (B, Hkv, G, D) f32.
    """
    if lut is None:
        lut = build_exp_lut()
    B, Hkv, G, D = q.shape
    bs = k_pool.shape[1]
    W = table.shape[1]
    scale = 1.0 / math.sqrt(D)

    m = jnp.full((B, Hkv, G, 1), NEG_CAP, jnp.float16)
    l = jnp.zeros((B, Hkv, G, 1), jnp.float32)
    acc = jnp.zeros((B, Hkv, G, D), jnp.float32)
    for j in range(W):
        kj = k_pool[table[:, j]]                      # (B, bs, Hkv, D)
        vj = v_pool[table[:, j]]
        s = jnp.einsum("bhgd,bshd->bhgs", q.astype(jnp.float32),
                       kj.astype(jnp.float32),
                       preferred_element_type=jnp.float32) * scale
        if softcap:
            s = jnp.tanh(s / softcap) * softcap
        kv_pos = j * bs + jnp.arange(bs)[None]        # (1, bs)
        valid = kv_pos < lengths[:, None]
        if window > 0:
            valid &= (lengths[:, None] - 1) - kv_pos < window
        vb = valid[:, None, None, :]                  # (B, 1, 1, bs)
        s16 = jnp.where(vb, s, NEG_CAP).astype(jnp.float16)
        m_new = jnp.maximum(m, jnp.max(s16, axis=-1, keepdims=True))
        p = _lut_exp_ref(lut, s16 - m_new)
        corr = _lut_exp_ref(lut, m - m_new).astype(jnp.float32)
        p = jnp.where(vb, p, jnp.float16(0))
        l = l * corr + jnp.sum(p.astype(jnp.float32), axis=-1, keepdims=True)
        acc = acc * corr + jnp.einsum(
            "bhgs,bshd->bhgd", p, vj.astype(jnp.float16),
            preferred_element_type=jnp.float32)
        m = m_new
    return acc / jnp.maximum(l, 1e-30)


def quant_lut_paged_decode_attention_ref(q, k_pool, v_pool, table, lengths,
                                         lut=None, *, window: int = 0,
                                         softcap: float = 0.0):
    """Oracle for ``quant_paged_attention(..., exp_mode='lut')``:
    dequantize the whole pool with the reference tile dequantizer, then
    run the fp16 LUT paged recurrence."""
    from repro.serving.kv_quant import dequantize_kv

    return lut_paged_decode_attention_ref(
        q, dequantize_kv(k_pool), dequantize_kv(v_pool), table, lengths,
        lut, window=window, softcap=softcap)


def quant_paged_decode_attention_ref(q, k_pool, v_pool, table, lengths, *,
                                     window: int = 0, softcap: float = 0.0):
    """Oracle for quant_paged_attention: dequantize the *whole* pool with
    the reference tile dequantizer (``repro.serving.kv_quant``), then run
    the fp paged oracle — the kernel's fused per-block VMEM dequant must
    be invisible next to materialize-then-attend.

    ``k_pool``/``v_pool``: {"codes", "scales"} leaf dicts with per-layer
    layout (n_blocks, bs, Hkv, Dc) / (n_blocks, bs, Hkv//gr, D//gc).
    """
    from repro.serving.kv_quant import dequantize_kv

    return paged_decode_attention_ref(
        q, dequantize_kv(k_pool), dequantize_kv(v_pool), table, lengths,
        window=window, softcap=softcap)


def attention_f32_ref(q, k, v, *, causal: bool = True):
    """Conventional F32 attention (the paper's Table-5 baseline)."""
    BH, Sq, D = q.shape
    s = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) / math.sqrt(D)
    if causal:
        Skv = k.shape[1]
        mask = jnp.arange(Skv)[None] <= jnp.arange(Sq)[:, None]
        s = jnp.where(mask, s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bqk,bkd->bqd", p, v.astype(jnp.float32))
