"""Pallas TPU kernel: FP16 FlashAttention with LUT-based exp (paper Alg. 1).

Faithful port of the paper's §5.2.1 design:

* S, P, m, l are FP16; QKᵀ / rowsum(P) / O-accumulation are FP32
  (``AccumType=FP32`` in Alg. 1);
* ``exp`` is a table lookup into a 2^15-entry FP16 table: safe softmax
  guarantees the argument x = s − m ≤ 0, so the sign bit is constant and
  the low 15 bits of the FP16 pattern index the table (the paper's
  "ignore the MSB, left-shift by one" trick, §5.2.1);
* the same table also yields the correction factor e^{m_prev − m_new}
  (Alg. 1 lines 5–6);
* the table is precomputed once at FP32+ precision (paper: "floating-point
  numbers with a width of 32 bits or higher"), so LUT-exp is *more*
  accurate than an in-kernel FP16 polynomial.

The kernel also exposes ``exp_mode='poly'|'exact'`` re-implementing the
paper's Fig. 14 ablation baselines (FP16 polynomial exp2, FP32 exp).

Grid: (B*Hq, nq, nkv), kv innermost; m/l/acc live in VMEM scratch.
The table (64 KiB) sits in VMEM — 0.05% of a v5e core's ~128 MiB, the
analogue of the paper's 0.8%-of-TCM budget.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

try:
    from jax.experimental.pallas import tpu as pltpu
except Exception:  # pragma: no cover
    pltpu = None

NEG_CAP = -30000.0  # finite "-inf" in fp16 range; LUT(e^{-30000}) == 0

LUT_SIZE = 32768


def build_exp_lut(dtype=jnp.float16) -> jnp.ndarray:
    """LUT[i] = exp(x) where x is the fp16 with bit pattern (0x8000 | i).

    Index = low 15 bits of the fp16 argument (which is ≤ 0 under safe
    softmax). Entries whose pattern decodes to -inf/NaN hold 0 — exp(-inf).
    Intermediates are computed in f32 (the paper's accuracy argument).
    """
    bits = (jnp.arange(LUT_SIZE, dtype=jnp.uint32) | 0x8000).astype(jnp.uint16)
    x = jax.lax.bitcast_convert_type(bits, jnp.float16).astype(jnp.float32)
    vals = jnp.exp(x)
    vals = jnp.where(jnp.isfinite(x), vals, 0.0)  # -inf and NaN patterns -> 0
    return vals.astype(dtype).reshape(1, LUT_SIZE)


def _lut_exp(lut, x16):
    """x16: fp16 (≤ 0). Returns fp16 exp via 15-bit table index."""
    bits = jax.lax.bitcast_convert_type(x16, jnp.uint16)
    idx = jnp.bitwise_and(bits.astype(jnp.int32), 0x7FFF)
    return jnp.take(lut[0], idx, axis=0)


def _poly_exp(x16):
    """FP16 polynomial exp2 baseline (paper's conventional approach):
    exp(x) = 2^{x·log2e}; split y into integer k and fraction f, 2^f by a
    degree-4 Taylor/minimax polynomial, scale by 2^k."""
    y = x16.astype(jnp.float32) * 1.4426950408889634
    k = jnp.floor(y)
    f = y - k
    ln2 = 0.6931471805599453
    t = f * ln2
    p = 1.0 + t * (1.0 + t * (0.5 + t * (1.0 / 6.0 + t * (1.0 / 24.0))))
    return jnp.ldexp(p, k.astype(jnp.int32)).astype(jnp.float16)


def _kernel(q_ref, k_ref, v_ref, lut_ref, o_ref, acc_ref, m_ref, l_ref,
            *, nkv: int, scale: float, causal: bool, bq: int, bkv: int,
            exp_mode: str):
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_CAP)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0]                                    # (bq, d)
    k = k_ref[0]                                    # (bkv, d)
    v = v_ref[0]

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    if causal:
        qi = pl.program_id(1)
        qpos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bkv), 0)
        kpos = j * bkv + jax.lax.broadcasted_iota(jnp.int32, (bq, bkv), 1)
        s = jnp.where(kpos <= qpos, s, NEG_CAP)

    s16 = s.astype(jnp.float16)                     # S in FP16 (Alg. 1)
    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s16, axis=-1, keepdims=True))
    x = s16 - m_new                                 # ≤ 0 by construction
    if exp_mode == "lut":
        p = _lut_exp(lut_ref, x)                    # FP16 P via table
        corr = _lut_exp(lut_ref, m_prev - m_new)
    elif exp_mode == "poly":
        p = _poly_exp(x)
        corr = _poly_exp(m_prev - m_new)
    else:  # exact f32
        p = jnp.exp(x.astype(jnp.float32)).astype(jnp.float16)
        corr = jnp.exp((m_prev - m_new).astype(jnp.float32)).astype(jnp.float16)

    corr_f = corr.astype(jnp.float32)
    l_ref[...] = (l_ref[...] * corr_f +
                  jnp.sum(p.astype(jnp.float32), axis=-1, keepdims=True))
    pv = jax.lax.dot_general(p, v.astype(jnp.float16),
                             (((1,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)
    acc_ref[...] = acc_ref[...] * corr_f + pv
    m_ref[...] = m_new

    @pl.when(j == nkv - 1)
    def _flush():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0] = (acc_ref[...] / l).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "bq", "bkv",
                                             "interpret", "exp_mode"))
def lut_softmax_attention(q, k, v, lut, *, causal: bool = True, bq: int = 128,
                          bkv: int = 128, interpret: bool = True,
                          exp_mode: str = "lut"):
    """q: (BH, Sq, D) fp16; k, v: (BH, Skv, D) fp16 (kv heads pre-expanded).

    Returns (BH, Sq, D) fp16. GQA head mapping is done by the ops wrapper.
    """
    BH, Sq, D = q.shape
    _, Skv, _ = k.shape
    bq, bkv = min(bq, Sq), min(bkv, Skv)
    if Sq % bq or Skv % bkv:
        raise ValueError(
            f"lut_softmax_attention: block sizes must divide the sequence "
            f"lengths, got Sq={Sq} with bq={bq} (Sq % bq = {Sq % bq}) and "
            f"Skv={Skv} with bkv={bkv} (Skv % bkv = {Skv % bkv})")
    nq, nkv = Sq // bq, Skv // bkv
    scale = 1.0 / math.sqrt(D)

    kern = functools.partial(_kernel, nkv=nkv, scale=scale, causal=causal,
                             bq=bq, bkv=bkv, exp_mode=exp_mode)
    return pl.pallas_call(
        kern,
        grid=(BH, nq, nkv),
        in_specs=[
            pl.BlockSpec((1, bq, D), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bkv, D), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, bkv, D), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, LUT_SIZE), lambda b, i, j: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, D), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, Sq, D), jnp.float16),
        scratch_shapes=[
            pltpu.VMEM((bq, D), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float16),
            pltpu.VMEM((bq, 1), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v, lut)
