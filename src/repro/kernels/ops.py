"""Jit'd public wrappers around the Pallas kernels.

``interpret=True`` everywhere by default: this container is CPU-only and
interpret mode executes the kernel bodies in Python for correctness; on a
real TPU set ``repro.kernels.ops.INTERPRET = False`` (or env
``REPRO_PALLAS_INTERPRET=0``) and the same BlockSpecs compile to Mosaic.
"""
from __future__ import annotations

import functools
import math
import os

import jax
import jax.numpy as jnp

from repro.kernels import autotune as _autotune
from repro.kernels import lut_dequant_gemm as _gemm
from repro.kernels import lut_softmax_attention as _attn
from repro.kernels import paged_attention as _paged
from repro.kernels import tile_quantize as _tq
from repro.quant import tile_quant as TQ

INTERPRET = os.environ.get("REPRO_PALLAS_INTERPRET", "1") != "0"

# Kernel-dispatch recording hook (serving/profiling.KernelProfiler).
# ``hook(name, flops, hbm_bytes)`` fires once per wrapper call with the
# analytic cost from ``kernels/autotune`` — for jitted callers that means
# at *trace* time, which is exactly what the profiler wants: it caches
# each phase's op roster at trace time and replays it on cached-
# executable steps.  None (the default) is zero overhead.
_OP_HOOK = None


def set_op_hook(hook):
    """Install the dispatch-layer cost hook; returns the previous one so
    callers can restore it (``set_op_hook(None)`` disables)."""
    global _OP_HOOK
    prev, _OP_HOOK = _OP_HOOK, hook
    return prev


def record_op(name: str, flops: float, hbm_bytes: float) -> None:
    """Report one op's analytic (flops, hbm_bytes) to the installed hook.
    Public so dispatch sites outside this module — e.g. the XLA fallback
    branch of ``layers.paged_decode_attention`` — attribute through the
    same funnel."""
    if _OP_HOOK is not None:
        _OP_HOOK(name, float(flops), float(hbm_bytes))


def pool_slab_bytes(pool_leaf) -> float:
    """Storage bytes of one token's (Hkv, D) slab in a per-layer pool
    leaf ``(n_blocks, bs, Hkv, D)`` — codes + scales for quantized
    {"codes", "scales"} leaves, dtype bytes for fp arrays."""
    if isinstance(pool_leaf, dict):
        c, s = pool_leaf["codes"], pool_leaf["scales"]
        return float(c.shape[-2] * c.shape[-1] * c.dtype.itemsize
                     + s.shape[-2] * s.shape[-1] * s.dtype.itemsize)
    return float(pool_leaf.shape[-2] * pool_leaf.shape[-1]
                 * pool_leaf.dtype.itemsize)


_EXP_LUT = None


def exp_lut():
    global _EXP_LUT
    if _EXP_LUT is None:
        # built eagerly even when first requested under a jit trace (e.g.
        # inside the engine's scanned decode step) — caching a traced
        # value here would leak the tracer into every later caller
        with jax.ensure_compile_time_eval():
            _EXP_LUT = _attn.build_exp_lut()
    return _EXP_LUT


def _pick_block(n: int, target: int, multiple_of: int = 1) -> int:
    """Largest divisor of n that is <= target and a multiple of
    ``multiple_of`` (falls back to n itself).  Raises ``ValueError`` when
    no valid block exists — i.e. ``n`` itself violates ``multiple_of``
    (previously returned silently, truncating downstream BlockSpecs)."""
    return _autotune.pick_block(n, target, multiple_of)


def plan_lut_dequant_matmul(qw: dict, *, m: int, group_size: int = 32):
    """Resolve scheme inference and block-size selection once for a fixed
    (M, K, N) and return a callable ``x -> x @ dequant(qw)``.

    The returned closure goes straight to the jitted kernel — no per-call
    Python scheme/shape work, which is what hot loops (and fair timed
    ablations, see ``benchmarks/kernel_ablation.fig15_dequant_gemm``)
    should pay."""
    codes, scales, codebook = qw["codes"], qw["scales"], qw["codebook"]
    scheme = TQ.infer_scheme(qw, group_size)
    K = codes.shape[0]
    N = codes.shape[1] * 2
    bm, bn, bk = _autotune.gemm_blocks(m, K, N, scheme=scheme,
                                       group_size=group_size)

    def run(x):
        record_op("lut_dequant_matmul", *_autotune.gemm_cost(m, K, N))
        return _gemm.lut_dequant_gemm(
            x, codes, scales, codebook, scheme=scheme,
            group_size=group_size, bm=bm, bn=bn, bk=bk, interpret=INTERPRET)

    return run


def lut_dequant_matmul(x, qw: dict, *, group_size: int = 32):
    """x: (M, K); qw: quantized-weight leaf dict -> (M, N)."""
    return plan_lut_dequant_matmul(qw, m=x.shape[0],
                                   group_size=group_size)(x)


def flash_attention(q, k, v, *, causal: bool = True, exp_mode: str = "lut",
                    bq: int = 128, bkv: int = 128):
    """LUT-softmax FlashAttention with GQA support.

    q: (B, Sq, Hq, D); k, v: (B, Skv, Hkv, D) — any fp dtype, computed in
    fp16 per Alg. 1. Returns (B, Sq, Hq, D) in q.dtype.
    """
    B, Sq, Hq, D = q.shape
    _, Skv, Hkv, _ = k.shape
    G = Hq // Hkv
    qt = q.transpose(0, 2, 1, 3).reshape(B * Hq, Sq, D).astype(jnp.float16)
    kt = jnp.repeat(k.transpose(0, 2, 1, 3), G, axis=1).reshape(
        B * Hq, Skv, D).astype(jnp.float16)
    vt = jnp.repeat(v.transpose(0, 2, 1, 3), G, axis=1).reshape(
        B * Hq, Skv, D).astype(jnp.float16)
    bq_pick, bkv_pick = _autotune.attn_blocks(B * Hq, Sq, Skv, D,
                                              bq_target=bq, bkv_target=bkv)
    record_op("flash_attention", *_autotune.attn_cost(B * Hq, Sq, Skv, D))
    o = _attn.lut_softmax_attention(
        qt, kt, vt, exp_lut(), causal=causal,
        bq=bq_pick, bkv=bkv_pick, interpret=INTERPRET, exp_mode=exp_mode)
    return o.reshape(B, Hq, Sq, D).transpose(0, 2, 1, 3).astype(q.dtype)


def paged_flash_decode(q, k_pool, v_pool, table, cache_len, *,
                       window: int = 0, softcap: float = 0.0,
                       exp_mode: str = "exact"):
    """Paged decode attention through the block-table-walking kernel.

    q: (B, 1, Hq, D); pools: (n_blocks, bs, Hkv, D) fp arrays *or*
    tile-quantized {"codes", "scales"} leaf dicts (``repro.serving.
    kv_quant``), which route to the fused-dequant kernel; table: (B, W)
    int32; cache_len: (B,) int32 including the current token.  Returns
    (B, 1, Hq, D) in q.dtype — drop-in for ``layers.paged_decode_attention``
    (the XLA gather fallback) on the TPU hot path.

    ``exp_mode='lut'`` runs the fp16 LUT-softmax recurrence (Alg. 1)
    inside the same table walk — block gather + VMEM dequant + LUT exp in
    one pass; ``'exact'`` keeps the f32 recurrence.
    """
    B, _, Hq, D = q.shape
    quantized = isinstance(k_pool, dict)
    Hkv = (k_pool["codes"] if quantized else k_pool).shape[2]
    G = Hq // Hkv
    if _OP_HOOK is not None:
        record_op("paged_flash_decode", *_autotune.paged_attn_cost(
            B, Hq, table.shape[1],
            (k_pool["codes"] if quantized else k_pool).shape[1], D,
            slab_bytes=pool_slab_bytes(k_pool)))
    qg = q.reshape(B, Hkv, G, D)
    lut = exp_lut() if exp_mode == "lut" else None
    fn = _paged.quant_paged_attention if quantized else _paged.paged_attention
    o = fn(qg, k_pool, v_pool, table, cache_len, lut, window=window,
           softcap=softcap, interpret=INTERPRET, exp_mode=exp_mode)
    return o.reshape(B, 1, Hq, D)


def lut_dequant_gather(gathered):
    """Dequantize a gathered quantized-pool view through the vlut16
    dequant kernel (identity on fp arrays).

    ``gathered``: {"codes", "scales"} leaf dict with arbitrary leading
    dims over the (Hkv, D) token slab — e.g. the (L, B, P, ...) prefix
    view of the engine's partial prefill.  Bit-identical to
    ``repro.serving.kv_quant.dequantize_kv`` (same unpack, codebook take,
    scale broadcast and multiply, per element), so swapping it into read
    paths cannot change greedy outputs.
    """
    if not isinstance(gathered, dict):
        return gathered
    from repro.quant.codebooks import get_codebook
    from repro.serving.kv_quant import Q4_CODEBOOK, kv_geometry

    mode, gr, gc, d = kv_geometry(gathered)
    codes, scales = gathered["codes"], gathered["scales"]
    lead = codes.shape[:-2]
    r = math.prod(lead) if lead else 1
    br = _autotune.dequant_rows(r, codes.shape[-2], d, mode)
    record_op("lut_dequant_kv",
              *_autotune.dequant_kv_cost(r, codes.shape[-2], d, mode))
    out = _gemm.lut_dequant_kv(
        codes.reshape(r, *codes.shape[-2:]),
        scales.reshape(r, *scales.shape[-2:]),
        get_codebook(Q4_CODEBOOK), mode=mode, gr=gr, gc=gc, br=br,
        interpret=INTERPRET)
    return out.reshape(*lead, codes.shape[-2], d)


def tile_quantize_op(w, *, group_size: int = 32):
    """Kernel-quantize a (K, N) weight -> quantized leaf dict."""
    K, N = w.shape
    bk, bn = _autotune.quantize_blocks(K, N)
    record_op("tile_quantize", *_autotune.quantize_cost(K, N))
    codes, scales = _tq.tile_quantize(
        w, group_size=group_size, bk=bk, bn=bn, interpret=INTERPRET)
    from repro.quant.codebooks import get_codebook

    return {"codes": codes, "scales": scales, "codebook": get_codebook("q4_0")}
