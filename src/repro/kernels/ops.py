"""Jit'd public wrappers around the Pallas kernels.

``interpret=True`` everywhere by default: this container is CPU-only and
interpret mode executes the kernel bodies in Python for correctness; on a
real TPU set ``repro.kernels.ops.INTERPRET = False`` (or env
``REPRO_PALLAS_INTERPRET=0``) and the same BlockSpecs compile to Mosaic.
"""
from __future__ import annotations

import functools
import os

import jax
import jax.numpy as jnp

from repro.kernels import lut_dequant_gemm as _gemm
from repro.kernels import lut_softmax_attention as _attn
from repro.kernels import paged_attention as _paged
from repro.kernels import tile_quantize as _tq
from repro.quant import tile_quant as TQ

INTERPRET = os.environ.get("REPRO_PALLAS_INTERPRET", "1") != "0"

_EXP_LUT = None


def exp_lut():
    global _EXP_LUT
    if _EXP_LUT is None:
        _EXP_LUT = _attn.build_exp_lut()
    return _EXP_LUT


def _pick_block(n: int, target: int, multiple_of: int = 1) -> int:
    """Largest divisor of n that is <= target and a multiple of
    ``multiple_of`` (falls back to n itself)."""
    b = min(n, target)
    while b > 1 and (n % b or b % multiple_of):
        b -= 1
    if b <= 1 or b % multiple_of:
        return n
    return b


def lut_dequant_matmul(x, qw: dict, *, group_size: int = 32):
    """x: (M, K); qw: quantized-weight leaf dict -> (M, N)."""
    codes, scales = qw["codes"], qw["scales"]
    scheme = TQ.infer_scheme(qw, group_size)
    M, K = x.shape
    N = codes.shape[1] * 2
    bm = _pick_block(M, 128)
    # block sizes must respect group geometry
    if scheme == "tile":
        bk = _pick_block(K, 128, multiple_of=2)
        bn = _pick_block(N, 256, multiple_of=group_size // 2)
    else:
        bk = _pick_block(K, 128, multiple_of=group_size)
        bn = _pick_block(N, 256, multiple_of=2)
    return _gemm.lut_dequant_gemm(
        x, codes, scales, qw["codebook"], scheme=scheme,
        group_size=group_size, bm=bm, bn=bn, bk=bk, interpret=INTERPRET)


def flash_attention(q, k, v, *, causal: bool = True, exp_mode: str = "lut",
                    bq: int = 128, bkv: int = 128):
    """LUT-softmax FlashAttention with GQA support.

    q: (B, Sq, Hq, D); k, v: (B, Skv, Hkv, D) — any fp dtype, computed in
    fp16 per Alg. 1. Returns (B, Sq, Hq, D) in q.dtype.
    """
    B, Sq, Hq, D = q.shape
    _, Skv, Hkv, _ = k.shape
    G = Hq // Hkv
    qt = q.transpose(0, 2, 1, 3).reshape(B * Hq, Sq, D).astype(jnp.float16)
    kt = jnp.repeat(k.transpose(0, 2, 1, 3), G, axis=1).reshape(
        B * Hq, Skv, D).astype(jnp.float16)
    vt = jnp.repeat(v.transpose(0, 2, 1, 3), G, axis=1).reshape(
        B * Hq, Skv, D).astype(jnp.float16)
    o = _attn.lut_softmax_attention(
        qt, kt, vt, exp_lut(), causal=causal,
        bq=_pick_block(Sq, bq), bkv=_pick_block(Skv, bkv),
        interpret=INTERPRET, exp_mode=exp_mode)
    return o.reshape(B, Hq, Sq, D).transpose(0, 2, 1, 3).astype(q.dtype)


def paged_flash_decode(q, k_pool, v_pool, table, cache_len, *,
                       window: int = 0, softcap: float = 0.0):
    """Paged decode attention through the block-table-walking kernel.

    q: (B, 1, Hq, D); pools: (n_blocks, bs, Hkv, D) fp arrays *or*
    tile-quantized {"codes", "scales"} leaf dicts (``repro.serving.
    kv_quant``), which route to the fused-dequant kernel; table: (B, W)
    int32; cache_len: (B,) int32 including the current token.  Returns
    (B, 1, Hq, D) in q.dtype — drop-in for ``layers.paged_decode_attention``
    (the XLA gather fallback) on the TPU hot path.
    """
    B, _, Hq, D = q.shape
    quantized = isinstance(k_pool, dict)
    Hkv = (k_pool["codes"] if quantized else k_pool).shape[2]
    G = Hq // Hkv
    qg = q.reshape(B, Hkv, G, D)
    fn = _paged.quant_paged_attention if quantized else _paged.paged_attention
    o = fn(qg, k_pool, v_pool, table, cache_len, window=window,
           softcap=softcap, interpret=INTERPRET)
    return o.reshape(B, 1, Hq, D)


def tile_quantize_op(w, *, group_size: int = 32):
    """Kernel-quantize a (K, N) weight -> quantized leaf dict."""
    K, N = w.shape
    codes, scales = _tq.tile_quantize(
        w, group_size=group_size, bk=_pick_block(K, 128),
        bn=_pick_block(N, 256), interpret=INTERPRET)
    from repro.quant.codebooks import get_codebook

    return {"codes": codes, "scales": scales, "codebook": get_codebook("q4_0")}
