"""Pallas TPU kernel: gather-based paged decode attention.

One query token per sequence attends against a *paged* KV cache: KV bytes
live in a shared block pool ``(n_blocks, bs, Hkv, D)`` and each sequence
maps logical positions to pool blocks through a block table (block ``w``
of a row holds positions ``[w·bs, (w+1)·bs)``).

The block table and the per-row lengths ride in as **scalar-prefetch**
arguments (``pltpu.PrefetchScalarGridSpec``): the grid walks
``(B, Hkv, W)`` with the block index innermost, and the K/V BlockSpec
index maps dereference ``table[b, j]`` so the DMA engine fetches exactly
the row's j-th block — no (B, W·bs, …) gather is ever materialized, which
is the point: HBM traffic per step is the *live* KV, not the ``max_len``
reservation.  Table padding points at the reserved scratch block 0; its
contents are masked out via ``lengths`` like any past-the-end position.

Online-softmax accumulation (m/l/acc in VMEM scratch) is plain FP32 — the
paged kernel is about the memory layout; the LUT-exp FP16 variant lives in
``lut_softmax_attention``.  The identical-semantics XLA fallback used on
CPU is ``repro.models.layers.paged_decode_attention``; the pure-jnp oracle
is ``repro.kernels.ref.paged_decode_attention_ref``.

:func:`quant_paged_attention` is the same walk over a *tile-quantized*
pool (``repro.serving.kv_quant``): the BlockSpec index maps dereference
the table for the codes **and** the per-(2, 16)-tile scales — both
unit-stride by construction, the §5.1 layout story applied to KV — and
dequantization happens per block in VMEM (int8 scale-multiply for Q8, a
16-entry codebook gather for packed Q4, the vlut16 analogue) right before
the Q·Kᵀ dot.  HBM traffic per step is therefore the *quantized* live KV:
the paged saving and the quantization saving compound.  Oracle:
``ref.quant_paged_decode_attention_ref``; XLA fallback: the same
``layers.paged_decode_attention`` dispatching on the pool's leaf dicts.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

try:
    from jax.experimental.pallas import tpu as pltpu
except Exception:  # pragma: no cover
    pltpu = None

NEG_INF = -1e30


def _kernel(table_ref, len_ref, q_ref, k_ref, v_ref, o_ref,
            acc_ref, m_ref, l_ref, *, n_blk: int, block_size: int,
            scale: float, window: int, softcap: float):
    b = pl.program_id(0)
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0, 0]                                  # (G, D)
    k = k_ref[0, :, 0]                               # (bs, D)
    v = v_ref[0, :, 0]

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    if softcap:
        s = jnp.tanh(s / softcap) * softcap
    seq_len = len_ref[b]
    q_pos = seq_len - 1
    kv_pos = j * block_size + jax.lax.broadcasted_iota(
        jnp.int32, s.shape, 1)                       # (G, bs)
    valid = kv_pos < seq_len
    if window > 0:
        valid &= q_pos - kv_pos < window
    s = jnp.where(valid, s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    p = jnp.exp(s - m_new)
    corr = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=-1, keepdims=True)
    pv = jax.lax.dot_general(p, v.astype(jnp.float32),
                             (((1,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)
    acc_ref[...] = acc_ref[...] * corr + pv
    m_ref[...] = m_new

    @pl.when(j == n_blk - 1)
    def _flush():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("window", "softcap",
                                             "interpret"))
def paged_attention(q, k_pool, v_pool, table, lengths, *, window: int = 0,
                    softcap: float = 0.0, interpret: bool = True):
    """q: (B, Hkv, G, D); pools: (n_blocks, bs, Hkv, D); table: (B, W)
    int32 block ids (padding = scratch block 0); lengths: (B,) int32
    including the current token.  Returns (B, Hkv, G, D) in q.dtype.
    """
    B, Hkv, G, D = q.shape
    _, bs, _, _ = k_pool.shape
    W = table.shape[1]
    scale = 1.0 / math.sqrt(D)

    kern = functools.partial(_kernel, n_blk=W, block_size=bs, scale=scale,
                             window=window, softcap=softcap)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B, Hkv, W),
        in_specs=[
            pl.BlockSpec((1, 1, G, D),
                         lambda b, h, j, tbl, lens: (b, h, 0, 0)),
            pl.BlockSpec((1, bs, 1, D),
                         lambda b, h, j, tbl, lens: (tbl[b, j], 0, h, 0)),
            pl.BlockSpec((1, bs, 1, D),
                         lambda b, h, j, tbl, lens: (tbl[b, j], 0, h, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, G, D),
                               lambda b, h, j, tbl, lens: (b, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((G, D), jnp.float32),
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, 1), jnp.float32),
        ],
    )
    return pl.pallas_call(
        kern,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, Hkv, G, D), q.dtype),
        interpret=interpret,
    )(table.astype(jnp.int32), lengths.astype(jnp.int32), q, k_pool, v_pool)


# ---------------------------------------------------------------------------
# Quantized-pool variant: per-block VMEM dequant fused into the table walk
# ---------------------------------------------------------------------------


def _dequant_block(codes, scales, cb, *, mode: str, gc: int):
    """Dequantize one pool block's (bs, Dc) codes with (bs, D//gc) scales
    to (bs, D) f32.  The head axis is already sliced to one head (codes)
    and its covering tile row (scales), so the only broadcast left is the
    cheap unit-stride repeat along dims — no scatter, by construction."""
    from repro.serving.kv_quant import _unpack_q4

    s = jnp.repeat(scales.astype(jnp.float32), gc, axis=-1)  # (bs, D)
    if mode == "q8":
        return codes.astype(jnp.float32) * s
    idx = _unpack_q4(codes).astype(jnp.int32)
    return jnp.take(cb, idx, axis=0) * s  # vlut16 analogue (§5.2.2)


def _quant_kernel(table_ref, len_ref, q_ref, kc_ref, ks_ref, vc_ref, vs_ref,
                  cb_ref, o_ref, acc_ref, m_ref, l_ref, *, n_blk: int,
                  block_size: int, scale: float, window: int, softcap: float,
                  mode: str, gc: int):
    b = pl.program_id(0)
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    cb = cb_ref[0]                                   # (16,) f32
    q = q_ref[0, 0].astype(jnp.float32)              # (G, D)
    k = _dequant_block(kc_ref[0, :, 0], ks_ref[0, :, 0], cb,
                       mode=mode, gc=gc)             # (bs, D) f32
    v = _dequant_block(vc_ref[0, :, 0], vs_ref[0, :, 0], cb,
                       mode=mode, gc=gc)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    if softcap:
        s = jnp.tanh(s / softcap) * softcap
    seq_len = len_ref[b]
    q_pos = seq_len - 1
    kv_pos = j * block_size + jax.lax.broadcasted_iota(
        jnp.int32, s.shape, 1)                       # (G, bs)
    valid = kv_pos < seq_len
    if window > 0:
        valid &= q_pos - kv_pos < window
    s = jnp.where(valid, s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    p = jnp.exp(s - m_new)
    corr = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=-1, keepdims=True)
    pv = jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)
    acc_ref[...] = acc_ref[...] * corr + pv
    m_ref[...] = m_new

    @pl.when(j == n_blk - 1)
    def _flush():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("window", "softcap",
                                             "interpret"))
def quant_paged_attention(q, k_pool, v_pool, table, lengths, *,
                          window: int = 0, softcap: float = 0.0,
                          interpret: bool = True):
    """Paged decode attention over a tile-quantized block pool.

    q: (B, Hkv, G, D); ``k_pool``/``v_pool``: {"codes", "scales"} leaf
    dicts per ``repro.serving.kv_quant`` — codes (n_blocks, bs, Hkv, Dc)
    int8 (q8) or packed uint8 (q4), scales (n_blocks, bs, Hkv//gr, D//gc);
    table: (B, W) int32 block ids; lengths: (B,) int32 including the
    current token.  Returns (B, Hkv, G, D) in q.dtype.  Geometry is
    inferred from the leaf shapes (static under jit).
    """
    from repro.serving.kv_quant import Q4_CODEBOOK, kv_geometry

    B, Hkv, G, D = q.shape
    codes = k_pool["codes"]
    bs = codes.shape[1]
    dc = codes.shape[-1]
    mode, gr, gc, _ = kv_geometry(k_pool)
    sd = k_pool["scales"].shape[-1]                  # D // gc
    W = table.shape[1]
    scale = 1.0 / math.sqrt(D)
    from repro.quant.codebooks import get_codebook

    cb = get_codebook(Q4_CODEBOOK).reshape(1, 16)    # unused under q8

    kern = functools.partial(_quant_kernel, n_blk=W, block_size=bs,
                             scale=scale, window=window, softcap=softcap,
                             mode=mode, gc=gc)
    code_spec = pl.BlockSpec((1, bs, 1, dc),
                             lambda b, h, j, tbl, lens: (tbl[b, j], 0, h, 0))
    # one scale tile row covers gr adjacent heads: head h reads row h//gr,
    # so the pair's scales stream in once per (h, j) step, unit-stride
    scale_spec = pl.BlockSpec(
        (1, bs, 1, sd),
        lambda b, h, j, tbl, lens: (tbl[b, j], 0, h // gr, 0))
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B, Hkv, W),
        in_specs=[
            pl.BlockSpec((1, 1, G, D),
                         lambda b, h, j, tbl, lens: (b, h, 0, 0)),
            code_spec,
            scale_spec,
            code_spec,
            scale_spec,
            pl.BlockSpec((1, 16), lambda b, h, j, tbl, lens: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, G, D),
                               lambda b, h, j, tbl, lens: (b, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((G, D), jnp.float32),
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, 1), jnp.float32),
        ],
    )
    return pl.pallas_call(
        kern,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, Hkv, G, D), q.dtype),
        interpret=interpret,
    )(table.astype(jnp.int32), lengths.astype(jnp.int32), q,
      k_pool["codes"], k_pool["scales"], v_pool["codes"], v_pool["scales"],
      cb)
