"""Pallas TPU kernel: gather-based paged decode attention.

One query token per sequence attends against a *paged* KV cache: KV bytes
live in a shared block pool ``(n_blocks, bs, Hkv, D)`` and each sequence
maps logical positions to pool blocks through a block table (block ``w``
of a row holds positions ``[w·bs, (w+1)·bs)``).

The block table, the per-row lengths and the sliding window ride in as
**scalar-prefetch** arguments (``pltpu.PrefetchScalarGridSpec``; the
window is dynamic because the model threads per-layer windows through
the layer scan as traced int32): the grid walks
``(B, Hkv, W)`` with the block index innermost, and the K/V BlockSpec
index maps dereference ``table[b, j]`` so the DMA engine fetches exactly
the row's j-th block — no (B, W·bs, …) gather is ever materialized, which
is the point: HBM traffic per step is the *live* KV, not the ``max_len``
reservation.  Table padding points at the reserved scratch block 0; its
contents are masked out via ``lengths`` like any past-the-end position.

Online-softmax accumulation (m/l/acc in VMEM scratch) is plain FP32 by
default; ``exp_mode='lut'`` instead runs the fp16 LUT-softmax recurrence
of ``lut_softmax_attention`` (paper Alg. 1) inside the same table walk —
the exp LUT rides in as a broadcast input exactly like there, so decode
does block gather + (de)quant + LUT softmax in one fused pass.  The
identical-semantics XLA fallback used on CPU is
``repro.models.layers.paged_decode_attention``; the pure-jnp oracles are
``repro.kernels.ref.paged_decode_attention_ref`` (exact) and
``ref.lut_paged_decode_attention_ref`` (fp16/LUT recurrence).

Fully-masked blocks (a ``lengths[b] == 0`` row, or table padding past the
row's last block) are guarded: ``p`` is zeroed on masked positions, so
``m_new == m_prev == -inf`` can no longer turn ``exp(0) == 1`` into
scratch-garbage accumulation — a zero-length row returns exactly 0.

:func:`quant_paged_attention` is the same walk over a *tile-quantized*
pool (``repro.serving.kv_quant``): the BlockSpec index maps dereference
the table for the codes **and** the per-(2, 16)-tile scales — both
unit-stride by construction, the §5.1 layout story applied to KV — and
dequantization happens per block in VMEM (int8 scale-multiply for Q8, a
16-entry codebook gather for packed Q4, the vlut16 analogue) right before
the Q·Kᵀ dot.  HBM traffic per step is therefore the *quantized* live KV:
the paged saving and the quantization saving compound.  Oracle:
``ref.quant_paged_decode_attention_ref``; XLA fallback: the same
``layers.paged_decode_attention`` dispatching on the pool's leaf dicts.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

try:
    from jax.experimental.pallas import tpu as pltpu
except Exception:  # pragma: no cover
    pltpu = None

from repro.kernels.lut_softmax_attention import NEG_CAP, _lut_exp

NEG_INF = -1e30


def _block_mask(s, len_ref, win_ref, j, block_size):
    """(G, bs) validity of this block's kv positions for row b.

    The window rides in as a scalar-prefetch value (w <= 0 = unbounded)
    because the model threads per-layer windows through the layer scan as
    traced int32 — it cannot be a static kernel parameter."""
    b = pl.program_id(0)
    seq_len = len_ref[b]
    w = win_ref[0]
    q_pos = seq_len - 1
    kv_pos = j * block_size + jax.lax.broadcasted_iota(
        jnp.int32, s.shape, 1)                       # (G, bs)
    valid = kv_pos < seq_len
    valid &= (w <= 0) | (q_pos - kv_pos < w)
    return valid


def _softmax_update(s, valid, v, lut_ref, acc_ref, m_ref, l_ref, *,
                    exp_mode: str):
    """One block's online-softmax accumulation.

    ``'exact'`` is the f32 recurrence; ``'lut'`` the fp16 Alg. 1
    recurrence with table-lookup exp (m scratch is fp16 there).  Both
    zero ``p`` on masked positions: in a fully-masked block
    ``m_new == m_prev`` makes the raw ``exp(s - m_new)`` equal 1 per
    masked position, which would accumulate garbage for zero-length rows
    and table padding.
    """
    m_prev = m_ref[...]
    if exp_mode == "lut":
        s16 = jnp.where(valid, s, NEG_CAP).astype(jnp.float16)
        m_new = jnp.maximum(m_prev, jnp.max(s16, axis=-1, keepdims=True))
        p = _lut_exp(lut_ref, s16 - m_new)
        corr = _lut_exp(lut_ref, m_prev - m_new).astype(jnp.float32)
        v = v.astype(jnp.float16)
    else:
        sm = jnp.where(valid, s, NEG_INF)
        m_new = jnp.maximum(m_prev, jnp.max(sm, axis=-1, keepdims=True))
        p = jnp.exp(sm - m_new)
        corr = jnp.exp(m_prev - m_new)
        v = v.astype(jnp.float32)
    p = jnp.where(valid, p, jnp.zeros_like(p))
    l_ref[...] = (l_ref[...] * corr +
                  jnp.sum(p.astype(jnp.float32), axis=-1, keepdims=True))
    pv = jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)
    acc_ref[...] = acc_ref[...] * corr + pv
    m_ref[...] = m_new


def _kernel(table_ref, len_ref, win_ref, q_ref, k_ref, v_ref, lut_ref, o_ref,
            acc_ref, m_ref, l_ref, *, n_blk: int, block_size: int,
            scale: float, softcap: float, exp_mode: str):
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(
            m_ref, NEG_CAP if exp_mode == "lut" else NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0, 0]                                  # (G, D)
    k = k_ref[0, :, 0]                               # (bs, D)
    v = v_ref[0, :, 0]

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    if softcap:
        s = jnp.tanh(s / softcap) * softcap
    valid = _block_mask(s, len_ref, win_ref, j, block_size)
    _softmax_update(s, valid, v, lut_ref, acc_ref, m_ref, l_ref,
                    exp_mode=exp_mode)

    @pl.when(j == n_blk - 1)
    def _flush():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l).astype(o_ref.dtype)


def _lut_input(lut, exp_mode: str):
    """The broadcast LUT input: the real table under ``'lut'`` (required),
    a 1-element placeholder otherwise (the kernel never reads it)."""
    if exp_mode not in ("exact", "lut"):
        raise ValueError(f"exp_mode must be 'exact' or 'lut', "
                         f"got {exp_mode!r}")
    if exp_mode == "lut":
        if lut is None:
            raise ValueError("exp_mode='lut' needs the exp LUT "
                             "(repro.kernels.ops.exp_lut())")
        return lut
    return jnp.zeros((1, 1), jnp.float16)


@functools.partial(jax.jit, static_argnames=("softcap", "interpret",
                                             "exp_mode"))
def paged_attention(q, k_pool, v_pool, table, lengths, lut=None, *,
                    window=0, softcap: float = 0.0,
                    interpret: bool = True, exp_mode: str = "exact"):
    """q: (B, Hkv, G, D); pools: (n_blocks, bs, Hkv, D); table: (B, W)
    int32 block ids (padding = scratch block 0); lengths: (B,) int32
    including the current token.  Returns (B, Hkv, G, D) in q.dtype.
    ``window`` may be a python int or a traced int32 scalar (the model's
    per-layer windows ride through the layer scan); <= 0 = unbounded.

    ``exp_mode='lut'`` runs the fp16 LUT-softmax recurrence; ``lut`` is
    then the (1, 32768) exp table (``lut_softmax_attention.build_exp_lut``)
    riding in as a broadcast input.
    """
    B, Hkv, G, D = q.shape
    _, bs, _, _ = k_pool.shape
    W = table.shape[1]
    scale = 1.0 / math.sqrt(D)
    lut = _lut_input(lut, exp_mode)
    lut_w = lut.shape[1]
    m_dtype = jnp.float16 if exp_mode == "lut" else jnp.float32
    win = jnp.asarray(window, jnp.int32).reshape(1)

    kern = functools.partial(_kernel, n_blk=W, block_size=bs, scale=scale,
                             softcap=softcap, exp_mode=exp_mode)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(B, Hkv, W),
        in_specs=[
            pl.BlockSpec((1, 1, G, D),
                         lambda b, h, j, tbl, lens, win: (b, h, 0, 0)),
            pl.BlockSpec((1, bs, 1, D),
                         lambda b, h, j, tbl, lens, win: (tbl[b, j], 0, h, 0)),
            pl.BlockSpec((1, bs, 1, D),
                         lambda b, h, j, tbl, lens, win: (tbl[b, j], 0, h, 0)),
            pl.BlockSpec((1, lut_w),
                         lambda b, h, j, tbl, lens, win: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, G, D),
                               lambda b, h, j, tbl, lens, win: (b, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((G, D), jnp.float32),
            pltpu.VMEM((G, 1), m_dtype),
            pltpu.VMEM((G, 1), jnp.float32),
        ],
    )
    return pl.pallas_call(
        kern,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, Hkv, G, D), q.dtype),
        interpret=interpret,
    )(table.astype(jnp.int32), lengths.astype(jnp.int32), win, q, k_pool,
      v_pool, lut)


# ---------------------------------------------------------------------------
# Quantized-pool variant: per-block VMEM dequant fused into the table walk
# ---------------------------------------------------------------------------


def _dequant_block(codes, scales, cb, *, mode: str, gc: int):
    """Dequantize one pool block's (bs, Dc) codes with (bs, D//gc) scales
    to (bs, D) f32.  The head axis is already sliced to one head (codes)
    and its covering tile row (scales), so the only broadcast left is the
    cheap unit-stride repeat along dims — no scatter, by construction."""
    from repro.serving.kv_quant import _unpack_q4

    s = jnp.repeat(scales.astype(jnp.float32), gc, axis=-1)  # (bs, D)
    if mode == "q8":
        return codes.astype(jnp.float32) * s
    idx = _unpack_q4(codes).astype(jnp.int32)
    return jnp.take(cb, idx, axis=0) * s  # vlut16 analogue (§5.2.2)


def _quant_kernel(table_ref, len_ref, win_ref, q_ref, kc_ref, ks_ref, vc_ref,
                  vs_ref, cb_ref, lut_ref, o_ref, acc_ref, m_ref, l_ref, *,
                  n_blk: int, block_size: int, scale: float,
                  softcap: float, mode: str, gc: int, exp_mode: str):
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(
            m_ref, NEG_CAP if exp_mode == "lut" else NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    cb = cb_ref[0]                                   # (16,) f32
    q = q_ref[0, 0].astype(jnp.float32)              # (G, D)
    k = _dequant_block(kc_ref[0, :, 0], ks_ref[0, :, 0], cb,
                       mode=mode, gc=gc)             # (bs, D) f32
    v = _dequant_block(vc_ref[0, :, 0], vs_ref[0, :, 0], cb,
                       mode=mode, gc=gc)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    if softcap:
        s = jnp.tanh(s / softcap) * softcap
    valid = _block_mask(s, len_ref, win_ref, j, block_size)
    _softmax_update(s, valid, v, lut_ref, acc_ref, m_ref, l_ref,
                    exp_mode=exp_mode)

    @pl.when(j == n_blk - 1)
    def _flush():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("softcap", "interpret",
                                             "exp_mode"))
def quant_paged_attention(q, k_pool, v_pool, table, lengths, lut=None, *,
                          window=0, softcap: float = 0.0,
                          interpret: bool = True, exp_mode: str = "exact"):
    """Paged decode attention over a tile-quantized block pool.

    q: (B, Hkv, G, D); ``k_pool``/``v_pool``: {"codes", "scales"} leaf
    dicts per ``repro.serving.kv_quant`` — codes (n_blocks, bs, Hkv, Dc)
    int8 (q8) or packed uint8 (q4), scales (n_blocks, bs, Hkv//gr, D//gc);
    table: (B, W) int32 block ids; lengths: (B,) int32 including the
    current token.  Returns (B, Hkv, G, D) in q.dtype.  Geometry is
    inferred from the leaf shapes (static under jit).

    ``exp_mode='lut'`` fuses the fp16 LUT softmax onto the same walk:
    table deref + VMEM dequant + table-lookup exp in one pass (``lut`` =
    the (1, 32768) exp table as a broadcast input, like the codebook).
    """
    from repro.serving.kv_quant import Q4_CODEBOOK, kv_geometry

    B, Hkv, G, D = q.shape
    codes = k_pool["codes"]
    bs = codes.shape[1]
    dc = codes.shape[-1]
    mode, gr, gc, _ = kv_geometry(k_pool)
    sd = k_pool["scales"].shape[-1]                  # D // gc
    W = table.shape[1]
    scale = 1.0 / math.sqrt(D)
    from repro.quant.codebooks import get_codebook

    cb = get_codebook(Q4_CODEBOOK).reshape(1, 16)    # unused under q8
    lut = _lut_input(lut, exp_mode)
    lut_w = lut.shape[1]
    m_dtype = jnp.float16 if exp_mode == "lut" else jnp.float32
    win = jnp.asarray(window, jnp.int32).reshape(1)

    kern = functools.partial(_quant_kernel, n_blk=W, block_size=bs,
                             scale=scale, softcap=softcap,
                             mode=mode, gc=gc, exp_mode=exp_mode)
    code_spec = pl.BlockSpec(
        (1, bs, 1, dc),
        lambda b, h, j, tbl, lens, win: (tbl[b, j], 0, h, 0))
    # one scale tile row covers gr adjacent heads: head h reads row h//gr,
    # so the pair's scales stream in once per (h, j) step, unit-stride
    scale_spec = pl.BlockSpec(
        (1, bs, 1, sd),
        lambda b, h, j, tbl, lens, win: (tbl[b, j], 0, h // gr, 0))
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(B, Hkv, W),
        in_specs=[
            pl.BlockSpec((1, 1, G, D),
                         lambda b, h, j, tbl, lens, win: (b, h, 0, 0)),
            code_spec,
            scale_spec,
            code_spec,
            scale_spec,
            pl.BlockSpec((1, 16), lambda b, h, j, tbl, lens, win: (0, 0)),
            pl.BlockSpec((1, lut_w), lambda b, h, j, tbl, lens, win: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, G, D),
                               lambda b, h, j, tbl, lens, win: (b, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((G, D), jnp.float32),
            pltpu.VMEM((G, 1), m_dtype),
            pltpu.VMEM((G, 1), jnp.float32),
        ],
    )
    return pl.pallas_call(
        kern,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, Hkv, G, D), q.dtype),
        interpret=interpret,
    )(table.astype(jnp.int32), lengths.astype(jnp.int32), win, q,
      k_pool["codes"], k_pool["scales"], v_pool["codes"], v_pool["scales"],
      cb, lut)
