"""Pallas TPU kernel: mixed-precision GEMM with in-kernel LUT dequantization.

The TPU adaptation of the paper's §5.1 + §5.2.2 pipeline:

* weights arrive as packed int4 codes (two per byte) in the tile-group
  layout produced offline by ``repro.quant.tile_quant`` — codes and scales
  are unit-stride for every (bk, bn) VMEM block (no scatter, the Fig. 6
  mismatch is designed away);
* dequantization inside the kernel is a 16-entry codebook lookup — the
  ``vlut16`` analogue — so swapping the table supports Q4_0 / NF4 / FP4 /
  IQ4_NL with zero code changes;
* scale broadcast is two cheap in-register repeats (2× along sublanes,
  16× along lanes), the analogue of the paper's scale-broadcast-via-LUT;
* the MXU consumes the dequantized (bk, bn) tile immediately — FP16/BF16
  weights never round-trip through HBM (this is what beats the paper's
  "HMX layout" ablation bar and approaches its "no dequantization" bound).

Grid: (M/bm, N/bn, K/bk), K innermost; f32 accumulation in VMEM scratch.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

try:  # TPU-specific memory spaces; interpret mode does not need them
    from jax.experimental.pallas import tpu as pltpu
    _VMEM = pltpu.VMEM
except Exception:  # pragma: no cover
    pltpu = None
    _VMEM = None


def _kernel(x_ref, codes_ref, scales_ref, cb_ref, o_ref, acc_ref,
            *, nk: int, scheme: str, group_size: int, out_dtype):
    k_idx = pl.program_id(2)

    @pl.when(k_idx == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    codes = codes_ref[...]                       # (bk, bn//2) uint8
    bk, bnh = codes.shape
    bn = bnh * 2
    # unpack two int4 per byte (low nibble = even column)
    lo = (codes & 0xF).astype(jnp.int32)
    hi = (codes >> 4).astype(jnp.int32)
    idx = jnp.stack([lo, hi], axis=-1).reshape(bk, bn)
    # vlut16 analogue: 16-entry codebook gather
    cb = cb_ref[0]                               # (16,)
    vals = jnp.take(cb, idx, axis=0)             # (bk, bn) f32

    s = scales_ref[...].astype(jnp.float32)
    if scheme == "tile":                         # (bk//2, bn//16)
        s = jnp.repeat(jnp.repeat(s, 2, axis=0), group_size // 2, axis=1)
    else:                                        # common: (bk//g, bn)
        s = jnp.repeat(s, group_size, axis=0)
    w = (vals * s).astype(x_ref.dtype)

    acc_ref[...] += jnp.dot(x_ref[...], w, preferred_element_type=jnp.float32)

    @pl.when(k_idx == nk - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("scheme", "group_size", "bm", "bn",
                                             "bk", "interpret", "out_dtype"))
def lut_dequant_gemm(x, codes, scales, codebook, *, scheme: str = "tile",
                     group_size: int = 32, bm: int = 128, bn: int = 256,
                     bk: int = 128, interpret: bool = True,
                     out_dtype=None):
    """x: (M, K) @ dequant(codes, scales, codebook): (K, N) -> (M, N).

    Block sizes default to MXU-aligned tiles: bm/bk multiples of 128 (lane
    width), bn sized so the packed codes block (bk, bn/2) is byte-aligned.
    """
    M, K = x.shape
    Kc, Nh = codes.shape
    N = Nh * 2
    if Kc != K:
        raise ValueError(
            f"lut_dequant_gemm: codes have {Kc} rows but x has K={K} "
            f"columns (x {x.shape} vs codes {codes.shape})")
    out_dtype = out_dtype or x.dtype
    bm, bn, bk = min(bm, M), min(bn, N), min(bk, K)
    if M % bm or N % bn or K % bk:
        raise ValueError(
            f"lut_dequant_gemm: block sizes must divide the GEMM shape, "
            f"got (M, N, K) = ({M}, {N}, {K}) with "
            f"(bm, bn, bk) = ({bm}, {bn}, {bk})")
    nk = K // bk
    g = group_size

    if scheme == "tile":
        s_block = (bk // 2, bn // (g // 2))
        s_index = lambda i, j, k: (k, j)
    else:
        s_block = (bk // g, bn)
        s_index = lambda i, j, k: (k, j)

    grid = (M // bm, N // bn, nk)
    kern = functools.partial(_kernel, nk=nk, scheme=scheme,
                             group_size=g, out_dtype=out_dtype)
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk, bn // 2), lambda i, j, k: (k, j)),
            pl.BlockSpec(s_block, s_index),
            pl.BlockSpec((1, 16), lambda i, j, k: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(x, codes, scales, codebook.reshape(1, 16))


# ---------------------------------------------------------------------------
# Dequant-only variant over KV token slabs (the vlut16 story applied to
# gathered quantized-KV views, e.g. the partial-prefill prefix gather)
# ---------------------------------------------------------------------------


def _kv_kernel(codes_ref, scales_ref, cb_ref, o_ref, *, mode: str, gr: int,
               gc: int):
    codes = codes_ref[...]                           # (br, H, Dc)
    s = scales_ref[...].astype(jnp.float32)          # (br, H//gr, D//gc)
    s = jnp.repeat(jnp.repeat(s, gr, axis=-2), gc, axis=-1)
    if mode == "q8":
        vals = codes.astype(jnp.float32)
    else:
        # unpack two int4 per byte (low nibble = even dim), vlut16 gather
        br, H, Dc = codes.shape
        lo = (codes & 0xF).astype(jnp.int32)
        hi = (codes >> 4).astype(jnp.int32)
        idx = jnp.stack([lo, hi], axis=-1).reshape(br, H, Dc * 2)
        vals = jnp.take(cb_ref[0], idx, axis=0)
    o_ref[...] = (vals * s).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("mode", "gr", "gc", "br",
                                             "interpret", "out_dtype"))
def lut_dequant_kv(codes, scales, codebook, *, mode: str, gr: int, gc: int,
                   br: int = 256, interpret: bool = True,
                   out_dtype=jnp.float32):
    """Dequantize (R, Hkv, Dc) KV token-slab codes with (R, Hkv//gr,
    D//gc) tile scales to (R, Hkv, D) — the kernel twin of
    ``repro.serving.kv_quant.dequantize_kv`` (same unpack, codebook
    lookup, scale broadcast and multiply per element, so the outputs are
    bit-identical).  Grid walks R in ``br``-row blocks.
    """
    R, H, Dc = codes.shape
    D = Dc * 2 if mode == "q4" else Dc
    Hs, Ds = scales.shape[-2:]
    br = min(br, R)
    if R % br:
        raise ValueError(f"lut_dequant_kv: row block br={br} must divide "
                         f"the {R} gathered token slabs")
    kern = functools.partial(_kv_kernel, mode=mode, gr=gr, gc=gc)
    return pl.pallas_call(
        kern,
        grid=(R // br,),
        in_specs=[
            pl.BlockSpec((br, H, Dc), lambda i: (i, 0, 0)),
            pl.BlockSpec((br, Hs, Ds), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, 16), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((br, H, D), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((R, H, D), out_dtype),
        interpret=interpret,
    )(codes, scales, codebook.reshape(1, 16))
