"""Pallas TPU kernel: mixed-precision GEMM with in-kernel LUT dequantization.

The TPU adaptation of the paper's §5.1 + §5.2.2 pipeline:

* weights arrive as packed int4 codes (two per byte) in the tile-group
  layout produced offline by ``repro.quant.tile_quant`` — codes and scales
  are unit-stride for every (bk, bn) VMEM block (no scatter, the Fig. 6
  mismatch is designed away);
* dequantization inside the kernel is a 16-entry codebook lookup — the
  ``vlut16`` analogue — so swapping the table supports Q4_0 / NF4 / FP4 /
  IQ4_NL with zero code changes;
* scale broadcast is two cheap in-register repeats (2× along sublanes,
  16× along lanes), the analogue of the paper's scale-broadcast-via-LUT;
* the MXU consumes the dequantized (bk, bn) tile immediately — FP16/BF16
  weights never round-trip through HBM (this is what beats the paper's
  "HMX layout" ablation bar and approaches its "no dequantization" bound).

Grid: (M/bm, N/bn, K/bk), K innermost; f32 accumulation in VMEM scratch.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

try:  # TPU-specific memory spaces; interpret mode does not need them
    from jax.experimental.pallas import tpu as pltpu
    _VMEM = pltpu.VMEM
except Exception:  # pragma: no cover
    pltpu = None
    _VMEM = None


def _kernel(x_ref, codes_ref, scales_ref, cb_ref, o_ref, acc_ref,
            *, nk: int, scheme: str, group_size: int, out_dtype):
    k_idx = pl.program_id(2)

    @pl.when(k_idx == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    codes = codes_ref[...]                       # (bk, bn//2) uint8
    bk, bnh = codes.shape
    bn = bnh * 2
    # unpack two int4 per byte (low nibble = even column)
    lo = (codes & 0xF).astype(jnp.int32)
    hi = (codes >> 4).astype(jnp.int32)
    idx = jnp.stack([lo, hi], axis=-1).reshape(bk, bn)
    # vlut16 analogue: 16-entry codebook gather
    cb = cb_ref[0]                               # (16,)
    vals = jnp.take(cb, idx, axis=0)             # (bk, bn) f32

    s = scales_ref[...].astype(jnp.float32)
    if scheme == "tile":                         # (bk//2, bn//16)
        s = jnp.repeat(jnp.repeat(s, 2, axis=0), group_size // 2, axis=1)
    else:                                        # common: (bk//g, bn)
        s = jnp.repeat(s, group_size, axis=0)
    w = (vals * s).astype(x_ref.dtype)

    acc_ref[...] += jnp.dot(x_ref[...], w, preferred_element_type=jnp.float32)

    @pl.when(k_idx == nk - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("scheme", "group_size", "bm", "bn",
                                             "bk", "interpret", "out_dtype"))
def lut_dequant_gemm(x, codes, scales, codebook, *, scheme: str = "tile",
                     group_size: int = 32, bm: int = 128, bn: int = 256,
                     bk: int = 128, interpret: bool = True,
                     out_dtype=None):
    """x: (M, K) @ dequant(codes, scales, codebook): (K, N) -> (M, N).

    Block sizes default to MXU-aligned tiles: bm/bk multiples of 128 (lane
    width), bn sized so the packed codes block (bk, bn/2) is byte-aligned.
    """
    M, K = x.shape
    Kc, Nh = codes.shape
    N = Nh * 2
    assert Kc == K
    out_dtype = out_dtype or x.dtype
    bm, bn, bk = min(bm, M), min(bn, N), min(bk, K)
    assert M % bm == 0 and N % bn == 0 and K % bk == 0, (M, N, K, bm, bn, bk)
    nk = K // bk
    g = group_size

    if scheme == "tile":
        s_block = (bk // 2, bn // (g // 2))
        s_index = lambda i, j, k: (k, j)
    else:
        s_block = (bk // g, bn)
        s_index = lambda i, j, k: (k, j)

    grid = (M // bm, N // bn, nk)
    kern = functools.partial(_kernel, nk=nk, scheme=scheme,
                             group_size=g, out_dtype=out_dtype)
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk, bn // 2), lambda i, j, k: (k, j)),
            pl.BlockSpec(s_block, s_index),
            pl.BlockSpec((1, 16), lambda i, j, k: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(x, codes, scales, codebook.reshape(1, 16))
