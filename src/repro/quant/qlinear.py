"""Quantized linear application + whole-model quantization policy.

``quantized_matmul`` is the integration point used by ``models.layers.
linear``: it consumes the quantized-weight leaf dict and either

* dequantizes in-graph (XLA path — used by dry-runs so ``cost_analysis``
  sees the true int4/int8 byte traffic), or
* calls the Pallas LUT-dequant GEMM kernel (TPU path / interpret mode).

``quantize_model_params`` applies the paper's deployment policy: Q4 tile
quantization for attention & FFN projections, Q8_0 for FFN down-projections
(§7.1: "we apply the Q8_0 quantization scheme [to FFN down] to reduce
quantization errors"), embeddings / norms / small vectors left in fp.
"""
from __future__ import annotations

import re
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from repro.quant import tile_quant as TQ

# toggled by ops layer / tests; default False so dry-runs lower pure XLA
_USE_PALLAS = False


def use_pallas_kernels(flag: bool) -> None:
    global _USE_PALLAS
    _USE_PALLAS = flag


def is_quantized(leaf) -> bool:
    return isinstance(leaf, dict) and "codes" in leaf


def quantized_matmul(x: jnp.ndarray, qw: dict, group_size: int = 32) -> jnp.ndarray:
    """x: (..., K) @ dequant(qw) (K, N) -> (..., N)."""
    if _USE_PALLAS and "codebook" in qw:
        from repro.kernels import ops

        lead = x.shape[:-1]
        x2 = x.reshape(-1, x.shape[-1])
        y = ops.lut_dequant_matmul(x2, qw, group_size=group_size)
        return y.reshape(*lead, y.shape[-1])
    if "codebook" in qw:
        w = TQ.dequantize(qw, dtype=x.dtype, group_size=group_size)
    else:
        w = TQ.dequantize_q8(qw, dtype=x.dtype, group_size=group_size)
    return jnp.einsum("...d,df->...f", x, w)


# ---------------------------------------------------------------------------
# Model-level quantization
# ---------------------------------------------------------------------------

# path regex -> scheme name ("q4" | "q8" | None). First match wins.
DEFAULT_POLICY = [
    (r".*(down|fc2)/w$", "q8"),            # FFN down: Q8_0 (paper §7.1)
    (r".*(gate|up|fc1)/w$", "q4"),
    (r".*w[qkvo]/w$", "q4"),
    (r".*in_proj/w$", "q4"),
    (r".*out_proj/w$", "q4"),
    (r".*experts/down$", "q8"),
    (r".*experts/(gate|up)$", "q4"),
    (r".*", None),                          # embeddings, norms, etc.
]


def _path_str(path) -> str:
    return "/".join(str(getattr(k, "key", k)) for k in path)


def quantize_model_params(params, *, scheme: str = "tile", codebook: str = "q4_0",
                          group_size: int = 32, policy=None):
    """Quantize eligible 2-D weights in a parameter pytree.

    Returns a new pytree in which quantized leaves are dicts
    {"codes", "scales"[, "codebook"]}.  Stacked (scanned) layer weights of
    shape (L, K, N) are quantized per-layer via vmap.
    """
    policy = policy or DEFAULT_POLICY

    def decide(path):
        for pat, sch in policy:
            if re.match(pat, path):
                return sch
        return None

    def q4(w):
        return TQ.quantize(w, scheme=scheme, codebook=codebook,
                           group_size=group_size)

    def q8(w):
        return TQ.quantize_q8(w, group_size=group_size)

    def one(path, leaf):
        ps = _path_str(path)
        sch = decide(ps)
        if sch is None or leaf.ndim not in (2, 3, 4):
            return leaf
        fn = q4 if sch == "q4" else q8
        for _ in range(leaf.ndim - 2):  # stacked layer and/or expert dims
            fn = jax.vmap(fn)
        # NB: the codebook is broadcast across stacked dims so that
        # lax.scan over stacked layer params can slice it uniformly.
        return fn(leaf)

    return jax.tree_util.tree_map_with_path(one, params)


def dequantize_model_params(params, group_size: int = 32):
    """Inverse of quantize_model_params (for accuracy baselines)."""

    def one(leaf):
        if not is_quantized(leaf):
            return leaf
        nstack = leaf["codes"].ndim - 2
        if "codebook" in leaf:
            fn = lambda c, s, cb: TQ.dequantize(
                {"codes": c, "scales": s, "codebook": cb}, group_size=group_size)
            for _ in range(nstack):
                fn = jax.vmap(fn)
            return fn(leaf["codes"], leaf["scales"], leaf["codebook"])
        fn = lambda c, s: TQ.dequantize_q8({"codes": c, "scales": s},
                                           group_size=group_size)
        for _ in range(nstack):
            fn = jax.vmap(fn)
        return fn(leaf["codes"], leaf["scales"])

    return jax.tree.map(one, params, is_leaf=is_quantized)
