"""Hardware-aware tile-group quantization (paper §5.1, adapted to TPU).

Two group geometries over a (K, N) weight (K = reduction dim):

* ``common``  — the conventional scheme: groups of ``g`` contiguous elements
  along K, one scale per (g, 1) column strip.  This is the llama.cpp /
  AutoAWQ layout the paper uses as baseline.

* ``tile``    — the paper's scheme mapped to the TPU MXU register tile:
  groups are (2, g//2) = (2 K-rows × 16 N-columns) rectangles — the exact
  2×16 sub-tile shape of the Hexagon HMX layout (Fig. 4a), which on TPU
  corresponds to a lane-contiguous strip inside a (16, 128) VREG tile.
  Dequantization therefore reads codes *and* scales unit-stride, with no
  scatter (Fig. 6's mismatch disappears by construction).

Codes are packed two-per-byte along N (low nibble = even column) so one
(8, 128) uint8 VMEM block holds a full (8, 256) int4 tile — the TPU
analogue of the paper's §5.1.2 super-group coalescing: 8 groups of 32
(= 256 codes = 128 bytes) land in one contiguous vector row.
"""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from repro.quant.codebooks import codebook_absmax, get_codebook

# Static metadata key (kept out of the jax pytree leaves on purpose: strings)
SCHEMES = ("common", "tile")


def pack_int4(codes: jnp.ndarray) -> jnp.ndarray:
    """(K, N) uint8 in [0,15] -> (K, N//2) packed: low nibble = even col."""
    lo = codes[:, 0::2]
    hi = codes[:, 1::2]
    return (lo | (hi << 4)).astype(jnp.uint8)


def unpack_int4(packed: jnp.ndarray) -> jnp.ndarray:
    """(K, N//2) uint8 -> (K, N) uint8 in [0,15]."""
    lo = packed & 0xF
    hi = packed >> 4
    K, Nh = packed.shape
    out = jnp.stack([lo, hi], axis=-1).reshape(K, Nh * 2)
    return out


def _nearest_code(wn: jnp.ndarray, codebook: jnp.ndarray) -> jnp.ndarray:
    """Nearest-codebook-entry assignment. wn: normalized weights."""
    d = jnp.abs(wn[..., None] - codebook)  # (..., 16)
    return jnp.argmin(d, axis=-1).astype(jnp.uint8)


def quantize(w: jnp.ndarray, *, scheme: str = "tile", codebook: str = "q4_0",
             group_size: int = 32, scale_dtype=jnp.float16) -> dict:
    """Weight-only 4-bit group quantization.

    Returns a pytree-leaf dict: {"codes": (K, N//2) uint8, "scales": ...,
    "codebook": (16,) f32}. ``scales`` shape is (K//g, N) for ``common`` and
    (K//2, N//(g//2)) for ``tile``.
    """
    assert scheme in SCHEMES, scheme
    K, N = w.shape
    g = group_size
    cb = get_codebook(codebook)
    cmax = codebook_absmax(codebook)
    wf = w.astype(jnp.float32)

    if scheme == "common":
        assert K % g == 0, (K, g)
        wg = wf.reshape(K // g, g, N)
        absmax = jnp.max(jnp.abs(wg), axis=1)                    # (K//g, N)
        scales = (absmax / cmax).astype(scale_dtype)
        sc = jnp.repeat(scales.astype(jnp.float32), g, axis=0)   # (K, N)
    else:  # tile: (2, g//2) rectangles
        gr, gc = 2, g // 2
        assert K % gr == 0 and N % gc == 0, (K, N, g)
        wg = wf.reshape(K // gr, gr, N // gc, gc)
        absmax = jnp.max(jnp.abs(wg), axis=(1, 3))               # (K//2, N//gc)
        scales = (absmax / cmax).astype(scale_dtype)
        sc = jnp.repeat(jnp.repeat(scales.astype(jnp.float32), gr, axis=0),
                        gc, axis=1)                              # (K, N)

    sc = jnp.maximum(sc, 1e-8)
    codes = _nearest_code(wf / sc, cb)                           # (K, N) uint8
    return {
        "codes": pack_int4(codes),
        "scales": scales,
        "codebook": cb,
    }


def infer_scheme(qw: dict, group_size: int = 32) -> str:
    """Recover the group geometry from array shapes."""
    K = qw["codes"].shape[0]
    sk = qw["scales"].shape[0]
    return "common" if sk == K // group_size else "tile"


def dequantize(qw: dict, *, dtype=jnp.float32, group_size: int = 32) -> jnp.ndarray:
    """Reference dequantization (pure jnp oracle for the Pallas kernel)."""
    codes = unpack_int4(qw["codes"])                              # (K, N)
    K, N = codes.shape
    vals = qw["codebook"][codes.astype(jnp.int32)]                # LUT (§5.2.2)
    scheme = infer_scheme(qw, group_size)
    g = group_size
    s = qw["scales"].astype(jnp.float32)
    if scheme == "common":
        sc = jnp.repeat(s, g, axis=0)
    else:
        gr, gc = 2, g // 2
        sc = jnp.repeat(jnp.repeat(s, gr, axis=0), gc, axis=1)
    return (vals * sc).astype(dtype)


def quantize_q8(w: jnp.ndarray, *, group_size: int = 32,
                scale_dtype=jnp.float16) -> dict:
    """Q8_0-style 8-bit symmetric group quantization (FFN-down per §7.1)."""
    K, N = w.shape
    g = group_size
    assert K % g == 0
    wf = w.astype(jnp.float32)
    wg = wf.reshape(K // g, g, N)
    absmax = jnp.max(jnp.abs(wg), axis=1)
    scales = (absmax / 127.0).astype(scale_dtype)
    sc = jnp.maximum(jnp.repeat(scales.astype(jnp.float32), g, axis=0), 1e-8)
    codes = jnp.clip(jnp.round(wf / sc), -127, 127).astype(jnp.int8)
    return {"codes": codes, "scales": scales}


def dequantize_q8(qw: dict, *, dtype=jnp.float32, group_size: int = 32) -> jnp.ndarray:
    sc = jnp.repeat(qw["scales"].astype(jnp.float32), group_size, axis=0)
    return (qw["codes"].astype(jnp.float32) * sc).astype(dtype)


# ---------------------------------------------------------------------------
# MXU tile layout transforms (the paper's offline pre/post-quantization
# permutes, §5.1.1).  Used by the GEMM-ablation benchmark to contrast the
# "conventional layout + runtime scatter" baseline with the tile layout.
# ---------------------------------------------------------------------------


def to_tile_layout(arr: jnp.ndarray, tk: int = 16, tn: int = 128) -> jnp.ndarray:
    """(K, N) -> (K//tk, N//tn, tk, tn): column-major-of-tiles MXU order."""
    K, N = arr.shape
    assert K % tk == 0 and N % tn == 0
    return arr.reshape(K // tk, tk, N // tn, tn).transpose(0, 2, 1, 3)


def from_tile_layout(t: jnp.ndarray) -> jnp.ndarray:
    kt, nt, tk, tn = t.shape
    return t.transpose(0, 2, 1, 3).reshape(kt * tk, nt * tn)
