"""4-bit codebooks for LUT-centric dequantization (paper §5.2.2).

The paper's key point: once dequantization is a 16-entry table lookup,
*any* 4-bit encoding (Q4_0 integer grid, FP4, NF4, llama.cpp's IQ4_NL)
is supported by swapping table contents.  These are those tables.

Codes are unsigned 4-bit [0, 15]; ``dequant = codebook[code] * scale``.
Scales are chosen as ``max|w_group| / max|codebook|`` so the full codebook
range is used.
"""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

# Q4_0: symmetric integer grid [-8, 7] (llama.cpp Q4_0 semantics).
Q4_0 = np.arange(-8.0, 8.0, dtype=np.float32)

# NF4 ("NormalFloat"), QLoRA (Dettmers et al. 2023), normalized to [-1, 1].
NF4 = np.array(
    [-1.0, -0.6961928009986877, -0.5250730514526367, -0.39491748809814453,
     -0.28444138169288635, -0.18477343022823334, -0.09105003625154495, 0.0,
     0.07958029955625534, 0.16093020141124725, 0.24611230194568634,
     0.33791524171829224, 0.44070982933044434, 0.5626170039176941,
     0.7229568362236023, 1.0], dtype=np.float32)

# FP4 (E2M1): ±{0, .5, 1, 1.5, 2, 3, 4, 6}
FP4_E2M1 = np.array(
    [0.0, 0.5, 1.0, 1.5, 2.0, 3.0, 4.0, 6.0,
     -0.0, -0.5, -1.0, -1.5, -2.0, -3.0, -4.0, -6.0], dtype=np.float32)

# IQ4_NL non-linear grid (llama.cpp), scaled to int8-ish range.
IQ4_NL = np.array(
    [-127.0, -104.0, -83.0, -65.0, -49.0, -35.0, -22.0, -10.0,
     1.0, 13.0, 25.0, 38.0, 53.0, 69.0, 89.0, 113.0], dtype=np.float32)

CODEBOOKS = {
    "q4_0": Q4_0,
    "nf4": NF4,
    "fp4": FP4_E2M1,
    "iq4_nl": IQ4_NL,
}


def get_codebook(name: str) -> jnp.ndarray:
    return jnp.asarray(CODEBOOKS[name])


def codebook_absmax(name: str) -> float:
    return float(np.abs(CODEBOOKS[name]).max())
