"""Sharded, async, elastic checkpointing.

Format: one directory per step containing a ``manifest.json`` (pytree
structure, global shapes, dtypes) and one ``.npy`` per leaf.  Leaves are
gathered to host before writing, so the manifest records *global* shapes —
restore works under ANY mesh (elastic restore: pass new shardings and the
loaded global arrays are device_put against them).

Fault-tolerance contract used by launch/train.py:
  * ``save`` is atomic (write to tmp dir, rename);
  * ``save_async`` runs on a background thread (training continues);
  * ``latest_step`` / ``restore`` implement restart-after-preemption;
  * an on-SIGTERM emergency save hook is provided by
    distributed.fault_tolerance.PreemptionHandler.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np


def _flatten(tree):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return leaves, treedef


class Checkpointer:
    def __init__(self, directory: str, *, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._thread: Optional[threading.Thread] = None

    # -- paths ---------------------------------------------------------------
    def _step_dir(self, step: int) -> str:
        return os.path.join(self.dir, f"step_{step:08d}")

    def latest_step(self) -> Optional[int]:
        steps = []
        for name in os.listdir(self.dir):
            if name.startswith("step_") and not name.endswith(".tmp"):
                try:
                    steps.append(int(name.split("_")[1]))
                except ValueError:
                    pass
        return max(steps) if steps else None

    # -- save ------------------------------------------------------------------
    def save(self, tree, step: int):
        """Blocking, atomic save of an arbitrary pytree."""
        leaves, treedef = _flatten(tree)
        host = [np.asarray(jax.device_get(l)) for l in leaves]
        tmp = self._step_dir(step) + ".tmp"
        final = self._step_dir(step)
        os.makedirs(tmp, exist_ok=True)
        manifest = {
            "step": step,
            "treedef": jax.tree_util.tree_structure(tree).serialize_using_proto().hex()
            if hasattr(jax.tree_util.tree_structure(tree), "serialize_using_proto")
            else None,
            "n_leaves": len(host),
            "leaves": [{"shape": list(a.shape), "dtype": str(a.dtype)}
                       for a in host],
        }
        for i, a in enumerate(host):
            np.save(os.path.join(tmp, f"leaf_{i:05d}.npy"), a)
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
        self._gc()

    def save_async(self, tree, step: int):
        """Non-blocking save; snapshots to host first so training can mutate
        the live arrays immediately after this returns."""
        leaves, treedef = _flatten(tree)
        host = [np.asarray(jax.device_get(l)) for l in leaves]
        snapshot = jax.tree_util.tree_unflatten(treedef, host)
        self.wait()
        self._thread = threading.Thread(
            target=self.save, args=(snapshot, step), daemon=True)
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self):
        steps = sorted(
            int(n.split("_")[1]) for n in os.listdir(self.dir)
            if n.startswith("step_") and not n.endswith(".tmp"))
        for s in steps[: max(0, len(steps) - self.keep)]:
            shutil.rmtree(self._step_dir(s), ignore_errors=True)

    # -- restore -------------------------------------------------------------
    def restore(self, abstract_tree, step: Optional[int] = None,
                shardings=None):
        """Restore into the structure of ``abstract_tree``.

        ``shardings``: optional pytree of NamedSharding congruent with the
        tree — global arrays are device_put against them, which is what
        makes restore *elastic* (a checkpoint written on a 256-chip mesh
        restores onto 512 chips or 1 CPU unchanged).
        """
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoint in {self.dir}")
        d = self._step_dir(step)
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)
        leaves_ref, treedef = _flatten(abstract_tree)
        assert len(leaves_ref) == manifest["n_leaves"], \
            f"tree mismatch: {len(leaves_ref)} vs {manifest['n_leaves']}"
        sh_leaves = (jax.tree_util.tree_flatten(shardings)[0]
                     if shardings is not None else [None] * len(leaves_ref))
        out = []
        for i, (ref, sh) in enumerate(zip(leaves_ref, sh_leaves)):
            a = np.load(os.path.join(d, f"leaf_{i:05d}.npy"))
            assert tuple(a.shape) == tuple(ref.shape), \
                f"leaf {i}: {a.shape} vs {ref.shape}"
            arr = jnp.asarray(a, dtype=ref.dtype)
            if sh is not None:
                arr = jax.device_put(arr, sh)
            out.append(arr)
        return jax.tree_util.tree_unflatten(treedef, out), step
