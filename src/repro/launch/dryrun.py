import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch × input-shape) cell on the
production mesh and extract the roofline terms from the compiled artifact.

Run:
  PYTHONPATH=src python -m repro.launch.dryrun --all                # 16x16
  PYTHONPATH=src python -m repro.launch.dryrun --all --multi-pod    # 2x16x16
  PYTHONPATH=src python -m repro.launch.dryrun --arch gemma3-1b --shape train_4k

Outputs one JSON per cell under runs/dryrun/ with:
  per-device HLO FLOPs / bytes (cost_analysis), per-device argument/output/
  temp bytes (memory_analysis — proves it fits), and collective bytes by
  primitive parsed from the compiled HLO (feeds EXPERIMENTS.md §Roofline).
"""
import argparse
import json
import re
import sys
import time
import traceback

import jax

from repro.configs.base import ALL_SHAPES, SHAPES_BY_NAME
from repro.configs.registry import ASSIGNED_ARCHS, cells, get_config
from repro.distributed.compat import cost_analysis_dict
from repro.distributed.sharding import ParallelContext
from repro.launch.mesh import make_production_mesh

# ---------------------------------------------------------------------------
# HLO collective parsing
# ---------------------------------------------------------------------------

_COLL_RE = re.compile(
    r"\b(all-gather(?:-start)?|all-reduce(?:-start)?|reduce-scatter"
    r"|all-to-all|collective-permute(?:-start)?)\b")
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "s4": 0.5, "u4": 0.5,
}


def _result_bytes(line: str) -> float:
    """Sum byte sizes of the result shapes on an HLO op line (= per-device
    payload moved by the collective)."""
    lhs = line.split(" = ", 1)
    if len(lhs) != 2:
        return 0.0
    # result type is just before the '=': "  name = bf16[1,2,3]{...} op(...)"
    total = 0.0
    rhs = lhs[1]
    opname = rhs.split("(", 1)[0]
    for dt, dims in _SHAPE_RE.findall(opname):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def parse_collectives(hlo_text: str) -> dict:
    """Collective op counts + per-device bytes by primitive."""
    out = {}
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m or " = " not in line:
            continue
        kind = m.group(1).replace("-start", "")
        b = _result_bytes(line)
        d = out.setdefault(kind, {"count": 0, "bytes": 0.0})
        d["count"] += 1
        d["bytes"] += b
    return out


# ---------------------------------------------------------------------------
# Cell runner
# ---------------------------------------------------------------------------


def run_cell(arch: str, shape_name: str, par: ParallelContext,
             out_dir: str = "runs/dryrun", mesh_tag: str = "",
             quantized: bool = False) -> dict:
    from repro.configs.inputs import build_cell

    shape = SHAPES_BY_NAME[shape_name]
    t0 = time.time()
    cell = build_cell(arch, shape, par, quantized=quantized)
    lowered = jax.jit(cell.fn, donate_argnums=cell.static.get("donate", ())).lower(*cell.args)
    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    ma = compiled.memory_analysis()
    ca = cost_analysis_dict(compiled)
    colls = parse_collectives(compiled.as_text())

    rec = {
        "arch": arch,
        "shape": shape_name,
        "quantized": quantized,
        "mesh": mesh_tag,
        "n_devices": par.mesh.size if par.mesh is not None else 1,
        "kind": shape.kind,
        "seq_len": shape.seq_len,
        "global_batch": shape.global_batch,
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        "per_device": {
            "flops": ca.get("flops", 0.0),
            "bytes_accessed": ca.get("bytes accessed", 0.0),
            "argument_bytes": getattr(ma, "argument_size_in_bytes", 0),
            "output_bytes": getattr(ma, "output_size_in_bytes", 0),
            "temp_bytes": getattr(ma, "temp_size_in_bytes", 0),
            "alias_bytes": getattr(ma, "alias_size_in_bytes", 0),
        },
        "collectives": colls,
        "param_count": get_config(arch).param_count(),
        "param_count_active": get_config(arch).param_count(active_only=True),
    }
    os.makedirs(out_dir, exist_ok=True)
    qtag = "__q4" if quantized else ""
    fname = f"{arch.replace('.', '_')}__{shape_name}__{mesh_tag}{qtag}.json"
    with open(os.path.join(out_dir, fname), "w") as f:
        json.dump(rec, f, indent=1)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--out", default="runs/dryrun")
    ap.add_argument("--continue-on-error", action="store_true")
    ap.add_argument("--quantized", action="store_true",
                    help="serve cells with tile-Q4 weights (paper deployment)")
    ap.add_argument("--layout", default="tp", choices=["tp", "fsdp"],
                    help="fsdp: no tensor parallelism (model axis = 2nd "
                         "FSDP axis) — §Perf H2 layout for small models")
    args = ap.parse_args()

    mesh = make_production_mesh(multi_pod=args.multi_pod)
    mesh_tag = "2x16x16" if args.multi_pod else "16x16"
    par = ParallelContext(mesh=mesh, tp=(args.layout == "tp"))
    if args.layout != "tp":
        mesh_tag += "_fsdp"
    print(f"[dryrun] mesh {mesh_tag}: {mesh.size} devices, axes "
          f"{mesh.axis_names}", flush=True)

    todo = []
    if args.all:
        for arch, shape, runnable, reason in cells():
            if runnable:
                todo.append((arch, shape.name))
            else:
                print(f"[dryrun] SKIP {arch}:{shape.name} — {reason}",
                      flush=True)
    else:
        assert args.arch and args.shape
        todo = [(args.arch, args.shape)]

    failures = []
    for arch, shape_name in todo:
        print(f"[dryrun] {arch}:{shape_name} ({mesh_tag}) ...",
              end=" ", flush=True)
        try:
            rec = run_cell(arch, shape_name, par, out_dir=args.out,
                           mesh_tag=mesh_tag, quantized=args.quantized)
            pd = rec["per_device"]
            print(f"ok lower={rec['lower_s']}s compile={rec['compile_s']}s "
                  f"flops/dev={pd['flops']:.3e} "
                  f"args/dev={pd['argument_bytes']/2**20:.0f}MiB "
                  f"temp/dev={pd['temp_bytes']/2**20:.0f}MiB", flush=True)
        except Exception as e:  # noqa
            print(f"FAIL: {type(e).__name__}: {e}", flush=True)
            failures.append((arch, shape_name, traceback.format_exc()))
            if not args.continue_on_error:
                traceback.print_exc()
                sys.exit(1)
    if failures:
        print(f"[dryrun] {len(failures)} failures:")
        for a, s, tb in failures:
            print(f"  {a}:{s}\n{tb}")
        sys.exit(1)
    print(f"[dryrun] all {len(todo)} cells compiled OK on {mesh_tag}")


if __name__ == "__main__":
    main()
