"""Serving entrypoint: batched decode with test-time scaling.

CPU-scale (real execution, reduced config):
  PYTHONPATH=src python -m repro.launch.serve --arch qwen2.5-1.5b --smoke \
      --method best_of_n --budget 8 --tasks 10 [--quantize] [--ckpt runs/ckpt]

The production path is the same engine under the production mesh
(launch/dryrun.py proves the serve_step lowers for every arch × shape).
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp

from repro.configs.registry import get_config
from repro.core import reward as R
from repro.core.controller import TTSSpec, sweep
from repro.data import tasks as T
from repro.data.tokenizer import ByteTokenizer
from repro.models import api
from repro.serving.engine import DecodeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--method", default="best_of_n",
                    choices=["best_of_n", "self_consistency", "beam_search"])
    ap.add_argument("--budget", type=int, default=8)
    ap.add_argument("--tasks", type=int, default=10)
    ap.add_argument("--max-tokens", type=int, default=48)
    ap.add_argument("--quantize", action="store_true",
                    help="apply tile-group W4A16 quantization (paper §5.1)")
    ap.add_argument("--ckpt", default="", help="restore trained params")
    ap.add_argument("--continuous", action="store_true",
                    help="serve best_of_n through the slot-based "
                         "continuous-batching scheduler")
    ap.add_argument("--slots", type=int, default=8,
                    help="decode slots for --continuous")
    ap.add_argument("--paged", action="store_true",
                    help="back the decode slots with the paged KV block "
                         "pool (copy-on-write prompt sharing) instead of "
                         "dense per-slot max_len caches")
    ap.add_argument("--block-size", type=int, default=16,
                    help="KV block size in tokens for --paged")
    ap.add_argument("--kv-blocks", type=int, default=0,
                    help="pool size in blocks for --paged (0 = auto: one "
                         "dense-equivalent reservation per slot)")
    ap.add_argument("--kv-quant", default="none",
                    choices=["none", "q8", "q4"],
                    help="store --paged pool blocks tile-quantized (Q8 "
                         "int8 / Q4 packed codes + per-(2,16)-tile "
                         "scales) with dequant fused into the paged "
                         "attention gather — ~4x / ~7x fewer KV bytes "
                         "than fp32 at matched block count")
    ap.add_argument("--prefix-cache", action="store_true",
                    help="keep completed prompt prefixes pinned in the "
                         "paged KV pool (radix tree, LRU-evicted under "
                         "pressure) so requests sharing a system prompt / "
                         "few-shot header skip re-prefilling it; requires "
                         "--paged --continuous")
    ap.add_argument("--cache-capacity", type=int, default=0,
                    help="max pool blocks the prefix cache may pin "
                         "(0 = bounded only by pool pressure)")
    ap.add_argument("--fewshot", type=int, default=0,
                    help="prepend a shared header of N worked examples to "
                         "every task prompt (the cross-request common "
                         "prefix the cache exploits)")
    ap.add_argument("--beam-width", type=int, default=0,
                    help="surviving beams for --method beam_search "
                         "(0 = budget // 2)")
    ap.add_argument("--beam-expand", type=int, default=2,
                    help="candidates per surviving beam per step")
    ap.add_argument("--beam-steps", type=int, default=8,
                    help="reasoning-step scoring boundaries before final "
                         "selection")
    ap.add_argument("--step-tokens", type=int, default=16,
                    help="token budget per reasoning step")
    ap.add_argument("--spec-k", type=int, default=0,
                    help="speculative decoding: draft-then-verify rounds "
                         "committing up to K tokens per slot per step "
                         "(greedy acceptance — outputs stay bit-identical "
                         "to plain decoding); 0 disables; requires "
                         "--continuous --paged; defaults to self-drafting "
                         "unless --draft-model is given")
    ap.add_argument("--draft-model", default="",
                    help="configs-registry arch of the small draft model "
                         "proposing tokens for --spec-k (smoke config, "
                         "vocab aligned to the target)")
    ap.add_argument("--self-draft", action="store_true",
                    help="draft with the target model itself on a forked "
                         "(copy-on-write) snapshot of its paged state — "
                         "no extra params, 100%% acceptance; the "
                         "machinery-exercising mode for --spec-k")
    ap.add_argument("--trace", default="",
                    help="write a Chrome-trace-event JSON of the request "
                         "lifecycle (slots as tracks, scheduler/engine "
                         "phase spans as nested slices, pool gauges as "
                         "counters) to this path — open it at "
                         "https://ui.perfetto.dev; requires --continuous")
    ap.add_argument("--profile", default="",
                    help="write a roofline-attributed kernel-profile JSON "
                         "report (per-kernel analytic FLOPs/HBM bytes vs "
                         "sampled measured wall time, prefill/decode cost "
                         "breakdown, canary drift gauges) to this path — "
                         "validate with `python -m repro.serving.profiling "
                         "PATH`; requires --continuous")
    ap.add_argument("--canary-rate", type=float, default=0.25,
                    help="fraction of decode steps the profiler re-runs "
                         "through the exact path (XLA paged attention, fp "
                         "dequant, exact softmax) to measure max logit "
                         "error / argmax flip rate / KV round-trip drift "
                         "online (0 disables; only active with --profile)")
    ap.add_argument("--metrics", action="store_true",
                    help="print every serving row's full "
                         "SchedulerMetrics.summary() dict (all latency "
                         "percentiles included) instead of the one-line "
                         "summaries; requires --continuous")
    ap.add_argument("--dry", action="store_true",
                    help="CI smoke: shrink tasks/budget/steps so the run "
                         "finishes in seconds while still exercising the "
                         "full serving path")
    args = ap.parse_args()
    if args.dry:
        args.tasks = min(args.tasks, 2)
        args.budget = min(args.budget, 4)
        args.max_tokens = min(args.max_tokens, 12)
        args.beam_steps = min(args.beam_steps, 2)
        args.step_tokens = min(args.step_tokens, 8)

    cfg = get_config(args.arch, smoke=args.smoke)
    tok = ByteTokenizer()
    if cfg.vocab_size < tok.vocab_size:
        cfg = cfg.with_(vocab_size=tok.vocab_size)
    model = api.get_model(cfg)

    if args.ckpt:
        from repro.checkpoint.checkpointer import Checkpointer

        ckpt = Checkpointer(args.ckpt)  # params-only checkpoint dir
        params, _ = ckpt.restore(model.abstract_params(cfg))
    else:
        params = model.init_params(jax.random.key(0), cfg)

    if args.quantize:
        from repro.quant.qlinear import quantize_model_params

        params = quantize_model_params(params)
        print("[serve] weights quantized: tile-group Q4_0 + Q8_0 down-proj")

    if args.continuous and args.method == "self_consistency":
        print(f"[serve] WARNING: --continuous routes best_of_n and "
              f"beam_search through the slot scheduler; {args.method} "
              f"uses the direct path")

    max_len = 256
    kv_kwargs = {}
    if args.kv_quant != "none" and not args.paged:
        raise SystemExit("--kv-quant requires --paged (the quantized pool "
                         "is a block-pool storage layout)")
    if args.paged:
        if max_len % args.block_size:
            raise SystemExit(f"--block-size must divide max_len={max_len}")
        # auto-size for the widest of the slot pool, the TTS fan-out and
        # the beam fan-out: the direct (non-continuous) path forks
        # `budget` (or width*expand) rows at once and has no preemption
        # to fall back on, and sweep() itself grows the scheduler to
        # max(slots, fan) slots
        fan = ((args.beam_width or max(1, args.budget // 2))
               * args.beam_expand if args.method == "beam_search" else 0)
        rows = max(args.slots, args.budget, fan)
        n_blocks = args.kv_blocks or (
            1 + rows * (max_len // args.block_size))
        kv_kwargs = dict(paged=True, block_size=args.block_size,
                         n_blocks=n_blocks, kv_quant=args.kv_quant)
    engine = DecodeEngine(params, cfg, max_len=max_len, eos_id=tok.eos_id,
                          pad_id=tok.pad_id, **kv_kwargs)
    prefix_cache = None
    if args.prefix_cache:
        if not (args.paged and args.continuous):
            raise SystemExit("--prefix-cache requires --paged --continuous "
                             "(the cache lives in the paged block pool and "
                             "is driven by the scheduler)")
        from repro.serving.prefix_cache import PrefixCache

        prefix_cache = PrefixCache(
            engine.pool, capacity_blocks=args.cache_capacity or None)
    tracer = None
    if args.trace or args.metrics:
        if not args.continuous:
            raise SystemExit("--trace/--metrics require --continuous (the "
                             "tracer records the scheduler's request "
                             "lifecycle)")
        from repro.serving.telemetry import Tracer

        tracer = Tracer()
    profiler = None
    if args.profile:
        if not args.continuous:
            raise SystemExit("--profile requires --continuous (the "
                             "profiler samples the scheduler's decode "
                             "steps)")
        from repro.serving.profiling import KernelProfiler

        profiler = KernelProfiler(canary_rate=args.canary_rate)
    spec_decode = None
    if args.spec_k or args.draft_model or args.self_draft:
        if not args.spec_k:
            raise SystemExit("--draft-model/--self-draft need --spec-k K "
                             "(the proposal budget per round)")
        if not (args.paged and args.continuous):
            raise SystemExit("--spec-k requires --paged --continuous "
                             "(draft lanes and rejected suffixes are "
                             "refcount operations on the block pool)")
        from repro.serving.engine import SpecConfig

        spec_decode = SpecConfig(
            k=args.spec_k, draft_model=args.draft_model,
            self_draft=args.self_draft or not args.draft_model)
        # acceptance compares greedy argmaxes, so speculative serving
        # decodes greedily (that is also what makes it bit-identical to
        # the plain path)
        print(f"[serve] speculative decoding: k={args.spec_k} "
              f"{'draft=' + args.draft_model if args.draft_model else 'self-draft'}"
              f" (greedy sampling forced)")
    if args.fewshot:
        tasks = T.shared_prefix_dataset(123, args.tasks,
                                        n_shots=args.fewshot)
    else:
        tasks = T.gen_dataset(123, args.tasks)
    scorer = R.OracleVerifier()
    spec = TTSSpec(method=args.method, budget=args.budget,
                   max_tokens=args.max_tokens, beam_width=args.beam_width,
                   beam_expand=args.beam_expand, beam_steps=args.beam_steps,
                   step_tokens=args.step_tokens)
    sc = None
    if spec_decode is not None:
        from repro.serving.sampler import SamplerConfig

        sc = SamplerConfig(greedy=True)
    rows = sweep(engine, tok, tasks, [spec], jax.random.key(0), scorer,
                 continuous=args.continuous, n_slots=args.slots,
                 prefix_cache=prefix_cache, tracer=tracer,
                 profiler=profiler, spec_decode=spec_decode, sc=sc)
    if args.trace:
        tracer.write_chrome_trace(args.trace)
        print(f"[serve] trace: {len(tracer.events)} events / "
              f"{len(tracer.spans)} spans -> {args.trace} "
              f"(load in https://ui.perfetto.dev)")
    if profiler is not None:
        profiler.uninstall()
        profiler.write_report(args.profile)
        ps = profiler.summary_metrics()
        print(f"[serve] profile: {len(profiler.report()['kernels'])} "
              f"kernels, kernel_time_share={ps['kernel_time_share']:.3f} "
              f"eff_p50={ps['roofline_efficiency_p50']:.3g} "
              f"canary_samples={ps['canary_samples']} "
              f"flip_rate={ps['canary_argmax_flip_rate']:.3g} "
              f"max_logit_err={ps['canary_max_logit_err']:.3g} "
              f"-> {args.profile}")
        for w in profiler.warnings:
            print(f"[serve] profile WARNING: {w}")
    if args.paged:
        # leak check: after a full drain the pool holds only the prefix
        # cache's pins — beam trees included (the pre-scheduler beam path
        # used to leak every task's blocks here)
        pinned = (prefix_cache.stats()["cached_blocks"]
                  if prefix_cache is not None else 0)
        in_use = engine.pool.blocks_in_use
        if in_use != pinned:
            raise SystemExit(
                f"[serve] KV pool leak: {in_use} blocks still in use after "
                f"drain (expected {pinned} cache-pinned)")
        print(f"[serve] kv pool clean: {in_use} blocks in use after drain "
              f"({pinned} cache-pinned)")
    for r in rows:
        print(f"[serve] {r['method']} budget={r['budget']} "
              f"accuracy={r['accuracy']:.3f} "
              f"decode_tokens={r['decode_tokens']}")
        if "serving" in r:
            s = r["serving"]
            if args.metrics:
                for k in sorted(s):
                    print(f"[serve]   {k}={s[k]}")
            print(f"[serve] continuous: slots={s['n_slots']} "
                  f"occupancy={s['avg_slot_occupancy']:.2f} "
                  f"requests_per_s={s['requests_per_s']:.2f} "
                  f"prefill_tokens={s['prefill_tokens']} "
                  f"decode_tokens={s['decode_tokens']} "
                  f"prefill_calls={s['prefill_calls']} "
                  f"calls_per_request={s['prefill_calls_per_request']:.2f} "
                  f"admission_batch_max={s['admission_batch_max']} "
                  f"preemptions={s['preemptions']}")
            # tail latency: ttft/itl/queue_wait come from the tracer's
            # per-request records (0 without --trace/--metrics);
            # step_time needs no tracer (StepRecord.wall_s)
            print(f"[serve] latency: "
                  f"ttft_p50={s['ttft_p50'] * 1e3:.1f}ms "
                  f"ttft_p99={s['ttft_p99'] * 1e3:.1f}ms "
                  f"itl_p50={s['itl_p50'] * 1e3:.1f}ms "
                  f"itl_p99={s['itl_p99'] * 1e3:.1f}ms "
                  f"queue_wait_p99={s['queue_wait_p99'] * 1e3:.1f}ms "
                  f"step_time_p50={s['step_time_p50'] * 1e3:.1f}ms "
                  f"step_time_p99={s['step_time_p99'] * 1e3:.1f}ms")
            if s.get("spec_rounds"):
                print(f"[serve] speculative: rounds={s['spec_rounds']} "
                      f"draft_tokens={s['draft_tokens']} "
                      f"acceptance_rate={s['spec_acceptance_rate']:.2f} "
                      f"accepted_tokens_per_step="
                      f"{s['accepted_tokens_per_step']:.2f}")
            if s.get("beam_boundaries"):
                print(f"[serve] beam: boundaries={s['beam_boundaries']} "
                      f"expansions={s['beam_expansions']} "
                      f"prunes={s['beam_prunes']} "
                      f"prm_batches={s['prm_batches']} "
                      f"prm_candidates_per_batch="
                      f"{s['prm_candidates_per_batch']:.1f}")
            if "prefix_cache" in s:
                pc = s["prefix_cache"]
                print(f"[serve] prefix cache: hit_rate={pc['hit_rate']:.2f} "
                      f"tokens_matched={pc['tokens_matched']} "
                      f"prefill_tokens_saved={s['prefill_tokens_saved']} "
                      f"cached_blocks={pc['cached_blocks']} "
                      f"evictions={pc['evictions']}")
            if "kv" in s:
                kv = s["kv"]
                print(f"[serve] paged kv: block_size={kv['block_size']} "
                      f"kv_quant={kv['kv_quant']} "
                      f"peak_blocks={kv['peak_blocks_in_use']} "
                      f"cow_copies={kv['cow_copies']} "
                      f"peak_bytes={kv['peak_bytes_in_use']} "
                      f"dense_bytes={kv['dense_bytes']} "
                      f"hbm_saved_rightsized={kv['hbm_saved_bytes']}")


if __name__ == "__main__":
    main()
