"""Serving entrypoint: batched decode with test-time scaling.

CPU-scale (real execution, reduced config):
  PYTHONPATH=src python -m repro.launch.serve --arch qwen2.5-1.5b --smoke \
      --method best_of_n --budget 8 --tasks 10 [--quantize] [--ckpt runs/ckpt]

The production path is the same engine under the production mesh
(launch/dryrun.py proves the serve_step lowers for every arch × shape).
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp

from repro.configs.registry import get_config
from repro.core import reward as R
from repro.core.controller import TTSSpec, sweep
from repro.data import tasks as T
from repro.data.tokenizer import ByteTokenizer
from repro.models import api
from repro.serving.engine import DecodeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--method", default="best_of_n",
                    choices=["best_of_n", "self_consistency", "beam_search"])
    ap.add_argument("--budget", type=int, default=8)
    ap.add_argument("--tasks", type=int, default=10)
    ap.add_argument("--max-tokens", type=int, default=48)
    ap.add_argument("--quantize", action="store_true",
                    help="apply tile-group W4A16 quantization (paper §5.1)")
    ap.add_argument("--ckpt", default="", help="restore trained params")
    ap.add_argument("--continuous", action="store_true",
                    help="serve best_of_n through the slot-based "
                         "continuous-batching scheduler")
    ap.add_argument("--slots", type=int, default=8,
                    help="decode slots for --continuous")
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=args.smoke)
    tok = ByteTokenizer()
    if cfg.vocab_size < tok.vocab_size:
        cfg = cfg.with_(vocab_size=tok.vocab_size)
    model = api.get_model(cfg)

    if args.ckpt:
        from repro.checkpoint.checkpointer import Checkpointer

        ckpt = Checkpointer(args.ckpt)  # params-only checkpoint dir
        params, _ = ckpt.restore(model.abstract_params(cfg))
    else:
        params = model.init_params(jax.random.key(0), cfg)

    if args.quantize:
        from repro.quant.qlinear import quantize_model_params

        params = quantize_model_params(params)
        print("[serve] weights quantized: tile-group Q4_0 + Q8_0 down-proj")

    if args.continuous and args.method != "best_of_n":
        print(f"[serve] WARNING: --continuous only routes best_of_n through "
              f"the slot scheduler; {args.method} uses the direct path")

    engine = DecodeEngine(params, cfg, max_len=256, eos_id=tok.eos_id,
                          pad_id=tok.pad_id)
    tasks = T.gen_dataset(123, args.tasks)
    scorer = R.OracleVerifier()
    spec = TTSSpec(method=args.method, budget=args.budget,
                   max_tokens=args.max_tokens)
    rows = sweep(engine, tok, tasks, [spec], jax.random.key(0), scorer,
                 continuous=args.continuous, n_slots=args.slots)
    for r in rows:
        print(f"[serve] {r['method']} budget={r['budget']} "
              f"accuracy={r['accuracy']:.3f} "
              f"decode_tokens={r['decode_tokens']}")
        if "serving" in r:
            s = r["serving"]
            print(f"[serve] continuous: slots={s['n_slots']} "
                  f"occupancy={s['avg_slot_occupancy']:.2f} "
                  f"requests_per_s={s['requests_per_s']:.2f} "
                  f"prefill_tokens={s['prefill_tokens']} "
                  f"decode_tokens={s['decode_tokens']}")


if __name__ == "__main__":
    main()
