"""Production mesh construction.

Defined as functions (not module-level constants) so importing this module
never touches jax device state — the dry-run sets
``--xla_force_host_platform_device_count=512`` *before* first jax init.
"""
from __future__ import annotations

import jax

from repro.distributed.compat import mesh_axis_types_kw


def make_production_mesh(*, multi_pod: bool = False):
    """16×16 = 256 chips per pod; 2 pods = 512 chips multi-pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, **mesh_axis_types_kw(len(axes)))


def make_host_mesh(n_data: int = 1, n_model: int = 1):
    """Small mesh over however many (host) devices exist — used by tests."""
    return jax.make_mesh((n_data, n_model), ("data", "model"),
                         **mesh_axis_types_kw(2))
