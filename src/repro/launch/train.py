"""Training entrypoint.

CPU-scale run (real execution):
  PYTHONPATH=src python -m repro.launch.train --arch qwen2.5-1.5b --smoke \
      --steps 100 --batch 8 --seq 128 --ckpt-dir runs/ckpt

On a real cluster every host runs this same command; jax.distributed
initializes from the environment, the mesh spans all pods, and the
checkpoint/restart + preemption machinery below gives fault tolerance:
relaunching the identical command resumes from the latest checkpoint.
"""
from __future__ import annotations

import argparse
import os

import jax
import jax.numpy as jnp

from repro.checkpoint.checkpointer import Checkpointer
from repro.configs.registry import get_config
from repro.data.dataset import MathDataLoader
from repro.data.tokenizer import ByteTokenizer
from repro.distributed.fault_tolerance import (PreemptionHandler,
                                               StragglerMonitor,
                                               resume_or_init)
from repro.distributed.sharding import ParallelContext
from repro.models import api
from repro.train.loop import make_train_step
from repro.train.optimizer import AdamWConfig, init_opt_state


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--mesh", default="", help="e.g. 2x1: data x model")
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=args.smoke)
    tok = ByteTokenizer(vocab_size=max(320, cfg.vocab_size))
    if cfg.vocab_size < tok.vocab_size:
        cfg = cfg.with_(vocab_size=tok.vocab_size)

    par = ParallelContext()
    if args.mesh:
        from repro.launch.mesh import make_host_mesh
        d, m = (int(x) for x in args.mesh.split("x"))
        par = ParallelContext(mesh=make_host_mesh(d, m),
                              shard_activations_seq=True)

    model = api.get_model(cfg)
    oc = AdamWConfig(lr=args.lr, warmup_steps=20, total_steps=args.steps)
    step_fn = jax.jit(make_train_step(cfg, oc, par,
                                      microbatches=args.microbatches))

    ckpt = Checkpointer(args.ckpt_dir) if args.ckpt_dir else None
    abstract = {
        "params": model.abstract_params(cfg),
        "opt": jax.eval_shape(lambda: init_opt_state(
            model.abstract_params(cfg))),
        "step": jax.ShapeDtypeStruct((), jnp.int32),
    }

    def init():
        p = model.init_params(jax.random.key(0), cfg)
        return {"params": p, "opt": init_opt_state(p),
                "step": jnp.zeros((), jnp.int32)}

    if ckpt is not None:
        state, start = resume_or_init(ckpt, abstract, init)
    else:
        state, start = init(), 0
    start = int(state["step"])

    loader = MathDataLoader(tok, batch_size=args.batch, seq_len=args.seq,
                            host_id=jax.process_index(),
                            n_hosts=jax.process_count())
    monitor = StragglerMonitor()

    def emergency_save():
        if ckpt is not None:
            print("[ft] preemption — emergency checkpoint")
            ckpt.save(state, step=int(state["step"]))

    import time
    with PreemptionHandler(emergency_save) as ph:
        t0 = time.time()
        for i in range(start, args.steps):
            batch = tuple(jnp.asarray(b) for b in next(loader))
            p, o, metrics = step_fn(state["params"], state["opt"], batch)
            state = {"params": p, "opt": o,
                     "step": jnp.asarray(i + 1, jnp.int32)}
            monitor.record_step(time.time() - t0)
            t0 = time.time()
            if i % 20 == 0 or i == args.steps - 1:
                print(f"step {i:5d} loss {float(metrics['loss']):.4f} "
                      f"lr {float(metrics['lr']):.2e}")
            if ckpt is not None and (i + 1) % args.ckpt_every == 0:
                ckpt.save_async(state, step=i + 1)
            if ph.preempted:
                break
    if ckpt is not None:
        ckpt.wait()
        ckpt.save(state, step=int(state["step"]))
    loader.close()
    print("[train] done;", monitor.summary())


if __name__ == "__main__":
    main()
