"""Unified decoder-only transformer covering the dense / GQA / bias / SWA /
local:global / MoE members of the architecture pool.

Layers are scanned (`jax.lax.scan` over stacked params) so the lowered HLO —
and therefore dry-run compile time — is independent of depth.  Per-layer
attention-pattern variation (gemma3's 5 local : 1 global) is expressed as a
per-layer window array threaded through the scan.
"""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.sharding import ParallelContext
from repro.models import layers as L
from repro.models.moe import init_moe, moe_ffn


def layer_windows(cfg: ModelConfig) -> jnp.ndarray:
    """Per-layer sliding window sizes (0 = unbounded full attention)."""
    if cfg.attn_pattern.startswith("local_global"):
        ratio = int(cfg.attn_pattern.split(":")[1])
        w = [cfg.window_size if (i % (ratio + 1)) != ratio else 0
             for i in range(cfg.n_layers)]
        return jnp.array(w, jnp.int32)
    if cfg.window_size:
        return jnp.full((cfg.n_layers,), cfg.window_size, jnp.int32)
    return jnp.zeros((cfg.n_layers,), jnp.int32)


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------


def _init_layer(key, cfg: ModelConfig, dtype) -> dict:
    ka, kf = jax.random.split(key)
    p = {
        "attn_norm": L.init_rmsnorm(cfg.d_model),
        "attn": L.init_attention(ka, cfg, dtype),
        "ffn_norm": L.init_rmsnorm(cfg.d_model),
    }
    if cfg.moe:
        p["moe"] = init_moe(kf, cfg, dtype)
    else:
        p["ffn"] = L.init_swiglu(kf, cfg.d_model, cfg.d_ff, dtype)
    return p


def init_params(key, cfg: ModelConfig) -> dict:
    dtype = jnp.dtype(cfg.param_dtype)
    ke, kl, kh, kp = jax.random.split(key, 4)
    layer_keys = jax.random.split(kl, cfg.n_layers)
    params = {
        "embedding": L.init_embedding(ke, cfg.vocab_size, cfg.d_model, dtype),
        "layers": jax.vmap(partial(_init_layer, cfg=cfg, dtype=dtype))(layer_keys),
        "final_norm": L.init_rmsnorm(cfg.d_model),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = L.init_embedding(kh, cfg.vocab_size, cfg.d_model, dtype)
    if cfg.frontend == "patch_stub":
        params["patch_proj"] = L.init_linear(kp, cfg.d_model, cfg.d_model,
                                             bias=False, dtype=dtype)
    return params


def abstract_params(cfg: ModelConfig) -> dict:
    return jax.eval_shape(lambda: init_params(jax.random.key(0), cfg))


# ---------------------------------------------------------------------------
# Layer body
# ---------------------------------------------------------------------------


def _layer(p, x, cfg, par, *, positions, window, cache=None, cache_len=None,
           prefix_kv=None, prefix_positions=None):
    h, new_kv = L.attention_block(
        p["attn"], L.rmsnorm(p["attn_norm"], x, cfg.norm_eps), cfg,
        positions=positions, window=window, cache=cache, cache_len=cache_len,
        prefix_kv=prefix_kv, prefix_positions=prefix_positions)
    x = x + h
    hn = L.rmsnorm(p["ffn_norm"], x, cfg.norm_eps)
    if cfg.moe:
        h, aux = moe_ffn(p["moe"], hn, cfg, par)
    else:
        h, aux = L.swiglu(p["ffn"], hn), jnp.zeros((), jnp.float32)
    if par is not None:
        # act_seq: the layer-boundary residual (which remat saves) is
        # sequence-sharded over the model axis (no-op unless enabled).
        x = par.constrain(x + h, "batch", "act_seq", None)
    else:
        x = x + h
    return x, new_kv, aux


# ---------------------------------------------------------------------------
# Forward passes
# ---------------------------------------------------------------------------


def forward(params, tokens, cfg: ModelConfig, par: ParallelContext = None,
            *, embeddings: Optional[jnp.ndarray] = None, return_kv: bool = False,
            logit_positions: Optional[jnp.ndarray] = None,
            prefix: Optional[dict] = None):
    """Full-sequence forward (training / prefill). Returns (logits, kv, aux).

    tokens: (B, S) int32.  ``embeddings``: optional (B, P, d) modality-stub
    prefix (VLM patches / audio frames) that replaces the embedding of the
    first P positions.

    ``prefix``: optional cached-prefix handle for *partial prefill* —
    {"k", "v": (L, B, P, Hkv, D) already-rope'd per-layer prefix KV,
    "len": (B,) int32 cached lengths}.  ``tokens`` then holds only the
    uncached suffix: token j of row b sits at global position
    ``len[b] + j``, queries attend over prefix + suffix, and the returned
    KV covers the suffix alone.  Prefix slots at or past a row's cached
    length get their position pushed past every query so the causal mask
    hides them (rows with len == 0 attend to none of the prefix).  The
    per-row ``len`` makes the prefix *ragged-batch* capable: B rows with
    different cached lengths (P is the batch-max padded width) run in one
    pass — the batched cache-aware admission path.
    """
    dtype = jnp.dtype(cfg.dtype)
    x = L.embed(params["embedding"], tokens, dtype)
    if embeddings is not None:
        if prefix is not None:
            raise NotImplementedError(
                "modality-stub embeddings cannot be combined with a cached "
                "prefix (the patch positions would be ambiguous)")
        pre = L.linear(params["patch_proj"], embeddings.astype(dtype))
        x = jnp.concatenate([pre, x[:, embeddings.shape[1]:]], axis=1)
    if par is not None:
        x = par.constrain(x, "batch", "act_seq", None)
    B, S = tokens.shape
    if prefix is not None:
        offset = prefix["len"].astype(jnp.int32)  # (B,)
        positions = offset[:, None] + jnp.arange(S, dtype=jnp.int32)[None]
        P = prefix["k"].shape[2]
        parr = jnp.arange(P, dtype=jnp.int32)[None]
        # invalid prefix slots -> position P + S: strictly past any query
        # (queries reach at most offset + S - 1 <= P + S - 2), so both the
        # causal mask and the chunked kv_len mask drop them
        prefix_positions = jnp.where(parr < offset[:, None], parr, P + S)
    else:
        positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None],
                                     (B, S))
        prefix_positions = None
    windows = layer_windows(cfg)

    def body(carry, xs):
        x, aux = carry
        if prefix is None:
            (lp, w), pkv = xs, None
        else:
            lp, w, pk, pv = xs
            pkv = (pk, pv)
        x, kv, a = _layer(lp, x, cfg, par, positions=positions, window=w,
                          prefix_kv=pkv, prefix_positions=prefix_positions)
        return (x, aux + a), (kv if return_kv else None)

    body = jax.checkpoint(body) if cfg.remat == "full" else body
    scan_xs = ((params["layers"], windows) if prefix is None else
               (params["layers"], windows, prefix["k"], prefix["v"]))
    (x, aux), kvs = jax.lax.scan(body, (x, jnp.zeros((), jnp.float32)),
                                 scan_xs)
    x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    if logit_positions is not None:
        # gather the true last position per sequence before the (large)
        # lm_head matmul — avoids materializing (B, S, V) logits in prefill
        x = x[jnp.arange(B), logit_positions]
    head = params["embedding"] if cfg.tie_embeddings else params["lm_head"]
    logits = L.lm_logits(head, x, cfg.logit_softcap)
    return logits, kvs, aux


def init_cache(cfg: ModelConfig, batch: int, max_len: int, dtype=None) -> dict:
    dtype = dtype or jnp.dtype(cfg.dtype)
    hd = cfg.resolved_head_dim()
    shape = (cfg.n_layers, batch, max_len, cfg.n_kv_heads, hd)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def abstract_cache(cfg: ModelConfig, batch: int, max_len: int, dtype=None) -> dict:
    dtype = dtype or jnp.dtype(cfg.dtype)
    hd = cfg.resolved_head_dim()
    shape = (cfg.n_layers, batch, max_len, cfg.n_kv_heads, hd)
    return {"k": jax.ShapeDtypeStruct(shape, dtype),
            "v": jax.ShapeDtypeStruct(shape, dtype)}


def init_paged_cache(cfg: ModelConfig, n_blocks: int, block_size: int,
                     dtype=None) -> dict:
    """Block-pool KV storage: (L, n_blocks, block_size, Hkv, D) per leaf.

    Unlike :func:`init_cache` there is no batch or max_len dimension — rows
    map positions to blocks through per-sequence block tables (see
    ``repro.serving.kv_pool``)."""
    dtype = dtype or jnp.dtype(cfg.dtype)
    hd = cfg.resolved_head_dim()
    shape = (cfg.n_layers, n_blocks, block_size, cfg.n_kv_heads, hd)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def _quantize_tokens(pool, kvs):
    """Match prefill KV (L, B, S, Hkv, D) to a pool's storage: identity
    for fp pools, tile quantize-on-write ({"codes", "scales"} leaves with
    the same (L, B, S, ...) leading layout) for quantized pools — fused
    into the same jitted scatter, so fp KV never round-trips through HBM.
    """
    from repro.serving.kv_quant import quantize_for_pool

    return quantize_for_pool(kvs, pool)


def _scatter_prefill_blocks(pool, kvs, table, block_size: int):
    """Write prefill KV (L, B, S, Hkv, D) into pool blocks via the table.

    S is padded up to a block multiple; chunk j of row b goes to block
    ``table[b, j]``.  Chunks past a row's true block count carry padding
    and target the scratch block (table padding = 0), whose contents are
    never attended.  Quantized pools scatter the quantized code and scale
    leaves through the identical index math (the trailing token-slab dims
    are free).
    """
    kvs = _quantize_tokens(pool, kvs)

    def leaf(p, x):
        L, B, S = x.shape[:3]
        nS = -(-S // block_size)
        pad = nS * block_size - S
        if pad:
            x = jnp.pad(x, ((0, 0), (0, 0), (0, pad)) +
                        ((0, 0),) * (x.ndim - 3))
        chunks = x.reshape(L, B * nS, block_size, *x.shape[3:])
        blocks = table[:, :nS].reshape(-1)
        return p.at[:, blocks].set(chunks.astype(p.dtype))

    return jax.tree.map(leaf, pool, kvs)


def _scatter_suffix_blocks(pool, kvs, table, block_size: int, start):
    """Write suffix KV (L, B, S, Hkv, D) into pool blocks at a per-row
    positional offset: row b's token j lands at global position
    ``start[b] + j``, i.e. pool[table[b, pos//bs], pos % bs].

    Unlike :func:`_scatter_prefill_blocks` this writes position-by-position
    (not whole blocks), because a misaligned cached prefix leaves the first
    suffix tokens *inside* a partially-filled tail block whose earlier
    offsets must survive.  Positions past the table's range (padding rows)
    are clamped to the last slot — an un-attended offset or the scratch
    block, mirroring the dense scratch-slot convention.  Groups of the
    quantized layout never span tokens, so per-position writes are exact
    on code+scale leaves too.
    """
    kvs = _quantize_tokens(pool, kvs)
    W = table.shape[1]

    def leaf(p, x):
        S = x.shape[2]
        pos = start.astype(jnp.int32)[:, None] + jnp.arange(S,
                                                            dtype=jnp.int32)
        pos = jnp.minimum(pos, W * block_size - 1)       # (B, S)
        blk = jnp.take_along_axis(table, pos // block_size, axis=1)
        return p.at[:, blk, pos % block_size].set(x.astype(p.dtype))

    return jax.tree.map(leaf, pool, kvs)


def prefill(params, tokens, cfg: ModelConfig, par: ParallelContext = None,
            *, max_len: int, embeddings=None, lengths=None, paged=None,
            prefix=None):
    """Run the prompt, build the KV cache. Returns (next_logits, cache).

    ``lengths``: (B,) true prompt lengths for right-padded batches; the
    returned logits are taken at each sequence's true last position.
    ``paged``: optional {"k", "v", "table"} handle — block pools
    (L, n_blocks, bs, Hkv, D) plus a (B, W) block table; prompt KV is
    scattered into the rows' blocks instead of a fresh dense cache and the
    returned cache carries the updated pools.
    ``prefix``: optional cached-prefix handle (see :func:`forward`) for
    *partial prefill* — requires ``paged``; ``tokens``/``lengths`` then
    describe only the uncached suffix, whose KV is scattered into the
    table at offset ``prefix["len"]`` while the prompt's cached positions
    stay untouched.
    """
    B, S = tokens.shape
    pos = (lengths - 1) if lengths is not None else jnp.full((B,), S - 1)
    if prefix is not None and paged is None:
        raise ValueError("partial prefill over a cached prefix requires the "
                         "paged cache layout")
    logits, kvs, _ = forward(params, tokens, cfg, par, embeddings=embeddings,
                             return_kv=True, logit_positions=pos,
                             prefix=prefix)
    k, v = kvs  # (L, B, S, Hkv, D)
    if paged is not None:
        from repro.serving.kv_quant import pool_block_size

        bs = pool_block_size(paged["k"], axis=2)
        if prefix is not None:
            start = prefix["len"]
            return logits, {
                "k": _scatter_suffix_blocks(paged["k"], k, paged["table"],
                                            bs, start),
                "v": _scatter_suffix_blocks(paged["v"], v, paged["table"],
                                            bs, start),
                "table": paged["table"],
            }
        return logits, {
            "k": _scatter_prefill_blocks(paged["k"], k, paged["table"], bs),
            "v": _scatter_prefill_blocks(paged["v"], v, paged["table"], bs),
            "table": paged["table"],
        }
    cache = init_cache(cfg, B, max_len)
    cache = {
        "k": jax.lax.dynamic_update_slice(cache["k"], k.astype(cache["k"].dtype),
                                          (0, 0, 0, 0, 0)),
        "v": jax.lax.dynamic_update_slice(cache["v"], v.astype(cache["v"].dtype),
                                          (0, 0, 0, 0, 0)),
    }
    return logits, cache


def decode_step(params, tokens, cache, cache_len, cfg: ModelConfig,
                par: ParallelContext = None):
    """One decode step.

    tokens: (B, 1) int32 — current token.  cache: stacked (L, B, S, Hkv, D),
    or a paged handle additionally carrying "table" (B, W) int32 with k/v
    leaves shaped (L, n_blocks, bs, Hkv, D).
    cache_len: (B,) int32 — sequence length *after* this token is appended.
    Returns (logits (B, vocab) f32, new_cache).
    """
    dtype = jnp.dtype(cfg.dtype)
    x = L.embed(params["embedding"], tokens, dtype)
    if par is not None:
        x = par.constrain(x, "batch", "act_seq", None)
    positions = (cache_len - 1)[:, None]
    windows = layer_windows(cfg)

    seq_par = par is not None and par.kv_seq_axis is not None
    table = cache.get("table") if isinstance(cache, dict) else None
    if table is not None and seq_par:
        raise NotImplementedError(
            "paged KV cache is not supported with sequence-parallel decode")

    def body(x, xs):
        lp, w, ck, cv = xs
        if seq_par:
            from repro.serving.seq_parallel import seq_parallel_decode_layer
            x, nk, nv = seq_parallel_decode_layer(
                lp, x, cfg, par, cache_k=ck, cache_v=cv,
                cache_len=cache_len, window=w)
        else:
            layer_cache = {"k": ck, "v": cv}
            if table is not None:
                layer_cache["table"] = table
            x, (nk, nv), _ = _layer(lp, x, cfg, par, positions=positions,
                                    window=w, cache=layer_cache,
                                    cache_len=cache_len)
        return x, (nk, nv)

    x, (nk, nv) = jax.lax.scan(
        body, x, (params["layers"], windows, cache["k"], cache["v"]))
    x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    head = params["embedding"] if cfg.tie_embeddings else params["lm_head"]
    logits = L.lm_logits(head, x[:, 0], cfg.logit_softcap)
    new_cache = {"k": nk, "v": nv}
    if table is not None:
        new_cache["table"] = table
    return logits, new_cache
