"""Shared model building blocks.

Everything is expressed as pure functions over parameter pytrees (nested
dicts of jnp arrays), so the same definitions serve training, prefill and
decode, and lower cleanly under pjit on the production mesh.

Attention is implemented as a *chunked online-softmax* ("flash"-style) scan
so that prefill_32k / train_4k never materialize S×S score matrices in the
lowered HLO.  The Pallas LUT-softmax kernel (`repro.kernels.
lut_softmax_attention`) is the TPU hot path with identical semantics; this
file is the XLA path used for dry-runs and CPU execution.
"""
from __future__ import annotations

import math
import os
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig

# ---------------------------------------------------------------------------
# Initialization helpers
# ---------------------------------------------------------------------------


def _dense_init(key, in_dim: int, out_shape, dtype) -> jnp.ndarray:
    scale = 1.0 / math.sqrt(in_dim)
    return jax.random.normal(key, (in_dim, *out_shape), dtype=jnp.float32).astype(dtype) * scale


def init_linear(key, in_dim: int, out_dim: int, *, bias: bool, dtype) -> dict:
    p = {"w": _dense_init(key, in_dim, (out_dim,), dtype)}
    if bias:
        p["b"] = jnp.zeros((out_dim,), dtype)
    return p


def linear(p: dict, x: jnp.ndarray) -> jnp.ndarray:
    """Dense layer. ``p`` may hold a plain weight or a quantized weight.

    Quantized weights (produced by ``repro.quant``) are dicts with a
    ``codes`` entry; they are dequantized in-graph (XLA path) or via the
    Pallas LUT kernel (TPU path) by ``repro.quant.qlinear.apply``.
    """
    w = p["w"]
    if isinstance(w, dict):  # quantized
        from repro.quant.qlinear import quantized_matmul

        y = quantized_matmul(x, w)
    else:
        y = jnp.einsum("...d,df->...f", x, w.astype(x.dtype))
    if "b" in p:
        y = y + p["b"].astype(y.dtype)
    return y


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------


def init_rmsnorm(d: int, dtype=jnp.float32) -> dict:
    return {"scale": jnp.ones((d,), dtype)}


def rmsnorm(p: dict, x: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps)
    return (y * p["scale"].astype(jnp.float32)).astype(dt)


def init_layernorm(d: int, dtype=jnp.float32) -> dict:
    return {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)}


def layernorm(p: dict, x: jnp.ndarray, eps: float = 1e-5) -> jnp.ndarray:
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps)
    return (y * p["scale"] + p["bias"]).astype(dt)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------


def rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """Rotary embedding. x: (..., S, H, D); positions: broadcastable to (..., S)."""
    d = x.shape[-1]
    half = d // 2
    freq = jnp.exp(-math.log(theta) * jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freq  # (..., S, half)
    cos = jnp.cos(ang)[..., None, :]  # (..., S, 1, half)
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    xf1, xf2 = x1.astype(jnp.float32), x2.astype(jnp.float32)
    return jnp.concatenate([xf1 * cos - xf2 * sin, xf2 * cos + xf1 * sin], axis=-1).astype(x.dtype)


# ---------------------------------------------------------------------------
# Chunked online-softmax attention (XLA path)
# ---------------------------------------------------------------------------

NEG_INF = -1e30


def _attn_mask(q_pos, kv_pos, *, causal: bool, window, kv_len=None):
    """(..., Sq, Skv) boolean validity mask.

    ``window`` may be a Python int or a traced scalar (per-layer windows are
    threaded through the layer scan); window <= 0 means unbounded.
    """
    m = jnp.ones(q_pos.shape[:-1] + (q_pos.shape[-1], kv_pos.shape[-1]), bool)
    qp = q_pos[..., :, None]
    kp = kv_pos[..., None, :]
    if causal:
        m &= kp <= qp
    w = jnp.asarray(window, jnp.int32)
    m &= (w <= 0) | (qp - kp < w)
    if kv_len is not None:
        m &= kp < kv_len[..., None, None]
    return m


def _gqa_scores(q, k, scale):
    """q: (B, Sq, Hkv, G, D); k: (B, Skv, Hkv, D) -> (B, Hkv, G, Sq, Skv) f32."""
    return jnp.einsum("bqhgd,bkhd->bhgqk", q, k, preferred_element_type=jnp.float32) * scale


def _softcap(s, cap: float):
    if cap:
        s = jnp.tanh(s / cap) * cap
    return s


def chunked_attention(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    *,
    q_positions: jnp.ndarray,
    kv_positions: jnp.ndarray,
    causal: bool = True,
    window: int = 0,
    softcap: float = 0.0,
    q_chunk: int = 512,
    kv_chunk: int = 1024,
    kv_len: Optional[jnp.ndarray] = None,
) -> jnp.ndarray:
    """Flash-style attention via a double scan over q- and kv-chunks.

    q: (B, Sq, Hq, D); k, v: (B, Skv, Hkv, D). Returns (B, Sq, Hq, D).
    Never materializes more than (B, Hq, q_chunk, kv_chunk) scores.
    """
    B, Sq, Hq, D = q.shape
    _, Skv, Hkv, _ = k.shape
    G = Hq // Hkv
    scale = 1.0 / math.sqrt(D)

    # pad to chunk multiples (e.g. whisper's 1500 encoder frames); padded
    # KV is masked via kv_len, padded Q rows are sliced off the output.
    q_chunk = min(q_chunk, Sq)
    kv_chunk = min(kv_chunk, Skv)
    Sq_orig, Skv_orig = Sq, Skv
    q_positions = jnp.broadcast_to(q_positions, (B, Sq))
    kv_positions = jnp.broadcast_to(kv_positions, (B, Skv))
    if Sq % q_chunk:
        pq = q_chunk - Sq % q_chunk
        q = jnp.pad(q, ((0, 0), (0, pq), (0, 0), (0, 0)))
        q_positions = jnp.pad(q_positions, ((0, 0), (0, pq)))
        Sq += pq
    if Skv % kv_chunk:
        pk = kv_chunk - Skv % kv_chunk
        k = jnp.pad(k, ((0, 0), (0, pk), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pk), (0, 0), (0, 0)))
        # pad kv positions with a sentinel past every reachable position
        # (not 0: callers pass *semantic* positions — a 0-padded slot would
        # alias the real position 0 and slip through the kv_len mask)
        kv_positions = jnp.pad(kv_positions, ((0, 0), (0, pk)),
                               constant_values=jnp.iinfo(jnp.int32).max // 2)
        Skv += pk
        if kv_len is None:
            kv_len = jnp.full((B,), Skv_orig, jnp.int32)
        else:
            kv_len = jnp.minimum(kv_len, Skv_orig)
    nq = Sq // q_chunk
    nkv = Skv // kv_chunk

    qg = q.reshape(B, nq, q_chunk, Hkv, G, D)
    qg = jnp.moveaxis(qg, 1, 0)  # (nq, B, qc, Hkv, G, D)
    qp = jnp.moveaxis(q_positions.reshape(B, nq, q_chunk), 1, 0)  # (nq, B, qc)

    kg = jnp.moveaxis(k.reshape(B, nkv, kv_chunk, Hkv, D), 1, 0)
    vg = jnp.moveaxis(v.reshape(B, nkv, kv_chunk, Hkv, D), 1, 0)
    kp = jnp.moveaxis(kv_positions.reshape(B, nkv, kv_chunk), 1, 0)  # (nkv, B, kc)

    def q_step(_, qc):
        qi, qpi = qc  # (B, qc, Hkv, G, D), (B, qc)

        def kv_step(carry, kc):
            o, m, l = carry
            ki, vi, kpi = kc
            s = _gqa_scores(qi, ki, scale)  # (B, Hkv, G, qc, kc) f32
            s = _softcap(s, softcap)
            mask = _attn_mask(qpi, kpi, causal=causal, window=window, kv_len=kv_len)
            s = jnp.where(mask[:, None, None], s, NEG_INF)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l = l * corr + jnp.sum(p, axis=-1)
            pv = jnp.einsum("bhgqk,bkhd->bhgqd", p.astype(vi.dtype), vi,
                            preferred_element_type=jnp.float32)
            o = o * corr[..., None] + pv
            return (o, m_new, l), None

        o0 = jnp.zeros((B, Hkv, G, qi.shape[1], D), jnp.float32)
        m0 = jnp.full((B, Hkv, G, qi.shape[1]), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, Hkv, G, qi.shape[1]), jnp.float32)
        # Checkpoint each KV block: backward recomputes the (qc, kc) score
        # tile instead of saving it — the flash-attention backward memory
        # pattern (saved state per block = the small (o, m, l) carry only).
        (o, m, l), _ = jax.lax.scan(jax.checkpoint(kv_step), (o0, m0, l0),
                                    (kg, vg, kp))
        o = o / jnp.maximum(l[..., None], 1e-30)
        # (B, Hkv, G, qc, D) -> (B, qc, Hkv*G, D)
        o = jnp.moveaxis(o, 3, 1).reshape(B, qi.shape[1], Hq, D)
        return None, o.astype(q.dtype)

    if nq == 1:
        _, o = q_step(None, (qg[0], qp[0]))
        return o[:, :Sq_orig]
    _, os = jax.lax.scan(q_step, None, (qg, qp))
    return jnp.moveaxis(os, 0, 1).reshape(B, Sq, Hq, D)[:, :Sq_orig]


def ring_slot_positions(slots, cache_len, ring_size: int):
    """Token position held by each ring-cache slot.

    slot i of a ring of W entries holds the most recent position p ≤
    cache_len-1 with p ≡ i (mod W); p < 0 means "slot not yet written".
    slots: (..., S) int; cache_len: (...,) broadcastable."""
    last = cache_len - 1
    return last - ((last - slots) % ring_size)


def decode_attention(
    q: jnp.ndarray,
    k_cache: jnp.ndarray,
    v_cache: jnp.ndarray,
    *,
    cache_len: jnp.ndarray,
    window: int = 0,
    softcap: float = 0.0,
    ring: bool = False,
) -> jnp.ndarray:
    """Single-step attention against a KV cache.

    q: (B, 1, Hq, D); caches: (B, S, Hkv, D); cache_len: (B,) int32 (length
    *including* the current token, whose K/V has already been written).
    ``ring``: cache is a circular buffer of S slots (slot = pos % S).
    """
    B, _, Hq, D = q.shape
    _, S, Hkv, _ = k_cache.shape
    G = Hq // Hkv
    scale = 1.0 / math.sqrt(D)
    qg = q.reshape(B, 1, Hkv, G, D)
    s = _gqa_scores(qg, k_cache, scale)[..., 0, :]  # (B, Hkv, G, S)
    s = _softcap(s, softcap)
    q_pos = (cache_len - 1)[:, None]
    if ring:
        kv_pos = ring_slot_positions(jnp.arange(S)[None], cache_len[:, None], S)
        valid = kv_pos >= 0
    else:
        kv_pos = jnp.arange(S)[None]
        valid = kv_pos < cache_len[:, None]
    w = jnp.asarray(window, jnp.int32)
    valid &= (w <= 0) | (q_pos - kv_pos < w)
    s = jnp.where(valid[:, None, None], s, NEG_INF)
    p = jax.nn.softmax(s.astype(jnp.float32), axis=-1)
    o = jnp.einsum("bhgk,bkhd->bhgd", p.astype(v_cache.dtype), v_cache,
                   preferred_element_type=jnp.float32)
    return o.reshape(B, 1, Hq, D).astype(q.dtype)


PAGED_ATTN_IMPLS = ("xla", "kernel", "kernel_lut")
_PAGED_ATTN_IMPL = os.environ.get("REPRO_PAGED_ATTN", "xla")


def set_paged_attention_impl(impl: str) -> str:
    """Select the decode-attention backend for paged KV caches.

    ``"xla"`` (default): gather-then-attend fallback below.  ``"kernel"``:
    fused Pallas block-table walk (``repro.kernels.paged_attention``).
    ``"kernel_lut"``: same kernel with the fp16 LUT softmax (Alg. 1) fused
    in.  Returns the previous impl so callers can restore it.  Engines jit
    their step functions at construction time, so set this *before*
    building the engine (or via ``REPRO_PAGED_ATTN``).
    """
    global _PAGED_ATTN_IMPL
    if impl not in PAGED_ATTN_IMPLS:
        raise ValueError(f"unknown paged-attention impl {impl!r}; "
                         f"expected one of {PAGED_ATTN_IMPLS}")
    prev, _PAGED_ATTN_IMPL = _PAGED_ATTN_IMPL, impl
    return prev


def paged_decode_attention(
    q: jnp.ndarray,
    k_pool: jnp.ndarray,
    v_pool: jnp.ndarray,
    *,
    table: jnp.ndarray,
    cache_len: jnp.ndarray,
    window: int = 0,
    softcap: float = 0.0,
) -> jnp.ndarray:
    """Single-step attention against a paged (block-pooled) KV cache.

    q: (B, 1, Hq, D); pools: (n_blocks, bs, Hkv, D); table: (B, W) int32
    block ids, position-ordered (block w of a row holds positions
    [w*bs, (w+1)*bs)); cache_len: (B,) int32 including the current token.

    XLA path: gather the row's blocks into a contiguous (B, W*bs, Hkv, D)
    view and reuse :func:`decode_attention` — padding entries point at the
    scratch block and land beyond ``cache_len``, so the standard length
    mask hides them.  Quantized pools ({"codes", "scales"} leaf dicts,
    see ``repro.serving.kv_quant``) gather codes and scales through the
    same table and dequantize the contiguous view before attending.  The
    Pallas kernel (`repro.kernels.paged_attention`) walks the table via
    scalar prefetch and dequantizes per block in VMEM instead of
    materializing the gather; this is the identical-semantics XLA
    fallback.
    """
    from repro.serving.kv_quant import dequantize_for_pool, pool_block_size

    impl = _PAGED_ATTN_IMPL
    if impl != "xla":
        if impl not in PAGED_ATTN_IMPLS:
            raise ValueError(f"unknown paged-attention impl {impl!r}; "
                             f"expected one of {PAGED_ATTN_IMPLS}")
        from repro.kernels import ops as _kops

        return _kops.paged_flash_decode(
            q, k_pool, v_pool, table, cache_len, window=window,
            softcap=softcap,
            exp_mode="lut" if impl == "kernel_lut" else "exact")

    B = q.shape[0]
    W = table.shape[1]
    bs = pool_block_size(k_pool)
    from repro.kernels import ops as _kops

    # attribute the gather+attend fallback through the same dispatch-hook
    # funnel as the kernels (the kernel branches above record inside
    # paged_flash_decode, so each call is counted exactly once)
    from repro.kernels import autotune as _autotune

    _kops.record_op("paged_attention_xla", *_autotune.paged_attn_cost(
        B, q.shape[2], W, bs, q.shape[3],
        slab_bytes=_kops.pool_slab_bytes(k_pool)))

    def gather(pool):
        seq = jax.tree.map(
            lambda a: a[table].reshape(B, W * bs, *a.shape[2:]), pool)
        return dequantize_for_pool(seq)

    return decode_attention(q, gather(k_pool), gather(v_pool),
                            cache_len=cache_len, window=window,
                            softcap=softcap)


def decode_attention_partial(q, k_cache, v_cache, *, valid, softcap=0.0):
    """Per-shard partial decode attention for sequence-parallel KV.

    Returns (o_unnormalized f32 (B,1,Hq,D), m (B,Hq), l (B,Hq)) so that the
    caller can combine shards with the distributed safe-softmax merge:
      m* = max_i m_i;  l* = sum_i l_i e^{m_i-m*};  o* = sum_i o_i e^{m_i-m*} / l*.
    """
    B, _, Hq, D = q.shape
    _, S, Hkv, _ = k_cache.shape
    G = Hq // Hkv
    scale = 1.0 / math.sqrt(D)
    qg = q.reshape(B, 1, Hkv, G, D)
    s = _gqa_scores(qg, k_cache, scale)[..., 0, :]  # (B, Hkv, G, S)
    s = _softcap(s, softcap)
    s = jnp.where(valid[:, None, None], s, NEG_INF)
    m = jnp.max(s, axis=-1)
    p = jnp.exp(s - m[..., None])
    p = jnp.where(valid[:, None, None], p, 0.0)
    l = jnp.sum(p, axis=-1)
    o = jnp.einsum("bhgk,bkhd->bhgd", p.astype(v_cache.dtype), v_cache,
                   preferred_element_type=jnp.float32)
    return (o.reshape(B, 1, Hq, D),
            m.reshape(B, Hq),
            l.reshape(B, Hq))


# ---------------------------------------------------------------------------
# Attention block (projections + rope + attention)
# ---------------------------------------------------------------------------


def init_attention(key, cfg: ModelConfig, dtype) -> dict:
    d, hd = cfg.d_model, cfg.resolved_head_dim()
    ks = jax.random.split(key, 4)
    return {
        "wq": init_linear(ks[0], d, cfg.n_heads * hd, bias=cfg.qkv_bias, dtype=dtype),
        "wk": init_linear(ks[1], d, cfg.n_kv_heads * hd, bias=cfg.qkv_bias, dtype=dtype),
        "wv": init_linear(ks[2], d, cfg.n_kv_heads * hd, bias=cfg.qkv_bias, dtype=dtype),
        "wo": init_linear(ks[3], cfg.n_heads * hd, d, bias=False, dtype=dtype),
    }


def attention_block(
    p: dict,
    x: jnp.ndarray,
    cfg: ModelConfig,
    *,
    positions: jnp.ndarray,
    window: int,
    cache: Optional[dict] = None,
    cache_len: Optional[jnp.ndarray] = None,
    cross_kv: Optional[tuple] = None,
    causal: bool = True,
    prefix_kv: Optional[tuple] = None,
    prefix_positions: Optional[jnp.ndarray] = None,
):
    """Full attention block. Returns (out, new_cache_kv or None).

    - training/prefill: cache is None, chunked attention over x itself.
    - partial prefill (cross-request prefix cache): additionally
      prefix_kv = (k, v) of shape (B, P, Hkv, D) — already-rope'd KV of a
      cached prompt prefix — and prefix_positions (B, P), the prefix
      token positions with invalid slots pushed past every query position
      so the causal mask hides them.  ``x`` then holds only the uncached
      suffix (its ``positions`` start at the cached length) and queries
      attend over the concatenated prefix + suffix keys; only the
      suffix's K/V is returned for caching.  The prefix may be *ragged*
      across B rows (per-row cached lengths, P = the batch-max padded
      width): each row's offsets and masked prefix slots are independent,
      which is what lets the scheduler admit a whole batch of cache-hit
      requests through one call.
    - decode: cache = {"k","v"} (B, S, Hkv, D); writes current K/V at
      cache_len-1 then attends (batch-sharded layout).
    - paged decode: cache additionally holds "table" (B, W) int32 and the
      k/v leaves are block pools (n_blocks, bs, Hkv, D); the current K/V
      is scattered into (table[b, (cache_len-1)//bs], (cache_len-1)%bs)
      and attention gathers through the table.
    - cross attention (whisper decoder): cross_kv = (k, v) precomputed.
    """
    B, S, _ = x.shape
    hd = cfg.resolved_head_dim()
    q = linear(p["wq"], x).reshape(B, S, cfg.n_heads, hd)

    if cross_kv is not None:
        k, v = cross_kv
        q = q  # no rope in whisper cross-attn
        o = chunked_attention(
            q, k, v,
            q_positions=positions, kv_positions=jnp.arange(k.shape[1])[None],
            causal=False, window=0, softcap=cfg.logit_softcap,
        ) if cache is None else decode_attention(
            q, k, v, cache_len=jnp.full((B,), k.shape[1], jnp.int32),
            softcap=cfg.logit_softcap)
        out = linear(p["wo"], o.reshape(B, S, cfg.n_heads * hd))
        return out, None

    k = linear(p["wk"], x).reshape(B, S, cfg.n_kv_heads, hd)
    v = linear(p["wv"], x).reshape(B, S, cfg.n_kv_heads, hd)
    if cfg.rope_theta:
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)

    if cache is None:
        if prefix_kv is not None:
            pk, pv = prefix_kv  # (B, P, Hkv, D), rope'd at pool-write time
            kv_pos = jnp.concatenate(
                [prefix_positions,
                 jnp.broadcast_to(positions, (B, S))], axis=1)
            o = chunked_attention(
                q, jnp.concatenate([pk.astype(k.dtype), k], axis=1),
                jnp.concatenate([pv.astype(v.dtype), v], axis=1),
                q_positions=positions, kv_positions=kv_pos,
                causal=causal, window=window, softcap=cfg.logit_softcap,
            )
        else:
            o = chunked_attention(
                q, k, v,
                q_positions=positions, kv_positions=positions,
                causal=causal, window=window, softcap=cfg.logit_softcap,
            )
        new_kv = (k, v)
    elif "table" in cache:
        # paged decode: route the write through the block table.  A done
        # row arrives with cache_len == max_len == W*bs; its write lands at
        # the last table slot's final offset — either the scratch block
        # (table padding) or a position >= the row's usable length, never
        # attended either way (the paged analogue of the dense scratch
        # slot).
        from repro.serving.kv_quant import pool_block_size, quantize_for_pool

        table = cache["table"]
        bs = pool_block_size(cache["k"])
        idx = cache_len - 1  # (B,)
        b_idx = jnp.arange(B)
        blk = table[b_idx, idx // bs]
        off = idx % bs

        def upd(pool, new_row):
            # quantize-on-write: the (B, Hkv, D) token slab becomes
            # code+scale leaves for quantized pools (identity on fp) and
            # scatters leaf-wise at the same (block, offset)
            payload = quantize_for_pool(new_row[:, 0], pool)
            return jax.tree.map(
                lambda p, x: p.at[blk, off].set(x.astype(p.dtype)),
                pool, payload)

        ck = upd(cache["k"], k)
        cv = upd(cache["v"], v)
        o = paged_decode_attention(q, ck, cv, table=table,
                                   cache_len=cache_len, window=window,
                                   softcap=cfg.logit_softcap)
        new_kv = (ck, cv)
    else:
        # decode: scatter K/V of the current token into the cache
        ring = getattr(cfg, "ring_cache", False)
        S_cache = cache["k"].shape[1]
        idx = (cache_len - 1) % S_cache if ring else cache_len - 1  # (B,)

        def upd(cache_arr, new_row):
            # cache_arr: (B, S, Hkv, D); new_row: (B, 1, Hkv, D)
            b_idx = jnp.arange(B)
            return cache_arr.at[b_idx, idx].set(new_row[:, 0].astype(cache_arr.dtype))

        ck = upd(cache["k"], k)
        cv = upd(cache["v"], v)
        o = decode_attention(q, ck, cv, cache_len=cache_len, window=window,
                             softcap=cfg.logit_softcap, ring=ring)
        new_kv = (ck, cv)

    out = linear(p["wo"], o.reshape(B, S, cfg.n_heads * hd))
    return out, new_kv


# ---------------------------------------------------------------------------
# FFN (SwiGLU) and classic MLP
# ---------------------------------------------------------------------------


def init_swiglu(key, d: int, f: int, dtype) -> dict:
    ks = jax.random.split(key, 3)
    return {
        "gate": init_linear(ks[0], d, f, bias=False, dtype=dtype),
        "up": init_linear(ks[1], d, f, bias=False, dtype=dtype),
        "down": init_linear(ks[2], f, d, bias=False, dtype=dtype),
    }


def swiglu(p: dict, x: jnp.ndarray) -> jnp.ndarray:
    return linear(p["down"], jax.nn.silu(linear(p["gate"], x)) * linear(p["up"], x))


def init_mlp(key, d: int, f: int, dtype) -> dict:
    ks = jax.random.split(key, 2)
    return {
        "fc1": init_linear(ks[0], d, f, bias=True, dtype=dtype),
        "fc2": init_linear(ks[1], f, d, bias=True, dtype=dtype),
    }


def mlp(p: dict, x: jnp.ndarray) -> jnp.ndarray:
    return linear(p["fc2"], jax.nn.gelu(linear(p["fc1"], x)))


# ---------------------------------------------------------------------------
# Embedding / LM head
# ---------------------------------------------------------------------------


def init_embedding(key, vocab: int, d: int, dtype) -> dict:
    return {"table": jax.random.normal(key, (vocab, d), jnp.float32).astype(dtype) * 0.02}


def embed(p: dict, tokens: jnp.ndarray, dtype) -> jnp.ndarray:
    return p["table"].astype(dtype)[tokens]


def lm_logits(p: dict, x: jnp.ndarray, softcap: float = 0.0) -> jnp.ndarray:
    logits = jnp.einsum("...d,vd->...v", x, p["table"].astype(x.dtype),
                        preferred_element_type=jnp.float32)
    return _softcap(logits, softcap)
