"""Mamba2 (SSD — state-space duality, arXiv:2405.21060) and the Zamba2-style
hybrid (Mamba2 backbone + one *shared* attention block applied every K layers,
arXiv:2411.15242).

Training / prefill use the chunked SSD algorithm: quadratic attention-like
compute *within* chunks of Q tokens plus a linear inter-chunk state scan —
sub-quadratic in sequence length, which is what qualifies these archs for
the long_500k shape.  Decode is the O(1)-per-step recurrence
    h ← h·e^{Δ·A} + Δ·x⊗B ;  y = C·h + D·x
with a rolling conv-state buffer.
"""
from __future__ import annotations

import math
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.sharding import ParallelContext
from repro.models import layers as L

# ---------------------------------------------------------------------------
# Single Mamba2 block
# ---------------------------------------------------------------------------


def _dims(cfg: ModelConfig):
    s = cfg.ssm
    di = s.d_inner(cfg.d_model)
    H = s.n_heads(cfg.d_model)
    conv_dim = di + 2 * s.ngroups * s.d_state
    return s, di, H, conv_dim


def init_mamba_layer(key, cfg: ModelConfig, dtype) -> dict:
    s, di, H, conv_dim = _dims(cfg)
    d = cfg.d_model
    k1, k2, k3 = jax.random.split(key, 3)
    d_in_proj = 2 * di + 2 * s.ngroups * s.d_state + H
    return {
        "norm": L.init_rmsnorm(d),
        "in_proj": L.init_linear(k1, d, d_in_proj, bias=False, dtype=dtype),
        "conv": {
            "w": jax.random.normal(k2, (s.conv_width, conv_dim), jnp.float32)
            .astype(dtype) * (1.0 / math.sqrt(s.conv_width)),
            "b": jnp.zeros((conv_dim,), dtype),
        },
        "A_log": jnp.zeros((H,), jnp.float32),       # A = -exp(A_log) = -1
        "dt_bias": jnp.full((H,), -2.0, jnp.float32),  # softplus ≈ 0.12
        "D": jnp.ones((H,), jnp.float32),
        "gated_norm": L.init_rmsnorm(di),
        "out_proj": L.init_linear(k3, di, d, bias=False, dtype=dtype),
    }


def _causal_conv(p, x):
    """Depthwise causal conv, width W. x: (B, S, C) -> (B, S, C)."""
    W = p["w"].shape[0]
    xp = jnp.pad(x, ((0, 0), (W - 1, 0), (0, 0)))
    y = sum(xp[:, i: i + x.shape[1]] * p["w"][i].astype(x.dtype) for i in range(W))
    return y + p["b"].astype(x.dtype)


def _split_in_proj(zxbcdt, cfg):
    s, di, H, _ = _dims(cfg)
    gn = s.ngroups * s.d_state
    z, xBC, dt = jnp.split(zxbcdt, [di, 2 * di + 2 * gn], axis=-1)
    return z, xBC, dt


def _ssd_chunked(x, dt, A, B_mat, C_mat, cfg, initial_state=None):
    """Chunked SSD scan.

    x: (B, S, H, P); dt: (B, S, H) (post-softplus); A: (H,) negative;
    B_mat, C_mat: (B, S, G, N).  Returns (y (B,S,H,P), final_state (B,H,P,N)).
    """
    s = cfg.ssm
    Bb, S, H, P = x.shape
    G, N = B_mat.shape[2], B_mat.shape[3]
    Q = min(s.chunk_size, S)
    S_orig = S
    if S % Q:
        # pad tail with dt=0 (decay 1, no state update) — safe for causal scan
        pad = Q - S % Q
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        B_mat = jnp.pad(B_mat, ((0, 0), (0, pad), (0, 0), (0, 0)))
        C_mat = jnp.pad(C_mat, ((0, 0), (0, pad), (0, 0), (0, 0)))
        S = S + pad
    nc = S // Q
    rep = H // G

    f32 = jnp.float32
    da = (dt * A[None, None]).astype(f32)            # (B, S, H), negative
    dtx = (x * dt[..., None].astype(x.dtype))        # (B, S, H, P)

    def chunk(t):  # (B, S, ...) -> (B, nc, Q, ...)
        return t.reshape(Bb, nc, Q, *t.shape[2:])

    da_c = chunk(da)
    cs = jnp.cumsum(da_c, axis=2)                    # (B, nc, Q, H) inclusive
    dtx_c = chunk(dtx)
    B_c = chunk(B_mat)                               # (B, nc, Q, G, N)
    C_c = chunk(C_mat)

    # --- intra-chunk (quadratic within chunk)
    # decay L[q, s] = exp(cs[q] - cs[s]) for s <= q
    diff = cs[:, :, :, None, :] - cs[:, :, None, :, :]   # (B,nc,Q,Q,H)
    causal = jnp.tril(jnp.ones((Q, Q), bool))
    Lmat = jnp.where(causal[None, None, :, :, None], jnp.exp(diff), 0.0)
    cb = jnp.einsum("bcqgn,bcsgn->bcqsg", C_c.astype(f32), B_c.astype(f32))
    if G == 1:
        cb_h = jnp.broadcast_to(cb, (*cb.shape[:-1], H))
    else:
        cb_h = jnp.repeat(cb, rep, axis=-1)  # (B,nc,Q,Q,H)
    w = (cb_h * Lmat).astype(x.dtype)
    y_intra = jnp.einsum("bcqsh,bcshp->bcqhp", w, dtx_c,
                         preferred_element_type=f32)

    # --- per-chunk states: sum_s exp(cs_last - cs[s]) dtx[s] ⊗ B[s]
    last = cs[:, :, -1:, :]                           # (B,nc,1,H)
    decay_state = jnp.exp(last - cs)                  # (B,nc,Q,H)
    Bh = B_c[:, :, :, :, None, :]                     # (B,nc,Q,G,1,N)
    Bh = jnp.broadcast_to(Bh, (Bb, nc, Q, G, rep, N)).reshape(Bb, nc, Q, H, N)
    states = jnp.einsum("bcqh,bcqhp,bcqhn->bchpn",
                        decay_state.astype(f32), dtx_c.astype(f32), Bh.astype(f32))

    # --- inter-chunk recurrence
    chunk_decay = jnp.exp(last[:, :, 0, :])           # (B, nc, H)
    h0 = (jnp.zeros((Bb, H, P, N), f32) if initial_state is None
          else initial_state.astype(f32))

    def step(h, inp):
        st, dec = inp  # (B,H,P,N), (B,H)
        h_new = h * dec[:, :, None, None] + st
        return h_new, h  # emit state *entering* the chunk

    (h_final, h_prevs) = jax.lax.scan(
        step, h0, (jnp.moveaxis(states, 1, 0), jnp.moveaxis(chunk_decay, 1, 0)))
    h_prev = jnp.moveaxis(h_prevs, 0, 1)               # (B, nc, H, P, N)

    # --- inter-chunk output: C[q] · (h_prev · exp(cs[q]))
    Ch = C_c[:, :, :, :, None, :]
    Ch = jnp.broadcast_to(Ch, (Bb, nc, Q, G, rep, N)).reshape(Bb, nc, Q, H, N)
    y_inter = jnp.einsum("bcqhn,bchpn,bcqh->bcqhp",
                         Ch.astype(f32), h_prev, jnp.exp(cs).astype(f32))

    y = (y_intra + y_inter).reshape(Bb, S, H, P).astype(x.dtype)
    return y[:, :S_orig], h_final


def mamba_layer(p, x, cfg: ModelConfig, par: Optional[ParallelContext] = None):
    """Full-sequence Mamba2 block (train / prefill). Returns (y, final_states).

    final_states = (conv_state (B, W-1, conv_dim), ssm_state (B, H, P, N)).
    """
    s, di, H, conv_dim = _dims(cfg)
    B, S, _ = x.shape
    hn = L.rmsnorm(p["norm"], x, cfg.norm_eps)
    zxbcdt = L.linear(p["in_proj"], hn)
    z, xBC, dt = _split_in_proj(zxbcdt, cfg)
    conv_state = xBC[:, S - (s.conv_width - 1):, :]   # last W-1 raw inputs
    xBC = jax.nn.silu(_causal_conv(p["conv"], xBC))
    gn = s.ngroups * s.d_state
    xs, B_mat, C_mat = jnp.split(xBC, [di, di + gn], axis=-1)
    xs = xs.reshape(B, S, H, s.head_dim)
    B_mat = B_mat.reshape(B, S, s.ngroups, s.d_state)
    C_mat = C_mat.reshape(B, S, s.ngroups, s.d_state)
    dt_a = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"][None, None])
    A = -jnp.exp(p["A_log"])
    y, h_final = _ssd_chunked(xs, dt_a, A, B_mat, C_mat, cfg)
    y = y + xs * p["D"][None, None, :, None].astype(xs.dtype)
    y = y.reshape(B, S, di)
    y = L.rmsnorm(p["gated_norm"], y * jax.nn.silu(z), cfg.norm_eps)
    out = L.linear(p["out_proj"], y)
    return x + out, (conv_state, h_final)


def mamba_decode_step(p, x, state, cfg: ModelConfig):
    """One-token recurrent step. x: (B, 1, d); state = (conv, ssm)."""
    s, di, H, conv_dim = _dims(cfg)
    B = x.shape[0]
    conv_st, ssm_st = state  # (B, W-1, conv_dim), (B, H, P, N) f32
    hn = L.rmsnorm(p["norm"], x, cfg.norm_eps)
    zxbcdt = L.linear(p["in_proj"], hn)
    z, xBC, dt = _split_in_proj(zxbcdt, cfg)          # xBC: (B, 1, conv_dim)

    window = jnp.concatenate([conv_st.astype(xBC.dtype), xBC], axis=1)  # (B, W, C)
    w = p["conv"]["w"].astype(xBC.dtype)              # (W, C)
    conv_out = jnp.einsum("bwc,wc->bc", window, w) + p["conv"]["b"].astype(xBC.dtype)
    xBC = jax.nn.silu(conv_out)[:, None, :]
    new_conv = window[:, 1:, :]

    gn = s.ngroups * s.d_state
    xs, B_mat, C_mat = jnp.split(xBC, [di, di + gn], axis=-1)
    xs = xs.reshape(B, H, s.head_dim)
    B_mat = B_mat.reshape(B, s.ngroups, s.d_state)
    C_mat = C_mat.reshape(B, s.ngroups, s.d_state)
    rep = H // s.ngroups
    Bh = jnp.repeat(B_mat, rep, axis=1) if s.ngroups > 1 else (
        jnp.broadcast_to(B_mat, (B, H, s.d_state)))
    Ch = jnp.repeat(C_mat, rep, axis=1) if s.ngroups > 1 else (
        jnp.broadcast_to(C_mat, (B, H, s.d_state)))

    dt_a = jax.nn.softplus(dt[:, 0].astype(jnp.float32) + p["dt_bias"][None])  # (B,H)
    A = -jnp.exp(p["A_log"])                           # (H,)
    decay = jnp.exp(dt_a * A[None])                    # (B,H)
    f32 = jnp.float32
    dx = xs.astype(f32) * dt_a[..., None]              # (B,H,P)
    upd = dx[..., :, None] * Bh.astype(f32)[:, :, None, :]   # (B,H,P,N)
    h = ssm_st.astype(f32) * decay[:, :, None, None] + upd
    y = jnp.einsum("bhpn,bhn->bhp", h, Ch.astype(f32))
    y = y + xs.astype(f32) * p["D"][None, :, None]
    y = y.reshape(B, 1, di).astype(x.dtype)
    y = L.rmsnorm(p["gated_norm"], y * jax.nn.silu(z), cfg.norm_eps)
    out = L.linear(p["out_proj"], y)
    return x + out, (new_conv.astype(conv_st.dtype), h)


# ---------------------------------------------------------------------------
# Full models (pure mamba2 and zamba2-style hybrid)
# ---------------------------------------------------------------------------


def _n_attn_apps(cfg: ModelConfig) -> int:
    if cfg.family != "hybrid" or not cfg.hybrid_attn_every:
        return 0
    return cfg.n_layers // cfg.hybrid_attn_every


def init_params(key, cfg: ModelConfig) -> dict:
    dtype = jnp.dtype(cfg.param_dtype)
    ke, kl, kh, ka, kf = jax.random.split(key, 5)
    layer_keys = jax.random.split(kl, cfg.n_layers)
    params = {
        "embedding": L.init_embedding(ke, cfg.vocab_size, cfg.d_model, dtype),
        "layers": jax.vmap(partial(init_mamba_layer, cfg=cfg, dtype=dtype))(layer_keys),
        "final_norm": L.init_rmsnorm(cfg.d_model),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = L.init_embedding(kh, cfg.vocab_size, cfg.d_model, dtype)
    if cfg.family == "hybrid" and cfg.hybrid_attn_every:
        params["shared_attn"] = {
            "attn_norm": L.init_rmsnorm(cfg.d_model),
            "attn": L.init_attention(ka, cfg, dtype),
            "ffn_norm": L.init_rmsnorm(cfg.d_model),
            "ffn": L.init_swiglu(kf, cfg.d_model, cfg.d_ff, dtype),
        }
    return params


def abstract_params(cfg: ModelConfig) -> dict:
    return jax.eval_shape(lambda: init_params(jax.random.key(0), cfg))


def _shared_attn_apply(sp, x, cfg, par, *, positions, cache=None, cache_len=None):
    h, kv = L.attention_block(
        sp["attn"], L.rmsnorm(sp["attn_norm"], x, cfg.norm_eps), cfg,
        positions=positions, window=0, cache=cache, cache_len=cache_len)
    x = x + h
    x = x + L.swiglu(sp["ffn"], L.rmsnorm(sp["ffn_norm"], x, cfg.norm_eps))
    return x, kv


def forward(params, tokens, cfg: ModelConfig, par: ParallelContext = None,
            *, embeddings=None, return_kv: bool = False, logit_positions=None):
    """Full-sequence forward. Returns (logits, (ssm_states, attn_kv), aux)."""
    dtype = jnp.dtype(cfg.dtype)
    x = L.embed(params["embedding"], tokens, dtype)
    if par is not None:
        x = par.constrain(x, "batch", "act_seq", None)
    B, S = tokens.shape
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
    every = cfg.hybrid_attn_every
    n_apps = _n_attn_apps(cfg)

    # hybrid: attention KV for each application point, carried through scan
    if n_apps and return_kv:
        hd = cfg.resolved_head_dim()
        kv0 = jnp.zeros((n_apps, 2, B, S, cfg.n_kv_heads, hd), dtype)
    else:
        kv0 = None

    def body(carry, xs):
        x, kvs = carry
        lp, i = xs
        x, states = mamba_layer(lp, x, cfg, par)
        if every:
            def apply_attn(x_kvs):
                x, kvs = x_kvs
                x2, kv = _shared_attn_apply(params["shared_attn"], x, cfg, par,
                                            positions=positions)
                if kvs is not None:
                    app = i // every
                    kvs = jax.lax.dynamic_update_slice(
                        kvs, jnp.stack(kv)[None].astype(kvs.dtype),
                        (app, 0, 0, 0, 0, 0))
                return (x2, kvs)

            x, kvs = jax.lax.cond(i % every == every - 1, apply_attn,
                                  lambda xk: xk, (x, kvs))
        out = states if return_kv else None
        return (x, kvs), out

    body = jax.checkpoint(body) if cfg.remat == "full" else body
    (x, kvs), states = jax.lax.scan(
        body, (x, kv0),
        (params["layers"], jnp.arange(cfg.n_layers, dtype=jnp.int32)))
    x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    if logit_positions is not None:
        x = x[jnp.arange(B), logit_positions]
    head = params["embedding"] if cfg.tie_embeddings else params["lm_head"]
    logits = L.lm_logits(head, x, cfg.logit_softcap)
    return logits, (states, kvs), jnp.zeros((), jnp.float32)


def init_cache(cfg: ModelConfig, batch: int, max_len: int, dtype=None) -> dict:
    dtype = dtype or jnp.dtype(cfg.dtype)
    s, di, H, conv_dim = _dims(cfg)
    cache = {
        "conv": jnp.zeros((cfg.n_layers, batch, s.conv_width - 1, conv_dim), dtype),
        "ssm": jnp.zeros((cfg.n_layers, batch, H, s.head_dim, s.d_state), jnp.float32),
    }
    n_apps = _n_attn_apps(cfg)
    if n_apps:
        hd = cfg.resolved_head_dim()
        cache["attn_k"] = jnp.zeros((n_apps, batch, max_len, cfg.n_kv_heads, hd), dtype)
        cache["attn_v"] = jnp.zeros((n_apps, batch, max_len, cfg.n_kv_heads, hd), dtype)
    return cache


def abstract_cache(cfg: ModelConfig, batch: int, max_len: int, dtype=None) -> dict:
    return jax.eval_shape(partial(init_cache, cfg, batch, max_len, dtype))


def prefill(params, tokens, cfg: ModelConfig, par: ParallelContext = None,
            *, max_len: int, embeddings=None, lengths=None):
    """NOTE: the SSM recurrence consumes every input position, so unlike
    attention families, right-padded *unequal* prompts would pollute the
    state — callers must pass equal-length prompts (the TTS drivers share
    one prompt across samples, which satisfies this)."""
    B, S = tokens.shape
    pos = (lengths - 1) if lengths is not None else jnp.full((B,), S - 1)
    logits, (states, kvs), _ = forward(params, tokens, cfg, par,
                                       embeddings=embeddings, return_kv=True,
                                       logit_positions=pos)
    conv_states, ssm_states = states  # (L,B,W-1,C), (L,B,H,P,N)
    cache = init_cache(cfg, B, max_len)
    cache["conv"] = conv_states.astype(cache["conv"].dtype)
    cache["ssm"] = ssm_states
    if kvs is not None:
        k = kvs[:, 0]  # (n_apps, B, S, Hkv, D)
        v = kvs[:, 1]
        cache["attn_k"] = jax.lax.dynamic_update_slice(
            cache["attn_k"], k.astype(cache["attn_k"].dtype), (0, 0, 0, 0, 0))
        cache["attn_v"] = jax.lax.dynamic_update_slice(
            cache["attn_v"], v.astype(cache["attn_v"].dtype), (0, 0, 0, 0, 0))
    return logits, cache


def decode_step(params, tokens, cache, cache_len, cfg: ModelConfig,
                par: ParallelContext = None):
    """One decode step for mamba2 / hybrid. Returns (logits, new_cache)."""
    dtype = jnp.dtype(cfg.dtype)
    x = L.embed(params["embedding"], tokens, dtype)
    every = cfg.hybrid_attn_every
    n_apps = _n_attn_apps(cfg)
    positions = (cache_len - 1)[:, None]

    has_attn = n_apps > 0
    seq_par = par is not None and par.kv_seq_axis is not None

    def body(carry, xs):
        x, ak, av = carry
        lp, conv_st, ssm_st, i = xs
        x, (new_conv, new_ssm) = mamba_decode_step(lp, x, (conv_st, ssm_st), cfg)
        if every:
            def apply_attn(args):
                x, ak, av = args
                app = i // every
                ck = jax.lax.dynamic_index_in_dim(ak, app, 0, keepdims=False)
                cv = jax.lax.dynamic_index_in_dim(av, app, 0, keepdims=False)
                sp = params["shared_attn"]
                if seq_par:
                    from repro.serving.seq_parallel import seq_parallel_decode_layer
                    x2, nk, nv = seq_parallel_decode_layer(
                        sp, x, cfg, par, cache_k=ck,
                        cache_v=cv, cache_len=cache_len, window=0)
                else:
                    x2, kv = _shared_attn_apply(sp, x, cfg, par,
                                                positions=positions,
                                                cache={"k": ck, "v": cv},
                                                cache_len=cache_len)
                    nk, nv = kv
                ak = jax.lax.dynamic_update_index_in_dim(ak, nk.astype(ak.dtype), app, 0)
                av = jax.lax.dynamic_update_index_in_dim(av, nv.astype(av.dtype), app, 0)
                return (x2, ak, av)

            x, ak, av = jax.lax.cond(i % every == every - 1, apply_attn,
                                     lambda a: a, (x, ak, av))
        return (x, ak, av), (new_conv, new_ssm)

    ak0 = cache.get("attn_k") if has_attn else jnp.zeros((1,), dtype)
    av0 = cache.get("attn_v") if has_attn else jnp.zeros((1,), dtype)
    (x, ak, av), (new_conv, new_ssm) = jax.lax.scan(
        body, (x, ak0, av0),
        (params["layers"], cache["conv"], cache["ssm"],
         jnp.arange(cfg.n_layers, dtype=jnp.int32)))
    x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    head = params["embedding"] if cfg.tie_embeddings else params["lm_head"]
    logits = L.lm_logits(head, x[:, 0], cfg.logit_softcap)
    new_cache = dict(cache, conv=new_conv, ssm=new_ssm)
    if has_attn:
        new_cache["attn_k"], new_cache["attn_v"] = ak, av
    return logits, new_cache
