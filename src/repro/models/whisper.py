"""Whisper-style encoder-decoder (arXiv:2212.04356) — transformer backbone
only; the conv audio frontend is a STUB per the assignment: ``input_specs``
supplies precomputed frame embeddings (B, T_enc, d_model).

Decoder decode-step maintains a self-attention KV cache plus the
precomputed cross-attention K/V from the encoder.
"""
from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.sharding import ParallelContext
from repro.models import layers as L


def _sinusoids(length: int, d: int) -> jnp.ndarray:
    half = d // 2
    t = jnp.arange(length, dtype=jnp.float32)[:, None]
    freq = jnp.exp(-math.log(10000.0) * jnp.arange(half, dtype=jnp.float32) / (half - 1))
    ang = t * freq[None]
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


def _init_enc_layer(key, cfg: ModelConfig, dtype) -> dict:
    ka, kf = jax.random.split(key)
    return {
        "attn_norm": L.init_layernorm(cfg.d_model),
        "attn": L.init_attention(ka, cfg, dtype),
        "mlp_norm": L.init_layernorm(cfg.d_model),
        "mlp": L.init_mlp(kf, cfg.d_model, cfg.d_ff, dtype),
    }


def _init_dec_layer(key, cfg: ModelConfig, dtype) -> dict:
    ka, kc, kf = jax.random.split(key, 3)
    return {
        "attn_norm": L.init_layernorm(cfg.d_model),
        "attn": L.init_attention(ka, cfg, dtype),
        "cross_norm": L.init_layernorm(cfg.d_model),
        "cross": L.init_attention(kc, cfg, dtype),
        "mlp_norm": L.init_layernorm(cfg.d_model),
        "mlp": L.init_mlp(kf, cfg.d_model, cfg.d_ff, dtype),
    }


def init_params(key, cfg: ModelConfig) -> dict:
    dtype = jnp.dtype(cfg.param_dtype)
    ke, kel, kdl, kp = jax.random.split(key, 4)
    ekeys = jax.random.split(kel, cfg.n_encoder_layers)
    dkeys = jax.random.split(kdl, cfg.n_layers)
    return {
        "frame_proj": L.init_linear(kp, cfg.d_model, cfg.d_model, bias=True,
                                    dtype=dtype),  # conv-frontend stub
        "enc_layers": jax.vmap(partial(_init_enc_layer, cfg=cfg, dtype=dtype))(ekeys),
        "enc_norm": L.init_layernorm(cfg.d_model),
        "embedding": L.init_embedding(ke, cfg.vocab_size, cfg.d_model, dtype),
        "pos_embedding": jax.random.normal(
            jax.random.fold_in(ke, 1), (cfg.max_seq_len, cfg.d_model),
            jnp.float32).astype(dtype) * 0.01,
        "dec_layers": jax.vmap(partial(_init_dec_layer, cfg=cfg, dtype=dtype))(dkeys),
        "dec_norm": L.init_layernorm(cfg.d_model),
    }


def abstract_params(cfg: ModelConfig) -> dict:
    return jax.eval_shape(lambda: init_params(jax.random.key(0), cfg))


def encode(params, frames, cfg: ModelConfig, par: ParallelContext = None):
    """frames: (B, T_enc, d_model) stub embeddings -> (B, T_enc, d)."""
    dtype = jnp.dtype(cfg.dtype)
    x = L.linear(params["frame_proj"], frames.astype(dtype))
    x = x + _sinusoids(x.shape[1], cfg.d_model).astype(dtype)[None]
    if par is not None:
        x = par.constrain(x, "batch", "act_seq", None)
    B, T = x.shape[:2]
    positions = jnp.broadcast_to(jnp.arange(T, dtype=jnp.int32)[None], (B, T))

    def body(x, lp):
        h, _ = L.attention_block(lp["attn"],
                                 L.layernorm(lp["attn_norm"], x), cfg,
                                 positions=positions, window=0, causal=False)
        x = x + h
        x = x + L.mlp(lp["mlp"], L.layernorm(lp["mlp_norm"], x))
        return x, None

    body_fn = (lambda c, xs: jax.checkpoint(body)(c, xs)) if cfg.remat == "full" else body
    x, _ = jax.lax.scan(body_fn, x, params["enc_layers"])
    return L.layernorm(params["enc_norm"], x)


def _cross_kv(params, enc_out, cfg):
    """Precompute per-decoder-layer cross K/V: (L, B, T_enc, Hkv, D)."""
    hd = cfg.resolved_head_dim()
    B, T = enc_out.shape[:2]

    def one(lp):
        k = L.linear(lp["cross"]["wk"], enc_out).reshape(B, T, cfg.n_kv_heads, hd)
        v = L.linear(lp["cross"]["wv"], enc_out).reshape(B, T, cfg.n_kv_heads, hd)
        return k, v

    return jax.lax.map(one, params["dec_layers"])


def _dec_layer(lp, x, cfg, par, *, positions, cross_k, cross_v,
               cache=None, cache_len=None):
    h, kv = L.attention_block(lp["attn"], L.layernorm(lp["attn_norm"], x), cfg,
                              positions=positions, window=0,
                              cache=cache, cache_len=cache_len)
    x = x + h
    h, _ = L.attention_block(lp["cross"], L.layernorm(lp["cross_norm"], x), cfg,
                             positions=positions, window=0,
                             cross_kv=(cross_k, cross_v),
                             cache=None if cache is None else {})
    x = x + h
    x = x + L.mlp(lp["mlp"], L.layernorm(lp["mlp_norm"], x))
    return x, kv


def forward(params, tokens, cfg: ModelConfig, par: ParallelContext = None,
            *, frames=None, embeddings=None, return_kv: bool = False,
            logit_positions=None):
    """Teacher-forced decoder over encoded audio. Returns (logits, kv, aux)."""
    if frames is None:
        frames = embeddings  # generic modality-stub argument name
    enc_out = encode(params, frames, cfg, par)
    cross = _cross_kv(params, enc_out, cfg)  # (k, v) each (L,B,T,Hkv,D)
    dtype = jnp.dtype(cfg.dtype)
    B, S = tokens.shape
    x = L.embed(params["embedding"], tokens, dtype)
    x = x + params["pos_embedding"][:S].astype(dtype)[None]
    if par is not None:
        x = par.constrain(x, "batch", "act_seq", None)
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))

    def body(x, xs):
        lp, ck, cv = xs
        x, kv = _dec_layer(lp, x, cfg, par, positions=positions,
                           cross_k=ck, cross_v=cv)
        return x, (kv if return_kv else None)

    body_fn = (lambda c, xs: jax.checkpoint(body)(c, xs)) if cfg.remat == "full" else body
    x, kvs = jax.lax.scan(body_fn, x, (params["dec_layers"], cross[0], cross[1]))
    x = L.layernorm(params["dec_norm"], x)
    if logit_positions is not None:
        x = x[jnp.arange(B), logit_positions]
    logits = L.lm_logits(params["embedding"], x, cfg.logit_softcap)
    return logits, (kvs, cross), jnp.zeros((), jnp.float32)


def init_cache(cfg: ModelConfig, batch: int, max_len: int, dtype=None,
               t_enc: int = 0) -> dict:
    dtype = dtype or jnp.dtype(cfg.dtype)
    hd = cfg.resolved_head_dim()
    t_enc = t_enc or cfg.encoder_seq_len
    shape = (cfg.n_layers, batch, max_len, cfg.n_kv_heads, hd)
    xshape = (cfg.n_layers, batch, t_enc, cfg.n_kv_heads, hd)
    return {
        "k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype),
        "cross_k": jnp.zeros(xshape, dtype), "cross_v": jnp.zeros(xshape, dtype),
    }


def abstract_cache(cfg: ModelConfig, batch: int, max_len: int, dtype=None,
                   t_enc: int = 0) -> dict:
    return jax.eval_shape(partial(init_cache, cfg, batch, max_len, dtype, t_enc))


def prefill(params, tokens, cfg: ModelConfig, par: ParallelContext = None,
            *, max_len: int, frames=None, embeddings=None, lengths=None):
    if frames is None:
        frames = embeddings
    B, S = tokens.shape
    pos = (lengths - 1) if lengths is not None else jnp.full((B,), S - 1)
    logits, (kvs, cross), _ = forward(params, tokens, cfg, par, frames=frames,
                                      return_kv=True, logit_positions=pos)
    cache = init_cache(cfg, B, max_len, t_enc=frames.shape[1])
    k, v = kvs
    cache["k"] = jax.lax.dynamic_update_slice(cache["k"], k.astype(cache["k"].dtype),
                                              (0, 0, 0, 0, 0))
    cache["v"] = jax.lax.dynamic_update_slice(cache["v"], v.astype(cache["v"].dtype),
                                              (0, 0, 0, 0, 0))
    cache["cross_k"] = cross[0].astype(cache["cross_k"].dtype)
    cache["cross_v"] = cross[1].astype(cache["cross_v"].dtype)
    return logits, cache


def decode_step(params, tokens, cache, cache_len, cfg: ModelConfig,
                par: ParallelContext = None):
    dtype = jnp.dtype(cfg.dtype)
    B = tokens.shape[0]
    x = L.embed(params["embedding"], tokens, dtype)
    pos = cache_len - 1
    x = x + params["pos_embedding"][pos].astype(dtype)[:, None]
    positions = pos[:, None]

    def body(x, xs):
        lp, ck, cv, xk, xv = xs
        x, (nk, nv) = _dec_layer(lp, x, cfg, par, positions=positions,
                                 cross_k=xk, cross_v=xv,
                                 cache={"k": ck, "v": cv}, cache_len=cache_len)
        return x, (nk, nv)

    x, (nk, nv) = jax.lax.scan(
        body, x, (params["dec_layers"], cache["k"], cache["v"],
                  cache["cross_k"], cache["cross_v"]))
    x = L.layernorm(params["dec_norm"], x)
    logits = L.lm_logits(params["embedding"], x[:, 0], cfg.logit_softcap)
    return logits, dict(cache, k=nk, v=nv)
