"""Mixture-of-Experts FFN.

Dispatch is the sort-by-expert / capacity scheme: per data shard, tokens are
routed top-k, sorted by expert id, packed into an (E, C, d) buffer
(C = capacity), run through a batched expert einsum, and combined back with
the router weights.  Compute cost is ~capacity_factor × the *active* FLOPs
(6·N_active·D), never the dense all-experts cost.

Token routing stays local to each data shard (no global sort); d_ff is
tensor-parallel over the ``model`` axis with one psum after the down
projection — the same collective pattern as the dense FFN.  When a mesh is
present the layer runs under shard_map; without a mesh it runs the same code
on the full array.
"""
from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig, MoEConfig
from repro.distributed.sharding import ParallelContext

from repro.distributed.compat import shard_map


def init_moe(key, cfg: ModelConfig, dtype) -> dict:
    m = cfg.moe
    d, f, E = cfg.d_model, m.expert_d_ff, m.n_experts
    ks = jax.random.split(key, 4)
    s_in = 1.0 / math.sqrt(d)
    s_out = 1.0 / math.sqrt(f)
    return {
        "router": {"w": jax.random.normal(ks[0], (d, E), jnp.float32).astype(dtype) * s_in},
        "experts": {
            "gate": jax.random.normal(ks[1], (E, d, f), jnp.float32).astype(dtype) * s_in,
            "up": jax.random.normal(ks[2], (E, d, f), jnp.float32).astype(dtype) * s_in,
            "down": jax.random.normal(ks[3], (E, f, d), jnp.float32).astype(dtype) * s_out,
        },
    }


def _route(logits: jnp.ndarray, m: MoEConfig):
    """logits: (T, E) -> (weights (T,k), ids (T,k), aux_loss)."""
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    weights, ids = jax.lax.top_k(probs, m.top_k)
    weights = weights / jnp.maximum(weights.sum(-1, keepdims=True), 1e-9)
    # Switch-style load balancing loss.
    T, E = probs.shape
    density = jnp.mean(jax.nn.one_hot(ids[:, 0], E, dtype=jnp.float32), axis=0)
    density_prob = jnp.mean(probs, axis=0)
    aux = E * jnp.sum(density * density_prob)
    return weights, ids, aux


def _dispatch_compute_combine(x_flat, weights, ids, experts, m: MoEConfig, axis_model):
    """Core per-shard MoE. x_flat: (T, d); experts have local f shard."""
    T, d = x_flat.shape
    E, k = m.n_experts, m.top_k
    C = max(8, int(math.ceil(T * k / E * m.capacity_factor)))

    flat_ids = ids.reshape(T * k)
    flat_w = weights.reshape(T * k)
    order = jnp.argsort(flat_ids, stable=True)            # (T*k,) sorted by expert
    sorted_ids = flat_ids[order]
    counts = jnp.bincount(flat_ids, length=E)
    starts = jnp.cumsum(counts) - counts                  # exclusive per-expert start
    pos = jnp.arange(T * k) - starts[sorted_ids]          # position within expert
    valid = pos < C
    slot = jnp.where(valid, sorted_ids * C + pos, E * C)  # E*C = drop slot

    # slot -> source token row (T = zero row for unfilled slots)
    slot_src = jnp.full((E * C + 1,), T, jnp.int32).at[slot].set(
        (order // k).astype(jnp.int32), mode="drop")
    x_pad = jnp.concatenate([x_flat, jnp.zeros((1, d), x_flat.dtype)], axis=0)
    xe = x_pad[slot_src[: E * C]].reshape(E, C, d)

    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xe, experts["gate"].astype(xe.dtype)))
    h = h * jnp.einsum("ecd,edf->ecf", xe, experts["up"].astype(xe.dtype))
    ye = jnp.einsum("ecf,efd->ecd", h, experts["down"].astype(h.dtype))
    if axis_model is not None:
        ye = jax.lax.psum(ye, axis_model)  # TP reduce over d_ff shards

    ye_pad = jnp.concatenate([ye.reshape(E * C, d), jnp.zeros((1, d), ye.dtype)], axis=0)
    token_slot = jnp.full((T * k,), E * C, jnp.int32).at[order].set(
        jnp.where(valid, slot, E * C).astype(jnp.int32))
    contrib = ye_pad[token_slot].reshape(T, k, d)
    y = jnp.sum(contrib * flat_w.reshape(T, k, 1).astype(contrib.dtype), axis=1)
    return y


def moe_ffn(p: dict, x: jnp.ndarray, cfg: ModelConfig, par: ParallelContext = None):
    """x: (B, S, d) -> (y (B, S, d), aux_loss scalar)."""
    m = cfg.moe
    B, S, d = x.shape
    par = par or ParallelContext()

    from repro.quant.qlinear import dequantize_model_params, is_quantized
    if is_quantized(p["experts"]["gate"]):
        p = dict(p, experts=dequantize_model_params(p["experts"]))

    def local_fn(x_loc, router_w, gate, up, down):
        T = x_loc.shape[0] * x_loc.shape[1]
        xf = x_loc.reshape(T, d)
        logits = xf @ router_w.astype(xf.dtype)
        weights, ids, aux = _route(logits, m)
        experts = {"gate": gate, "up": up, "down": down}
        axis_model = ("model" if (par.mesh is not None and par.tp
                                  and "model" in par.axes) else None)
        y = _dispatch_compute_combine(xf, weights, ids, experts, m, axis_model)
        if par.mesh is not None:
            aux = jax.lax.pmean(aux, tuple(par.axes))  # replicate for out_spec P()
        return y.reshape(x_loc.shape), aux

    if par.mesh is None:
        return local_fn(x, p["router"]["w"], p["experts"]["gate"],
                        p["experts"]["up"], p["experts"]["down"])

    # Small decode batches may not divide the data axis: fall back toward
    # replicated tokens (compute duplicated — trivial at batch 1 / seq 1).
    batch_axes = par.batch_axes_for(B)
    xs = P(batch_axes, None, None)
    ws = P(None, None)          # router replicated
    if par.tp:
        es_in = P(None, None, "model")   # gate/up: f sharded (TP)
        es_out = P(None, "model", None)  # down: f sharded
    else:
        es_in = es_out = P(None, None, None)  # fsdp-only: gathered per layer
    # checkpoint: the (E, C, d) dispatch/activation buffers are recomputed
    # in backward instead of saved — they dominate MoE training memory.
    fn = shard_map(
        jax.checkpoint(local_fn),
        mesh=par.mesh,
        in_specs=(xs, ws, es_in, es_in, es_out),
        out_specs=(xs, P()),
        check_vma=False,
    )
    return fn(x, p["router"]["w"], p["experts"]["gate"], p["experts"]["up"],
              p["experts"]["down"])
