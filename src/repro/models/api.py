"""Uniform model API across families.

Every family module exposes:
  init_params(key, cfg) / abstract_params(cfg)
  forward(params, tokens, cfg, par, *, embeddings=None, return_kv=False)
      -> (logits, kv_or_states, aux_loss)
  prefill(params, tokens, cfg, par, *, max_len, embeddings=None)
      -> (last_logits, cache)
  decode_step(params, tokens, cache, cache_len, cfg, par)
      -> (logits, new_cache)
  init_cache(cfg, batch, max_len) / abstract_cache(...)

The transformer family additionally supports a *paged* cache layout:
``prefill(..., paged={"k", "v", "table"})`` scatters prompt KV into a
block pool and ``decode_step`` routes through per-row block tables when
the cache dict carries a ``"table"`` leaf (``init_paged_cache`` builds the
pool storage; see ``repro.serving.kv_pool`` for the allocator).  On top
of that, ``prefill(..., paged=..., prefix={"k", "v", "len"})`` is a
*partial prefill*: tokens hold only a prompt's uncached suffix, which
attends over the supplied per-layer prefix KV (gathered from cached pool
blocks) and is scattered into the table at the per-row cached offset —
the engine hook for the cross-request prefix cache
(``repro.serving.prefix_cache``).
"""
from __future__ import annotations

from types import ModuleType

from repro.configs.base import ModelConfig
from repro.models import mamba2, transformer, whisper


def get_model(cfg: ModelConfig) -> ModuleType:
    if cfg.family in ("transformer",):
        return transformer
    if cfg.family in ("mamba2", "hybrid"):
        return mamba2
    if cfg.family == "encdec":
        return whisper
    raise ValueError(f"unknown model family: {cfg.family}")
