"""Step-level beam search with a process reward model (paper §2.1, Fig. 1
right; Snell et al. 2024).

Beams decode in one batch (width × expansion) — like Best-of-N this rides
the idle matrix-unit rows.  After every reasoning *step* (delimiter '.'),
each beam's prefix is scored by the PRM; the top ``width`` of
``width × expand`` candidates survive (``engine.reorder`` gathers their KV
cache rows) and are re-expanded.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.best_of_n import TTSResult
from repro.core.reward import prm_final_scores, prm_step_scores
from repro.data import tasks as T
from repro.data.tokenizer import ByteTokenizer
from repro.serving.engine import DecodeEngine
from repro.serving.sampler import SamplerConfig


def beam_search(engine: DecodeEngine, tok: ByteTokenizer, task: T.MathTask,
                *, width: int, expand: int, max_steps: int = 8,
                step_tokens: int = 16, rng, prm,
                sc: SamplerConfig = SamplerConfig(temperature=0.8),
                prompt_len: int = 64) -> TTSResult:
    """width = surviving beams; expand = candidates per beam per step.

    On a paged engine every pool block the search holds is released on
    return — normal exit, early answer break, or an exception mid-search
    (``fork``/``reorder``/``prepare_decode`` are atomic w.r.t. the pool,
    so the live ``state`` always accounts for every held block)."""
    dot_id = tok.encode(".", bos=False)[0]
    ids, lens = tok.encode_batch([task.prompt], prompt_len)
    state = engine.prefill(jnp.asarray(ids), jnp.asarray(lens))
    try:
        state = engine.fork(state, width)
        beams = [[] for _ in range(width)]   # generated ids per beam
        total_tokens = 0

        for step in range(max_steps):
            # expand each beam
            state = engine.fork(state, expand)
            beams = [list(b) for b in beams for _ in range(expand)]
            state = engine.resume(state)
            rng, k = jax.random.split(rng)
            state, out = engine.generate(state, step_tokens, k, sc,
                                         stop_ids=(engine.eos_id, dot_id))
            total_tokens += int(np.sum(np.asarray(out) != engine.pad_id))
            for b, row in zip(beams, out.tolist()):
                b.extend(t for t in row if t != engine.pad_id)
            # decode each candidate's FULL id list (a per-round decode
            # would split multi-byte UTF-8 sequences at round boundaries
            # and feed the PRM different texts than the scheduler path);
            # decode() keeps the '.' stop token (a regular byte)
            texts = [tok.decode(b) for b in beams]
            # PRM-score all width*expand candidates in one batched call
            scores = jnp.asarray(prm_step_scores(
                prm, task, texts, state.logprob_sum, state.n_gen))
            keep = jnp.argsort(-scores)[:width]
            state = engine.reorder(state, keep)
            beams = [beams[int(i)] for i in keep]
            texts = [texts[int(i)] for i in keep]
            if all("A:" in t for t in texts):
                break

        # final selection: best-scoring finished beam
        final_scores = prm_final_scores(prm, task, texts,
                                        state.logprob_sum, state.n_gen)
        chosen = int(jnp.argmax(final_scores))
    finally:
        if engine.paged:
            state = engine.release_rows(
                state, list(range(int(state.done.shape[0]))))
    ans = T.extract_answer(texts[chosen])
    return TTSResult(
        completions=texts,
        scores=final_scores,
        chosen=chosen,
        answer=ans,
        correct=(ans == task.answer) if ans is not None else False,
        decode_tokens=total_tokens,
    )
