"""Step-level beam search with a process reward model (paper §2.1, Fig. 1
right; Snell et al. 2024).

Beams decode in one batch (width × expansion) — like Best-of-N this rides
the idle matrix-unit rows.  After every reasoning *step* (delimiter '.'),
each beam's prefix is scored by the PRM; the top ``width`` of
``width × expand`` candidates survive (``engine.reorder`` gathers their KV
cache rows) and are re-expanded.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.best_of_n import TTSResult
from repro.data import tasks as T
from repro.data.tokenizer import ByteTokenizer
from repro.serving.engine import DecodeEngine
from repro.serving.sampler import SamplerConfig


def beam_search(engine: DecodeEngine, tok: ByteTokenizer, task: T.MathTask,
                *, width: int, expand: int, max_steps: int = 8,
                step_tokens: int = 16, rng, prm,
                sc: SamplerConfig = SamplerConfig(temperature=0.8),
                prompt_len: int = 64) -> TTSResult:
    """width = surviving beams; expand = candidates per beam per step."""
    dot_id = tok.encode(".", bos=False)[0]
    ids, lens = tok.encode_batch([task.prompt], prompt_len)
    state = engine.prefill(jnp.asarray(ids), jnp.asarray(lens))
    state = engine.fork(state, width)
    texts = [""] * width
    total_tokens = 0

    for step in range(max_steps):
        # expand each beam
        state = engine.fork(state, expand)
        texts = [t for t in texts for _ in range(expand)]
        state = engine.resume(state)
        rng, k = jax.random.split(rng)
        state, out = engine.generate(state, step_tokens, k, sc,
                                     stop_ids=(engine.eos_id, dot_id))
        total_tokens += int(np.sum(np.asarray(out) != engine.pad_id))
        # decode() keeps the '.' stop token (a regular byte) and drops pads
        texts = [t + tok.decode(row) for t, row in zip(texts, out.tolist())]
        # PRM-score each candidate prefix
        if hasattr(prm, "score_steps"):
            scores = jnp.array(
                [float(prm.score_steps(task, t)[-1]) for t in texts])
        else:  # logprob PRM fallback
            scores = prm.score_states(state.logprob_sum, state.n_gen)
        keep = jnp.argsort(-scores)[:width]
        state = engine.reorder(state, keep)
        texts = [texts[int(i)] for i in keep]
        if all("A:" in t for t in texts):
            break

    # final selection: best-scoring finished beam
    if hasattr(prm, "score_texts"):
        final_scores = prm.score_texts(task, texts)
    else:
        final_scores = prm.score_states(state.logprob_sum, state.n_gen)
    chosen = int(jnp.argmax(final_scores))
    ans = T.extract_answer(texts[chosen])
    return TTSResult(
        completions=texts,
        scores=final_scores,
        chosen=chosen,
        answer=ans,
        correct=(ans == task.answer) if ans is not None else False,
        decode_tokens=total_tokens,
    )
