"""Reward / scoring models for test-time scaling (ORM and PRM roles).

Three scorers mirroring the paper's §2.1 taxonomy:

* ``OracleVerifier`` — outcome check against the verifiable task answer
  (the paper's Best-of-N upper bound / coverage, Fig. 5);
* ``LogProbScorer`` — model self-certainty (mean sampled logprob), a
  verifier-free ORM baseline;
* ``LearnedScorer`` — a trained value model (Skywork-PRM stand-in): a small
  transformer trunk + scalar head scoring (prompt ⊕ completion) prefixes.
  The same model serves as ORM (score the full sequence) and PRM (score
  each step prefix).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import List, Optional, Sequence

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.data import tasks as T
from repro.data.tokenizer import ByteTokenizer
from repro.models import layers as L
from repro.models import transformer as TR


class OracleVerifier:
    """Outcome-reward oracle for verifiable tasks."""

    def score_texts(self, task: T.MathTask, completions: Sequence[str]):
        return jnp.array([1.0 if T.verify(task, c) else 0.0
                          for c in completions], jnp.float32)


class LogProbScorer:
    """Self-certainty ORM: length-normalized cumulative sample logprob."""

    def score_states(self, logprob_sum, n_gen):
        return logprob_sum / jnp.maximum(n_gen, 1)


# ---------------------------------------------------------------------------
# Learned scorer (ORM / PRM)
# ---------------------------------------------------------------------------


def reward_config(vocab_size: int, *, d_model: int = 64, n_layers: int = 2,
                  n_heads: int = 4) -> ModelConfig:
    return ModelConfig(
        name="reward", family="transformer", n_layers=n_layers,
        d_model=d_model, n_heads=n_heads, n_kv_heads=n_heads,
        d_ff=d_model * 4, vocab_size=vocab_size, dtype="float32",
        param_dtype="float32", remat="none")


def init_reward_params(key, cfg: ModelConfig) -> dict:
    k1, k2 = jax.random.split(key)
    trunk = TR.init_params(k1, cfg)
    trunk.pop("lm_head", None)
    return {
        "trunk": trunk,
        "head": L.init_linear(k2, cfg.d_model, 1, bias=True,
                              dtype=jnp.float32),
    }


def reward_apply(params, tokens, lengths, cfg: ModelConfig):
    """tokens: (B, S) right-padded; -> scalar score (B,) (pre-sigmoid)."""
    dtype = jnp.dtype(cfg.dtype)
    x = L.embed(params["trunk"]["embedding"], tokens, dtype)
    B, S = tokens.shape
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
    windows = TR.layer_windows(cfg)

    def body(x, xs):
        lp, w = xs
        x, _, _ = TR._layer(lp, x, cfg, None, positions=positions, window=w)
        return x, None

    x, _ = jax.lax.scan(body, x, (params["trunk"]["layers"], windows))
    x = L.rmsnorm(params["trunk"]["final_norm"], x, cfg.norm_eps)
    h = x[jnp.arange(B), lengths - 1]  # causal trunk: last position summarizes
    return L.linear(params["head"], h)[:, 0]


def reward_loss(params, tokens, lengths, labels, cfg: ModelConfig):
    """Binary cross-entropy on (sequence, correct?) pairs."""
    logits = reward_apply(params, tokens, lengths, cfg)
    return jnp.mean(
        jnp.maximum(logits, 0) - logits * labels +
        jnp.log1p(jnp.exp(-jnp.abs(logits))))


class LearnedScorer:
    """Trained ORM/PRM wrapper operating on text (tokenizes internally).

    ``n_forwards`` counts reward-model forward passes (one per ``_apply``
    call) — the serving stack asserts PRM scoring stays *batched* (one
    forward per beam boundary, not one per candidate) against it."""

    def __init__(self, params, cfg: ModelConfig, tok: ByteTokenizer,
                 max_len: int = 256):
        self.params = params
        self.cfg = cfg
        self.tok = tok
        self.max_len = max_len
        self.n_forwards = 0
        self._apply = jax.jit(partial(reward_apply, cfg=cfg))

    def _score_prefixes(self, prefixes: Sequence[str]):
        ids, lens = self.tok.encode_batch(list(prefixes), self.max_len)
        self.n_forwards += 1
        return jax.nn.sigmoid(self._apply(self.params, jnp.asarray(ids),
                                          jnp.asarray(lens)))

    def score_texts(self, task: T.MathTask, completions: Sequence[str]):
        return self._score_prefixes([task.prompt + c for c in completions])

    @staticmethod
    def _last_step_prefix(task: T.MathTask, completion: str) -> str:
        """The prefix ``score_steps(task, completion)[-1]`` scores: the
        prompt plus every (delimiter-normalized) step of the completion."""
        steps = T.split_steps(completion)
        if not steps:
            return task.prompt + completion
        return task.prompt + "".join(steps)

    def score_steps(self, task: T.MathTask, completion: str):
        """PRM mode: score every step prefix of a completion."""
        steps = T.split_steps(completion)
        prefixes, acc = [], ""
        for s in steps:
            acc += s
            prefixes.append(task.prompt + acc)
        if not prefixes:
            prefixes = [task.prompt + completion]
        return self._score_prefixes(prefixes)

    def score_step_batch(self, task: T.MathTask,
                         completions: Sequence[str]):
        """PRM mode, batched across candidates: the last-step score of
        every completion (``score_steps(task, c)[-1]`` for each ``c``) in
        ONE reward forward.  This is what beam search calls at a scoring
        boundary — width × expand candidates ride one batch instead of
        the per-candidate B=1 loop."""
        return self._score_prefixes(
            [self._last_step_prefix(task, c) for c in completions])


# ---------------------------------------------------------------------------
# Scorer dispatch (shared by direct beam search and the scheduler path)
# ---------------------------------------------------------------------------


def prm_step_scores(prm, task: T.MathTask, completions: Sequence[str],
                    logprob_sum=None, n_gen=None):
    """Score candidate step-prefixes with whatever the scorer supports:
    batched PRM (``score_step_batch``) > per-candidate PRM
    (``score_steps``) > outcome scorer (``score_texts``, e.g.
    :class:`OracleVerifier`) > state-based fallback (``score_states``,
    needs ``logprob_sum``/``n_gen``).  Returns (n,) scores."""
    if hasattr(prm, "score_step_batch"):
        return prm.score_step_batch(task, completions)
    if hasattr(prm, "score_steps"):
        return jnp.array(
            [float(prm.score_steps(task, c)[-1]) for c in completions])
    if hasattr(prm, "score_texts"):
        return prm.score_texts(task, completions)
    return prm.score_states(logprob_sum, n_gen)


def prm_final_scores(prm, task: T.MathTask, completions: Sequence[str],
                     logprob_sum=None, n_gen=None):
    """Final-selection scores over surviving beams: full-sequence ORM view
    (``score_texts``) when available, else the state-based fallback."""
    if hasattr(prm, "score_texts"):
        return prm.score_texts(task, completions)
    return prm.score_states(logprob_sum, n_gen)
