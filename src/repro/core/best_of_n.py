"""Best-of-N parallel test-time scaling (paper §2.1, Fig. 1 left).

One prefill per prompt; the KV cache is forked N ways and all N samples
decode in a single batch — the exact workload that fills the idle matrix
unit rows during decode (paper §3.2).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

import jax
import jax.numpy as jnp

from repro.data import tasks as T
from repro.data.tokenizer import ByteTokenizer
from repro.serving.engine import DecodeEngine
from repro.serving.sampler import SamplerConfig


@dataclasses.dataclass
class TTSResult:
    completions: list          # list[str], length N (or B*N flattened)
    scores: jnp.ndarray
    chosen: int
    answer: Optional[int]
    correct: Optional[bool]
    decode_tokens: int         # total decode cost (batch-steps summed)


def select_best(task: T.MathTask, completions, scorer, logprob_sum, n_gen):
    """Scorer dispatch + argmax selection shared by the direct and
    continuous serving paths.  Returns (scores, chosen, answer, correct)."""
    if hasattr(scorer, "score_texts"):
        scores = scorer.score_texts(task, completions)
    else:  # LogProbScorer
        scores = scorer.score_states(logprob_sum, n_gen)
    chosen = int(jnp.argmax(scores))
    ans = T.extract_answer(completions[chosen])
    correct = (ans == task.answer) if ans is not None else False
    return scores, chosen, ans, correct


def best_of_n(engine: DecodeEngine, tok: ByteTokenizer, task: T.MathTask,
              *, n: int, max_tokens: int, rng, scorer,
              sc: SamplerConfig = SamplerConfig(temperature=0.8),
              prompt_len: int = 64) -> TTSResult:
    """Generate N samples of one task, pick the scorer's argmax."""
    ids, lens = tok.encode_batch([task.prompt], prompt_len)
    state = engine.prefill(jnp.asarray(ids), jnp.asarray(lens))
    state = engine.fork(state, n)
    rng, k = jax.random.split(rng)
    state, out = engine.generate(state, max_tokens, k, sc)
    if engine.paged:
        # return the task's KV blocks to the pool (the direct path builds
        # one throwaway state per task; paged blocks must be freed by hand)
        engine.release_rows(state, list(range(n)))
    completions = [tok.decode(row) for row in out.tolist()]

    scores, chosen, ans, correct = select_best(
        task, completions, scorer, state.logprob_sum, state.n_gen)
    return TTSResult(
        completions=completions,
        scores=scores,
        chosen=chosen,
        answer=ans,
        correct=correct,
        decode_tokens=int(jnp.sum(state.n_gen)),
    )


def evaluate_best_of_n(engine, tok, tasks: Sequence[T.MathTask], *, n: int,
                       max_tokens: int, rng, scorer,
                       sc: SamplerConfig = SamplerConfig(temperature=0.8)):
    """Accuracy + cost over a task set (one Fig. 10 curve point)."""
    correct, cost = 0, 0
    for i, task in enumerate(tasks):
        rng, k = jax.random.split(rng)
        r = best_of_n(engine, tok, task, n=n, max_tokens=max_tokens, rng=k,
                      scorer=scorer, sc=sc)
        correct += int(r.correct)
        cost += r.decode_tokens
    return {"accuracy": correct / max(1, len(tasks)),
            "decode_tokens": cost,
            "n": n}
