"""Budget controller: maps a compute budget to a TTS configuration and runs
the accuracy/cost sweep behind the paper's Pareto plots (Fig. 10).

Two serving paths:

* the direct path (``run_method``) builds one decode batch per task —
  prefill, fork, generate-to-completion; fine for offline evaluation;
* the continuous path (``serve_best_of_n`` / ``serve_beam_search`` /
  ``sweep(continuous=True)``) routes every task through one
  :class:`ContinuousScheduler` slot pool, so all tasks' samples (or beam
  lanes) share the decode batch and slots refill mid-flight — the
  production serving shape, with occupancy/requests-per-second metrics.

Serving rows carry ``SchedulerMetrics.summary()`` under ``"serving"``.
Beyond the occupancy/prefill/preemption keys, the beam-search workload
adds: ``beam_boundaries`` (prune+expand commits), ``beam_expansions`` /
``beam_prunes`` (lanes forked / released at those commits — ``fan -
width`` each), and ``prm_batches`` / ``prm_candidates`` /
``prm_candidates_per_batch`` (batched score-callback calls and the
candidates they covered; per-batch > 1 means PRM scoring batched with the
tree's fan instead of the per-candidate B=1 loop the direct path used to
run).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import beam_search as BS
from repro.core import best_of_n as BoN
from repro.core import reward as R
from repro.core import self_consistency as SC
from repro.data import tasks as T
from repro.serving.engine import BeamSpec, ContinuousScheduler, Request
from repro.serving.sampler import SamplerConfig


@dataclasses.dataclass
class TTSSpec:
    method: str            # "best_of_n" | "self_consistency" | "beam_search"
    budget: int            # N (parallel samples) or width*expand
    max_tokens: int = 48
    beam_width: int = 0    # beam search only
    beam_expand: int = 0
    beam_steps: int = 8    # scoring boundaries (beam search only)
    step_tokens: int = 16  # token budget per reasoning step


def run_method(engine, tok, task, spec: TTSSpec, rng, scorer):
    if spec.method == "best_of_n":
        return BoN.best_of_n(engine, tok, task, n=spec.budget,
                             max_tokens=spec.max_tokens, rng=rng,
                             scorer=scorer)
    if spec.method == "self_consistency":
        return SC.self_consistency(engine, tok, task, n=spec.budget,
                                   max_tokens=spec.max_tokens, rng=rng)
    if spec.method == "beam_search":
        width = spec.beam_width or max(1, spec.budget // 2)
        expand = spec.beam_expand or 2
        return BS.beam_search(engine, tok, task, width=width, expand=expand,
                              max_steps=spec.beam_steps,
                              step_tokens=spec.step_tokens,
                              rng=rng, prm=scorer)
    raise ValueError(spec.method)


def _attach_serving_stats(serving: dict, engine, n_slots: int, cow_base: int,
                          prefix_cache, cache_base) -> None:
    """Attach paged-KV / prefix-cache interval stats to a serving row.

    paged-KV accounting: hbm_saved_bytes = dense reservation minus the
    *logical* peak block usage, i.e. what a pool right-sized to this
    workload saves (this run's pool itself physically backs
    pool_reserved_bytes regardless of use).  peak_bytes_in_use is
    dtype-aware (block_bytes measures the device leaves), so a quantized
    pool (stats()["kv_quant"] of "q8"/"q4") reports its compounded paged ×
    quantization saving against the fp dense baseline here."""
    if engine.paged:
        from repro.serving.kv_pool import dense_kv_bytes

        serving["kv"] = engine.pool.stats()
        serving["kv"]["cow_copies"] -= cow_base
        serving["kv"]["dense_bytes"] = dense_kv_bytes(
            engine.cfg, n_slots, engine.max_len)
        serving["kv"]["hbm_saved_bytes"] = (
            serving["kv"]["dense_bytes"] - serving["kv"]["peak_bytes_in_use"])
    if prefix_cache is not None:
        # cache counters are lifetime values on a sweep-shared cache:
        # report this row's interval (cached_blocks/bytes stay gauges)
        pc = prefix_cache.stats()
        for key in ("lookups", "hits", "tokens_matched", "insertions",
                    "evictions"):
            pc[key] -= cache_base[key]
        pc["hit_rate"] = pc["hits"] / pc["lookups"] if pc["lookups"] else 0.0
        serving["prefix_cache"] = pc


def serve_best_of_n(engine, tok, tasks: Sequence[T.MathTask], *, n: int,
                    max_tokens: int, rng, scorer, n_slots: int = 8,
                    prompt_len: Optional[int] = None,
                    sc: SamplerConfig = SamplerConfig(temperature=0.8),
                    prefix_cache=None, tracer=None, profiler=None,
                    spec=None):
    """Best-of-N over a task set through the continuous-batching scheduler.

    Every task is one TTS request: one prefill, ``fork`` into ``n`` slots;
    all tasks' samples share the slot pool, so the decode batch stays full
    across task boundaries instead of draining per task.  ``prompt_len``
    defaults to the longest prompt in the task set.  ``prefix_cache``: a
    :class:`~repro.serving.prefix_cache.PrefixCache` over the engine's
    block pool (paged engines only); tasks sharing a system-prompt /
    few-shot header then skip re-prefilling the common prefix, and the
    serving row gains the cache's hit-rate/eviction stats.  Returns the
    same accuracy/cost row shape as ``sweep`` plus the scheduler's step
    metrics — including the admission-batching counters
    (``prefill_calls``, ``prefill_calls_per_request``,
    ``admission_batch_max``): with a cache attached, runs of cache-hit
    requests share one batched partial prefill per step, so
    ``prefill_calls_per_request`` drops below 1 on shared-header
    workloads (it is pinned at 1 request-per-call for TTS groups, which
    admit via one prefill + fork).
    """
    prompts = [jnp.asarray(tok.encode(task.prompt)) for task in tasks]
    if prompt_len is None:
        prompt_len = max((int(p.shape[0]) for p in prompts), default=1)
    sched = ContinuousScheduler(engine, n_slots=n_slots,
                                prompt_len=prompt_len,
                                prefix_cache=prefix_cache, tracer=tracer,
                                profiler=profiler, spec=spec)
    # the pool's peak/CoW counters are lifetime values on a shared engine;
    # rebase them so this row reports its own interval, not the sweep's
    cow_base = engine.pool.reset_peak() if engine.paged else 0
    cache_base = prefix_cache.stats() if prefix_cache is not None else None
    for i, prompt in enumerate(prompts):
        sched.submit(Request(req_id=i, prompt=prompt,
                             max_new_tokens=max_tokens, n_samples=n))
    sched.run(rng, sc)
    serving = sched.metrics.summary()
    _attach_serving_stats(serving, engine, n_slots, cow_base,
                          prefix_cache, cache_base)
    correct = cost = 0
    for i, task in enumerate(tasks):
        samples = sorted(sched.completed[i], key=lambda s: s.sample_idx)
        completions = [tok.decode(s.tokens) for s in samples]
        # n_gen counts the sampled stop token, matching the direct path's
        # decode_tokens accounting (best_of_n uses state.n_gen)
        cost += sum(s.n_gen for s in samples)
        _, _, _, ok = BoN.select_best(
            task, completions, scorer,
            jnp.array([s.logprob_sum for s in samples], jnp.float32),
            jnp.array([s.n_gen for s in samples], jnp.int32))
        correct += int(ok)
    return {
        "method": "best_of_n",
        "budget": n,
        "accuracy": correct / max(1, len(tasks)),
        "decode_tokens": cost,
        "serving": serving,
    }


def _beam_callbacks(tok, task: T.MathTask, prm):
    """Tokenizer/PRM closures for a :class:`BeamSpec` — the scheduler sees
    token lists only; texts and scorer dispatch live here.  The dispatch
    order matches the direct path (``prm_step_scores`` /
    ``prm_final_scores``), so direct-vs-scheduler scores are identical."""

    def step_score(token_lists, logprob_sum, n_gen):
        texts = [tok.decode(t) for t in token_lists]
        return np.asarray(R.prm_step_scores(
            prm, task, texts, jnp.asarray(logprob_sum),
            jnp.asarray(n_gen)))

    def final_score(token_lists, logprob_sum, n_gen):
        texts = [tok.decode(t) for t in token_lists]
        return np.asarray(R.prm_final_scores(
            prm, task, texts, jnp.asarray(logprob_sum),
            jnp.asarray(n_gen)))

    def finished(token_lists):
        return all("A:" in tok.decode(t) for t in token_lists)

    return step_score, final_score, finished


def serve_beam_search(engine, tok, tasks: Sequence[T.MathTask], *,
                      width: int, expand: int, step_tokens: int = 16,
                      max_steps: int = 8, rng, prm, n_slots: int = 8,
                      prompt_len: Optional[int] = None,
                      sc: SamplerConfig = SamplerConfig(temperature=0.8),
                      prefix_cache=None, tracer=None, profiler=None,
                      spec=None):
    """Step-level PRM beam search over a task set through the
    continuous-batching scheduler (the production counterpart of the
    direct ``core.beam_search`` path).

    Every task is one tree request (``search=BeamSpec(width, expand,
    ...)``): one prefill forked into ``width * expand`` lanes that decode
    inside the shared slot pool — beam expansion is a paged ``fork``
    (refcount bump), pruning a block release, and PRM scoring runs one
    batched callback per scoring boundary, so generator steps and scorer
    calls interleave in the same step loop across *all* in-flight trees
    and any chat/BoN traffic sharing the scheduler.  Returns the sweep
    row shape plus ``"results"`` (one :class:`TTSResult` per task, with
    the scheduler's completions/chosen — greedy decoding makes these
    bit-identical to the direct path) and the scheduler metrics under
    ``"serving"``, including the ``beam_*`` / ``prm_*`` keys documented
    in the module docstring."""
    prompts = [jnp.asarray(tok.encode(task.prompt)) for task in tasks]
    if prompt_len is None:
        prompt_len = max((int(p.shape[0]) for p in prompts), default=1)
    fan = width * expand
    n_slots = max(n_slots, fan)
    sched = ContinuousScheduler(engine, n_slots=n_slots,
                                prompt_len=prompt_len,
                                prefix_cache=prefix_cache, tracer=tracer,
                                profiler=profiler, spec=spec)
    cow_base = engine.pool.reset_peak() if engine.paged else 0
    cache_base = prefix_cache.stats() if prefix_cache is not None else None
    dot_id = int(tok.encode(".", bos=False)[0])
    for i, task in enumerate(tasks):
        step_score, final_score, finished = _beam_callbacks(tok, task, prm)
        sched.submit(Request(
            req_id=i, prompt=prompts[i],
            search=BeamSpec(width=width, expand=expand,
                            step_tokens=step_tokens, max_steps=max_steps,
                            step_stop_id=dot_id, score=step_score,
                            final_score=final_score, finished=finished)))
    sched.run(rng, sc)
    serving = sched.metrics.summary()
    _attach_serving_stats(serving, engine, n_slots, cow_base,
                          prefix_cache, cache_base)
    correct = 0
    results = []
    for i, task in enumerate(tasks):
        samples = sorted(sched.completed[i], key=lambda s: s.sample_idx)
        completions = [tok.decode(s.tokens) for s in samples]
        res = sched.beam_results[i]
        chosen = res["chosen"]
        ans = T.extract_answer(completions[chosen])
        ok = (ans == task.answer) if ans is not None else False
        correct += int(ok)
        results.append(BoN.TTSResult(
            completions=completions,
            scores=jnp.asarray(res["scores"], jnp.float32),
            chosen=chosen, answer=ans, correct=ok,
            decode_tokens=sum(s.n_gen for s in samples)))
    return {
        "method": "beam_search",
        "budget": fan,
        "accuracy": correct / max(1, len(tasks)),
        # serving cost: every decode step a lane occupies a slot (the
        # pruned lanes' tokens included), not just the survivors' tokens
        "decode_tokens": serving["decode_tokens"],
        "serving": serving,
        "results": results,
    }


def sweep(engine, tok, tasks: Sequence[T.MathTask], specs: Sequence[TTSSpec],
          rng, scorer, *, continuous: bool = False, n_slots: int = 8,
          prefix_cache=None, tracer=None, profiler=None, spec_decode=None,
          sc: Optional[SamplerConfig] = None):
    """Accuracy / decode-cost for each spec — one row per Pareto point.

    ``continuous=True`` runs Best-of-N and beam-search specs through the
    slot-based scheduler (shared decode batch across tasks); other
    methods fall back to the direct per-task path.  ``prefix_cache``
    (continuous rows only) is shared across every row, so common prompt
    prefixes persist across the whole sweep, not just within one row.
    ``tracer`` (continuous rows only) is a
    :class:`~repro.serving.telemetry.Tracer` shared the same way: every
    row's scheduler records its lifecycle events into it, and each row's
    ``serving`` dict carries that scheduler's ``ttft_*``/``itl_*``/
    ``queue_wait_*``/``step_time_*`` percentile keys.  ``spec_decode``
    (continuous rows, paged engines) is a
    :class:`~repro.serving.engine.SpecConfig` enabling draft-then-verify
    decode rounds; each row's ``serving`` dict then carries
    ``spec_rounds`` / ``spec_acceptance_rate`` /
    ``accepted_tokens_per_step``.  Speculative rounds only trigger under
    greedy sampling, so pass ``sc=SamplerConfig(greedy=True)`` alongside
    it (``sc=None`` keeps each serving path's default sampler).
    """
    sc_kwargs = {} if sc is None else {"sc": sc}
    rows = []
    for spec in specs:
        if continuous and spec.method == "best_of_n":
            rng, k = jax.random.split(rng)
            rows.append(serve_best_of_n(
                engine, tok, tasks, n=spec.budget,
                max_tokens=spec.max_tokens, rng=k, scorer=scorer,
                n_slots=max(n_slots, spec.budget),
                prefix_cache=prefix_cache, tracer=tracer,
                profiler=profiler, spec=spec_decode, **sc_kwargs))
            continue
        if continuous and spec.method == "beam_search":
            rng, k = jax.random.split(rng)
            width = spec.beam_width or max(1, spec.budget // 2)
            expand = spec.beam_expand or 2
            rows.append(serve_beam_search(
                engine, tok, tasks, width=width, expand=expand,
                step_tokens=spec.step_tokens, max_steps=spec.beam_steps,
                rng=k, prm=scorer, n_slots=max(n_slots, width * expand),
                prefix_cache=prefix_cache, tracer=tracer,
                profiler=profiler, spec=spec_decode, **sc_kwargs))
            continue
        correct = cost = 0
        for task in tasks:
            rng, k = jax.random.split(rng)
            r = run_method(engine, tok, task, spec, k, scorer)
            correct += int(r.correct)
            cost += r.decode_tokens
        rows.append({
            "method": spec.method,
            "budget": spec.budget,
            "accuracy": correct / max(1, len(tasks)),
            "decode_tokens": cost,
        })
    return rows
