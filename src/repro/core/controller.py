"""Budget controller: maps a compute budget to a TTS configuration and runs
the accuracy/cost sweep behind the paper's Pareto plots (Fig. 10)."""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional, Sequence

import jax

from repro.core import beam_search as BS
from repro.core import best_of_n as BoN
from repro.core import self_consistency as SC
from repro.data import tasks as T


@dataclasses.dataclass
class TTSSpec:
    method: str            # "best_of_n" | "self_consistency" | "beam_search"
    budget: int            # N (parallel samples) or width*expand
    max_tokens: int = 48
    beam_width: int = 0    # beam search only
    beam_expand: int = 0


def run_method(engine, tok, task, spec: TTSSpec, rng, scorer):
    if spec.method == "best_of_n":
        return BoN.best_of_n(engine, tok, task, n=spec.budget,
                             max_tokens=spec.max_tokens, rng=rng,
                             scorer=scorer)
    if spec.method == "self_consistency":
        return SC.self_consistency(engine, tok, task, n=spec.budget,
                                   max_tokens=spec.max_tokens, rng=rng)
    if spec.method == "beam_search":
        width = spec.beam_width or max(1, spec.budget // 2)
        expand = spec.beam_expand or 2
        return BS.beam_search(engine, tok, task, width=width, expand=expand,
                              rng=rng, prm=scorer)
    raise ValueError(spec.method)


def sweep(engine, tok, tasks: Sequence[T.MathTask], specs: Sequence[TTSSpec],
          rng, scorer):
    """Accuracy / decode-cost for each spec — one row per Pareto point."""
    rows = []
    for spec in specs:
        correct = cost = 0
        for task in tasks:
            rng, k = jax.random.split(rng)
            r = run_method(engine, tok, task, spec, k, scorer)
            correct += int(r.correct)
            cost += r.decode_tokens
        rows.append({
            "method": spec.method,
            "budget": spec.budget,
            "accuracy": correct / max(1, len(tasks)),
            "decode_tokens": cost,
        })
    return rows
