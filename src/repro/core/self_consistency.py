"""Self-consistency / majority voting (paper §2.1): verifier-free TTS."""
from __future__ import annotations

from collections import Counter

import jax
import jax.numpy as jnp

from repro.core.best_of_n import TTSResult
from repro.data import tasks as T
from repro.data.tokenizer import ByteTokenizer
from repro.serving.engine import DecodeEngine
from repro.serving.sampler import SamplerConfig


def self_consistency(engine: DecodeEngine, tok: ByteTokenizer,
                     task: T.MathTask, *, n: int, max_tokens: int, rng,
                     sc: SamplerConfig = SamplerConfig(temperature=0.8),
                     prompt_len: int = 64) -> TTSResult:
    ids, lens = tok.encode_batch([task.prompt], prompt_len)
    state = engine.prefill(jnp.asarray(ids), jnp.asarray(lens))
    state = engine.fork(state, n)
    rng, k = jax.random.split(rng)
    state, out = engine.generate(state, max_tokens, k, sc)
    if engine.paged:
        engine.release_rows(state, list(range(n)))
    completions = [tok.decode(row) for row in out.tolist()]
    answers = [T.extract_answer(c) for c in completions]
    votes = Counter(a for a in answers if a is not None)
    ans = votes.most_common(1)[0][0] if votes else None
    chosen = answers.index(ans) if ans is not None else 0
    return TTSResult(
        completions=completions,
        scores=jnp.array([votes.get(a, 0) if a is not None else 0
                          for a in answers], jnp.float32),
        chosen=chosen,
        answer=ans,
        correct=(ans == task.answer) if ans is not None else False,
        decode_tokens=int(jnp.sum(state.n_gen)),
    )
