"""Training data pipeline: packing, host-sharded batching, async prefetch."""
from __future__ import annotations

import queue
import threading
from typing import Iterator, Optional

import numpy as np

from repro.data.tasks import gen_dataset
from repro.data.tokenizer import ByteTokenizer


def pack_documents(docs, tok: ByteTokenizer, seq_len: int,
                   *, loss_prompt: bool = False):
    """Pack (prompt, target) docs into (tokens, targets, loss_mask) rows.

    Documents are concatenated (each ``bos ... eos``) and split into rows of
    ``seq_len``+1; targets are the 1-shifted tokens; loss_mask optionally
    zeroes prompt positions so only completions are learned.
    """
    stream, mask = [], []
    for prompt, target in docs:
        p_ids = tok.encode(prompt, bos=True, eos=False)
        t_ids = tok.encode(target, bos=False, eos=True)
        stream.extend(p_ids + t_ids)
        mask.extend(([1] * len(p_ids) if loss_prompt else [0] * len(p_ids))
                    + [1] * len(t_ids))
    n_rows = max(1, (len(stream) - 1) // seq_len)
    rows_t, rows_y, rows_m = [], [], []
    for r in range(n_rows):
        a = r * seq_len
        chunk = stream[a: a + seq_len + 1]
        m = mask[a + 1: a + seq_len + 1]
        if len(chunk) < seq_len + 1:
            pad = seq_len + 1 - len(chunk)
            chunk = chunk + [tok.pad_id] * pad
            m = m + [0] * pad
        rows_t.append(chunk[:-1])
        rows_y.append(chunk[1:])
        rows_m.append(m[: seq_len])
    return (np.array(rows_t, np.int32), np.array(rows_y, np.int32),
            np.array(rows_m, np.float32))


class MathDataLoader:
    """Deterministic, host-shardable loader over synthetic math tasks.

    ``host_id``/``n_hosts`` split the stream so each host of a multi-pod job
    reads disjoint data (the seed folds the host id in).  ``prefetch`` keeps
    a background thread one batch ahead of the training loop.
    """

    def __init__(self, tok: ByteTokenizer, *, batch_size: int, seq_len: int,
                 seed: int = 0, host_id: int = 0, n_hosts: int = 1,
                 tasks_per_chunk: int = 512, reasoning: bool = True,
                 max_terms: int = 4, prefetch: int = 2):
        self.tok = tok
        self.batch_size = batch_size
        self.seq_len = seq_len
        self.seed = seed * n_hosts + host_id
        self.reasoning = reasoning
        self.max_terms = max_terms
        self.tasks_per_chunk = tasks_per_chunk
        self._q: queue.Queue = queue.Queue(maxsize=prefetch)
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._producer, daemon=True)
        self._thread.start()

    def _producer(self):
        chunk = 0
        buf_t = buf_y = buf_m = None
        while not self._stop.is_set():
            tasks = gen_dataset(self.seed + chunk * 7919, self.tasks_per_chunk,
                                reasoning=self.reasoning,
                                max_terms=self.max_terms)
            chunk += 1
            t, y, m = pack_documents(
                [(tk.prompt, tk.target) for tk in tasks], self.tok, self.seq_len)
            if buf_t is not None:
                t = np.concatenate([buf_t, t]); y = np.concatenate([buf_y, y])
                m = np.concatenate([buf_m, m])
            n_full = (len(t) // self.batch_size) * self.batch_size
            for i in range(0, n_full, self.batch_size):
                if self._stop.is_set():
                    return
                self._q.put((t[i:i + self.batch_size],
                             y[i:i + self.batch_size],
                             m[i:i + self.batch_size]))
            buf_t, buf_y, buf_m = t[n_full:], y[n_full:], m[n_full:]

    def __iter__(self) -> Iterator:
        return self

    def __next__(self):
        return self._q.get()

    def close(self):
        self._stop.set()
        try:
            while True:
                self._q.get_nowait()
        except queue.Empty:
            pass
