"""Synthetic *verifiable* math tasks — the MATH500/GSM8K stand-in.

The paper's end-to-end claim (Figs. 5/10) is that accuracy on verifiable
math scales with the parallel-sampling budget.  Reproducing that claim
needs (a) a task family with checkable answers and graded difficulty and
(b) a model imperfect enough that independent samples disagree.  These
chained-arithmetic word problems provide (a); the ~1M-param model trained
in ``examples/tts_math_demo.py`` provides (b).

Format (all ASCII, byte-tokenizer friendly):
    Q:3+4*2=?A:11.
Multi-step "reasoning" variant writes intermediate steps:
    Q:3+4+5=?R:3+4=7.7+5=12.A:12.
The step delimiter '.' is what step-level beam search segments on.
"""
from __future__ import annotations

import dataclasses
import random
import re
from typing import List, Optional, Tuple


@dataclasses.dataclass
class MathTask:
    question: str          # "Q:3+4*2=?"
    answer: int
    reasoning: str         # "R:3+4=7.7+5=12." ("" for direct tasks)
    difficulty: int

    @property
    def prompt(self) -> str:
        return self.question + ("R:" if self.reasoning else "A:")

    @property
    def target(self) -> str:
        if self.reasoning:
            return self.reasoning[2:] + "A:" + str(self.answer) + "."
        return str(self.answer) + "."

    @property
    def full_text(self) -> str:
        return self.prompt + self.target


def gen_task(rng: random.Random, *, n_terms: int = 3, max_operand: int = 9,
             reasoning: bool = True) -> MathTask:
    """Chained additions/subtractions with running-total reasoning steps."""
    terms = [rng.randint(1, max_operand) for _ in range(n_terms)]
    ops = [rng.choice("+-") for _ in range(n_terms - 1)]
    expr = str(terms[0])
    total = terms[0]
    steps = []
    run = terms[0]
    for op, t in zip(ops, terms[1:]):
        expr += op + str(t)
        new = run + t if op == "+" else run - t
        steps.append(f"{run}{op}{t}={new}.")
        run = new
    total = run
    q = f"Q:{expr}=?"
    r = ("R:" + "".join(steps)) if reasoning else ""
    return MathTask(question=q, answer=total, reasoning=r,
                    difficulty=n_terms)


def gen_dataset(seed: int, n: int, *, min_terms: int = 2, max_terms: int = 4,
                max_operand: int = 9, reasoning: bool = True) -> List[MathTask]:
    rng = random.Random(seed)
    return [gen_task(rng, n_terms=rng.randint(min_terms, max_terms),
                     max_operand=max_operand, reasoning=reasoning)
            for _ in range(n)]


ANSWER_RE = re.compile(r"A:(-?\d+)\.")


def extract_answer(text: str) -> Optional[int]:
    """Pull the final answer out of a generated completion."""
    m = ANSWER_RE.search(text)
    if m:
        try:
            return int(m.group(1))
        except ValueError:
            return None
    # direct-answer format: leading integer
    m = re.match(r"\s*(-?\d+)\.", text)
    return int(m.group(1)) if m else None


def verify(task: MathTask, completion: str) -> bool:
    """Outcome verification (the Best-of-N oracle ORM)."""
    ans = extract_answer(completion if "A:" in completion
                         else "A:" + completion)
    return ans is not None and ans == task.answer


def split_steps(completion: str) -> List[str]:
    """Segment a completion into reasoning steps (for step-level PRM)."""
    parts = [p + "." for p in completion.split(".") if p]
    return parts
