"""Synthetic *verifiable* math tasks — the MATH500/GSM8K stand-in.

The paper's end-to-end claim (Figs. 5/10) is that accuracy on verifiable
math scales with the parallel-sampling budget.  Reproducing that claim
needs (a) a task family with checkable answers and graded difficulty and
(b) a model imperfect enough that independent samples disagree.  These
chained-arithmetic word problems provide (a); the ~1M-param model trained
in ``examples/tts_math_demo.py`` provides (b).

Format (all ASCII, byte-tokenizer friendly):
    Q:3+4*2=?A:11.
Multi-step "reasoning" variant writes intermediate steps:
    Q:3+4+5=?R:3+4=7.7+5=12.A:12.
The step delimiter '.' is what step-level beam search segments on.
"""
from __future__ import annotations

import dataclasses
import random
import re
from typing import List, Optional, Tuple


@dataclasses.dataclass
class MathTask:
    question: str          # "Q:3+4*2=?"
    answer: int
    reasoning: str         # "R:3+4=7.7+5=12." ("" for direct tasks)
    difficulty: int

    @property
    def prompt(self) -> str:
        return self.question + ("R:" if self.reasoning else "A:")

    @property
    def target(self) -> str:
        if self.reasoning:
            return self.reasoning[2:] + "A:" + str(self.answer) + "."
        return str(self.answer) + "."

    @property
    def full_text(self) -> str:
        return self.prompt + self.target


def gen_task(rng: random.Random, *, n_terms: int = 3, max_operand: int = 9,
             reasoning: bool = True) -> MathTask:
    """Chained additions/subtractions with running-total reasoning steps."""
    terms = [rng.randint(1, max_operand) for _ in range(n_terms)]
    ops = [rng.choice("+-") for _ in range(n_terms - 1)]
    expr = str(terms[0])
    total = terms[0]
    steps = []
    run = terms[0]
    for op, t in zip(ops, terms[1:]):
        expr += op + str(t)
        new = run + t if op == "+" else run - t
        steps.append(f"{run}{op}{t}={new}.")
        run = new
    total = run
    q = f"Q:{expr}=?"
    r = ("R:" + "".join(steps)) if reasoning else ""
    return MathTask(question=q, answer=total, reasoning=r,
                    difficulty=n_terms)


def gen_dataset(seed: int, n: int, *, min_terms: int = 2, max_terms: int = 4,
                max_operand: int = 9, reasoning: bool = True) -> List[MathTask]:
    rng = random.Random(seed)
    return [gen_task(rng, n_terms=rng.randint(min_terms, max_terms),
                     max_operand=max_operand, reasoning=reasoning)
            for _ in range(n)]


# ---------------------------------------------------------------------------
# Shared-prefix prompt building (the cross-request prefix-cache workload)
# ---------------------------------------------------------------------------

SYSTEM_PROMPT = "You solve arithmetic step by step."


def fewshot_header(seed: int = 0, n_shots: int = 3, *,
                   reasoning: bool = False,
                   system_prompt: str = SYSTEM_PROMPT) -> str:
    """A deterministic system-prompt + worked-examples header.

    Test-time-scaling traffic repeats the same instructions and few-shot
    examples in front of every task, so prompts built with one header
    share a long common token prefix across *requests* — exactly what the
    serving layer's cross-request prefix cache
    (``repro.serving.prefix_cache``) converts into skipped prefill
    compute.  Same (seed, n_shots) -> byte-identical header.
    """
    rng = random.Random(seed)
    shots = [gen_task(rng, n_terms=2, reasoning=reasoning)
             for _ in range(n_shots)]
    return system_prompt + "".join(t.full_text for t in shots)


def with_header(task: MathTask, header: str) -> MathTask:
    """The task with ``header`` prepended to its question: ``prompt`` /
    ``full_text`` then start with the shared prefix while answer checking
    (``verify`` parses the completion, not the prompt) is unchanged."""
    return dataclasses.replace(task, question=header + task.question)


def shared_prefix_dataset(seed: int, n: int, *, n_shots: int = 3,
                          reasoning: bool = False, **gen_kwargs) -> List[MathTask]:
    """``gen_dataset`` with one common few-shot header on every prompt —
    the benchmark/demo workload for the cross-request prefix cache."""
    header = fewshot_header(seed, n_shots, reasoning=reasoning)
    return [with_header(t, header)
            for t in gen_dataset(seed, n, reasoning=reasoning, **gen_kwargs)]


ANSWER_RE = re.compile(r"A:(-?\d+)\.")


def extract_answer(text: str) -> Optional[int]:
    """Pull the final answer out of a generated completion."""
    m = ANSWER_RE.search(text)
    if m:
        try:
            return int(m.group(1))
        except ValueError:
            return None
    # direct-answer format: leading integer
    m = re.match(r"\s*(-?\d+)\.", text)
    return int(m.group(1)) if m else None


def verify(task: MathTask, completion: str) -> bool:
    """Outcome verification (the Best-of-N oracle ORM)."""
    ans = extract_answer(completion if "A:" in completion
                         else "A:" + completion)
    return ans is not None and ans == task.answer


def split_steps(completion: str) -> List[str]:
    """Segment a completion into reasoning steps (for step-level PRM)."""
    parts = [p + "." for p in completion.split(".") if p]
    return parts
