"""Byte-level tokenizer (no external vocab files).

ids: 0=PAD, 1=BOS, 2=EOS, bytes b -> b+3. Vocab padded to a multiple of 64
so the vocab dim shards cleanly on the ``model`` mesh axis.
"""
from __future__ import annotations

import numpy as np

PAD_ID = 0
BOS_ID = 1
EOS_ID = 2
_OFFSET = 3


class ByteTokenizer:
    def __init__(self, vocab_size: int = 320):
        assert vocab_size >= 256 + _OFFSET
        self.vocab_size = vocab_size
        self.pad_id = PAD_ID
        self.bos_id = BOS_ID
        self.eos_id = EOS_ID

    def encode(self, text: str, *, bos: bool = True, eos: bool = False) -> list:
        ids = [b + _OFFSET for b in text.encode("utf-8")]
        if bos:
            ids = [BOS_ID] + ids
        if eos:
            ids = ids + [EOS_ID]
        return ids

    def decode(self, ids) -> str:
        bs = bytes(int(i) - _OFFSET for i in ids
                   if int(i) >= _OFFSET and int(i) < 256 + _OFFSET)
        return bs.decode("utf-8", errors="replace")

    def encode_batch(self, texts, max_len: int, *, bos=True, eos=False):
        """Right-padded (B, max_len) int32 + lengths (B,)."""
        out = np.full((len(texts), max_len), PAD_ID, np.int32)
        lens = np.zeros((len(texts),), np.int32)
        for i, t in enumerate(texts):
            ids = self.encode(t, bos=bos, eos=eos)[:max_len]
            out[i, : len(ids)] = ids
            lens[i] = len(ids)
        return out, lens
